"""Integration: the dry-run path end-to-end on an 8-device host mesh with a
reduced architecture (fast analogue of the 512-device production dry-run,
exercised in CI per commit; the production sweep writes artifacts/)."""

import json
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "@SRC@")
import dataclasses, json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.dist.sharding import ArraySpec, ShardingPlan, abstract_tree, use_plan
from repro.dist.hlo_cost import analyze
from repro.models import build_model
from repro.optim import AdamW, constant
from repro.train import make_train_step

arch, kind = sys.argv[1], sys.argv[2]
cfg = get_reduced(arch)
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = ShardingPlan(mesh, {"seq": "model"} if kind == "train" else {})
model = build_model(cfg)
specs = model.param_specs()
params_abs = abstract_tree(specs)
param_sh = plan.tree_shardings(specs)
repl = NamedSharding(mesh, P())
b, s = 8, 32

with use_plan(plan):
    if kind == "train":
        ins = {
            "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        if cfg.family == "vlm":
            ins["patch_embeds"] = jax.ShapeDtypeStruct((b, cfg.n_patches, cfg.d_model), jnp.float32)
        if cfg.family == "encdec":
            ins["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model), jnp.float32)
        in_sh = {k: NamedSharding(mesh, P("data") if v.ndim == 2 else P("data", None, None))
                 for k, v in ins.items()}
        opt = AdamW(schedule=constant(1e-4))
        step = make_train_step(model, opt, div={"batch": 4, "model": 2})
        state_abs = {"params": params_abs, "opt": jax.eval_shape(opt.init, params_abs),
                     "step": jax.ShapeDtypeStruct((), jnp.int32)}
        state_sh = {"params": param_sh,
                    "opt": {"mu": param_sh, "nu": param_sh, "master": param_sh, "count": repl},
                    "step": repl}
        out_struct = jax.eval_shape(step, state_abs, ins)
        out_sh = (state_sh, jax.tree.map(lambda _: repl, out_struct[1]))
        lowered = jax.jit(step, in_shardings=(state_sh, in_sh), out_shardings=out_sh).lower(state_abs, ins)
    else:
        cache_specs = model.cache_specs(b, s)
        cache_abs = abstract_tree(cache_specs)
        cache_sh = plan.tree_shardings(cache_specs)
        toks = jax.ShapeDtypeStruct((b, 1), jnp.int32)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        def decode_fn(p, c, t, cp):
            return model.decode_step(p, c, t, cp, div={"batch": 4, "model": 2})
        lowered = jax.jit(
            decode_fn,
            in_shardings=(param_sh, cache_sh, NamedSharding(mesh, P("data", None)), NamedSharding(mesh, P("data"))),
        ).lower(params_abs, cache_abs, toks, pos)

    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = analyze(compiled.as_text())
    print(json.dumps({
        "temp": int(mem.temp_size_in_bytes),
        "flops": cost.flops,
        "coll_bytes": cost.coll_bytes,
    }))
"""


def _run(arch, kind):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.replace("@SRC@", SRC), arch, kind],
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["granite-8b", "olmoe-1b-7b", "zamba2-1.2b"])
def test_reduced_train_lowers_on_8dev_mesh(arch):
    out = _run(arch, "train")
    assert out["flops"] > 0
    assert out["coll_bytes"] > 0  # sharded training must communicate


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-1.3b"])
def test_reduced_decode_lowers_on_8dev_mesh(arch):
    out = _run(arch, "decode")
    assert out["flops"] > 0
