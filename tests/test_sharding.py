"""Sharding rule solver tests (divisibility demotion, axis dedup, plans)."""

import subprocess
import sys
import os

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    ArraySpec,
    DEFAULT_RULES,
    ShardingPlan,
    abstract_tree,
    constrain,
    materialize_tree,
    use_plan,
)


@pytest.fixture(scope="module")
def mesh():
    # 1-device mesh with named axes of size 1 — rule plumbing is mesh-size
    # independent; divisibility tests use the subprocess below.
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_for_basic(mesh):
    plan = ShardingPlan(mesh)
    spec = plan.spec_for(ArraySpec((64, 128), "float32", ("embed", "ffn")))
    # size-1 mesh axes are demoted to replication (div == 1: sharding is a
    # no-op and would only add partition metadata) — positive sharding
    # assertions live in the 8-device subprocess test below
    assert spec == P(None, None)


def test_divisibility_demotion(mesh):
    plan = ShardingPlan(mesh)
    # dim 7 not divisible by ... size-1 axes always divide; force demotion
    # with a fake rule targeting a missing axis
    plan2 = ShardingPlan(mesh, {"embed": "nonexistent_axis"})
    spec = plan2.spec_for(ArraySpec((64, 128), "float32", ("embed", None)))
    assert spec == P(None, None)


def test_axis_dedup_subprocess_covered(mesh):
    # axis dedup on a real mesh is asserted in DIVIS_SCRIPT (s3/s4); here we
    # only check the rules plumbing accepts custom rules
    plan = ShardingPlan(mesh, {"a": "model", "b": "model"})
    spec = plan.spec_for(ArraySpec((8, 8), "float32", ("a", "b")))
    assert spec == P(None, None)  # size-1 mesh -> replicated


def test_tree_shardings_and_abstract(mesh):
    plan = ShardingPlan(mesh)
    tree = {
        "w": ArraySpec((16, 32), "bfloat16", ("embed", "heads")),
        "b": ArraySpec((32,), "float32", (None,)),
    }
    sh = plan.tree_shardings(tree)
    assert sh["w"].spec == P(None, None)  # size-1 mesh -> replicated
    abs_tree = abstract_tree(tree)
    assert abs_tree["w"].shape == (16, 32)
    assert str(abs_tree["w"].dtype) == "bfloat16"
    params = materialize_tree(tree, jax.random.PRNGKey(0))
    assert params["w"].dtype.name == "bfloat16"
    assert params["b"].shape == (32,)


def test_constrain_noop_without_plan():
    import jax.numpy as jnp

    x = jnp.ones((4, 4))
    assert constrain(x, "batch", None) is x


DIVIS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "{src}")
import jax
from jax.sharding import PartitionSpec as P
from repro.dist.sharding import ArraySpec, ShardingPlan

mesh = jax.make_mesh((2, 4), ("data", "model"))
plan = ShardingPlan(mesh)
# divisible: shard
s1 = plan.spec_for(ArraySpec((6, 8), "float32", ("embed", "heads")))
assert s1 == P("data", "model"), s1
# not divisible by model=4: demote dim 1
s2 = plan.spec_for(ArraySpec((6, 6), "float32", ("embed", "heads")))
assert s2 == P("data", None), s2
# batch spans (pod, data): pod missing from this mesh -> only data used
s3 = plan.spec_for(ArraySpec((4, 3), "float32", ("batch", None)))
assert s3 == P("data", None), s3
# dims smaller than the axis: replicate
s4 = plan.spec_for(ArraySpec((1, 8), "float32", ("batch", "ffn")))
assert s4 == P(None, "model"), s4
assert plan.axis_divisor("heads") == 4
assert plan.axis_divisor("batch") == 2
# axis dedup: two logical axes both ruled to 'model' -> second demoted
plan2 = ShardingPlan(mesh, {{"a": "model", "b": "model"}})
s5 = plan2.spec_for(ArraySpec((8, 8), "float32", ("a", "b")))
assert s5 == P("model", None), s5
print("OK")
"""


def test_divisibility_on_real_multidevice_mesh():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", DIVIS_SCRIPT.format(src=src)],
        capture_output=True,
        text=True,
        timeout=120,
    )
    assert r.returncode == 0, r.stderr
    assert "OK" in r.stdout
