"""ServeEngine online adaptation: a cold-start engine serving repeated
novel shapes converges to db-hit dispatch, dispatch_stats counters stay
consistent, and journal commits survive into the next run."""

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.selector import KernelSelector
from repro.core.tuner import TuningDatabase
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.serve import DispatchStats, ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def cold_adaptive(**overrides):
    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    cfg = AdaptiveConfig(
        **{"hot_threshold": 1, "max_tunes_per_step": 8, "rebuild_every": 4, **overrides}
    )
    return AdaptiveTuner(sel, config=cfg), db


def submit_wave(eng, cfg, n=3, prompt_len=8, new_tokens=4, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n):
        eng.submit(
            rng.integers(1, cfg.vocab_size, size=prompt_len),
            max_new_tokens=new_tokens,
        )


def test_cold_engine_converges_to_db_hits(served):
    cfg, model, params = served
    adaptive, db = cold_adaptive()
    eng = ServeEngine(
        model,
        params,
        ServeConfig(n_slots=2, max_seq=64, eos=-1),
        adaptive=adaptive,
        adapt_every=1,
    )
    assert eng.selector is adaptive.selector  # engine adopts the tuner's selector

    submit_wave(eng, cfg, seed=0)
    eng.run()
    assert adaptive.stats.misses > 0  # cold start: nothing was tuned
    assert adaptive.stats.adaptations > 0  # ...and the decode loop tuned it
    assert adaptive.pending_hot == 0  # end-of-run drain flushed the queue
    assert len(db.records) == adaptive.stats.adaptations

    # second wave over the same shapes: every dispatch is now a DB hit
    start = len(eng.selection_log)
    submit_wave(eng, cfg, seed=1)
    eng.run()
    wave2 = eng.selection_log[start:]
    assert wave2, "second wave produced no dispatches"
    assert all(e.selection.source == "tuned" for e in wave2)
    misses_after = adaptive.stats.misses
    submit_wave(eng, cfg, seed=2)
    eng.run()
    assert adaptive.stats.misses == misses_after  # converged: misses stopped


def test_dispatch_stats_counters_consistent(served):
    cfg, model, params = served
    adaptive, db = cold_adaptive(rebuild_every=2)
    eng = ServeEngine(
        model,
        params,
        ServeConfig(n_slots=2, max_seq=64, eos=-1),
        adaptive=adaptive,
        adapt_every=2,
    )
    submit_wave(eng, cfg)
    eng.run()
    st = eng.dispatch_stats
    assert isinstance(st, DispatchStats)
    assert st.misses == adaptive.stats.misses
    assert st.adaptations == adaptive.stats.adaptations == len(db.records)
    assert st.sieve_generation == adaptive.selector.sieve_generation >= 1
    assert st.db_records == len(db.records) > 0
    assert st.pending_hot == 0
    # selector-field delegation still works and agrees with the selector
    assert st.lookups == adaptive.selector.stats.lookups > 0
    assert st.tuned_hits == adaptive.selector.stats.tuned_hits
    # every dispatch was categorised exactly once
    s = st.selector
    assert s.lookups == (
        s.tuned_hits + s.sieve_hits + s.fallbacks + s.cache_hits + s.forced
    )


def test_adaptation_off_without_step_hook(served):
    """adaptive without adapt_every (or vice versa) never tunes: the step
    hook is the only trigger."""
    cfg, model, params = served
    adaptive, db = cold_adaptive()
    eng = ServeEngine(
        model,
        params,
        ServeConfig(n_slots=2, max_seq=64, eos=-1),
        adaptive=adaptive,
        adapt_every=0,
    )
    submit_wave(eng, cfg, n=2, new_tokens=2)
    eng.run()
    assert adaptive.stats.misses > 0  # misses were observed...
    assert adaptive.stats.adaptations == 0  # ...but nothing tuned
    assert len(db.records) == 0
    assert eng.dispatch_stats.pending_hot == adaptive.pending_hot > 0


def test_engine_journal_warm_starts_next_engine(served, tmp_path):
    cfg, model, params = served
    journal = str(tmp_path / "serve_journal.jsonl")
    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    adaptive = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=1), journal=journal
    )
    eng = ServeEngine(
        model,
        params,
        ServeConfig(n_slots=2, max_seq=64, eos=-1),
        adaptive=adaptive,
        adapt_every=1,
    )
    submit_wave(eng, cfg)
    eng.run()
    assert adaptive.stats.adaptations > 0

    # "restart": a fresh engine warm-started from the journal alone serves
    # the same traffic entirely from the database
    db2 = TuningDatabase()
    assert db2.replay_journal(journal) == adaptive.stats.adaptations
    sel2 = KernelSelector(sieve=db2.build_sieve(), db=db2)
    eng2 = ServeEngine(
        model,
        params,
        ServeConfig(n_slots=2, max_seq=64, eos=-1),
        selector=sel2,
    )
    submit_wave(eng2, cfg, seed=3)
    eng2.run()
    assert eng2.selection_log
    assert all(e.selection.source == "tuned" for e in eng2.selection_log)
    assert eng2.dispatch_stats.misses == 0
