"""Paged serving engine: token identity with the dense engine, page
exhaustion / stall / gridlock behavior, admission control, chunked prefill
(including across an adaptation round), run() exhaustion accounting, and the
per-array-aware serve divisor table."""

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.selector import KernelSelector
from repro.core.tuner import TuningDatabase
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.serve import (
    AdmissionError,
    PagedServeConfig,
    PagedServeEngine,
    ServeConfig,
    ServeEngine,
    serve_gemm_div,
)


@pytest.fixture(scope="module")
def served():
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def mixed_prompts(cfg, n=6, lo=4, hi=13, seed=0):
    rng = np.random.default_rng(seed)
    return [
        rng.integers(1, cfg.vocab_size, size=int(rng.integers(lo, hi))).astype(
            np.int32
        )
        for _ in range(n)
    ]


# -- token identity ----------------------------------------------------------


def run_dense(model, params, prompts, max_new=6, n_slots=4, max_seq=64):
    eng = ServeEngine(
        model, params, ServeConfig(n_slots=n_slots, max_seq=max_seq, eos=-1)
    )
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    done = eng.run()
    return {u: r.out_tokens for u, r in zip(uids, sorted(done, key=lambda r: r.uid))}


def run_paged(model, params, prompts, max_new=6, max_seq=64, **over):
    cfg = PagedServeConfig(
        page_size=8,
        max_pages=32,
        max_active=4,
        max_seq=max_seq,
        eos=-1,
        **over,
    )
    eng = PagedServeEngine(model, params, cfg)
    uids = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    done = eng.run()
    return (
        {u: r.out_tokens for u, r in zip(uids, sorted(done, key=lambda r: r.uid))},
        eng,
    )


def test_paged_tokens_identical_to_dense(served):
    """Greedy decode through the page pool must be bit-identical to the
    dense slot engine: same prefill numerics (whole-prompt fast path), same
    fixed decode batch width, garbage page tails masked to exact zeros."""
    cfg, model, params = served
    prompts = mixed_prompts(cfg)
    dense = run_dense(model, params, prompts)
    paged, eng = run_paged(model, params, prompts)
    assert paged == dense
    assert eng.kv.used_pages == 0  # every retirement returned its pages


def test_chunked_prefill_tokens_identical_to_dense(served):
    """Chunked prefill (chunk size straddling page boundaries, prompts not
    chunk-aligned) must produce the same first token and decode chain."""
    cfg, model, params = served
    prompts = mixed_prompts(cfg, n=4, lo=11, hi=21, seed=3)
    dense = run_dense(model, params, prompts)
    paged, eng = run_paged(model, params, prompts, prefill_chunk=5)
    assert paged == dense


def test_page_exhaustion_mid_decode_stalls_then_recovers(served):
    """A sequence that outgrows its pages while the pool is empty must
    stall (skip decode ticks) and resume once a retirement frees a page —
    completing untruncated with its full token budget."""
    cfg, model, params = served
    eng = PagedServeEngine(
        model,
        params,
        PagedServeConfig(
            page_size=4,
            max_pages=2,
            max_active=2,
            max_seq=12,
            watermark=0.0,
            eos=-1,
        ),
    )
    short = eng.submit(np.array([3, 1], np.int32), max_new_tokens=3)
    grower = eng.submit(np.array([2, 7, 5], np.int32), max_new_tokens=6)
    done = {r.uid: r for r in eng.run()}
    assert set(done) == {short, grower}
    assert not done[grower].truncated and not done[short].truncated
    assert len(done[grower].out_tokens) == 6  # full budget despite the stall
    assert eng.stall_events >= 1
    assert eng.truncated == 0
    assert eng.kv.free_pages == eng.kv.n_pages


def test_gridlock_truncates_oldest_instead_of_deadlocking(served):
    """When every resident sequence is stalled and nothing can be admitted,
    the engine must retire the oldest with truncated=True (freeing its
    pages for the rest) rather than spin forever."""
    cfg, model, params = served
    eng = PagedServeEngine(
        model,
        params,
        PagedServeConfig(
            page_size=4,
            max_pages=2,
            max_active=2,
            max_seq=16,
            watermark=0.0,
            eos=-1,
        ),
    )
    uids = [
        eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=12)
        for _ in range(2)
    ]
    done = {r.uid: r for r in eng.run(max_steps=200)}
    assert set(done) == set(uids)  # drained: nothing silently dropped
    assert not eng.exhausted
    assert eng.truncated >= 1
    assert done[uids[0]].truncated  # the oldest was the victim
    for r in done.values():
        assert len(r.out_tokens) >= 1  # partial output survives truncation


def test_admission_rejection_then_retry_succeeds(served):
    """Queue-depth backpressure: a full queue raises AdmissionError (counted
    in rejected), and the same request submits cleanly once the scheduler
    drains the queue — no eviction, no lost work."""
    cfg, model, params = served
    eng = PagedServeEngine(
        model,
        params,
        PagedServeConfig(
            page_size=8, max_pages=16, max_active=2, max_seq=32,
            max_queue=1, eos=-1,
        ),
    )
    prompt = np.array([1, 2, 3], np.int32)
    eng.submit(prompt, max_new_tokens=3)
    with pytest.raises(AdmissionError):
        eng.submit(prompt, max_new_tokens=3)
    assert eng.rejected == 1
    eng.step()  # the scheduler admits the queue head, freeing queue depth
    retry = eng.submit(prompt, max_new_tokens=3)  # succeeds now
    done = eng.run()
    assert retry in {r.uid for r in done}
    assert all(len(r.out_tokens) == 3 for r in done)


def test_never_admissible_prompt_rejected_at_submit(served):
    """A prompt needing more pages than the pool can ever spare past the
    watermark reserve is a caller error, not backpressure."""
    cfg, model, params = served
    eng = PagedServeEngine(
        model,
        params,
        PagedServeConfig(page_size=4, max_pages=4, max_seq=64, eos=-1),
    )
    with pytest.raises(ValueError, match="watermark reserve"):
        eng.submit(np.arange(1, 17, dtype=np.int32))  # 16 tokens = 4 pages
    assert eng.rejected == 0  # ValueError is not the backpressure counter


def test_empty_prompt_rejected_by_both_engines(served):
    cfg, model, params = served
    dense = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=16, eos=-1))
    paged = PagedServeEngine(
        model, params, PagedServeConfig(page_size=4, max_pages=4, max_seq=16)
    )
    for eng in (dense, paged):
        with pytest.raises(ValueError, match="empty"):
            eng.submit(np.array([], np.int32))
        with pytest.raises(ValueError, match="empty"):
            eng.submit([])


def test_run_exhaustion_flags_unfinished_both_engines(served):
    """run(max_steps) running out of budget must not silently drop work:
    the remainder stays resident, engine.exhausted is set, and a follow-up
    run() finishes exactly the flagged requests."""
    cfg, model, params = served
    dense = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=32, eos=-1))
    paged = PagedServeEngine(
        model,
        params,
        PagedServeConfig(page_size=8, max_pages=8, max_active=1, max_seq=32),
    )
    for eng in (dense, paged):
        uids = [
            eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=8)
            for _ in range(3)
        ]
        first = eng.run(max_steps=2)
        assert eng.exhausted
        left = {r.uid for r in eng.unfinished}
        assert left and left <= set(uids)
        assert {r.uid for r in first} | left == set(uids)
        rest = eng.run()
        assert not eng.exhausted and eng.unfinished == []
        assert {r.uid for r in rest} >= left


def test_chunked_prefill_spans_adaptation_round(served):
    """A prompt whose chunked prefill straddles an AdaptiveTuner adaptation
    round must decode to the same tokens as the dense engine: adaptation
    swaps dispatch tables between steps, never numerics."""
    cfg, model, params = served
    prompts = mixed_prompts(cfg, n=2, lo=13, hi=17, seed=5)
    dense = run_dense(model, params, prompts, max_new=4)

    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    adaptive = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=1, rebuild_every=1)
    )
    eng = PagedServeEngine(
        model,
        params,
        PagedServeConfig(
            page_size=8, max_pages=16, max_active=4, max_seq=64,
            prefill_chunk=4, eos=-1,
        ),
        adaptive=adaptive,
        adapt_every=1,  # adapt between every chunk/decode quantum
    )
    uids = [eng.submit(p, max_new_tokens=4) for p in prompts]
    done = {u: r.out_tokens for u, r in zip(uids, sorted(eng.run(), key=lambda r: r.uid))}
    assert done == dense
    assert adaptive.stats.adaptations > 0  # rounds actually fired mid-prefill
    assert eng.dispatch_stats.db_records > 0


# -- per-array-aware serve divisors (ROADMAP item 6) -------------------------


def test_serve_gemm_div_no_plan_is_empty(served):
    cfg, model, params = served
    assert serve_gemm_div(model) == {}


@pytest.mark.skipif(
    jax.device_count() < 2, reason="needs a >=2-device mesh (multi-device lane)"
)
def test_serve_gemm_div_demotes_indivisible_weight_dims():
    """On a model=N mesh, a weight dim the sharding solver demotes to
    replication must demote the serve table's model divisor to 1 — the
    fingerprints must describe the local shapes the kernels execute."""
    from repro.dist.sharding import ShardingPlan, use_plan
    from repro.launch.mesh import make_host_mesh

    tp = 2
    mesh = make_host_mesh(model=tp)
    plan = ShardingPlan(mesh)
    clean = build_model(tiny("granite-8b"))
    with use_plan(plan):
        div = serve_gemm_div(clean)
        assert div["model"] == tp  # every tensor-parallel dim divides

        # an odd vocab cannot split over the model axis: spec_for demotes
        # the lm_head/vocab dim, so the serve table must drop to 1
        odd = build_model(tiny("granite-8b", vocab_size=2049))
        assert plan.demoted_dims(odd.param_specs(), mesh_axis="model")
        assert serve_gemm_div(odd)["model"] == 1

        # a decode width indivisible by the batch factor demotes "batch"
        dp = plan.gemm_div()["batch"]
        if dp > 1:
            assert serve_gemm_div(clean, batch=dp + 1)["batch"] == 1
            assert serve_gemm_div(clean, batch=2 * dp)["batch"] == dp
