"""Mamba2/SSD numerics: the chunked algorithm must match the naive
sequential recurrence (the SSM ground truth), and hypothesis drives shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is absent

from repro.models.ssd import _ssd_chunked


def _naive_ssd(x, dt, a, b_in, c_in, h0=None):
    """h_t = exp(dt_t a) h_{t-1} + dt_t B_t x_t ; y_t = C_t . h_t"""
    bsz, s, nh, dh = x.shape
    ds = b_in.shape[-1]
    h = np.zeros((bsz, nh, dh, ds), np.float32) if h0 is None else np.asarray(h0)
    ys = np.zeros((bsz, s, nh, dh), np.float32)
    x = np.asarray(x, np.float32)
    dt = np.asarray(dt, np.float32)
    b_in = np.asarray(b_in, np.float32)
    c_in = np.asarray(c_in, np.float32)
    for t in range(s):
        decay = np.exp(dt[:, t] * a[None, :])  # (B, nh)
        outer = np.einsum("bh,bs,bhd->bhds", dt[:, t], b_in[:, t], x[:, t])
        h = h * decay[:, :, None, None] + outer
        ys[:, t] = np.einsum("bs,bhds->bhd", c_in[:, t], h)
    return ys, h


def _inputs(bsz, s, nh, dh, ds, seed=0):
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(bsz, s, nh, dh)), jnp.float32)
    dt = jnp.asarray(r.uniform(0.05, 0.5, size=(bsz, s, nh)), jnp.float32)
    a = jnp.asarray(-r.uniform(0.1, 2.0, size=(nh,)), jnp.float32)
    b_in = jnp.asarray(r.normal(size=(bsz, s, ds)), jnp.float32)
    c_in = jnp.asarray(r.normal(size=(bsz, s, ds)), jnp.float32)
    return x, dt, a, b_in, c_in


@pytest.mark.parametrize("chunk", [4, 8, 16])
def test_chunked_matches_naive(chunk):
    x, dt, a, b_in, c_in = _inputs(2, 16, 3, 4, 5)
    y, h = _ssd_chunked(x, dt, a, b_in, c_in, chunk)
    y_ref, h_ref = _naive_ssd(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


def test_initial_state_carries():
    x, dt, a, b_in, c_in = _inputs(1, 8, 2, 4, 3, seed=1)
    r = np.random.default_rng(2)
    h0 = jnp.asarray(r.normal(size=(1, 2, 4, 3)), jnp.float32)
    y, h = _ssd_chunked(x, dt, a, b_in, c_in, chunk=4, h0=h0)
    y_ref, h_ref = _naive_ssd(x, dt, a, b_in, c_in, h0=h0)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    st.integers(min_value=1, max_value=3),  # batch
    st.sampled_from([4, 8, 12]),  # seq (multiple of chunk 4)
    st.integers(min_value=1, max_value=4),  # heads
    st.sampled_from([2, 4]),  # dh
    st.sampled_from([2, 3]),  # ds
)
def test_chunked_matches_naive_property(bsz, s, nh, dh, ds):
    x, dt, a, b_in, c_in = _inputs(bsz, s, nh, dh, ds, seed=s * 7 + nh)
    y, h = _ssd_chunked(x, dt, a, b_in, c_in, chunk=4)
    y_ref, h_ref = _naive_ssd(x, dt, a, b_in, c_in)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)


def test_prefill_state_equals_decode_chain():
    """ssd_apply: chunked prefill final state == running the decode
    recurrence token by token (the long_500k serving contract)."""
    import dataclasses

    from conftest import tiny
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model
    from repro.models.ssd import ssd_apply, ssd_init_state

    cfg = tiny("mamba2-1.3b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["layers"])["ssm"]

    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(1, 8, cfg.d_model)) * 0.3, jnp.float32)

    y_full, st_full = ssd_apply(p0, x, cfg, div={})
    st = ssd_init_state(cfg, 1)
    ys = []
    for t in range(8):
        y_t, st = ssd_apply(p0, x[:, t : t + 1], cfg, div={}, state=st)
        ys.append(y_t)
    y_chain = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_chain), np.asarray(y_full), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(st["h"]), np.asarray(st_full["h"]), rtol=2e-3, atol=2e-3
    )
