"""Checkpoint manager: atomic commits, retention, async writer, elastic
restore (different mesh via subprocess with 8 host devices)."""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.normal(size=(16, 8)), jnp.float32)},
        "opt": {"mu": jnp.zeros((16, 8)), "count": jnp.asarray(3, jnp.int32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    state = _state()
    mgr.save(7, state, extra={"data": {"seed": 1, "step": 7}})
    restored, step = mgr.restore(state)
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert mgr.read_extra()["data"]["step"] == 7


def test_latest_pointer_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for step in (1, 2, 3, 4):
        mgr.save(step, _state(step))
    assert mgr.latest_step() == 4
    assert mgr.all_steps() == [3, 4]  # retention pruned 1, 2


def test_async_writer(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    for step in (5, 10):
        mgr.save(step, _state(step), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 10


def test_no_partial_checkpoint_visible(tmp_path):
    """A .tmp dir must never be restorable (atomic rename contract)."""
    mgr = CheckpointManager(str(tmp_path), keep=2)
    os.makedirs(os.path.join(tmp_path, "step_0000000099.tmp"))
    assert mgr.latest_step() is None
    assert 99 not in mgr.all_steps()


def test_restore_missing_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        mgr.restore(_state())


def test_dtype_cast_on_restore(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    state = {"w": jnp.ones((4, 4), jnp.float32)}
    mgr.save(1, state)
    target = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    restored, _ = mgr.restore(target)
    assert restored["w"].dtype == jnp.bfloat16


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "{src}")
from repro.checkpoint import CheckpointManager

mode, ckdir = sys.argv[1], sys.argv[2]
if mode == "save":
    mesh = jax.make_mesh((8,), ("data",))
    w = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                       NamedSharding(mesh, P("data", None)))
    CheckpointManager(ckdir).save(1, {{"w": w}})
    print("SAVED")
else:
    # restore onto a DIFFERENT mesh: 2x4 with model sharding on dim 1
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    target = {{"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)}}
    sh = {{"w": NamedSharding(mesh, P("data", "model"))}}
    restored, step = CheckpointManager(ckdir).restore(target, shardings=sh)
    got = np.asarray(restored["w"])
    assert np.array_equal(got, np.arange(64, dtype=np.float32).reshape(8, 8))
    assert restored["w"].sharding.spec == P("data", "model")
    print("RESTORED", step)
"""


def test_elastic_restore_different_mesh(tmp_path):
    """Save sharded on (8,) data mesh, restore onto (2,4) data x model."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    script = ELASTIC_SCRIPT.format(src=os.path.abspath(src))
    ckdir = str(tmp_path / "ck")
    for mode, want in (("save", "SAVED"), ("restore", "RESTORED 1")):
        r = subprocess.run(
            [sys.executable, "-c", script, mode, ckdir],
            capture_output=True,
            text=True,
            timeout=120,
        )
        assert r.returncode == 0, r.stderr
        assert want in r.stdout
