"""Property tests on the MoE capacity-dispatch invariants (hypothesis)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is absent

from conftest import tiny
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.models.layers import moe_apply


def _moe_params(cfg, seed=0):
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(seed))
    return jax.tree.map(lambda a: a[0], params["layers"])["moe"]


@settings(max_examples=10, deadline=None)
@given(
    st.integers(min_value=1, max_value=4),  # batch
    st.sampled_from([8, 16, 32]),  # seq
    st.integers(min_value=0, max_value=3),  # seed
)
def test_moe_output_finite_and_bounded(b, s, seed):
    cfg = tiny("olmoe-1b-7b")
    p = _moe_params(cfg, 0)
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.normal(size=(b, s, cfg.d_model)) * 0.5, jnp.float32)
    out, aux = moe_apply(p, x, cfg, div={})
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) >= 0.0
    # combine is a convex-ish mixture of expert outputs: magnitude bounded
    # by the largest expert response on these inputs (loose sanity bound)
    assert float(jnp.max(jnp.abs(out))) < 1e3


def test_moe_generous_capacity_matches_token_order_permutation():
    """With drop-free capacity, permuting the batch rows permutes outputs
    identically (routing is per-token)."""
    cfg = dataclasses.replace(tiny("olmoe-1b-7b"), capacity_factor=8.0)
    p = _moe_params(cfg)
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(4, 8, cfg.d_model)) * 0.5, jnp.float32)
    out1, _ = moe_apply(p, x, cfg, div={})
    perm = jnp.asarray([2, 0, 3, 1])
    out2, _ = moe_apply(p, x[perm], cfg, div={})
    np.testing.assert_allclose(
        np.asarray(out2), np.asarray(out1[perm]), rtol=1e-4, atol=1e-5
    )


def test_moe_capacity_actually_drops():
    """With capacity << demand, outputs differ from the drop-free run (the
    GShard semantics are real, not vestigial)."""
    base = tiny("olmoe-1b-7b")
    p = _moe_params(base)
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(2, 64, base.d_model)) * 0.5, jnp.float32)
    lo = dataclasses.replace(base, capacity_factor=0.10)
    hi = dataclasses.replace(base, capacity_factor=8.0)
    out_lo, _ = moe_apply(p, x, lo, div={})
    out_hi, _ = moe_apply(p, x, hi, div={})
    assert float(jnp.max(jnp.abs(out_lo - out_hi))) > 1e-3


def test_moe_zero_gate_token_passthrough_is_zero():
    """A dropped token's MoE output is exactly zero (residual passthrough
    happens at the layer level)."""
    # enough tokens that the min(t,16) decode floor doesn't mask the tiny
    # capacity factor: demand 512*2/8 = 128/expert >> cap floor 16
    cfg = dataclasses.replace(tiny("olmoe-1b-7b"), capacity_factor=0.01)
    p = _moe_params(cfg)
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(2, 256, cfg.d_model)) * 0.5, jnp.float32)
    out, _ = moe_apply(p, x, cfg, div={})
    # most tokens dropped -> many exact-zero rows
    zero_rows = int(jnp.sum(jnp.all(out == 0.0, axis=-1)))
    assert zero_rows > 0


@pytest.mark.parametrize("impl", ["global", "hinted"])
def test_moe_impls_agree_dropfree(impl):
    cfg = dataclasses.replace(tiny("olmoe-1b-7b"), capacity_factor=8.0)
    p = _moe_params(cfg)
    r = np.random.default_rng(3)
    x = jnp.asarray(r.normal(size=(2, 16, cfg.d_model)) * 0.5, jnp.float32)
    ref, _ = moe_apply(p, x, cfg, div={})
    cfg2 = dataclasses.replace(cfg, moe_impl=impl)
    got, _ = moe_apply(p, x, cfg2, div={})
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), rtol=1e-4, atol=1e-5)
