"""Elastic scaling end-to-end: train sharded on mesh A, checkpoint, resume
sharded on a different mesh B — losses must continue identically (the
mesh-agnostic checkpoint contract at fleet scale)."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "@SRC@")
import dataclasses, json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.checkpoint import CheckpointManager
from repro.data import SyntheticLMData
from repro.dist.sharding import ShardingPlan, materialize_tree, use_plan
from repro.models import build_model
from repro.optim import make_optimizer, constant
from repro.train import init_train_state, make_train_step

mode, ckdir, mesh_spec = sys.argv[1], sys.argv[2], sys.argv[3]
d_sz, m_sz = (int(x) for x in mesh_spec.split("x"))
mesh = jax.make_mesh((d_sz, m_sz), ("data", "model"))
plan = ShardingPlan(mesh)

cfg = dataclasses.replace(get_reduced("granite-8b"), dtype="float32")
model = build_model(cfg)
opt = make_optimizer("sgd", constant(1e-2))
data = SyntheticLMData(cfg, batch=8, seq_len=32, seed=5)
step_fn = jax.jit(make_train_step(model, opt))

def shard_state(state):
    param_sh = plan.tree_shardings(model.param_specs())
    put = lambda tree, sh: jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sh)
    state["params"] = put(state["params"], param_sh)
    return state

with use_plan(plan):
    mgr = CheckpointManager(ckdir)
    if mode == "phase1":
        params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
        state = shard_state(init_train_state(model, opt, params))
        losses = []
        for step in range(6):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        mgr.save(6, state, extra={"data": {"seed": 5, "step": 6}})
        print("PHASE1", json.dumps(losses))
    else:
        params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
        target = init_train_state(model, opt, params)
        state, at = mgr.restore(target)
        state = shard_state(state)
        losses = []
        for step in range(6, 12):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
            state, m = step_fn(state, batch)
            losses.append(float(m["loss"]))
        print("PHASE2", json.dumps(losses))
"""


def _run(mode, ckdir, mesh):
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.replace("@SRC@", src), mode, ckdir, mesh],
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    return r.stdout


def test_rescale_mesh_mid_training(tmp_path):
    import json as j

    ck = str(tmp_path / "ck")
    # train 6 steps on (8 data, 1 model)
    out1 = _run("phase1", ck, "8x1")
    # resume on (2 data, 4 model) — a completely different factorisation
    out2 = _run("phase2", ck, "2x4")
    # and on (4, 2)
    out3 = _run("phase2", ck, "4x2")
    l2 = j.loads(out2.split("PHASE2 ")[1])
    l3 = j.loads(out3.split("PHASE2 ")[1])
    # same data stream + same restored state => identical trajectories
    # regardless of the mesh factorisation (f32, deterministic CPU)
    assert all(abs(a - b) < 1e-4 for a, b in zip(l2, l3)), (l2, l3)
