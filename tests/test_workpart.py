"""Property tests for the Stream-K++ work partition (Algorithm 1 math)."""

import pytest
from hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is absent

from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DP,
    HYBRIDS,
    Policy,
    PolicyKind,
    TileConfig,
    policy_from_name,
)
from repro.core.workpart import (
    GemmShape,
    cdiv,
    iter_to_tile,
    partition,
    validate_partition,
    wave_quantization_efficiency,
)

CFGS = [TileConfig(128, 128, 128), TileConfig(8, 256, 1024), TileConfig(256, 512, 128)]

dims_m = st.integers(min_value=1, max_value=8192)
dims_n = st.integers(min_value=1, max_value=8192)
dims_k = st.integers(min_value=1, max_value=65536)
grids = st.integers(min_value=1, max_value=64)
policies = st.sampled_from(ALL_POLICIES)
cfgs = st.sampled_from(CFGS)


@settings(max_examples=300, deadline=None)
@given(dims_m, dims_n, dims_k, grids, policies, cfgs)
def test_partition_invariants(m, n, k, g, policy, cfg):
    p = partition(GemmShape(m, n, k), cfg, g, policy)
    validate_partition(p)


@settings(max_examples=200, deadline=None)
@given(dims_m, dims_n, dims_k, grids, policies, cfgs)
def test_every_iteration_covered_exactly_once(m, n, k, g, policy, cfg):
    """The flattened SK iteration space is a disjoint exact cover, and the
    SK+DP tile split covers all output tiles."""
    p = partition(GemmShape(m, n, k), cfg, g, policy)
    covered = 0
    prev_end = 0
    for r in p.sk_ranges:
        assert r.start >= prev_end or r.size == 0
        covered += r.size
        prev_end = max(prev_end, r.end)
    assert covered == p.sk_total_iters
    assert p.sk_tiles + p.dp_tiles == p.m_tiles * p.n_tiles


@settings(max_examples=200, deadline=None)
@given(dims_m, dims_n, dims_k, grids, cfgs)
def test_all_sk_balance(m, n, k, g, cfg):
    """ALL_SK: no workgroup gets more than ceil(total/g) iterations and the
    max-min spread is at most ceil (Algorithm 1 line 4)."""
    p = partition(GemmShape(m, n, k), cfg, g, ALL_SK)
    total = p.sk_total_iters
    ipw = cdiv(total, g)
    sizes = [r.size for r in p.sk_ranges]
    assert max(sizes) <= ipw
    assert sum(sizes) == total


@settings(max_examples=200, deadline=None)
@given(dims_m, dims_n, dims_k, grids, cfgs, st.integers(min_value=1, max_value=6))
def test_hybrid_sk_region_is_prefix_and_bounded(m, n, k, g, cfg, b):
    p = partition(GemmShape(m, n, k), cfg, g, Policy(PolicyKind.HYBRID, b))
    t = p.m_tiles * p.n_tiles
    rem = t % g
    expected = min(t, (rem if rem else 0) + (b - 1) * g)
    assert p.sk_tiles == expected
    # contributions only reference SK-region tiles
    for c in p.contributions:
        assert c.tile < p.sk_tiles


@settings(max_examples=100, deadline=None)
@given(st.integers(min_value=0, max_value=10_000), st.integers(min_value=1, max_value=512))
def test_wave_quantization_bounds(tiles, lanes):
    e = wave_quantization_efficiency(tiles, lanes)
    assert 0.0 < e <= 1.0
    if tiles % lanes == 0 and tiles:
        assert e == 1.0


def test_iter_to_tile_roundtrip():
    ipt = 7
    for it in range(100):
        tile, local = iter_to_tile(it, ipt)
        assert tile * ipt + local == it
        assert 0 <= local < ipt


def test_dp_policy_has_empty_sk_region():
    p = partition(GemmShape(512, 512, 512), TileConfig(128, 128, 128), 8, DP)
    assert p.sk_tiles == 0
    assert p.sk_total_iters == 0
    assert p.dp_tiles == 16


def test_policy_names_roundtrip():
    for pol in ALL_POLICIES:
        assert policy_from_name(pol.name) == pol
    with pytest.raises(ValueError):
        policy_from_name("bogus")


@settings(max_examples=200, deadline=None)
@given(dims_m, dims_n, dims_k, grids, policies, cfgs)
def test_partition_stats_agree_with_full_partition(m, n, k, g, policy, cfg):
    """The O(g) aggregate view must agree with the full O(tiles) partition
    on every statistic the cost model consumes."""
    from repro.core.workpart import partition_stats

    p = partition(GemmShape(m, n, k), cfg, g, policy)
    st = partition_stats(GemmShape(m, n, k), cfg, g, policy)
    assert st.sk_tiles == p.sk_tiles
    assert st.sk_total_iters == p.sk_total_iters
    assert st.dp_tiles == p.dp_tiles
    assert st.dp_waves == p.dp_waves
    assert st.n_split_tiles == p.n_split_tiles
    assert st.extra_contributors == sum(
        c.num_contributors - 1 for c in p.contributions
    )
