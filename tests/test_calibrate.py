"""Analytical-first selection: calibration fit recovery, persistence,
federated LWW determinism, machine-keyed scoring caches, model-source
dispatch, and top-k budgeted sweeps.

The planted-machine tests exploit that the in-container measurement oracle
*is* the cost model: tuning under a perturbed machine produces journal wall
clocks the fit must decompose back into exactly the planted terms. Terms
the winner set cannot identify (peak FLOP/s when every winner is
memory-bound) must pin to the base machine rather than drift.
"""

import dataclasses
import json

import pytest

from repro.core import costmodel
from repro.core.calibrate import (
    MIN_RECORDS,
    CalibratedMachine,
    CalibrationError,
    append_calibration,
    better_calibration,
    calibrate_db,
    calibrate_journal,
    calibrate_records,
    calibration_entry,
    calibration_from_json,
    key_dtypes,
    machine_from_json,
    machine_to_json,
    profile_key,
    record_wall_s,
)
from repro.core.costmodel import V5E
from repro.core.federate import merge_databases
from repro.core.op import GemmOp
from repro.core.selector import KernelSelector
from repro.core.tuner import Tuner, TuningDatabase
from repro.core.workpart import GemmShape

from tests.hypothesis_compat import given, settings, st


F32 = costmodel.profile_for("float32", "float32")

#: the machine the synthetic journal is measured under — bandwidth, launch
#: and fix-up all moved off the V5E defaults the fit starts from
PLANTED = dataclasses.replace(
    V5E, hbm_bw=600e9, launch_overhead_s=5e-6, fixup_serial_s=3e-6
)

#: square-ish shapes identify bandwidth + launch; the skinny large-K tail
#: forces ALL_SK winners with split-tile fix-ups so the serialization term
#: is excited too (without them it pins to base — see the pinning test)
SQUAREISH = [
    (m, n, k)
    for m in (64, 128, 256, 512)
    for n in (128, 256, 512)
    for k in (128, 512)
][:20]
SKINNY = [
    (8, 128, 4096),
    (16, 128, 8192),
    (8, 256, 4096),
    (16, 64, 8192),
    (32, 128, 4096),
    (8, 128, 8192),
    (16, 256, 4096),
    (32, 64, 8192),
]


@pytest.fixture(scope="module")
def planted_db():
    """Full-sweep database measured under the planted machine."""
    return Tuner(mach=PLANTED).tune(SQUAREISH + SKINNY)


@pytest.fixture(scope="module")
def planted_cm(planted_db):
    return calibrate_db(planted_db, base=V5E)


# -- fit recovery ------------------------------------------------------------


def test_fit_recovers_planted_terms(planted_db, planted_cm):
    """The fit decomposes synthetic walls back into the planted machine:
    bandwidth, launch overhead and fix-up serialization recover to within
    0.1%, and the residual is numerically zero."""
    m = planted_cm.machine_for(F32)
    assert m.hbm_bw == pytest.approx(PLANTED.hbm_bw, rel=1e-3)
    assert m.launch_overhead_s == pytest.approx(
        PLANTED.launch_overhead_s, rel=1e-3
    )
    assert m.fixup_serial_s == pytest.approx(PLANTED.fixup_serial_s, rel=1e-3)
    assert planted_cm.residual < 1e-6
    assert planted_cm.n_records == len(SQUAREISH) + len(SKINNY)
    assert planted_cm.fitted_profiles == (profile_key(F32),)


def test_unidentifiable_terms_pin_to_base(planted_db, planted_cm):
    """Every winner in the synthetic journal is memory-bound, so the
    1/peak_flops column is never excited — the fit must pin it to the base
    machine's value instead of inventing a coefficient. Likewise a journal
    with no split-tile winners cannot identify the fix-up tail."""
    assert planted_cm.machine_for(F32).peak_flops == V5E.peak_flops

    no_fixup = {k: planted_db.records[k] for k in map(tuple, SQUAREISH)}
    cm = calibrate_records(no_fixup.items(), base=V5E)
    m = cm.machine_for(F32)
    assert m.fixup_serial_s == V5E.fixup_serial_s  # pinned, not drifted
    assert m.hbm_bw == pytest.approx(PLANTED.hbm_bw, rel=1e-3)


def test_unfitted_profile_falls_back_to_base(planted_cm):
    bf16 = costmodel.profile_for("bfloat16", "bfloat16")
    assert planted_cm.machine_for(bf16) is planted_cm.base


def test_under_floor_profile_skipped_not_fatal():
    """A mixed journal fits the profiles that reach the floor and skips the
    sparse ones (extended op keys carry their dtypes in the key itself)."""
    ops = [GemmOp.plain(m, n, k, in_dtype="bfloat16") for m, n, k in SQUAREISH[:2]]
    db = Tuner(mach=PLANTED).tune(SQUAREISH + ops)
    bf16 = costmodel.profile_for("bfloat16", "bfloat16")
    assert key_dtypes(ops[0].key) == bf16  # 7-part key: dtypes from the key
    cm = calibrate_db(db, base=V5E)
    assert cm.fitted_profiles == (profile_key(F32),)  # bf16 under the floor
    assert cm.machine_for(bf16) is cm.base
    assert cm.machine_for(F32).hbm_bw == pytest.approx(
        PLANTED.hbm_bw, rel=1e-3
    )


def test_min_records_refusal():
    """A fit on a handful of records is refused outright — model-first
    dispatch must never launch from coefficients fitted on noise."""
    db = Tuner(mach=PLANTED).tune(SQUAREISH[:3])
    with pytest.raises(CalibrationError):
        calibrate_db(db, base=V5E)
    # the floor is a parameter, not a constant baked into the refusal
    cm = calibrate_db(db, base=V5E, min_records=3)
    assert cm.n_records == 3
    assert len(SQUAREISH[:3]) < MIN_RECORDS


def test_record_wall_reconstruction(planted_db):
    """wall = flops / tflops, and unusable records answer None."""
    key = tuple(SQUAREISH[0])
    rec = planted_db.records[key]
    wall = record_wall_s(key, rec)
    assert wall == pytest.approx(
        GemmShape(*key).flops / (rec.tflops * 1e12)
    )
    assert record_wall_s(key, dataclasses.replace(rec, tflops=0.0)) is None


# -- persistence: the calibration journal entry type -------------------------


def test_calibration_entry_roundtrip(planted_cm):
    line = calibration_entry(planted_cm)
    back = calibration_from_json(json.loads(line)["calibration"])
    assert back == planted_cm


@settings(max_examples=25, deadline=None)
@given(
    st.floats(min_value=1e9, max_value=1e15),
    st.floats(min_value=1e8, max_value=1e12),
    st.floats(min_value=0, max_value=1e-3),
    st.floats(min_value=0, max_value=1e-3),
    st.integers(min_value=0, max_value=10**6),
    st.floats(min_value=0, max_value=2e9),
    st.integers(min_value=0, max_value=10**6),
)
def test_calibration_roundtrip_property(
    peak, bw, launch, fixup, n, wall, version
):
    """Any fitted machine + stamp survives the JSONL entry byte-exactly
    (finite floats roundtrip through json verbatim)."""
    m = dataclasses.replace(
        V5E,
        peak_flops=peak,
        hbm_bw=bw,
        launch_overhead_s=launch,
        fixup_serial_s=fixup,
    )
    cm = CalibratedMachine(
        base=V5E,
        profiles=((profile_key(F32), m),),
        n_records=n,
        residual=0.25,
        wall=wall,
        version=version,
    )
    back = calibration_from_json(
        json.loads(calibration_entry(cm))["calibration"]
    )
    assert back == cm


def test_machine_json_rejects_unknown_fields():
    d = machine_to_json(V5E)
    assert machine_from_json(d) == V5E
    d["warp_size"] = 32
    with pytest.raises(ValueError, match="warp_size"):
        machine_from_json(d)


def test_journal_carries_calibration(tmp_path, planted_cm):
    """The journal's second entry type: records + a calibration replay into
    a fresh database, and the snapshot roundtrip keeps the calibration."""
    journal = str(tmp_path / "journal.jsonl")
    Tuner(mach=PLANTED).tune(SQUAREISH[:4], journal=journal)
    append_calibration(journal, planted_cm)

    db = TuningDatabase()
    assert db.replay_journal(journal) == 5  # 4 records + 1 calibration
    assert db.load_errors == 0
    assert db.calibration == planted_cm
    assert len(db.records) == 4

    snap = str(tmp_path / "db.json")
    db.save(snap)
    loaded = TuningDatabase.load(snap)
    assert loaded.calibration == planted_cm

    # the convenience fitter reads the same journal it was written to
    cm2 = calibrate_journal(journal, base=V5E, min_records=4)
    assert cm2.fitted_profiles == (profile_key(F32),)


# -- federation: calibrations merge deterministically ------------------------


def _stamped(cm: CalibratedMachine, wall: float, version: int):
    return dataclasses.replace(cm, wall=wall, version=version)


def test_federated_calibration_lww_commutes(planted_cm):
    """Two producers' calibrations merge to the same winner whatever order
    the shards arrive in: later wall stamp wins, and a full stamp tie falls
    through to the deterministic payload arbiter."""
    older = _stamped(planted_cm, wall=100.0, version=7)
    newer = _stamped(
        dataclasses.replace(planted_cm, n_records=planted_cm.n_records + 1),
        wall=200.0,
        version=1,
    )
    a, b = TuningDatabase(calibration=older), TuningDatabase(calibration=newer)
    ab, rep_ab = merge_databases([a, b])
    ba, rep_ba = merge_databases([b, a])
    assert ab.calibration == ba.calibration == newer
    assert rep_ab.superseded == rep_ba.superseded == 1

    # stamp tie, different payloads: the serialized form arbitrates, so
    # both orders still agree (merge is commutative, never clock-dependent)
    tied1 = _stamped(planted_cm, wall=50.0, version=3)
    tied2 = _stamped(
        CalibratedMachine(base=PLANTED, n_records=planted_cm.n_records),
        wall=50.0,
        version=3,
    )
    x, _ = merge_databases(
        [TuningDatabase(calibration=tied1), TuningDatabase(calibration=tied2)]
    )
    y, _ = merge_databases(
        [TuningDatabase(calibration=tied2), TuningDatabase(calibration=tied1)]
    )
    assert x.calibration == y.calibration
    assert better_calibration(tied1, tied2) == better_calibration(tied2, tied1)
    assert better_calibration(None, tied1) == tied1


def test_set_calibration_lww_and_force(planted_cm):
    db = TuningDatabase()
    newer = _stamped(planted_cm, wall=200.0, version=1)
    older = _stamped(
        CalibratedMachine(base=PLANTED), wall=100.0, version=5
    )
    assert db.set_calibration(newer, stamp=False)
    assert not db.set_calibration(older, stamp=False)  # loses LWW, kept out
    assert db.calibration == newer
    assert db.set_calibration(older, stamp=False, force=True)  # journal-
    assert db.calibration == older  # on-top structural precedence


# -- machine-keyed scoring caches --------------------------------------------


def test_swapping_machines_changes_the_pick():
    """Scoring caches key on the Machine instance: the same shape ranked
    under a perturbed machine yields a different winner, and re-querying
    under the original machine still returns the original pick (no cache
    aliasing between machines)."""
    shape = GemmShape(8, 128, 4096)
    heavy_fixup = dataclasses.replace(V5E, fixup_serial_s=5e-4)

    before = costmodel.rank_candidates(shape, V5E)[0]
    swapped = costmodel.rank_candidates(shape, heavy_fixup)[0]
    assert before[0].name == "all_sk"  # split-K wins the skinny shape...
    assert swapped[0].name == "dp"  # ...until the fix-up tail is punitive
    assert (before[0], before[1], before[2]) != (
        swapped[0],
        swapped[1],
        swapped[2],
    )
    again = costmodel.rank_candidates(shape, V5E)[0]
    assert again == before


def test_rank_candidates_head_is_best_config():
    """best_config is exactly the argmin of the ranking primitive."""
    shape = GemmShape(256, 512, 128)
    ranked = costmodel.rank_candidates(shape, V5E)
    assert [t for *_, t in ranked] == sorted(t for *_, t in ranked)
    pol, cfg, g, t = ranked[0]
    cfg2, tflops = costmodel.best_config(shape, pol, V5E, g=g)
    assert cfg2 == cfg
    assert tflops == pytest.approx(shape.flops / t / 1e12)


# -- model-source dispatch ---------------------------------------------------


def test_unseen_fingerprint_dispatches_via_model(planted_cm):
    """With a calibration installed, a fingerprint every filter calls
    absent launches the calibrated model's argmin (source "model") instead
    of the DP-vs-SK fallback — and stats count it as a model warm start."""
    db = TuningDatabase()
    sel = KernelSelector(
        sieve=db.build_sieve(), db=db, calibration=planted_cm
    )
    op = GemmOp.plain(8, 128, 4096)
    got = sel.select_op(op)
    assert got.source == "model"
    assert sel.stats.model_warm == 1
    # the pick IS the head of the ranking under the calibrated machine
    pol, cfg, g, _ = costmodel.rank_candidates(
        GemmShape(8, 128, 4096),
        planted_cm.machine_for(F32),
        sel.policies,
        sel.tile_configs,
        sel.grid_sizes,
        F32,
    )[0]
    assert (got.policy, got.cfg, got.g) == (pol, cfg, g)


def test_hot_swapping_calibration_rescoring(planted_cm):
    """Installing a calibration mid-stream drops the whole memo: the next
    dispatch of a previously-fallback fingerprint re-resolves as "model"."""
    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    op = GemmOp.plain(8, 128, 4096)
    assert sel.select_op(op).source == "fallback"
    assert sel.hot_swap(calibration=planted_cm) == 1  # full memo drop
    assert sel.select_op(op).source == "model"
    assert sel.stats.model_warm == 1


# -- top-k budgeted sweeps ---------------------------------------------------


def test_top_k_rejects_nonpositive():
    with pytest.raises(ValueError):
        Tuner(top_k=0)


def test_top_k_budget_and_quality(planted_cm):
    """The analytical-first budget: a top-5 sweep measures >= 5x fewer
    candidates than the exhaustive oracle, lands within 10% of the full
    winner on every shape, and records the winner's model rank."""
    sizes = SQUAREISH[:8] + SKINNY[:4]
    full = Tuner(mach=PLANTED)
    db_full = full.tune(sizes)
    budget = Tuner(mach=PLANTED, top_k=5, calibration=planted_cm)
    db_top = budget.tune(sizes)

    assert budget.measurements * 5 <= full.measurements
    for size in sizes:
        key = tuple(size)
        top, oracle = db_top.records[key], db_full.records[key]
        assert top.tflops >= 0.9 * oracle.tflops
        assert top.model_rank >= 1
        assert top.dp_best_tflops > 0  # DP baseline stays meaningful
        assert top.runner_up_policy != top.policy or top.runner_up_tflops == 0
    # full-sweep records carry the rank too (the drift signal)
    assert all(r.model_rank >= 1 for r in db_full.records.values())
