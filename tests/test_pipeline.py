"""Pipeline parallelism: the GPipe schedule must equal sequential layer
application (4-stage pipeline on an 8-device subprocess mesh) and be
differentiable."""

import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "@SRC@")
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import pipeline_apply, split_stages

L, D, M, MB = 8, 16, 6, 4  # layers, width, microbatches, microbatch size
r = np.random.default_rng(0)
params = {"w": jnp.asarray(r.normal(size=(L, D, D)) * 0.3, jnp.float32),
          "b": jnp.asarray(r.normal(size=(L, D)) * 0.1, jnp.float32)}
x = jnp.asarray(r.normal(size=(M, MB, D)), jnp.float32)

def layer(p, h):
    return jnp.tanh(h @ p["w"] + p["b"])

def stage_fn(stage_params, h):
    def body(h, p):
        return layer(p, h), None
    h, _ = jax.lax.scan(body, h, stage_params)
    return h

# sequential reference
def seq_apply(params, x):
    def body(h, p):
        return layer(p, h), None
    h, _ = jax.lax.scan(body, x, params)
    return h

ref = jax.vmap(lambda xb: seq_apply(params, xb))(x.reshape(M * MB // MB, MB, D).reshape(M, MB, D))
ref = jnp.stack([seq_apply(params, x[m]) for m in range(M)])

mesh = jax.make_mesh((4, 2), ("pod", "data"))
staged = split_stages(params, 4)
got = jax.jit(lambda sp, x: pipeline_apply(stage_fn, sp, x, mesh=mesh, axis="pod"))(staged, x)
err = float(jnp.max(jnp.abs(got - ref)))
assert err < 1e-5, err

# differentiability: grads vs sequential
def loss_pipe(sp, x):
    return jnp.sum(pipeline_apply(stage_fn, sp, x, mesh=mesh, axis="pod") ** 2)

def loss_seq(p, x):
    return sum(jnp.sum(seq_apply(p, x[m]) ** 2) for m in range(M))

g_pipe = jax.jit(jax.grad(loss_pipe))(staged, x)
g_seq = jax.grad(loss_seq)(params, x)
g_pipe_flat = jax.tree.map(lambda a: a.reshape(-1, *a.shape[2:]), g_pipe)
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in
           zip(jax.tree.leaves(g_pipe_flat), jax.tree.leaves(g_seq)))
assert gerr < 1e-4, gerr
print("PIPELINE OK", err, gerr)
"""


def test_gpipe_matches_sequential_and_differentiates():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT.replace("@SRC@", src)],
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "PIPELINE OK" in r.stdout
