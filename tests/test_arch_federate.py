"""Arch-class federation: profile classification, per-class partitioning,
cross-arch warm seeding, the SelectorState install path, and the tagged
journal-entry registry's forward compatibility.

The multi-device CI lane also runs this file (arch classes exist for
heterogeneous fleets); every test here is device-count-agnostic."""

import dataclasses
import json
import logging

import pytest

from repro.core.arch import DEFAULT_ARCH, ArchProfile, append_arch, detect_arch
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.bloom import optimal_params
from repro.core.calibrate import CalibratedMachine
from repro.core.costmodel import V5E
from repro.core.federate import federate_selector, merge_databases
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import (
    Tuner,
    TuningDatabase,
    TuningRecord,
    journal_entry,
)

SIZES = [(64, 512, 256), (128, 256, 512), (32, 1024, 128)]


def _rec(size=(64, 512, 256), policy="dp", tflops=1.0, arch=DEFAULT_ARCH, wall=0.0):
    return TuningRecord(
        size=size,
        policy=policy,
        cfg="128x128x128",
        tflops=tflops,
        runner_up_policy="all_sk",
        runner_up_tflops=tflops * 0.9,
        dp_best_tflops=tflops,
        g=8,
        wall=wall,
        arch=arch,
    )


# -- ArchProfile classification ---------------------------------------------


def test_arch_profile_cls_is_stable_and_readable():
    p = ArchProfile(backend="tpu", lanes=8, vmem_bytes=16 << 20, flops_per_byte=250)
    assert p.cls == "tpu:l8:v16m:r250"


def test_from_machine_quantizes_roofline_ratio():
    # two hosts of one generation with slightly different calibrated
    # constants must land in the same class (ratio centered in a bin so
    # the perturbation exercises quantization, not a bin boundary)
    base = dataclasses.replace(V5E, hbm_bw=V5E.peak_flops / 250.0)
    a = dataclasses.replace(base, hbm_bw=base.hbm_bw * 1.02)
    b = dataclasses.replace(base, hbm_bw=base.hbm_bw * 0.98)
    assert ArchProfile.from_machine(a).cls == ArchProfile.from_machine(b).cls
    assert ArchProfile.from_machine(a).flops_per_byte == 250


def test_arch_profile_json_roundtrip_rederives_cls():
    p = detect_arch()
    d = p.to_json()
    assert d["cls"] == p.cls
    d["cls"] = "hand:edited"  # redundant field must not desynchronize
    assert ArchProfile.from_json(d) == p
    assert ArchProfile.from_json(d).cls == p.cls


def test_default_arch_record_serializes_without_arch_field():
    # byte-compat: a default-class journal line is identical to pre-arch
    line = journal_entry(_rec())
    assert "arch" not in json.loads(line)["record"]
    stamped = journal_entry(_rec(arch="tpu:l8:v16m:r275"))
    assert json.loads(stamped)["record"]["arch"] == "tpu:l8:v16m:r275"


# -- legacy artifacts land in the "default" class ---------------------------


def test_archless_journal_federates_into_default_class(tmp_path):
    shard = str(tmp_path / "legacy.jsonl")
    Tuner().tune(SIZES, journal=shard)  # default Tuner: arch-less lines

    sel = KernelSelector()  # default class
    state = federate_selector(sel, journals=[shard])
    assert state.merged == len(SIZES)
    # every record landed in the own-class partition under "default"...
    assert set(sel.db.records) == {tuple(s) for s in SIZES}
    assert all(r.arch == DEFAULT_ARCH for r in sel.db.records.values())
    assert not sel.db.xarch
    # ...and dispatches identically to a direct database hit
    for m, n, k in SIZES:
        chosen = sel.select(m, n, k)
        rec = sel.db.records[(m, n, k)]
        assert chosen.source == "tuned"
        assert (chosen.policy.name, chosen.g) == (rec.policy, rec.g)


def test_archless_calibration_parses_into_default_class():
    cm = CalibratedMachine(wall=1.0)
    assert cm.arch == DEFAULT_ARCH
    db = TuningDatabase()
    assert db.set_calibration(cm, stamp=False)
    assert db.calibration is cm
    assert not db.xarch_calibrations


def test_foreign_class_calibration_routes_to_side_table():
    cm = CalibratedMachine(wall=1.0, arch="tpu:l8:v16m:r275")
    db = TuningDatabase()  # default class
    db.set_calibration(cm, stamp=False)
    assert db.calibration is None  # never steers local model-first dispatch
    assert db.xarch_calibrations["tpu:l8:v16m:r275"] is cm


# -- tagged journal registry: forward compatibility -------------------------


def test_unknown_tag_lines_skip_and_count_without_warning(tmp_path, caplog):
    shard = tmp_path / "mixed.jsonl"
    lines = [
        journal_entry(_rec()),
        json.dumps({"telemetry": {"qps": 1200}}),  # a future producer's type
        journal_entry(_rec(size=(128, 256, 512))),
    ]
    shard.write_text("\n".join(lines) + "\n")
    db = TuningDatabase()
    with caplog.at_level(logging.DEBUG, logger="repro.tuner"):
        applied = db.replay_journal(str(shard))
    assert applied == 2
    assert len(db.records) == 2
    assert db.load_errors == 1  # the skip stays visible...
    warnings_seen = [r for r in caplog.records if r.levelno >= logging.WARNING]
    assert not warnings_seen  # ...but is NOT warned as malformed


def test_arch_entry_replays_into_profile_table(tmp_path):
    shard = str(tmp_path / "arch.jsonl")
    profile = detect_arch()
    append_arch(shard, profile)
    db = TuningDatabase()
    assert db.replay_journal(shard) == 1
    assert db.arch_profiles[profile.cls] == profile
    assert db.load_errors == 0


# -- cross-arch dispatch: seeds, never direct hits --------------------------


def test_cross_arch_record_is_xarch_seed_never_direct_hit():
    foreign = _rec(policy="sk2dp", arch="tpu:l8:v16m:r275", wall=1.0)
    db = TuningDatabase(arch="tpu:l8:v16m:r225")
    db.add_record(foreign, stamp=False)
    assert not db.records  # routed to the foreign-class partition
    assert db.xarch["tpu:l8:v16m:r275"][foreign.size] is foreign

    sel = KernelSelector(state=SelectorState(db=db, arch="tpu:l8:v16m:r225"))
    chosen = sel.select(*foreign.size)
    assert chosen.source == "xarch"
    assert sel.stats.xarch_seeds == 1
    # the seed set is the foreign winner + runner-up, re-ranked locally
    assert chosen.policy.name in (foreign.policy, foreign.runner_up_policy)


def test_xarch_seed_superseded_by_local_adaptation():
    foreign = _rec(arch="tpu:l8:v16m:r275", wall=1.0)
    db = TuningDatabase(arch=DEFAULT_ARCH)
    db.add_record(foreign, stamp=False)
    sel = KernelSelector(state=SelectorState(db=db))
    adaptive = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1))

    assert sel.select(*foreign.size).source == "xarch"  # still a miss
    assert adaptive.stats.misses == 1
    assert adaptive.drain() == 1
    after = sel.select(*foreign.size)
    assert after.source == "tuned"
    assert sel.db.records[foreign.size].arch == DEFAULT_ARCH
    # the foreign copy survives as provenance, not as the dispatch source
    assert sel.db.xarch["tpu:l8:v16m:r275"][foreign.size] is foreign


def test_same_class_merge_is_direct_hit_other_class_is_not(tmp_path):
    cls = "tpu:l8:v16m:r275"
    same = TuningDatabase(arch=cls)
    same.add_record(_rec(policy="sk2dp", arch=cls, wall=1.0), stamp=False)
    other = TuningDatabase(arch="tpu:l8:v16m:r225")
    other.add_record(
        _rec(size=(128, 256, 512), arch="tpu:l8:v16m:r225", wall=1.0), stamp=False
    )
    into = TuningDatabase(arch=cls)
    merge_databases([same, other], into=into)
    assert set(into.records) == {(64, 512, 256)}  # same class: direct
    assert set(into.xarch["tpu:l8:v16m:r225"]) == {(128, 256, 512)}


# -- SelectorState install path ---------------------------------------------


def test_legacy_artifact_kwargs_emit_deprecation_warning():
    db = TuningDatabase()
    with pytest.warns(DeprecationWarning, match="SelectorState"):
        KernelSelector(db=db)
    sel = KernelSelector()
    with pytest.warns(DeprecationWarning, match="hot_swap"):
        sel.hot_swap(db=db)
    assert sel.db is db


def test_state_path_and_bare_calls_do_not_warn(recwarn):
    sel = KernelSelector(state=SelectorState(db=TuningDatabase()))
    sel.hot_swap(state=SelectorState())
    sel.hot_swap(keys=[(64, 512, 256)])  # keys-only invalidation
    sel.hot_swap()  # bare full invalidation
    KernelSelector()
    deprecations = [w for w in recwarn if w.category is DeprecationWarning]
    assert not deprecations


def test_state_mixed_with_legacy_kwargs_raises():
    with pytest.raises(TypeError, match="not both"):
        KernelSelector(state=SelectorState(), db=TuningDatabase())
    sel = KernelSelector()
    with pytest.raises(TypeError, match="not both"):
        sel.hot_swap(state=SelectorState(), sieve=None or TuningDatabase())


def test_hot_swap_state_installs_all_artifacts_atomically():
    db = TuningDatabase()
    db.add_record(_rec())
    sieve = db.build_sieve(generation=3)
    cm = CalibratedMachine(wall=1.0)
    sel = KernelSelector()
    sel.select(64, 512, 256)
    state = SelectorState(db=db, sieve=sieve, calibration=cm, arch=DEFAULT_ARCH)
    dropped = sel.hot_swap(state=state)
    assert dropped == 1  # new calibration identity drops the whole memo
    assert sel.state is state
    assert (sel.db, sel.sieve, sel.calibration) == (db, sieve, cm)
    assert sel.sieve_generation == 3
    assert sel.select(64, 512, 256).source == "tuned"


def test_federate_selector_returns_installed_state_with_report(tmp_path):
    shard = str(tmp_path / "s.jsonl")
    Tuner().tune(SIZES, journal=shard)
    sel = KernelSelector()
    state = federate_selector(sel, journals=[shard])
    assert isinstance(state, SelectorState)
    assert sel.state is state  # what it returned is what it installed
    assert state.merged == len(SIZES)  # MergeReport rides on the state
    assert state.conflicts == 0


# -- federate_selector sieve-geometry bugfix --------------------------------


def test_federate_inherits_installed_sieve_geometry(tmp_path):
    shard = str(tmp_path / "s.jsonl")
    Tuner().tune(SIZES, journal=shard)
    db = TuningDatabase()
    db.add_record(_rec(size=(8, 8, 8)))
    sel = KernelSelector(
        state=SelectorState(db=db, sieve=db.build_sieve(capacity=512, fp_rate=0.05))
    )
    state = federate_selector(sel, journals=[shard])
    # the rebuilt sieve keeps the worker's installed geometry, not the
    # historical fixed (10_000, 0.01) defaults
    n_bits, n_hashes = optimal_params(512, 0.05)
    got = next(iter(state.sieve.filters.values()))
    # BloomFilter pads n_bits up to a whole byte
    assert (got.n_bits, got.n_hashes) == (n_bits + (-n_bits % 8), n_hashes)
    assert (state.sieve.capacity, state.sieve.fp_rate) == (512, 0.05)


def test_federate_explicit_mismatched_geometry_raises_early(tmp_path):
    shard = str(tmp_path / "s.jsonl")
    Tuner().tune(SIZES, journal=shard)
    db = TuningDatabase()
    db.add_record(_rec(size=(8, 8, 8)))
    sel = KernelSelector(
        state=SelectorState(db=db, sieve=db.build_sieve(capacity=512, fp_rate=0.05))
    )
    before = sel.state
    with pytest.raises(ValueError, match="mismatched parameters") as ei:
        federate_selector(sel, journals=[shard], capacity=10_000, fp_rate=0.01)
    # both configurations are named, and nothing was installed
    assert "10000" in str(ei.value).replace("10_000", "10000")
    assert sel.state is before


def test_federate_explicit_matching_geometry_is_accepted(tmp_path):
    shard = str(tmp_path / "s.jsonl")
    Tuner().tune(SIZES, journal=shard)
    db = TuningDatabase()
    db.add_record(_rec(size=(8, 8, 8)))
    sel = KernelSelector(
        state=SelectorState(db=db, sieve=db.build_sieve(capacity=512, fp_rate=0.05))
    )
    state = federate_selector(sel, journals=[shard], capacity=512, fp_rate=0.05)
    assert state.merged == len(SIZES) + 1
