"""Paged KV pool: allocator lifecycle (alloc/free/recycle/exhaustion) and
the gather/scatter adapters' position mapping — page ``i`` of a table holds
positions ``i*page_size..(i+1)*page_size - 1``, so a gathered view must BE
the dense layout of the table's sequence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.serve import PagedKVCache, PageExhausted, PageTable
from repro.serve.paged_kv import paged_cache_specs, pages_for


@pytest.fixture(scope="module")
def model():
    return build_model(tiny("granite-8b"))


def make_pool(model, page_size=4, n_pages=6):
    return PagedKVCache(model, page_size=page_size, n_pages=n_pages)


# -- allocator ---------------------------------------------------------------


def test_pages_for_rounds_up_and_reserves_one():
    assert pages_for(0, 4) == 1  # even an empty table holds its first page
    assert pages_for(1, 4) == 1
    assert pages_for(4, 4) == 1
    assert pages_for(5, 4) == 2
    assert pages_for(17, 16) == 2


def test_alloc_free_recycle(model):
    kv = make_pool(model)
    a = kv.alloc(4)
    assert len(a) == 4 and len(set(a)) == 4
    assert all(0 <= p < kv.n_pages for p in a)
    assert kv.used_pages == 4 and kv.free_pages == 2
    kv.free(a[:2])
    assert kv.free_pages == 4
    b = kv.alloc(4)  # must reuse the freed pages to satisfy this
    assert set(a[:2]) <= set(b) | set(a[2:]) or kv.free_pages == 0
    assert kv.used_pages == 6
    assert kv.peak_used == 6


def test_exhaustion_raises_and_try_alloc_is_atomic(model):
    kv = make_pool(model)
    kv.alloc(5)
    assert kv.try_alloc(2) is None  # refused whole: no partial grab
    assert kv.free_pages == 1  # state unchanged by the failed attempt
    with pytest.raises(PageExhausted):
        kv.alloc(2)
    assert kv.free_pages == 1
    assert kv.alloc(1)  # the remainder is still allocatable


def test_double_free_and_invalid_id_rejected(model):
    kv = make_pool(model)
    pages = kv.alloc(2)
    kv.free(pages)
    with pytest.raises(ValueError, match="double free"):
        kv.free([pages[0]])
    with pytest.raises(ValueError, match="invalid page"):
        kv.free([kv.n_pages])  # the scratch page is never allocator-owned
    with pytest.raises(ValueError, match="invalid page"):
        kv.free([-1])


def test_occupancy_metrics(model):
    kv = make_pool(model)
    pages = kv.alloc(3)
    occ = kv.occupancy()
    assert occ["n_pages"] == 6
    assert occ["used_pages"] == 3 and occ["free_pages"] == 3
    assert occ["utilization"] == pytest.approx(0.5)
    kv.free(pages)
    assert kv.occupancy()["used_pages"] == 0
    assert kv.occupancy()["peak_used_pages"] == 3  # high-water persists


# -- gather/scatter adapters -------------------------------------------------


def test_gather_view_concatenates_pages_in_table_order(model):
    kv = make_pool(model, page_size=2, n_pages=4)
    # stamp page p, offset o with value 10*p + o, broadcast over the rest
    n_layers = jax.tree.leaves(kv.pool)[0].shape[0]
    stamp = np.zeros((n_layers, 5, 2), np.float32)  # incl. scratch page 4
    for p in range(5):
        for o in range(2):
            stamp[:, p, o] = 10 * p + o
    kv.pool = jax.tree.map(
        lambda a: jnp.asarray(
            np.broadcast_to(
                stamp.reshape(stamp.shape + (1,) * (a.ndim - 3)), a.shape
            ).astype(a.dtype)
        ),
        kv.pool,
    )
    view = kv.gather_view(kv.pool, jnp.asarray([[2, 0, 3]], jnp.int32))
    got = np.asarray(jax.tree.leaves(view)[0])[0, 0]  # (S=6, *rest)
    flat = got.reshape(6, -1)[:, 0]
    assert list(flat) == [20, 21, 0, 1, 30, 31]


def test_scatter_rows_then_gather_roundtrip(model):
    kv = make_pool(model, page_size=4, n_pages=4)
    tables = [PageTable([1, 3], 0), PageTable([2], 0)]
    pages_2d = kv.padded_tables(tables)
    assert pages_2d.shape == (2, 2)
    assert int(pages_2d[1, 1]) == kv.scratch  # short table scratch-padded
    # write position 5 of seq 0 (page 3, offset 1) and 2 of seq 1
    pos = np.array([5, 2], np.int32)
    pg = pages_2d[np.arange(2), pos // kv.page_size]
    rows = jax.tree.map(
        lambda a: jnp.full((a.shape[0], 2, *a.shape[3:]), 7.5, a.dtype), kv.pool
    )
    kv.pool = kv.scatter_rows(kv.pool, pg, jnp.asarray(pos % kv.page_size), rows)
    view = kv.gather_view(kv.pool, pages_2d)
    got = kv.rows_at(view, jnp.asarray(pos))
    for leaf in jax.tree.leaves(got):
        assert np.all(np.asarray(leaf, np.float64) == 7.5)
    # nothing else was touched: the rest of the view is still zero
    vleaf = np.asarray(jax.tree.leaves(view)[0], np.float64)
    assert np.count_nonzero(vleaf[:, 0].reshape(vleaf.shape[0], 8, -1).sum(-1)) \
        == vleaf.shape[0]


def test_scatter_prefill_writes_whole_pages(model):
    kv = make_pool(model, page_size=2, n_pages=4)
    pages = kv.alloc(2)
    fresh = jax.tree.map(
        lambda a: jnp.broadcast_to(
            jnp.arange(4, dtype=a.dtype).reshape(1, 1, 4, *(1,) * (a.ndim - 3)),
            (a.shape[0], 1, 4, *a.shape[3:]),
        ),
        kv.pool,
    )
    kv.pool = kv.scatter_prefill(kv.pool, jnp.asarray(pages, jnp.int32), fresh)
    view = kv.gather_view(kv.pool, jnp.asarray([pages], jnp.int32))
    for leaf in jax.tree.leaves(view):
        got = np.asarray(leaf)[0, 0].reshape(4, -1)
        assert np.all(got == np.arange(4)[:, None])


def test_padded_tables_pads_to_power_of_two(model):
    kv = make_pool(model, page_size=4, n_pages=8)
    t = kv.padded_tables([PageTable([0, 1, 2], 0)])
    assert t.shape == (1, 4)  # 3 -> 4
    assert int(t[0, 3]) == kv.scratch
    assert kv.padded_tables([PageTable([5], 0)]).shape == (1, 1)
    assert kv.padded_tables([PageTable([], 0)]).shape == (1, 1)
    five = [PageTable(list(range(5)), 0)]
    assert kv.padded_tables(five).shape == (1, 8)


# -- family gating -----------------------------------------------------------


def test_paged_cache_specs_rejects_stateful_families():
    ssm = build_model(tiny("mamba2-1.3b"))
    with pytest.raises(ValueError, match="attention-cache families"):
        paged_cache_specs(ssm, 4)


def test_paged_cache_specs_rejects_ring_caches():
    gemma = build_model(tiny("gemma3-27b", window_cache=True))
    with pytest.raises(ValueError, match="ring caches"):
        paged_cache_specs(gemma, 4)
