"""Serving engine: continuous batching, slot lifecycle, sampling, dispatch
log integration."""

import jax
import numpy as np
import pytest

from conftest import tiny
from repro.core.gemm import current_log, gemm_context
from repro.core.selector import default_selector
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine


@pytest.fixture(scope="module")
def served():
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def test_continuous_batching_drains_queue(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, ServeConfig(n_slots=3, max_seq=64, eos=-1))
    rng = np.random.default_rng(0)
    uids = [
        eng.submit(rng.integers(1, cfg.vocab_size, size=int(rng.integers(4, 10))), max_new_tokens=5)
        for _ in range(7)  # more requests than slots -> continuous batching
    ]
    done = eng.run()
    assert sorted(r.uid for r in done) == sorted(uids)
    for r in done:
        assert len(r.out_tokens) == 5
        assert r.done


def test_greedy_matches_decode_chain(served):
    """Engine greedy output == manual prefill/decode greedy chain."""
    import jax.numpy as jnp

    cfg, model, params = served
    prompt = np.array([5, 9, 2, 7], np.int32)
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=32, eos=-1))
    eng.submit(prompt, max_new_tokens=4)
    [req] = eng.run()

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None], max_seq=32)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(prompt)
    for _ in range(3):
        l, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32), jnp.asarray([pos])
        )
        toks.append(int(jnp.argmax(l[0, 0])))
        pos += 1
    assert req.out_tokens == toks


def test_eos_frees_slot(served):
    cfg, model, params = served
    # eos = whatever greedy produces first => finishes in 1 token
    import jax.numpy as jnp

    prompt = np.array([1, 2, 3], np.int32)
    logits, _ = model.prefill(params, jnp.asarray(prompt)[None], max_seq=16)
    first = int(jnp.argmax(logits[0, -1]))
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=16, eos=first))
    eng.submit(prompt, max_new_tokens=10)
    [req] = eng.run()
    assert req.out_tokens[0] == first
    assert len(req.out_tokens) == 1  # EOS terminated immediately


def test_temperature_sampling_is_seeded(served):
    cfg, model, params = served
    out = []
    for _ in range(2):
        eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=32, eos=-1, seed=42))
        eng.submit(np.array([3, 1, 4], np.int32), max_new_tokens=5, temperature=1.0)
        [req] = eng.run()
        out.append(req.out_tokens)
    assert out[0] == out[1]  # same seed -> same samples


def test_slot_serves_until_cache_actually_full(served):
    """Regression for the retire-one-early off-by-one: a slot must keep
    decoding until the *next* write position is out of bounds, so a request
    bounded only by max_seq yields exactly max_seq - len(prompt) + 1 tokens
    (the prefill-sampled token plus one per free cache line)."""
    cfg, model, params = served
    max_seq, prompt_len = 16, 4
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=max_seq, eos=-1))
    eng.submit(np.arange(1, prompt_len + 1, dtype=np.int32), max_new_tokens=1000)
    [req] = eng.run()
    assert req.done
    assert len(req.out_tokens) == max_seq - prompt_len + 1


def test_submit_rejects_overlong_prompt(served):
    cfg, model, params = served
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=8, eos=-1))
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.submit(np.arange(1, 10, dtype=np.int32))  # 9 tokens > max_seq=8
    # direct prefill of an oversized request is refused too (no silent
    # out-of-bounds scatter), even for callers that bypass submit()
    from repro.serve.engine import Request

    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng._prefill_slot(0, Request(99, np.arange(1, 10, dtype=np.int32)))


def test_queue_drains_when_every_request_finishes_at_prefill(served):
    """Regression: a prefill-finished request frees its slot after _admit's
    loop passed it — the engine must keep admitting into that slot instead
    of returning with the queue non-empty (previously the 2nd request was
    silently abandoned)."""
    cfg, model, params = served
    max_seq = 8
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=max_seq, eos=-1))
    uids = [
        eng.submit(np.arange(1, max_seq + 1, dtype=np.int32), max_new_tokens=100)
        for _ in range(3)  # every one fills the cache and finishes at prefill
    ]
    done = eng.run()
    assert sorted(r.uid for r in done) == sorted(uids)
    assert all(len(r.out_tokens) == 1 for r in done)
    assert eng._queue == []


def test_prompt_exactly_max_seq_finishes_at_prefill(served):
    """Boundary: a prompt that exactly fills the cache is admitted, yields
    the one prefill-sampled token, and frees its slot immediately (no decode
    step may write at position max_seq)."""
    cfg, model, params = served
    max_seq = 8
    eng = ServeEngine(model, params, ServeConfig(n_slots=1, max_seq=max_seq, eos=-1))
    eng.submit(np.arange(1, max_seq + 1, dtype=np.int32), max_new_tokens=100)
    [req] = eng.run()
    assert req.done
    assert len(req.out_tokens) == 1
    assert eng.slot_req == [None] and eng.pos[0] == 0


def test_dispatch_log_records_decode_gemms(served):
    cfg, model, params = served
    with gemm_context(selector=default_selector()) as ctx:
        eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_seq=32, eos=-1))
        eng.submit(np.array([1, 2, 3, 4], np.int32), max_new_tokens=3)
        eng.run()
        assert len(ctx.log) > 0
        tags = {e.tag for e in ctx.log}
        assert "attn.q" in tags and "lm_head" in tags
