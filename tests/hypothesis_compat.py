"""Optional-dependency shim for hypothesis.

``hypothesis`` is a tier-2 dependency (CI installs it; the minimal test
environment may not). Importing ``given``/``settings``/``st`` from here
keeps module collection working either way: with hypothesis installed the
real API is re-exported; without it, ``@given(...)`` marks the test skipped
and the strategy namespace degrades to inert placeholders so module-level
strategy expressions still evaluate. Non-property tests in the same module
keep running."""

import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert placeholder: any method/call returns another placeholder."""

        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

        def __call__(self, *a, **k):
            return _Strategy()

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

    st = _Strategies()

    def given(*a, **k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*a, **k):
        return lambda f: f
