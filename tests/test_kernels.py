"""Pallas kernel validation (interpret mode) against the pure-jnp oracles.

Sweeps shapes (aligned, ragged, skinny), dtypes (f32, bf16) and every
Stream-K++ policy; also validates the partials workspace itself against the
Algorithm-1 numpy emulation.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.policies import ALL_POLICIES, ALL_SK, DP, HYBRIDS, TileConfig
from repro.core.workpart import GemmShape, partition
from repro.kernels.dp import ops as dp_ops
from repro.kernels.splitk import ops as sk_ops_split
from repro.kernels.streamk import ops as sk_ops
from repro.kernels.streamk.ref import gemm_ref, streamk_partition_ref
from repro.kernels.streamk.streamk_gemm import streamk_phase1

CFG = TileConfig(8, 128, 128)
SHAPES = [
    (8, 128, 128),  # single tile
    (16, 256, 256),  # 2x2 tiles
    (24, 384, 640),  # 3x3 tiles, 5 k-iters
    (17, 200, 300),  # ragged: padding on every dim
    (1, 128, 1024),  # skinny decode-style
]


def _mk(m, n, k, dtype, seed=0):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)), dtype)
    b = jnp.asarray(r.normal(size=(k, n)), dtype)
    return a, b


def _tol(dtype):
    # f32: tiled K-split accumulation differs from one-pass jnp.dot by
    # O(1e-5) on K=640 reductions — tolerance reflects reduction-order noise
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_streamk_gemm_matches_oracle(shape, policy, dtype):
    m, n, k = shape
    a, b = _mk(m, n, k, dtype)
    want = gemm_ref(a, b, out_dtype=jnp.float32)
    got = sk_ops.gemm(
        a, b, policy=policy, cfg=CFG, g=4, interpret=True, out_dtype=jnp.float32
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("g", [1, 3, 4, 8, 16])
def test_streamk_grid_sizes(g):
    a, b = _mk(24, 384, 640, jnp.float32)
    want = gemm_ref(a, b)
    got = sk_ops.gemm(a, b, policy=ALL_SK, cfg=CFG, g=g, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("cfg", [TileConfig(8, 128, 128), TileConfig(16, 128, 256)])
def test_streamk_tile_configs(cfg):
    a, b = _mk(40, 256, 512, jnp.float32)
    want = gemm_ref(a, b)
    for policy in (ALL_SK, HYBRIDS[1]):
        got = sk_ops.gemm(a, b, policy=policy, cfg=cfg, g=4, interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_partials_workspace_matches_algorithm1_emulation():
    """Phase-1 output (the partials workspace itself) equals a direct numpy
    emulation of Algorithm 1 — validates the slot assignment, not just the
    final sum."""
    m, n, k = 16, 256, 512
    a, b = _mk(m, n, k, jnp.float32)
    from repro.kernels.common import pad_to

    ap = pad_to(a, (CFG.bm, CFG.bk))
    bp = pad_to(b, (CFG.bk, CFG.bn))
    part = partition(GemmShape(m, n, k), CFG, 4, ALL_SK)
    got = streamk_phase1(ap, bp, part, interpret=True)
    want_partials, want_c = streamk_partition_ref(ap, bp, part)
    # compare slot sums per tile (trash slot excluded from ref by masking)
    got_sum = np.asarray(got)[:, :-1].sum(axis=1)
    np.testing.assert_allclose(got_sum, np.asarray(want_c), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dp_gemm(dtype):
    for shape in SHAPES:
        a, b = _mk(*shape, dtype)
        want = gemm_ref(a, b, out_dtype=jnp.float32)
        got = dp_ops.gemm(a, b, cfg=CFG, interpret=True, out_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


@pytest.mark.parametrize("s", [1, 2, 4])
def test_splitk_gemm(s):
    a, b = _mk(16, 256, 1024, jnp.float32)
    want = gemm_ref(a, b)
    got = sk_ops_split.gemm(a, b, cfg=CFG, s=s, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_streamk_deterministic():
    """TPU adaptation replaces GPU atomics with a fixed-order reduction:
    results must be bitwise identical across runs."""
    a, b = _mk(24, 384, 640, jnp.float32)
    x1 = np.asarray(sk_ops.gemm(a, b, policy=ALL_SK, cfg=CFG, g=4, interpret=True))
    x2 = np.asarray(sk_ops.gemm(a, b, policy=ALL_SK, cfg=CFG, g=4, interpret=True))
    assert np.array_equal(x1, x2)


def test_bad_operands_raise():
    a = jnp.zeros((4, 8))
    b = jnp.zeros((9, 4))
    with pytest.raises(ValueError):
        sk_ops.gemm(a, b, interpret=True)
    with pytest.raises(ValueError):
        dp_ops.gemm(a, b, interpret=True)


@pytest.mark.parametrize("epilogue", ["relu", "silu", "gelu", "square"])
def test_fused_epilogues(epilogue):
    """Composable-Kernel-style fused activation epilogues: GEMM+act in one
    pass must equal act(GEMM) for every policy family."""
    from repro.kernels.common import apply_epilogue

    a, b = _mk(24, 384, 640, jnp.float32)
    want = apply_epilogue(
        jnp.dot(a, b, preferred_element_type=jnp.float32), epilogue
    )
    for policy in (DP, ALL_SK, HYBRIDS[0]):
        got = sk_ops.gemm(
            a, b, policy=policy, cfg=CFG, g=4, interpret=True, epilogue=epilogue
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
        )


def test_unknown_epilogue_raises():
    from repro.kernels.common import apply_epilogue

    with pytest.raises(ValueError):
        apply_epilogue(jnp.zeros((2, 2)), "tanh2")
