"""Tests for the one-kernel fused grouped GEMM (`kernels/streamk/grouped`)
and its dispatch/selection/tuning threading.

The per-group loop backend is the differential oracle throughout: the fused
kernel must match it within per-dtype tolerances on every policy, ragged
group-size pattern, and epilogue/quantization combination, while issuing
exactly ONE pallas_call.
"""

import importlib
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

gemm_mod = importlib.import_module("repro.core.gemm")
from repro.core.op import GROUPED_FUSED_MARKER, Epilogue, GemmOp
from repro.core.policies import ALL_SK, DP, HYBRIDS, TileConfig
from repro.core.quant import QuantizedTensor
from repro.core.selector import KernelSelector
from repro.core.tuner import (
    Tuner,
    TuningDatabase,
    journal_entry,
    key_from_str,
    key_to_str,
    parse_journal_line,
)
from repro.core.workpart import GroupedGemmShape, partition_stats
from repro.kernels.common import count_launches
from repro.kernels.streamk.grouped import gemm_grouped_streamk

#: per-dtype absolute tolerances for fused-vs-loop differentials: both paths
#: accumulate f32 in identical k-order, so f32/int8 should agree to float
#: roundoff of the output store; bf16 outputs round to bf16 precision.
TOLS = {"float32": 1e-4, "bfloat16": 2e-2, "float32*int8": 1e-4}

CFG = TileConfig(8, 128, 128)


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x).astype(dtype)


def _loop_oracle(a, b, sizes, **kw):
    """Per-group dense reference with ragged row masking."""
    outs = []
    for i in range(a.shape[0]):
        w = b[i].astype(jnp.float32)
        acc = a[i].astype(jnp.float32) @ w
        row = jnp.arange(a.shape[1])[:, None] < sizes[i]
        outs.append(jnp.where(row, acc, 0.0))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Kernel-level differentials
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", [DP, ALL_SK, HYBRIDS[0], HYBRIDS[5]])
@pytest.mark.parametrize("g", [2, 8])
def test_fused_matches_oracle_across_policies(policy, g):
    rng = np.random.default_rng(0)
    a = _rand(rng, (3, 20, 160), jnp.float32)
    b = _rand(rng, (3, 160, 200), jnp.float32)
    want = _loop_oracle(a, b, (20, 20, 20))
    got = gemm_grouped_streamk(
        a, b, policy=policy, cfg=CFG, g=g, interpret=True
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), atol=TOLS["float32"]
    )


@pytest.mark.parametrize(
    "sizes",
    [
        (17, 3, 20),  # uneven, none tile-aligned
        (20, 0, 5),  # empty expert in the middle
        (0, 0, 11),  # single live expert
    ],
)
def test_fused_ragged_group_sizes(sizes):
    rng = np.random.default_rng(1)
    a = _rand(rng, (3, 20, 96), jnp.float32)
    b = _rand(rng, (3, 96, 72), jnp.float32)
    want = _loop_oracle(a, b, sizes)
    got = gemm_grouped_streamk(
        a, b, policy=ALL_SK, cfg=CFG, g=4, interpret=True, group_sizes=sizes
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)
    # rows past a group's size are exactly zero
    for i, s in enumerate(sizes):
        assert not np.any(np.asarray(got)[i, s:])


def test_fused_all_empty_groups_no_launch():
    a = jnp.zeros((2, 8, 128), jnp.float32)
    b = jnp.zeros((2, 128, 128), jnp.float32)
    jax.clear_caches()
    with count_launches() as log:
        out = gemm_grouped_streamk(
            a, b, cfg=CFG, interpret=True, group_sizes=(0, 0)
        )
    assert not log
    assert out.shape == (2, 8, 128) and not np.any(np.asarray(out))


@pytest.mark.parametrize("in_dtype", ["float32", "bfloat16", "float32*int8"])
def test_fused_matches_loop_backend_per_dtype(in_dtype):
    """Dispatch-level differential: gemm_grouped fused vs fused=False."""
    rng = np.random.default_rng(2)
    g_count, m, k, n = 3, 12, 96, 200
    if in_dtype == "float32*int8":
        x = _rand(rng, (g_count, m, k), jnp.float32)
        vals = jnp.asarray(
            rng.integers(-127, 127, (g_count, k, n)).astype(np.int8)
        )
        scales = jnp.asarray(
            (np.abs(rng.standard_normal((g_count, n))) * 0.05 + 1e-3).astype(
                np.float32
            )
        )
        w = QuantizedTensor(vals, scales)
        tol = TOLS[in_dtype]
    else:
        dt = jnp.dtype(in_dtype)
        x = _rand(rng, (g_count, m, k), dt)
        w = _rand(rng, (g_count, k, n), dt)
        tol = TOLS[in_dtype]
    with gemm_mod.gemm_context(backend="pallas_interpret"):
        out_f = gemm_mod.gemm_grouped(x, w, out_dtype=jnp.float32)
        out_l = gemm_mod.gemm_grouped(
            x, w, out_dtype=jnp.float32, fused=False
        )
    np.testing.assert_allclose(
        np.asarray(out_f, np.float32), np.asarray(out_l, np.float32), atol=tol
    )


def test_fused_epilogue_stack_matches_loop():
    """bias + mul_silu + int8 dequant, fused vs loop backends."""
    rng = np.random.default_rng(3)
    g_count, m, k, n = 2, 16, 128, 136
    x = _rand(rng, (g_count, m, k), jnp.float32)
    vals = jnp.asarray(rng.integers(-127, 127, (g_count, k, n)).astype(np.int8))
    scales = jnp.asarray(
        (np.abs(rng.standard_normal((g_count, n))) * 0.05 + 1e-3).astype(np.float32)
    )
    w = QuantizedTensor(vals, scales)
    bias = _rand(rng, (g_count, n), jnp.float32)
    operand = _rand(rng, (g_count, m, n), jnp.float32)
    epi = Epilogue(bias=True, binary="mul_silu")
    with gemm_mod.gemm_context(backend="pallas_interpret"):
        out_f = gemm_mod.gemm_grouped(
            x, w, epilogue=epi, bias=bias, operand=operand
        )
        out_l = gemm_mod.gemm_grouped(
            x, w, epilogue=epi, bias=bias, operand=operand, fused=False
        )
    np.testing.assert_allclose(
        np.asarray(out_f), np.asarray(out_l), atol=1e-4
    )


# ---------------------------------------------------------------------------
# Launch counting: the headline claim
# ---------------------------------------------------------------------------


def test_fused_dispatch_issues_exactly_one_pallas_call():
    rng = np.random.default_rng(4)
    x = _rand(rng, (6, 16, 128), jnp.float32)
    w = _rand(rng, (6, 128, 128), jnp.float32)
    with gemm_mod.gemm_context(backend="pallas_interpret"):
        jax.clear_caches()
        with count_launches() as fused_log:
            gemm_mod.gemm_grouped(x, w)
        jax.clear_caches()
        with count_launches() as loop_log:
            gemm_mod.gemm_grouped(x, w, fused=False)
    assert len(fused_log) == 1, fused_log
    assert fused_log[0].startswith("grouped_")
    assert len(loop_log) >= 6, loop_log  # one launch per group, minimum


# ---------------------------------------------------------------------------
# Fingerprint / key behaviour
# ---------------------------------------------------------------------------


def test_fused_default_and_key_shape():
    rng = np.random.default_rng(5)
    x = _rand(rng, (2, 8, 128), jnp.float32)
    w = _rand(rng, (2, 128, 128), jnp.float32)
    with gemm_mod.gemm_context(backend="xla") as ctx:
        gemm_mod.gemm_grouped(x, w)
        gemm_mod.gemm_grouped(x, w, fused=False)
    k_fused, k_loop = ctx.log[0].op.key, ctx.log[1].op.key
    assert len(k_fused) == 8 and k_fused[7] == GROUPED_FUSED_MARKER
    assert len(k_loop) == 7
    assert k_fused[:7] == k_loop
    # string codec roundtrips both
    assert key_from_str(key_to_str(k_fused)) == k_fused
    assert key_from_str(key_to_str(k_loop)) == k_loop


def test_fused_requires_grouped_kind():
    with pytest.raises(ValueError):
        GemmOp(8, 8, 8, g=2, kind="batched", fused=True)


def test_batched_dispatch_stays_loop():
    rng = np.random.default_rng(6)
    x = _rand(rng, (2, 8, 128), jnp.float32)
    w = _rand(rng, (2, 128, 128), jnp.float32)
    with gemm_mod.gemm_context(backend="xla") as ctx:
        gemm_mod.gemm_batched(x, w)
    assert len(ctx.log[0].op.key) == 7
    assert not ctx.log[0].op.fused


# ---------------------------------------------------------------------------
# Cost model: one launch, concatenated tile space
# ---------------------------------------------------------------------------


def test_grouped_shape_partition_stats():
    shape = GroupedGemmShape(256, 256, 512, groups=4)
    st_dp = partition_stats(shape, CFG, 8, DP)
    st_sk = partition_stats(shape, CFG, 8, ALL_SK)
    per_group = (256 // CFG.bm) * (256 // CFG.bn)
    assert st_dp.n_tiles_total == 4 * per_group
    assert st_dp.sk_tiles == 0
    assert st_sk.sk_tiles == 4 * per_group
    # sequential-carry fused form: no partials workspace, no split tiles
    assert st_sk.n_split_tiles == 0 and st_sk.extra_contributors == 0
    assert shape.flops == 4 * 2 * 256 * 256 * 512


def test_costmodel_op_shape_routes_fused():
    from repro.core import costmodel

    op = GemmOp(
        64, 64, 128, g=4, kind="grouped", in_dtype="float32",
        out_dtype="float32", fused=True,
    )
    shape = costmodel.op_shape(op)
    assert isinstance(shape, GroupedGemmShape) and shape.groups == 4
    assert costmodel.op_shape(replace(op, fused=False)) == shape.__class__.__mro__[1](
        64, 64, 128
    )


# ---------------------------------------------------------------------------
# Tune / journal / warm-start roundtrip for the fused op form
# ---------------------------------------------------------------------------


def _fused_op():
    return GemmOp(
        24, 72, 96, g=4, kind="grouped", in_dtype="float32",
        out_dtype="float32", fused=True,
    )


def test_fused_op_tunes_journals_and_warm_starts(tmp_path):
    op = _fused_op()
    tuner = Tuner()
    rec, per = tuner.tune_size(op)
    assert rec.size == op.key

    journal = tmp_path / "journal.jsonl"
    journal.write_text(journal_entry(rec, per) + "\n")
    rec2, per2 = parse_journal_line(journal.read_text().strip())
    assert rec2.size == op.key and per2 == per

    db = TuningDatabase()
    db.replay_journal(str(journal))
    sel = KernelSelector(db=db).select_op(op)
    assert sel.source == "tuned"
    assert sel.policy.name == rec.policy and sel.cfg.name == rec.cfg
    assert sel.g == rec.g

    # the loop-form sibling must not warm-start off the fused record
    sel_loop = KernelSelector(db=db).select_op(replace(op, fused=False))
    assert sel_loop.source != "tuned"


def test_legacy_7part_journal_still_parses_and_selects(tmp_path):
    """Old G-keyed (7-part) records parse and keep steering the loop form."""
    op_loop = replace(_fused_op(), fused=False)
    rec, per = Tuner().tune_size(op_loop)
    line = journal_entry(rec, per)
    rec2, _ = parse_journal_line(line)
    assert rec2.size == op_loop.key and len(rec2.size) == 7
    db = TuningDatabase()
    db.add_record(rec2)
    sel = KernelSelector(db=db).select_op(op_loop)
    assert sel.source == "tuned"


def test_malformed_key_raises():
    with pytest.raises(ValueError):
        key_from_str("1,2,3,4,5")
