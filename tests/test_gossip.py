"""Streaming journal gossip: incremental tails (torn-write resume, shrink
recovery, malformed-line accounting) and live cross-worker exchange into a
running selector.

The multi-device CI lane also runs this file; every test is
device-count-agnostic."""

import json
import logging

import pytest

from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.arch import DEFAULT_ARCH
from repro.core.gossip import GossipExchange, JournalTail
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import (
    TuningDatabase,
    TuningRecord,
    append_journal,
    journal_entry,
)

SIZES = [(64, 512, 256), (128, 256, 512), (32, 1024, 128)]


def _rec(size=(64, 512, 256), policy="dp", tflops=1.0, arch=DEFAULT_ARCH, wall=0.0):
    return TuningRecord(
        size=size,
        policy=policy,
        cfg="128x128x128",
        tflops=tflops,
        runner_up_policy="all_sk",
        runner_up_tflops=tflops * 0.9,
        dp_best_tflops=tflops,
        g=8,
        wall=wall,
        arch=arch,
    )


# -- JournalTail: incremental reads ----------------------------------------


def test_tail_reads_incrementally(tmp_path):
    shard = str(tmp_path / "s.jsonl")
    tail = JournalTail(shard)
    assert tail.poll() == []  # missing shard: nothing yet, no raise

    append_journal(shard, _rec(SIZES[0]))
    first = tail.poll()
    assert [e["key"] for e in first] == ["64,512,256"]
    assert tail.poll() == []  # nothing new

    append_journal(shard, _rec(SIZES[1]))
    append_journal(shard, _rec(SIZES[2]))
    assert [e["key"] for e in tail.poll()] == ["128,256,512", "32,1024,128"]


def test_tail_missing_shard_raises_when_not_ok(tmp_path):
    tail = JournalTail(str(tmp_path / "never.jsonl"), missing_ok=False)
    with pytest.raises(FileNotFoundError):
        tail.poll()


def test_tail_resumes_across_torn_multibyte_final_line(tmp_path):
    shard = tmp_path / "s.jsonl"
    complete = journal_entry(_rec(SIZES[0])) + "\n"
    # a crash mid-append, torn *inside* a multi-byte UTF-8 sequence: the
    # tail must neither raise nor consume the partial line
    entry = json.loads(journal_entry(_rec(SIZES[1])))
    entry["note"] = "émigré"
    torn_line = json.dumps(entry, ensure_ascii=False).encode("utf-8")
    split = torn_line.index("é".encode("utf-8")) + 1  # mid-sequence
    shard.write_bytes(complete.encode("utf-8") + torn_line[:split])

    tail = JournalTail(str(shard))
    assert [e["key"] for e in tail.poll()] == ["64,512,256"]
    assert tail.load_errors == 0  # torn != malformed: it may still heal
    assert tail.offset == len(complete.encode("utf-8"))

    # the producer finishes the append: the healed line reads whole
    shard.write_bytes(complete.encode("utf-8") + torn_line + b"\n")
    assert [e["key"] for e in tail.poll()] == ["128,256,512"]
    assert tail.load_errors == 0


def test_tail_counts_complete_malformed_lines_once(tmp_path):
    shard = tmp_path / "s.jsonl"
    shard.write_text(
        journal_entry(_rec(SIZES[0])) + "\n" + "{not json\n"
        + journal_entry(_rec(SIZES[1])) + "\n"
    )
    tail = JournalTail(str(shard))
    assert len(tail.poll()) == 2
    assert tail.load_errors == 1
    assert tail.poll() == []  # the malformed line was consumed, not retried
    assert tail.load_errors == 1


def test_tail_rereads_after_shrink(tmp_path):
    shard = tmp_path / "s.jsonl"
    shard.write_text(
        journal_entry(_rec(SIZES[0])) + "\n" + journal_entry(_rec(SIZES[1])) + "\n"
    )
    tail = JournalTail(str(shard))
    assert len(tail.poll()) == 2
    # rotation/truncation: the shard restarts smaller than our offset, so
    # the only safe resume is a full re-read from byte 0
    shard.write_text(journal_entry(_rec(SIZES[2])) + "\n")
    assert [e["key"] for e in tail.poll()] == ["32,1024,128"]


def test_tail_skips_blank_lines(tmp_path):
    shard = tmp_path / "s.jsonl"
    shard.write_text("\n" + journal_entry(_rec(SIZES[0])) + "\n\n")
    assert len(JournalTail(str(shard)).poll()) == 1


# -- GossipExchange: live cross-worker convergence --------------------------


def _worker(journal=None, hot_threshold=1):
    sel = KernelSelector()
    adaptive = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=hot_threshold), journal=journal
    )
    return sel, adaptive


def test_gossip_folds_sibling_commits_without_restart(tmp_path):
    shard_a = str(tmp_path / "a.jsonl")
    shard_b = str(tmp_path / "b.jsonl")
    sel_a, ad_a = _worker(journal=shard_a)
    sel_b, ad_b = _worker(journal=shard_b)
    gossip_b = GossipExchange(sel_b, [shard_a])

    # worker A tunes its workload; B has never seen those fingerprints
    for s in SIZES:
        sel_a.select(*s)
    assert ad_a.drain() == len(SIZES)

    assert gossip_b.exchange() == len(SIZES)
    misses_before = ad_b.stats.misses
    for s in SIZES:
        assert sel_b.select(*s).source == "tuned"  # direct DB hits, no misses
    assert ad_b.stats.misses == misses_before
    assert gossip_b.stats.swaps == 1
    assert gossip_b.stats.entries == len(SIZES)


def test_quiet_round_installs_nothing(tmp_path):
    shard = str(tmp_path / "a.jsonl")
    sel, _ = _worker()
    gossip = GossipExchange(sel, [shard])
    state = sel.state
    assert gossip.exchange() == 0  # sibling shard does not even exist yet
    assert sel.state is state  # no swap: memoised picks survive
    assert gossip.stats.swaps == 0
    assert gossip.stats.rounds == 1


def test_gossip_does_not_clobber_newer_local_commit(tmp_path):
    shard = str(tmp_path / "a.jsonl")
    append_journal(shard, _rec(policy="dp", tflops=1.0, wall=1.0))
    db = TuningDatabase()
    local = _rec(policy="sk2dp", tflops=2.0, wall=2.0)  # newer wall stamp
    db.add_record(local, stamp=False)
    sel = KernelSelector(state=SelectorState(db=db))
    gossip = GossipExchange(sel, [shard])
    gossip.exchange()
    assert sel.db.records[local.size].policy == "sk2dp"  # LWW: local stands


def test_gossip_unknown_tags_skip_and_count(tmp_path, caplog):
    shard = tmp_path / "a.jsonl"
    shard.write_text(
        journal_entry(_rec(wall=1.0)) + "\n"
        + json.dumps({"telemetry": {"qps": 9}}) + "\n"
    )
    sel, _ = _worker()
    gossip = GossipExchange(sel, [str(shard)])
    with caplog.at_level(logging.DEBUG, logger="repro.gossip"):
        assert gossip.exchange() == 1
    assert gossip.stats.load_errors == 1
    warnings_seen = [r for r in caplog.records if r.levelno >= logging.WARNING]
    assert not warnings_seen  # forward compatibility is not corruption


def test_gossip_foreign_class_records_surface_as_xarch_seeds(tmp_path):
    shard = str(tmp_path / "a.jsonl")
    foreign = _rec(policy="sk2dp", arch="tpu:l8:v16m:r275", wall=1.0)
    append_journal(shard, foreign)
    sel, _ = _worker()
    GossipExchange(sel, [shard]).exchange()
    assert not sel.db.records  # never a direct hit across classes
    chosen = sel.select(*foreign.size)
    assert chosen.source == "xarch"
    assert sel.stats.xarch_seeds == 1


def test_gossip_bumps_sieve_generation_per_swap(tmp_path):
    shard = str(tmp_path / "a.jsonl")
    sel, _ = _worker()
    gossip = GossipExchange(sel, [shard])
    append_journal(shard, _rec(SIZES[0], wall=1.0))
    gossip.exchange()
    assert sel.sieve_generation == 1
    append_journal(shard, _rec(SIZES[1], wall=2.0))
    gossip.exchange()
    assert sel.sieve_generation == 2
    assert gossip.stats.swaps == 2
