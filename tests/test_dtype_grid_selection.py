"""Dtype-aware cost modeling end-to-end + grid size ``g`` as a tuning axis.

Covers the ISSUE-3 acceptance criteria: f32/bf16 ops of the same MNK can
select different (policy, cfg, g); the tuner's g-sweep commits records with
g != 8; and legacy g-less TuningRecords/journals load and dispatch
identically.
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.gemm_suite import suite
from repro.core import costmodel
from repro.core.costmodel import DtypeBytes
from repro.core.gemm import gemm, gemm_context, register_backend
from repro.core.op import GemmOp
from repro.core.policies import ALL_POLICIES, ALL_SK, DP, TileConfig
from repro.core.selector import KernelSelector, default_selector
from repro.core.tuner import (
    LEGACY_GRID,
    Tuner,
    TuningDatabase,
    TuningRecord,
    journal_entry,
)
from repro.core.workpart import GemmShape


# ---------------------------------------------------------------------------
# dtype byte-width profiles
# ---------------------------------------------------------------------------


def test_dtype_width_table_and_fallbacks():
    assert costmodel.dtype_width("float32") == 4
    assert costmodel.dtype_width("bfloat16") == 2
    assert costmodel.dtype_width("int8") == 1
    assert costmodel.dtype_width("float8_e4m3fn") == 1  # bit-count fallback
    assert costmodel.dtype_width("mystery") == 4  # safe default


def test_profile_for_mixed_dtypes():
    dt = costmodel.profile_for("bfloat16*int8", "bfloat16")
    assert (dt.a, dt.b, dt.out, dt.acc) == (2, 1, 2, 4)
    dt32 = costmodel.profile_for("float32", "float32")
    assert (dt32.a, dt32.b, dt32.out) == (4, 4, 4)


def test_op_dtypes_reads_the_fingerprint():
    op = GemmOp.plain(64, 64, 64, in_dtype="int8", out_dtype="bfloat16")
    dt = costmodel.op_dtypes(op)
    assert (dt.a, dt.b, dt.out) == (1, 1, 2)


# ---------------------------------------------------------------------------
# dtype-aware timing terms
# ---------------------------------------------------------------------------


def test_f32_never_faster_than_bf16_same_shape():
    """Wider operands can only add HBM traffic: modeled time is monotone in
    the byte widths for every (policy, cfg)."""
    s = GemmShape(256, 512, 2048)
    f32 = DtypeBytes(4, 4, 4)
    for pol in ALL_POLICIES:
        for cfg in (TileConfig(128, 128, 128), TileConfig(8, 128, 512)):
            t_bf16 = costmodel.gemm_time_s(s, cfg, pol, dt=costmodel.DEFAULT_DTYPES)
            t_f32 = costmodel.gemm_time_s(s, cfg, pol, dt=f32)
            assert t_f32 >= t_bf16


def test_default_profile_matches_legacy_scoring():
    """Bare-shape scoring is unchanged: the module default is the paper's
    fp16-suite 2-byte profile, so omitting ``dt`` reproduces it exactly."""
    s = GemmShape(1152, 1152, 8192)
    cfg = TileConfig(128, 128, 128)
    assert costmodel.gemm_time_s(s, cfg, ALL_SK) == costmodel.gemm_time_s(
        s, cfg, ALL_SK, dt=costmodel.DEFAULT_DTYPES
    )


def test_vmem_feasibility_is_dtype_aware():
    """A tile config that fits bf16 operands can overflow VMEM for f32 —
    the feasibility filter must use the real widths."""
    cfg = TileConfig(512, 512, 256)
    bf16_ws = costmodel.vmem_working_set(cfg)
    f32_ws = costmodel.vmem_working_set(cfg, DtypeBytes(4, 4, 4))
    assert f32_ws > bf16_ws
    mach = costmodel.Machine(vmem_bytes=(bf16_ws + f32_ws) // 2)
    shape = GemmShape(1024, 1024, 1024)
    # feasible at bf16 ...
    assert costmodel.best_config(shape, DP, mach, tile_configs=(cfg,))[1] > 0
    # ... infeasible at f32
    with pytest.raises(AssertionError):
        costmodel.best_config(
            shape, DP, mach, tile_configs=(cfg,), dt=DtypeBytes(4, 4, 4)
        )


def test_grid_multiplexing_keeps_g_equals_lanes_identical():
    """g == lanes is the legacy schedule: the lane-multiplex factor is 1 and
    the modeled time matches the g=None default exactly."""
    s = GemmShape(1152, 1152, 8192)
    cfg = TileConfig(128, 128, 128)
    for pol in ALL_POLICIES:
        assert costmodel.gemm_time_s(s, cfg, pol, g=costmodel.V5E.lanes) == (
            costmodel.gemm_time_s(s, cfg, pol)
        )


def test_oversubscribed_dp_never_beats_lanes():
    """DP gains nothing from g > lanes: g programs time-share the physical
    slots, so the model must not reward free oversubscription."""
    cfg = TileConfig(128, 128, 128)
    for mnk in [(1024, 1024, 1024), (1152, 1152, 8192), (640, 768, 512)]:
        s = GemmShape(*mnk)
        t8 = costmodel.gemm_time_s(s, cfg, DP, g=8)
        t16 = costmodel.gemm_time_s(s, cfg, DP, g=16)
        assert t16 >= t8 - 1e-12


def test_default_grid_sizes_bracket_lanes():
    assert costmodel.default_grid_sizes() == (4, 8, 16)
    assert costmodel.default_grid_sizes(costmodel.Machine(lanes=1)) == (1, 2)


# ---------------------------------------------------------------------------
# acceptance: dtype changes the selected winner on suite shapes
# ---------------------------------------------------------------------------


def test_dtype_flips_winner_for_suite_shapes():
    """f32 and bf16 ops of the same gemm_suite MNK must be able to select
    different (policy, cfg, g) — the mis-selection bug this PR fixes was
    scoring every dtype as bf16."""
    sel = default_selector()
    flips = 0
    for m, n, k in suite()[:60]:
        f32 = sel.select_op(GemmOp.plain(m, n, k, in_dtype="float32"))
        bf16 = sel.select_op(GemmOp.plain(m, n, k, in_dtype="bfloat16"))
        if (f32.policy, f32.cfg, f32.g) != (bf16.policy, bf16.cfg, bf16.g):
            flips += 1
    assert flips >= 1


def test_f32_and_bf16_ops_key_and_cache_independently():
    sel = default_selector()
    f32 = sel.select_op(GemmOp.plain(1, 64, 2048, in_dtype="float32"))
    bf16 = sel.select_op(GemmOp.plain(1, 64, 2048, in_dtype="bfloat16"))
    assert sel.stats.cache_hits == 0  # distinct fingerprints, both cold
    assert (f32.cfg, f32.g) != (bf16.cfg, bf16.g)  # known flipping shape


# ---------------------------------------------------------------------------
# acceptance: the tuner sweeps g and commits g != 8
# ---------------------------------------------------------------------------


def test_tuner_commits_records_with_non_default_g():
    db = Tuner().tune(suite()[:40])
    gs = {rec.g for rec in db.records.values()}
    assert gs <= set(costmodel.default_grid_sizes())
    assert any(g != LEGACY_GRID for g in gs)


def test_selector_serves_tuned_g():
    sizes = [(64, 64, 64), (1152, 1152, 8192)]
    db = Tuner().tune(sizes)
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    for s in sizes:
        got = sel.select(*s)
        assert got.source == "tuned"
        assert got.g == db.records[s].g


def test_scored_selection_g_comes_from_the_sweep():
    sel = KernelSelector(grid_sizes=(2, 4))
    got = sel.select(640, 768, 512)
    assert got.source == "fallback"
    assert got.g in (2, 4)


def test_tuner_respects_custom_grid_sizes():
    db = Tuner(grid_sizes=(3,)).tune([(256, 256, 256)])
    [rec] = db.records.values()
    assert rec.g == 3


# ---------------------------------------------------------------------------
# acceptance: legacy g-less artifacts load and dispatch identically
# ---------------------------------------------------------------------------


def _strip_g(payload: dict) -> dict:
    for rec in payload["records"].values():
        rec.pop("g", None)
    return payload


def test_legacy_gless_snapshot_loads_with_legacy_grid(tmp_path):
    sizes = [(64, 64, 64), (1152, 1152, 8192)]
    db = Tuner().tune(sizes)
    path = str(tmp_path / "db.json")
    db.save(path)
    payload = _strip_g(json.load(open(path)))
    json.dump(payload, open(path, "w"))

    legacy = TuningDatabase.load(path)
    assert legacy.load_errors == 0
    assert set(legacy.records) == set(db.records)
    for s in sizes:
        assert legacy.records[s].g == LEGACY_GRID  # not dropped, not guessed
        assert legacy.records[s].policy == db.records[s].policy
        assert legacy.records[s].cfg == db.records[s].cfg
    # and dispatch serves exactly the legacy launch configuration
    sel = KernelSelector(sieve=legacy.build_sieve(), db=legacy)
    for s in sizes:
        got = sel.select(*s)
        assert got.source == "tuned" and got.g == LEGACY_GRID


def test_legacy_gless_journal_replays_with_legacy_grid(tmp_path):
    rec, pp = Tuner().tune_size((640, 768, 512))
    line = json.loads(journal_entry(rec, pp))
    line["record"].pop("g")
    path = str(tmp_path / "journal.jsonl")
    with open(path, "w") as f:
        f.write(json.dumps(line) + "\n")
    db = TuningDatabase()
    assert db.replay_journal(path) == 1
    assert db.load_errors == 0
    assert db.records[rec.size].g == LEGACY_GRID


def test_committed_artifact_snapshot_still_loads():
    """The repo's own pre-g tuning_db.json is the real legacy artifact —
    it must keep loading (records parse with g = LEGACY_GRID)."""
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "artifacts", "tuning_db.json")
    if not os.path.exists(path):
        pytest.skip("artifact cache absent")
    db = TuningDatabase.load(path)
    assert db.load_errors == 0
    assert db.records


def test_g_survives_journal_roundtrip(tmp_path):
    rec = TuningRecord(
        size=(8, 128, 256),
        policy="all_sk",
        cfg="128x128x128",
        tflops=1.0,
        runner_up_policy="dp",
        runner_up_tflops=0.5,
        dp_best_tflops=0.5,
        g=4,
    )
    path = str(tmp_path / "journal.jsonl")
    with open(path, "w") as f:
        f.write(journal_entry(rec) + "\n")
    db = TuningDatabase()
    db.replay_journal(path)
    assert db.records[rec.size].g == 4


# ---------------------------------------------------------------------------
# g threads through dispatch to the backend
# ---------------------------------------------------------------------------


def test_dispatch_threads_selected_g_to_backend():
    seen = {}

    def probe_backend(x, w, *, op, policy, cfg, g, bias, operand):
        seen["g"] = g
        return jnp.einsum("gmk,gkn->gmn", x, w).astype(op.out_dtype)

    register_backend("g_probe", probe_backend, overwrite=True)
    sizes = [(16, 128, 64)]
    db = Tuner().tune(sizes)
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    x, w = jnp.ones((16, 64)), jnp.ones((64, 128))
    with gemm_context(selector=sel, backend="g_probe") as ctx:
        gemm(x, w)
    assert ctx.log[0].selection.source == "tuned"
    assert seen["g"] == db.records[(16, 128, 64)].g


def test_forced_g_override_logged_and_dispatched():
    seen = {}

    def probe_backend(x, w, *, op, policy, cfg, g, bias, operand):
        seen["g"] = g
        return jnp.einsum("gmk,gkn->gmn", x, w).astype(op.out_dtype)

    register_backend("g_probe2", probe_backend, overwrite=True)
    x, w = jnp.ones((16, 64)), jnp.ones((64, 128))
    with gemm_context(selector=default_selector(), backend="g_probe2") as ctx:
        gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128), g=5)
    [e] = ctx.log
    assert e.selection.source == "forced"
    assert e.selection.g == 5 and seen["g"] == 5


def test_forced_policy_cfg_without_g_uses_legacy_grid():
    """(policy, cfg)-forced callers predate the g axis: their launches must
    stay bit-identical, i.e. the legacy g=8."""
    x, w = jnp.ones((16, 64)), jnp.ones((64, 128))
    with gemm_context(selector=default_selector()) as ctx:
        gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128))
    assert ctx.log[0].selection.g == LEGACY_GRID


def test_pallas_interpret_runs_selected_g():
    """End-to-end: a non-default tuned g reaches the Pallas kernel and the
    result still matches the oracle."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(r.normal(size=(64, 128)), jnp.float32)
    with gemm_context(selector=default_selector(), backend="pallas_interpret"):
        got = gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128), g=3)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.dot(x, w)), rtol=1e-4, atol=1e-4
    )
