"""Differential numerics sweep for the quantized-weight serving path.

Every fused int8-weight kernel is compared against the *dequantize-then-
``jnp.dot``* reference — the dense f32 GEMM on ``QuantizedTensor.
dequantize()`` — across all 8 policies x grid sizes x operand-dtype modes
x epilogues, extending the ``test_policy_degenerate`` pattern to the
quantized path. The kernels compute ``(A @ V) * s`` where the reference
computes ``A @ (V * s)``: exact algebra for per-output-channel scales, so
the only divergence is floating-point reassociation (plus bf16 MAC
rounding when activations are bf16).

Tolerances (documented per dtype mode, asserted below):

  ==================  =====================================  ==============
  mode                what runs in the kernel                rtol / atol
  ==================  =====================================  ==============
  f32                 dense f32 x f32, f32 accumulation      1e-4 / 1e-4
  int8 (f32 acts)     f32 x int8 widened to f32, f32 acc     1e-4 / 1e-4
  bf16                dense bf16 x bf16, f32 accumulation    2e-2 / 2e-2
  int8 (bf16 acts)    bf16 acts widened to f32 x int8        2e-2 / 2e-2
  ==================  =====================================  ==============

f32-act modes see only reassociation error; bf16-act modes inherit the
bf16 input-rounding noise of the dense bf16 path (the quantized kernel is
never *worse* than dense bf16, because the int8->f32 weight conversion is
exact).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gemm import gemm, gemm_context, gemm_grouped
from repro.core.op import Epilogue, GemmOp
from repro.core.policies import ALL_POLICIES, ALL_SK, DP, HYBRIDS, TileConfig
from repro.core.quant import quantize_activations, quantize_weight
from repro.core.selector import KernelSelector, default_selector
from repro.core.tuner import Tuner, TuningDatabase
from repro.kernels.dp import ops as dp_ops
from repro.kernels.splitk import ops as splitk_ops
from repro.kernels.streamk import ops as sk_ops

CFG = TileConfig(8, 128, 128)
ODD = (17, 200, 300)  # ragged on every dim: padding on M, N and K

#: the dtype-mode axis of the sweep: (activation dtype, weights quantized?)
MODES = {
    "f32": (jnp.float32, False),
    "bf16": (jnp.bfloat16, False),
    "int8": (jnp.float32, True),
    "int8_bf16act": (jnp.bfloat16, True),
}

#: documented per-dtype-mode tolerances (see module docstring)
TOLS = {
    "f32": dict(rtol=1e-4, atol=1e-4),
    "bf16": dict(rtol=2e-2, atol=2e-2),
    "int8": dict(rtol=1e-4, atol=1e-4),
    "int8_bf16act": dict(rtol=2e-2, atol=2e-2),
}


def _problem(m, n, k, mode, seed=0):
    """(a, b_operand, scale, reference-weight) for one dtype mode: the
    kernel runs (a, b_operand, scale); the oracle contracts a against the
    reference weight (the dequantized master for quantized modes)."""
    act_dtype, quantized = MODES[mode]
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)), act_dtype)
    w = jnp.asarray(r.normal(size=(k, n)), jnp.float32)
    if quantized:
        q = quantize_weight(w)
        return a, q.values, q.scales, q.dequantize()
    w = w.astype(act_dtype)
    return a, w, None, w


def _oracle(a, w_ref, epilogue=None, bias=None, operand=None):
    acc = jnp.dot(
        a.astype(jnp.float32),
        w_ref.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if epilogue is not None:
        acc = epilogue.apply(acc, bias=bias, operand=operand)
    return acc


# ---------------------------------------------------------------------------
# all policies x grid sizes x dtype modes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mode", list(MODES), ids=list(MODES))
@pytest.mark.parametrize("g", [4, 16])
@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_all_policies_grids_dtypes_match_dequant_reference(policy, g, mode):
    m, n, k = ODD
    a, b, scale, w_ref = _problem(m, n, k, mode)
    want = _oracle(a, w_ref)
    got = sk_ops.gemm(
        a,
        b,
        policy=policy,
        cfg=CFG,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        scale=scale,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **TOLS[mode])


# ---------------------------------------------------------------------------
# dequant composes in front of the bias/activation/binary epilogues
# ---------------------------------------------------------------------------

EPILOGUES = [
    Epilogue(bias=True, activation="gelu"),
    Epilogue(binary="mul_silu"),
    Epilogue(bias=True, activation="silu", binary="add"),
]


@pytest.mark.parametrize("g", [4, 16])
@pytest.mark.parametrize("epi", EPILOGUES, ids=lambda e: e.name)
@pytest.mark.parametrize(
    "policy", [DP, ALL_SK, HYBRIDS[0], HYBRIDS[3]], ids=lambda p: p.name
)
def test_int8_dequant_composes_with_epilogues(policy, epi, g):
    m, n, k = 24, 384, 640  # 3x3 tiles over g=4: quantized remainder wave
    a, b, scale, w_ref = _problem(m, n, k, "int8", seed=2)
    r = np.random.default_rng(3)
    bias = jnp.asarray(r.normal(size=(n,)), jnp.float32) if epi.bias else None
    operand = (
        jnp.asarray(r.normal(size=(m, n)), jnp.float32)
        if epi.binary != "none"
        else None
    )
    want = _oracle(a, w_ref, epilogue=epi, bias=bias, operand=operand)
    got = sk_ops.gemm(
        a,
        b,
        policy=policy,
        cfg=CFG,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        epilogue=epi,
        bias=bias,
        operand=operand,
        scale=scale,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **TOLS["int8"]
    )


# ---------------------------------------------------------------------------
# the dp / splitk baseline families fuse the same dequant stage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g", [0, 3, 16])
def test_dp_ops_int8_scale_matches_reference(g):
    a, b, scale, w_ref = _problem(*ODD, "int8", seed=4)
    got = dp_ops.gemm(
        a, b, cfg=CFG, g=g, interpret=True, out_dtype=jnp.float32, scale=scale
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a, w_ref)), **TOLS["int8"]
    )


@pytest.mark.parametrize("g", [0, 3, 8])
def test_splitk_ops_int8_scale_matches_reference(g):
    a, b, scale, w_ref = _problem(24, 256, 512, "int8", seed=5)
    got = splitk_ops.gemm(
        a, b, cfg=CFG, s=2, g=g, interpret=True, out_dtype=jnp.float32,
        scale=scale,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a, w_ref)), **TOLS["int8"]
    )


# ---------------------------------------------------------------------------
# dispatch layer: QuantizedTensor weights through both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_dispatch_quantized_weight_matches_reference(backend):
    r = np.random.default_rng(7)
    x = jnp.asarray(r.normal(size=(2, 9, 96)), jnp.float32)
    w = jnp.asarray(r.normal(size=(96, 64)), jnp.float32)
    q = quantize_weight(w)
    want = jnp.einsum("bsk,kn->bsn", x, q.dequantize())
    with gemm_context(backend=backend) as ctx:
        got = gemm(x, q, tag="q")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **TOLS["int8"]
    )
    op = ctx.log[-1].op
    # the mixed a*w fingerprint keys the quantized op away from the dense
    # f32 op at the same MNK (own tuning records, own Bloom pruning)
    assert op.in_dtype == "float32*int8"
    assert op.key != (18, 64, 96)


def test_dispatch_grouped_quantized_with_epilogue():
    r = np.random.default_rng(8)
    x = jnp.asarray(r.normal(size=(3, 4, 32)), jnp.float32)
    w = jnp.asarray(r.normal(size=(3, 32, 48)), jnp.float32)
    q = quantize_weight(w)
    want = jax.nn.gelu(jnp.einsum("gmk,gkn->gmn", x, q.dequantize()))
    with gemm_context(backend="xla") as ctx:
        got = gemm_grouped(x, q, epilogue="gelu")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **TOLS["int8"]
    )
    assert ctx.log[-1].op.in_dtype == "float32*int8"
    assert ctx.log[-1].op.g == 3


def test_dispatch_backends_agree_on_quantized_weight():
    """xla and pallas_interpret must implement the same dequant contract."""
    r = np.random.default_rng(9)
    x = jnp.asarray(r.normal(size=(5, 40)), jnp.float32)
    q = quantize_weight(jnp.asarray(r.normal(size=(40, 56)), jnp.float32))
    outs = {}
    for backend in ("xla", "pallas_interpret"):
        with gemm_context(backend=backend):
            outs[backend] = np.asarray(gemm(x, q))
    np.testing.assert_allclose(
        outs["xla"], outs["pallas_interpret"], rtol=1e-5, atol=1e-5
    )


# ---------------------------------------------------------------------------
# selection + tuning: quantized fingerprints are first-class tuning targets
# ---------------------------------------------------------------------------


def _quant_op(m, n, k, in_dtype="float32*int8"):
    return GemmOp.plain(m, n, k, in_dtype=in_dtype, out_dtype="float32")


def test_some_suite_shape_selects_differently_for_int8_weight():
    """Acceptance: the cost model scores the 1-byte B operand for real —
    at least one suite shape must pick a different (policy, cfg, g) for
    the int8-weight profile than for f32 at the same MNK."""
    from repro.configs.gemm_suite import suite

    sel = default_selector()
    diverged = 0
    for m, n, k in suite()[::12][:80]:
        s_f = sel.select_op(GemmOp.plain(m, n, k))
        s_q = sel.select_op(_quant_op(m, n, k))
        if (s_f.policy, s_f.cfg, s_f.g) != (s_q.policy, s_q.cfg, s_q.g):
            diverged += 1
    assert diverged > 0


def test_serving_stack_quantized_vs_dequantized_dense_model():
    """End-to-end model-level differential: an LM with QuantizedTensor
    weight leaves must decode within f32-reassociation tolerance of the
    SAME model holding the dequantized dense weights — the fused in-kernel
    dequant is the only difference between the two parameter trees."""
    from conftest import tiny

    from repro.core.quant import QuantizedTensor
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    qparams, n_quant, _ = model.quantize_weights(params)
    assert n_quant > 0
    dense = jax.tree.map(
        lambda leaf: leaf.dequantize(cfg.dtype) if isinstance(leaf, QuantizedTensor) else leaf,
        qparams,
        is_leaf=lambda x: isinstance(x, QuantizedTensor),
    )

    tokens = jnp.asarray([[5, 9, 2, 7, 1, 3]], jnp.int32)
    lq, cache_q = model.prefill(qparams, tokens, max_seq=16)
    ld, cache_d = model.prefill(dense, tokens, max_seq=16)
    np.testing.assert_allclose(
        np.asarray(lq), np.asarray(ld), rtol=1e-4, atol=1e-4
    )
    step = jnp.asarray([[int(jnp.argmax(lq[0, -1]))]], jnp.int32)
    pos = jnp.asarray([tokens.shape[1]])
    lq2, _ = model.decode_step(qparams, cache_q, step, pos)
    ld2, _ = model.decode_step(dense, cache_d, step, pos)
    np.testing.assert_allclose(
        np.asarray(lq2), np.asarray(ld2), rtol=1e-4, atol=1e-4
    )

    # and the engine serves the quantized tree, dispatching every decode
    # projection under the mixed float32*int8 fingerprint
    with gemm_context(selector=default_selector()):
        eng = ServeEngine(
            model, qparams, ServeConfig(n_slots=2, max_seq=32, eos=-1)
        )
        eng.submit(np.array([5, 9, 2, 7], np.int32), max_new_tokens=3)
        done = eng.run()
    assert len(done) == 1 and len(done[0].out_tokens) == 3
    quant_tags = {
        e.tag for e in eng.selection_log if e.op.in_dtype == "float32*int8"
    }
    assert {"attn.q", "mlp.in", "lm_head"} <= quant_tags


# ---------------------------------------------------------------------------
# the low-precision ladder below int8-weight: int8 x int8 and packed int4
# ---------------------------------------------------------------------------

#: ladder rungs: both dequantize exactly in f32 (int8->f32 and the rank-1
#: rescale are exact; the int8 x int8 MAC is exact integer arithmetic), so
#: the only divergence vs the dequantize-then-dot oracle is reassociation.
LADDER = ("int8x8", "int4")
LTOLS = {
    "int8x8": dict(rtol=1e-4, atol=1e-4),
    "int4": dict(rtol=1e-4, atol=1e-4),
}


def _ladder_problem(m, n, k, rung, seed=0):
    """(a_kernel, b, scale, scale_a, a_ref, w_ref, b_bits): the kernel runs
    the first four; the oracle contracts a_ref @ w_ref in dense f32 (both
    are the dequantized masters, so the oracle IS dequantize-then-dot)."""
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(k, n)), jnp.float32)
    if rung == "int4":
        q = quantize_weight(w, bits=4)
        return a, q.values, q.scales, None, a, q.dequantize(), 4
    q = quantize_weight(w)
    aq, sa = quantize_activations(a)
    a_ref = aq.astype(jnp.float32) * sa[:, None]
    return aq, q.values, q.scales, sa, a_ref, q.dequantize(), 8


@pytest.mark.parametrize("rung", LADDER)
@pytest.mark.parametrize("g", [4, 16])
@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_ladder_all_policies_grids_match_dequant_reference(policy, g, rung):
    m, n, k = ODD
    a, b, scale, scale_a, a_ref, w_ref, b_bits = _ladder_problem(m, n, k, rung)
    want = _oracle(a_ref, w_ref)
    got = sk_ops.gemm(
        a,
        b,
        policy=policy,
        cfg=CFG,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        scale=scale,
        scale_a=scale_a,
        b_bits=b_bits,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **LTOLS[rung])


@pytest.mark.parametrize("rung", LADDER)
@pytest.mark.parametrize("epi", EPILOGUES, ids=lambda e: e.name)
@pytest.mark.parametrize(
    "policy", [DP, ALL_SK, HYBRIDS[0]], ids=lambda p: p.name
)
def test_ladder_composes_with_epilogues(policy, epi, rung):
    """Rescale order: the rank-1 ``s_a (x) s_b`` applies on the f32
    accumulator BEFORE bias/activation/binary — same contract as the
    int8-weight rung's per-channel scale."""
    m, n, k = 24, 384, 640
    a, b, scale, scale_a, a_ref, w_ref, b_bits = _ladder_problem(
        m, n, k, rung, seed=11
    )
    r = np.random.default_rng(12)
    bias = jnp.asarray(r.normal(size=(n,)), jnp.float32) if epi.bias else None
    operand = (
        jnp.asarray(r.normal(size=(m, n)), jnp.float32)
        if epi.binary != "none"
        else None
    )
    want = _oracle(a_ref, w_ref, epilogue=epi, bias=bias, operand=operand)
    got = sk_ops.gemm(
        a,
        b,
        policy=policy,
        cfg=CFG,
        g=4,
        interpret=True,
        out_dtype=jnp.float32,
        epilogue=epi,
        bias=bias,
        operand=operand,
        scale=scale,
        scale_a=scale_a,
        b_bits=b_bits,
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **LTOLS[rung])


@pytest.mark.parametrize("rung", LADDER)
@pytest.mark.parametrize("g", [0, 3])
def test_dp_ops_ladder_matches_reference(g, rung):
    a, b, scale, scale_a, a_ref, w_ref, b_bits = _ladder_problem(
        *ODD, rung, seed=13
    )
    got = dp_ops.gemm(
        a,
        b,
        cfg=CFG,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        scale=scale,
        scale_a=scale_a,
        b_bits=b_bits,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a_ref, w_ref)), **LTOLS[rung]
    )


@pytest.mark.parametrize("rung", LADDER)
@pytest.mark.parametrize("g", [0, 3])
def test_splitk_ops_ladder_matches_reference(g, rung):
    a, b, scale, scale_a, a_ref, w_ref, b_bits = _ladder_problem(
        24, 256, 512, rung, seed=14
    )
    got = splitk_ops.gemm(
        a,
        b,
        cfg=CFG,
        s=2,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        scale=scale,
        scale_a=scale_a,
        b_bits=b_bits,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a_ref, w_ref)), **LTOLS[rung]
    )


@pytest.mark.parametrize("rung", LADDER)
@pytest.mark.parametrize("policy", [ALL_SK, DP], ids=lambda p: p.name)
def test_grouped_fused_ladder_matches_reference(policy, rung):
    """The fused grouped kernel unpacks/rescales per group: ragged sizes,
    one empty group, per-group (G, M) activation-scale rows."""
    from repro.kernels.streamk.grouped import gemm_grouped_streamk

    n_groups, m_cap, k, n = 3, 16, 96, 128
    sizes = [13, 0, 7]
    r = np.random.default_rng(15)
    a = jnp.asarray(r.normal(size=(n_groups, m_cap, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(n_groups, k, n)), jnp.float32)
    if rung == "int4":
        q = quantize_weight(w, bits=4)
        a_kernel, scale_a, a_ref, b_bits = a, None, a, 4
    else:
        q = quantize_weight(w)
        aq, sa = quantize_activations(a)
        a_kernel, scale_a, b_bits = aq, sa, 8
        a_ref = aq.astype(jnp.float32) * sa[..., None]
    want = jnp.einsum("gmk,gkn->gmn", a_ref, q.dequantize())
    got = gemm_grouped_streamk(
        a_kernel,
        q.values,
        group_sizes=tuple(sizes),
        policy=policy,
        cfg=CFG,
        g=4,
        interpret=True,
        out_dtype=jnp.float32,
        scale=q.scales,
        scale_a=scale_a,
        b_bits=b_bits,
    )
    for i, s in enumerate(sizes):
        np.testing.assert_allclose(
            np.asarray(got[i, :s]), np.asarray(want[i, :s]), **LTOLS[rung]
        )


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_dispatch_int4_weight_fingerprint_and_numerics(backend):
    r = np.random.default_rng(21)
    x = jnp.asarray(r.normal(size=(2, 9, 96)), jnp.float32)
    w = jnp.asarray(r.normal(size=(96, 64)), jnp.float32)
    q = quantize_weight(w, bits=4)
    want = jnp.einsum("bsk,kn->bsn", x, q.dequantize())
    with gemm_context(backend=backend) as ctx:
        got = gemm(x, q, tag="q4")
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **LTOLS["int4"]
    )
    op = ctx.log[-1].op
    assert op.in_dtype == "float32*int4"
    assert op.key[:3] == (18, 64, 96)  # logical K, not the packed row count


@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_dispatch_dynamic_int8_act_fingerprint_and_numerics(backend):
    """act_bits=8 weights quantize the f32 activations on the fly; the op
    fingerprints as int8*int8 (NOT collapsed to plain "int8") and the
    output stays the activations' original float dtype."""
    r = np.random.default_rng(22)
    x = jnp.asarray(r.normal(size=(2, 9, 96)), jnp.float32)
    w = jnp.asarray(r.normal(size=(96, 64)), jnp.float32)
    q = quantize_weight(w, act_bits=8)
    xq, sa = quantize_activations(x)
    want = jnp.einsum(
        "bsk,kn->bsn",
        xq.astype(jnp.float32) * sa[..., None],
        q.dequantize(),
    )
    with gemm_context(backend=backend) as ctx:
        got = gemm(x, q, tag="q88")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), **LTOLS["int8x8"]
    )
    assert ctx.log[-1].op.in_dtype == "int8*int8"


def test_dispatch_grouped_ladder_backends_agree():
    r = np.random.default_rng(23)
    x = jnp.asarray(r.normal(size=(3, 4, 32)), jnp.float32)
    w = jnp.asarray(r.normal(size=(3, 32, 48)), jnp.float32)
    for q in (quantize_weight(w, bits=4), quantize_weight(w, act_bits=8)):
        outs = {}
        for backend in ("xla", "pallas_interpret"):
            with gemm_context(backend=backend):
                outs[backend] = np.asarray(gemm_grouped(x, q))
        np.testing.assert_allclose(
            outs["xla"], outs["pallas_interpret"], rtol=1e-5, atol=1e-5
        )


# ---------------------------------------------------------------------------
# cost model: the integer-dtype bugs (satellite regressions)
# ---------------------------------------------------------------------------


def test_profile_clamps_integer_fallback_store_width():
    """Regression: with no out_dtype the fallback used to score C at
    ``max(a, b)`` = 1 byte for int8*int8, but every kernel stores >= 2-byte
    outputs — low-precision inputs shrink A/B traffic, never the store."""
    from repro.core.costmodel import profile_for

    p = profile_for("int8*int8")
    assert (p.a, p.b) == (1, 1)
    assert p.out == 2  # clamped; max(a, b) would claim 1
    # an explicit out_dtype is still honored verbatim
    assert profile_for("int8*int8", "bfloat16").out == 2
    assert profile_for("int8*int8", "float32").out == 4


def test_int4_scores_half_byte_b_and_flips_selection_vs_int8():
    """Acceptance: packed int4 B traffic is 0.5 bytes/element and that
    halving flips the analytical selection away from the int8-weight
    profile on at least one suite shape."""
    from repro.configs.gemm_suite import suite
    from repro.core.costmodel import profile_for

    assert profile_for("float32*int4").b == 0.5
    assert profile_for("float32*int4").a == 4
    sel = default_selector()
    diverged = 0
    for m, n, k in suite()[::12][:80]:
        s8 = sel.select_op(
            GemmOp.plain(m, n, k, in_dtype="float32*int8", out_dtype="float32")
        )
        s4 = sel.select_op(
            GemmOp.plain(m, n, k, in_dtype="float32*int4", out_dtype="float32")
        )
        if (s8.policy, s8.cfg, s8.g) != (s4.policy, s4.cfg, s4.g):
            diverged += 1
    assert diverged > 0


@pytest.mark.parametrize(
    "in_dtype", ["float32*int8", "int8*int8", "float32*int4"]
)
def test_quantized_fingerprint_tunes_journals_and_warm_starts(
    tmp_path, in_dtype
):
    """A mixed-dtype op tunes under its own key, journals, and replays to
    an exact database hit — the serve-path warm-start contract. Covers
    every ladder rung: int8-weight, int8*int8 and packed int4."""
    journal = str(tmp_path / "j.jsonl")
    op = _quant_op(64, 512, 256, in_dtype)
    db = Tuner().tune([op], journal=journal)
    assert op.key in db.records
    # measured at the real widths: the record differs from the same-MNK
    # f32 sweep in at least one of (policy, cfg, g, tflops)
    f32_rec = Tuner().tune_size((64, 512, 256))[0]
    q_rec = db.records[op.key]
    assert (q_rec.policy, q_rec.cfg, q_rec.g, q_rec.tflops) != (
        f32_rec.policy,
        f32_rec.cfg,
        f32_rec.g,
        f32_rec.tflops,
    )
    # warm-start replay: a fresh selector resolves the quantized op from
    # the replayed journal as a tuned hit, not a fallback
    warm = TuningDatabase()
    warm.replay_journal(journal)
    sel = KernelSelector(sieve=warm.build_sieve(), db=warm)
    s = sel.select_op(op)
    assert s.source == "tuned"
    assert (s.policy.name, s.cfg.name, s.g) == (q_rec.policy, q_rec.cfg, q_rec.g)
