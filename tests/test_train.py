"""Training-loop tests: convergence, microbatch equivalence, bitwise
checkpoint resume, straggler monitor, gradient compression."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.data import SyntheticLMData
from repro.dist.compression import ErrorFeedback, compress_decompress, quantize_int8
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.optim import make_optimizer, warmup_cosine, constant
from repro.train import (
    StragglerMonitor,
    Trainer,
    TrainerConfig,
    init_train_state,
    make_train_step,
    train_gemm_div,
)


def _setup(arch="granite-8b", seed=0):
    cfg = tiny(arch)
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(seed))
    return cfg, model, params


def test_loss_decreases():
    cfg, model, params = _setup()
    opt = make_optimizer("adamw", warmup_cosine(3e-3, 5, 40))
    data = SyntheticLMData(cfg, batch=8, seq_len=64, seed=1)
    t = Trainer(model, opt, data, TrainerConfig(total_steps=25, log_every=100))
    t.fit(init_train_state(model, opt, params))
    assert t.history[-1] < t.history[0] * 0.9


def test_microbatch_equivalence():
    """grad accumulation over 4 microbatches == single big batch (same data)."""
    cfg, model, params = _setup()
    opt = make_optimizer("sgd", constant(1e-2), momentum=0.0)
    data = SyntheticLMData(cfg, batch=8, seq_len=32, seed=2)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}

    s1 = init_train_state(model, opt, params)
    step1 = make_train_step(model, opt, microbatches=1)
    out1, m1 = step1(s1, batch)

    params2 = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    s2 = init_train_state(model, opt, params2)
    step4 = make_train_step(model, opt, microbatches=4)
    out4, m4 = step4(s2, batch)

    # losses may differ (per-microbatch means) but params must be close:
    # with sum-preserving masks each microbatch has identical token counts
    for a, b in zip(jax.tree.leaves(out1["params"]), jax.tree.leaves(out4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_checkpoint_resume_bitwise(tmp_path):
    cfg, model, _ = _setup()
    opt = make_optimizer("adamw", warmup_cosine(1e-3, 2, 30))
    fresh = lambda: materialize_tree(model.param_specs(), jax.random.PRNGKey(0))

    d_ref = os.path.join(tmp_path, "ref")
    data = SyntheticLMData(cfg, batch=4, seq_len=32, seed=3)
    t_ref = Trainer(
        model, opt, data,
        TrainerConfig(total_steps=12, ckpt_dir=d_ref, ckpt_every=100, log_every=100),
    )
    t_ref.fit(init_train_state(model, opt, fresh()))

    d = os.path.join(tmp_path, "crash")
    crash = {"armed": True}

    def boom(step):
        if step == 7 and crash["armed"]:
            crash["armed"] = False
            raise RuntimeError("injected")

    data2 = SyntheticLMData(cfg, batch=4, seq_len=32, seed=3)
    t1 = Trainer(
        model, opt, data2,
        TrainerConfig(total_steps=12, ckpt_dir=d, ckpt_every=5, log_every=100, async_ckpt=False),
        failure_injector=boom,
    )
    with pytest.raises(RuntimeError):
        t1.fit(init_train_state(model, opt, fresh()))

    data3 = SyntheticLMData(cfg, batch=4, seq_len=32, seed=3)
    t2 = Trainer(
        model, opt, data3,
        TrainerConfig(total_steps=12, ckpt_dir=d, ckpt_every=5, log_every=100, async_ckpt=False),
    )
    t2.fit(init_train_state(model, opt, fresh()))
    # the post-resume trajectory must be bitwise identical to uninterrupted
    assert t2.history[-5:] == t_ref.history[-5:]


def test_straggler_monitor():
    m = StragglerMonitor(k=3.0)
    for _ in range(20):
        m.observe(0.1)
    assert m.flagged == 0
    assert m.observe(10.0) is True
    assert m.flagged == 1


def test_quantize_roundtrip_error_bounded():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(256, 128)), jnp.float32)
    q, s = quantize_int8(x)
    xr = q.astype(jnp.float32) * s
    max_err = float(jnp.max(jnp.abs(x - xr)))
    assert max_err <= float(s) * 0.5 + 1e-9


def test_error_feedback_reduces_bias():
    """With error feedback, the accumulated applied updates converge to the
    accumulated true gradient (residual stays bounded)."""
    r = np.random.default_rng(1)
    g = jnp.asarray(r.normal(size=(64, 64)), jnp.float32) * 1e-3
    res = jnp.zeros_like(g)
    applied = jnp.zeros_like(g)
    for _ in range(50):
        ghat, res = compress_decompress(g + res)
        applied += ghat
    total_true = g * 50
    rel = float(jnp.linalg.norm(applied - total_true) / jnp.linalg.norm(total_true))
    assert rel < 0.05


def test_grad_compression_training_still_converges():
    cfg, model, params = _setup()
    opt = make_optimizer("adamw", warmup_cosine(3e-3, 5, 40))
    data = SyntheticLMData(cfg, batch=8, seq_len=64, seed=1)
    t = Trainer(
        model, opt, data,
        TrainerConfig(total_steps=25, log_every=100, grad_compression=True),
    )
    t.fit(init_train_state(model, opt, params, grad_compression=True))
    assert t.history[-1] < t.history[0] * 0.9


# -- per-array-aware train divisors (the serve_gemm_div gap, train side) -----


class _StubPlan:
    """Duck-typed stand-in for ShardingPlan: train_gemm_div only touches
    gemm_div() and demoted_dims()."""

    def __init__(self, offenders=(), div=None):
        self._off = list(offenders)
        self._div = dict(div or {"batch": 2, "model": 4})

    def gemm_div(self):
        return dict(self._div)

    def demoted_dims(self, specs, mesh_axis="model"):
        assert mesh_axis == "model"
        return list(self._off)


class _StubModel:
    def param_specs(self):
        return {}


def test_train_gemm_div_threads_mesh_table_when_arrays_divide():
    div = train_gemm_div(_StubModel(), batch=4, plan=_StubPlan())
    assert div == {"batch": 2, "model": 4}


def test_train_gemm_div_demotes_model_on_offending_weight_dims():
    """Regression: the trainer used to thread the mesh-level
    ``plan.gemm_div()`` verbatim, so an odd vocab on a model=4 mesh
    fingerprinted quarter-shapes the kernels never executed. The per-array
    probe must drop the model divisor to 1 when any weight dim fails the
    plan's own divisibility solver."""
    offenders = [((2049, 64), "model", None, 0)]
    div = train_gemm_div(
        _StubModel(), batch=4, plan=_StubPlan(offenders=offenders)
    )
    assert div["model"] == 1
    assert div["batch"] == 2  # batch untouched by the model-axis probe


def test_train_gemm_div_demotes_batch_on_indivisible_global_batch():
    div = train_gemm_div(_StubModel(), batch=5, plan=_StubPlan())
    assert div["batch"] == 1
    assert div["model"] == 4
    # divisible batch keeps the table; batch=None skips the probe
    assert train_gemm_div(_StubModel(), batch=6, plan=_StubPlan())["batch"] == 2
    assert train_gemm_div(_StubModel(), plan=_StubPlan())["batch"] == 2


def test_train_gemm_div_no_plan_is_empty():
    assert train_gemm_div(_StubModel()) == {}


def test_trainer_defaults_div_from_ambient_probe(monkeypatch):
    """Trainer() without an explicit div runs the probe (a no-op {} -> None
    when no plan is installed) instead of silently fingerprinting global
    shapes under an active plan."""
    cfg, model, params = _setup()
    opt = make_optimizer("sgd", constant(1e-2), momentum=0.0)
    data = SyntheticLMData(cfg, batch=4, seq_len=16, seed=3)
    t = Trainer(model, opt, data, TrainerConfig(total_steps=1), jit=False)
    assert t.div is None  # no ambient plan -> unsharded fingerprints

    import repro.train.trainer as trainer_mod

    monkeypatch.setattr(
        trainer_mod,
        "train_gemm_div",
        lambda m, batch=None, plan=None: {"batch": 1, "model": 1},
    )
    t2 = Trainer(model, opt, data, TrainerConfig(total_steps=1), jit=False)
    assert t2.div == {"batch": 1, "model": 1}
