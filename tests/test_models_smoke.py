"""Required per-architecture smoke tests: reduced config of the same family,
one forward/train step on CPU, asserting output shapes + no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny
from repro.configs import get_config, list_archs
from repro.dist.sharding import materialize_tree
from repro.models import applicable_shapes, build_model

ARCHS = list_archs()


def test_all_ten_archs_registered():
    assert len(ARCHS) == 10


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    # full configs instantiate (metadata only) and expose the assigned dims
    assigned = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "granite-8b": (36, 4096, 32, 8, 14336, 49152),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "mamba2-1.3b": (48, 2048, 0, 0, 0, 50280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    }[arch]
    got = (
        cfg.n_layers,
        cfg.d_model,
        cfg.n_heads,
        cfg.n_kv_heads,
        cfg.d_ff,
        cfg.vocab_size,
    )
    assert got == assigned


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = tiny(arch)
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = make_batch(cfg, b, s)

    if cfg.family == "encdec":
        logits, aux = jax.jit(model.forward)(params, batch["frames"], batch["tokens"])
    else:
        kw = {}
        if cfg.family == "vlm":
            kw["patch_embeds"] = batch["patch_embeds"]
        logits, aux = jax.jit(lambda p, t: model.forward(p, t, **kw))(
            params, batch["tokens"]
        )
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss))

    grads = jax.jit(jax.grad(lambda p: model.loss_fn(p, batch)[0]))(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_and_counts(arch):
    cfg = tiny(arch)
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    n_actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    n_predicted = cfg.param_count()
    # analytic count matches instantiated tree
    assert abs(n_actual - n_predicted) / n_predicted < 1e-6


def test_full_param_counts_match_names():
    expected = {
        "llava-next-34b": 34.4e9,
        "olmoe-1b-7b": 6.9e9,
        "qwen3-moe-235b-a22b": 232e9,
        "mistral-large-123b": 123e9,
        "gemma3-27b": 28e9,
        "granite-8b": 8.3e9,
        "nemotron-4-15b": 15.6e9,
        "mamba2-1.3b": 1.3e9,
        "whisper-large-v3": 1.5e9,
        "zamba2-1.2b": 1.1e9,
    }
    for arch, want in expected.items():
        got = get_config(arch).param_count()
        assert abs(got - want) / want < 0.15, (arch, got, want)


def test_applicable_shapes_rules():
    """long_500k only for sub-quadratic archs (DESIGN.md §Arch-applicability)."""
    long_ok = {"mamba2-1.3b", "zamba2-1.2b", "gemma3-27b"}
    for arch in ARCHS:
        names = {s.name for s in applicable_shapes(get_config(arch))}
        if arch in long_ok:
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= names


def test_total_cell_count_is_40():
    """10 archs x their applicable shape sets must give exactly the assigned
    40 cells (37 applicable + 3 documented long_500k skips... the assignment
    counts 40 nominal cells; we lower 33 + 7 skips? No: 10*4=40 nominal,
    7 skipped long_500k -> 33 lowered)."""
    total = sum(len(applicable_shapes(get_config(a))) for a in ARCHS)
    assert total == 33  # 40 nominal cells minus 7 documented long_500k skips
