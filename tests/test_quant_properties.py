"""Property-based coverage (via tests/hypothesis_compat) for the quantized
serving path and the tuning-journal format:

  * quantize -> dequantize roundtrip error bounds: round-to-nearest
    symmetric per-output-channel quantization reconstructs within
    ``scale / 2`` elementwise (``scale = amax / 127`` per channel);
  * scale-shape validation: ``QuantizedTensor`` rejects scales that do not
    drop exactly the contraction axis;
  * ``TuningRecord`` journal encode/decode roundtrip, including the
    quantized-dtype op keys (``"<a>*<w>"`` in_dtype forms) and the hybrid
    ``(wall, version)`` commit stamp — with legacy stamp-less / g-less
    lines still parsing unchanged.

Deterministic spot-checks of each invariant run even without hypothesis
installed (the property tests then skip via the compat shim).
"""

import dataclasses
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quant import (
    QuantizedTensor,
    pack_int4,
    quantize_lm_params,
    quantize_weight,
    unpack_int4,
)
from repro.core.tuner import (
    LEGACY_GRID,
    TuningRecord,
    journal_entry,
    parse_journal_line,
)

from tests.hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# roundtrip error bounds
# ---------------------------------------------------------------------------


def _assert_roundtrip_bound(w: np.ndarray):
    q = quantize_weight(jnp.asarray(w, jnp.float32))
    err = np.abs(np.asarray(q.dequantize()) - w)
    # round-to-nearest: |x - s*round(x/s)| <= s/2 per element, channelwise
    bound = np.asarray(q.scales)[..., None, :] / 2.0
    assert np.all(err <= bound + 1e-7), (err.max(), bound.max())
    assert np.asarray(q.values).dtype == np.int8
    assert np.abs(np.asarray(q.values)).max() <= 127


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=9),
    st.integers(min_value=1, max_value=9),
    st.floats(min_value=1e-3, max_value=1e3),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_roundtrip_error_bound_property(k, n, amp, seed):
    r = np.random.default_rng(seed)
    _assert_roundtrip_bound(amp * r.normal(size=(k, n)))


def test_roundtrip_error_bound_spot():
    r = np.random.default_rng(0)
    _assert_roundtrip_bound(r.normal(size=(64, 48)))
    _assert_roundtrip_bound(1e-4 * r.normal(size=(8, 8)))  # tiny magnitudes
    _assert_roundtrip_bound(r.normal(size=(3, 16, 8)))  # stacked (G, K, N)


def _assert_roundtrip_bound_int4(w: np.ndarray):
    q = quantize_weight(jnp.asarray(w, jnp.float32), bits=4)
    err = np.abs(np.asarray(q.dequantize()) - w)
    # int4 codes span +-7: scale = amax / 7, same round-to-nearest bound
    bound = np.asarray(q.scales)[..., None, :] / 2.0
    assert np.all(err <= bound + 1e-7), (err.max(), bound.max())
    assert q.bits == 4
    assert q.dtype_name == "int4"
    # stored packed: ceil(k/2) rows of two nibbles each
    assert q.values.shape[-2] == (w.shape[-2] + 1) // 2
    assert q.shape == w.shape  # logical shape reports the unpacked K


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=11),
    st.integers(min_value=1, max_value=9),
    st.floats(min_value=1e-3, max_value=1e3),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_int4_roundtrip_error_bound_property(k, n, amp, seed):
    r = np.random.default_rng(seed)
    _assert_roundtrip_bound_int4(amp * r.normal(size=(k, n)))


def test_int4_roundtrip_error_bound_spot():
    r = np.random.default_rng(1)
    _assert_roundtrip_bound_int4(r.normal(size=(63, 48)))  # odd K: pad row
    _assert_roundtrip_bound_int4(r.normal(size=(3, 16, 8)))  # stacked


@settings(max_examples=30, deadline=None)
@given(
    st.integers(min_value=1, max_value=17),
    st.integers(min_value=1, max_value=9),
    st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pack_unpack_int4_roundtrip_property(k, n, seed):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.integers(-8, 8, size=(k, n)), jnp.int8)
    packed = pack_int4(q)
    assert packed.dtype == jnp.int8
    assert packed.shape == ((k + 1) // 2, n)
    restored = unpack_int4(packed)[:k]
    np.testing.assert_array_equal(np.asarray(restored), np.asarray(q))


def test_pack_unpack_int4_roundtrip_spot():
    # full nibble range survives the sign-extension, odd and even K,
    # stacked (G, K, N) layout included
    q = jnp.asarray(
        np.arange(-8, 8, dtype=np.int8).reshape(16, 1).repeat(3, axis=1)
    )
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(q))), np.asarray(q)
    )
    odd = q[:15]
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(odd))[:15]), np.asarray(odd)
    )
    r = np.random.default_rng(2)
    g = jnp.asarray(r.integers(-8, 8, size=(4, 10, 6)), jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(unpack_int4(pack_int4(g))), np.asarray(g)
    )


def test_roundtrip_zero_and_constant_channels():
    # all-zero channels must not divide by zero; constant channels land
    # exactly on a code point (amax -> code +-127)
    w = np.zeros((16, 4), np.float32)
    w[:, 1] = 2.5
    w[:, 2] = -1.25
    q = quantize_weight(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(q.dequantize()), w, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# scale-shape validation
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=1, max_value=6),
    st.integers(min_value=0, max_value=3),
)
def test_scale_shape_validation_property(k, n, extra):
    values = jnp.zeros((k, n), jnp.int8)
    good = jnp.ones((n,), jnp.float32)
    QuantizedTensor(values, good)  # the contract shape constructs
    bad_shape = (n + extra + 1,)
    with pytest.raises(ValueError):
        QuantizedTensor(values, jnp.ones(bad_shape, jnp.float32))


def test_scale_shape_validation_spot():
    values = jnp.zeros((4, 32, 8), jnp.int8)
    QuantizedTensor(values, jnp.ones((4, 8), jnp.float32))
    for bad in ((8,), (4, 32), (4, 8, 1), (32, 8)):
        with pytest.raises(ValueError, match="scale shape"):
            QuantizedTensor(values, jnp.ones(bad, jnp.float32))
    with pytest.raises(ValueError, match="at least 2-D"):
        QuantizedTensor(jnp.zeros((8,), jnp.int8), jnp.ones((8,), jnp.float32))
    with pytest.raises(ValueError, match="contraction axis"):
        quantize_weight(jnp.ones((4, 4), jnp.float32), axis=-1)


def test_quantize_lm_params_converts_only_projection_leaves():
    params = {
        "embed": jnp.ones((32, 8), jnp.float32),
        "layers": {
            "attn": {"wq": jnp.ones((2, 8, 8), jnp.float32)},
            "mlp": {
                "w_in": jnp.ones((2, 8, 16), jnp.float32),
                "w_out": jnp.ones((2, 16, 8), jnp.float32),
            },
            "norm1": {"scale": jnp.ones((8,), jnp.float32)},
            "moe": {"router": jnp.ones((8, 4), jnp.float32)},
        },
    }
    out, n, n_skipped = quantize_lm_params(params)
    assert n == 3
    assert n_skipped == 0
    assert isinstance(out["layers"]["attn"]["wq"], QuantizedTensor)
    assert isinstance(out["layers"]["mlp"]["w_in"], QuantizedTensor)
    # embeddings / norms / routers stay dense
    assert not isinstance(out["embed"], QuantizedTensor)
    assert not isinstance(out["layers"]["norm1"]["scale"], QuantizedTensor)
    assert not isinstance(out["layers"]["moe"]["router"], QuantizedTensor)
    # stacked leaves carry the leading axis into the scales, so lax.scan
    # slices both leaves coherently
    assert out["layers"]["attn"]["wq"].scales.shape == (2, 8)


def test_quantize_lm_params_recurses_sequences():
    """Regression: the walk used to visit only dict nodes, so list/tuple-
    nested blocks (pipeline stages, per-layer lists) were silently served
    dense with n_quantized undercounted and no skip report."""
    params = {
        "blocks": [
            {"attn": {"wq": jnp.ones((8, 8), jnp.float32)}},
            {"mlp": {"w_in": jnp.ones((8, 16), jnp.float32)}},
        ],
        "heads": ({"lm_head": jnp.ones((8, 32), jnp.float32)},),
        "embed": jnp.ones((32, 8), jnp.float32),
    }
    out, n, n_skipped = quantize_lm_params(params)
    assert n == 3
    assert n_skipped == 0
    assert isinstance(out["blocks"][0]["attn"]["wq"], QuantizedTensor)
    assert isinstance(out["blocks"][1]["mlp"]["w_in"], QuantizedTensor)
    assert isinstance(out["heads"][0]["lm_head"], QuantizedTensor)
    assert isinstance(out["blocks"], list) and isinstance(out["heads"], tuple)
    assert not isinstance(out["embed"], QuantizedTensor)


def test_quantize_lm_params_reports_skipped_float_leaves():
    # a named projection that cannot be quantized (ndim < 2) is surfaced
    # as a skip count instead of vanishing into the dense tree
    params = {
        "wq": jnp.ones((8, 8), jnp.float32),
        "layers": [{"w_out": jnp.ones((4,), jnp.float32)}],
    }
    out, n, n_skipped = quantize_lm_params(params)
    assert n == 1
    assert n_skipped == 1
    assert not isinstance(out["layers"][0]["w_out"], QuantizedTensor)


def test_quantize_lm_params_int4_and_dynamic_act_flags():
    params = {"wq": jnp.ones((8, 8), jnp.float32)}
    out, n, _ = quantize_lm_params(params, bits=4, act_bits=8)
    assert n == 1
    q = out["wq"]
    assert q.bits == 4 and q.act_bits == 8
    assert q.values.shape == (4, 8)  # packed along K


# ---------------------------------------------------------------------------
# journal encode/decode roundtrip (quantized-dtype keys + hybrid stamp)
# ---------------------------------------------------------------------------

_DTYPES = ("float32", "bfloat16", "int8", "float16")
_POLICIES = ("dp", "all_sk", "sk1dp", "sk4dp")


def _roundtrip(rec: TuningRecord, per_policy=None):
    parsed, pp = parse_journal_line(journal_entry(rec, per_policy))
    assert parsed == rec
    assert pp == per_policy
    return parsed


@settings(max_examples=40, deadline=None)
@given(
    st.integers(min_value=1, max_value=8192),
    st.integers(min_value=1, max_value=8192),
    st.integers(min_value=1, max_value=8192),
    st.integers(min_value=1, max_value=64),
    st.sampled_from(_DTYPES),
    st.sampled_from(_DTYPES),
    st.sampled_from(_POLICIES),
    st.integers(min_value=0, max_value=10**6),
    st.floats(min_value=0.0, max_value=2e9, allow_nan=False),
)
def test_journal_roundtrip_property(m, n, k, g, a_dt, w_dt, policy, version, wall):
    in_dt = a_dt if a_dt == w_dt else f"{a_dt}*{w_dt}"
    key = (m, n, k, 1, in_dt, a_dt, "none")
    rec = TuningRecord(
        size=key,
        policy=policy,
        cfg="8x128x512",
        tflops=1.5,
        runner_up_policy="dp",
        runner_up_tflops=1.0,
        dp_best_tflops=1.0,
        g=g,
        version=version,
        wall=wall,
    )
    _roundtrip(rec, {"dp": 1.0, policy: 1.5})


def test_journal_roundtrip_quantized_key_spot():
    rec = TuningRecord(
        size=(55, 512, 512, 1, "float32*int8", "float32", "bias+gelu"),
        policy="all_sk",
        cfg="64x128x256",
        tflops=7.1,
        runner_up_policy="dp",
        runner_up_tflops=7.0,
        dp_best_tflops=7.0,
        g=4,
        version=3,
        wall=1.7e9,
    )
    parsed = _roundtrip(rec)
    assert parsed.size[4] == "float32*int8"
    assert parsed.wall == 1.7e9


@pytest.mark.parametrize("in_dt", ["int8*int8", "float32*int4", "bfloat16*int4"])
def test_journal_roundtrip_low_precision_ladder_keys(in_dt):
    """The new ladder rungs journal under their own mixed fingerprints —
    including int8*int8, which must NOT collapse to plain "int8"."""
    rec = TuningRecord(
        size=(96, 256, 1024, 1, in_dt, "float32", "none"),
        policy="sk2dp",
        cfg="8x128x512",
        tflops=3.3,
        runner_up_policy="dp",
        runner_up_tflops=3.0,
        dp_best_tflops=3.0,
        g=8,
        version=1,
        wall=2.0e9,
    )
    parsed = _roundtrip(rec, {"dp": 3.0, "sk2dp": 3.3})
    assert parsed.size[4] == in_dt


def test_legacy_journal_lines_parse_unchanged():
    """Lines written before g / version / wall existed must parse with the
    documented defaults and an unchanged dispatch payload."""
    rec = TuningRecord(
        size=(64, 512, 256),
        policy="sk1dp",
        cfg="256x128x128",
        tflops=2.0,
        runner_up_policy="dp",
        runner_up_tflops=1.5,
        dp_best_tflops=1.5,
        g=7,
        version=9,
        wall=123.0,
    )
    entry = json.loads(journal_entry(rec, {"dp": 1.5}))
    for legacy_field in ("g", "version", "wall"):
        stripped = json.loads(json.dumps(entry))
        del stripped["record"][legacy_field]
        parsed, pp = parse_journal_line(json.dumps(stripped))
        defaults = {"g": LEGACY_GRID, "version": 0, "wall": 0.0}
        assert getattr(parsed, legacy_field) == defaults[legacy_field]
        # every other field roundtrips untouched
        restored = dataclasses.replace(
            parsed, **{legacy_field: getattr(rec, legacy_field)}
        )
        assert restored == rec
        assert pp == {"dp": 1.5}
    # fully legacy line: all three fields absent at once
    for f in ("g", "version", "wall"):
        del entry["record"][f]
    parsed, _ = parse_journal_line(json.dumps(entry))
    assert (parsed.g, parsed.version, parsed.wall) == (LEGACY_GRID, 0, 0.0)
    assert (parsed.policy, parsed.cfg, parsed.tflops) == ("sk1dp", "256x128x128", 2.0)
