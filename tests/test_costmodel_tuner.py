"""Cost model, tuner (ckProfiler analogue) and selector tests."""

import os

import pytest

from repro.core import costmodel
from repro.core.policies import ALL_POLICIES, ALL_SK, DP, HYBRIDS, TileConfig
from repro.core.selector import KernelSelector, default_selector
from repro.core.tuner import Tuner, TuningDatabase
from repro.core.workpart import GemmShape


def test_dp_optimal_on_divisible_big_gemm():
    """No quantization pathology -> Stream-K adds only overhead."""
    s = GemmShape(8192, 8192, 4096)
    dp = costmodel.best_config(s, DP)[1]
    for pol in (ALL_SK, *HYBRIDS):
        assert costmodel.best_config(s, pol)[1] <= dp + 1e-9


def test_streamk_wins_on_quantized_shape():
    """T mod C pathological -> SK-based schedule beats DP (the paper's
    headline mechanism)."""
    s = GemmShape(1152, 1152, 8192)  # 81 tiles over 8 lanes with 512-tiles
    dp = costmodel.best_config(s, DP)[1]
    best_sk = max(costmodel.best_config(s, p)[1] for p in (ALL_SK, *HYBRIDS))
    assert best_sk > dp * 1.05


def test_costmodel_monotone_in_flops():
    t1 = costmodel.gemm_time_s(GemmShape(1024, 1024, 1024), TileConfig(128, 128, 128), DP)
    t2 = costmodel.gemm_time_s(GemmShape(2048, 2048, 2048), TileConfig(128, 128, 128), DP)
    assert t2 > t1


def test_vmem_guard():
    mach = costmodel.Machine(vmem_bytes=100)  # nothing fits
    with pytest.raises(AssertionError):
        costmodel.best_config(GemmShape(256, 256, 256), DP, mach)


def test_tuner_and_db_roundtrip(tmp_path):
    sizes = [(64, 64, 64), (1152, 1152, 8192), (1, 4096, 65536), (8192, 8192, 512)]
    db = Tuner().tune(sizes)
    assert set(db.records) == set(sizes)
    for s, rec in db.records.items():
        assert rec.tflops >= rec.runner_up_tflops > 0
        assert rec.dp_best_tflops > 0
    path = os.path.join(tmp_path, "db.json")
    db.save(path)
    db2 = TuningDatabase.load(path)
    assert db2.records.keys() == db.records.keys()
    for s in sizes:
        assert db2.records[s].policy == db.records[s].policy
        assert db2.per_policy[s] == db.per_policy[s]


def test_selector_paths():
    sizes = [(64, 64, 64), (1152, 1152, 8192), (640, 768, 32768)]
    db = Tuner().tune(sizes)
    sieve = db.build_sieve()
    sel = KernelSelector(sieve=sieve, db=db)

    # tuned hit
    s0 = sel.select(*sizes[0])
    assert s0.source == "tuned"
    # sieve path: drop the db so it must consult the filters
    sel2 = KernelSelector(sieve=sieve, db=None)
    s1 = sel2.select(*sizes[1])
    assert s1.source in ("sieve", "fallback")
    # unknown size -> fallback (with high probability all filters miss)
    s2 = sel2.select(31, 77, 1023)
    assert s2.source in ("fallback", "sieve")
    # caching: same selection object
    assert sel.select(*sizes[0]) is s0


def test_selector_matches_tuner_winner():
    """Selection through the sieve must recover the tuned winner's policy
    for sizes the tuner saw (modulo Bloom false positives, which can only
    ADD candidates, never remove the winner)."""
    sizes = [(1152, 1152, 8192), (8192, 8192, 4096), (1, 64, 16)]
    db = Tuner().tune(sizes)
    sieve = db.build_sieve()
    sel = KernelSelector(sieve=sieve, db=None)
    for s in sizes:
        got = sel.select(*s)
        assert got.policy.name == db.records[s].policy


def test_default_selector_scores_all():
    sel = default_selector()
    out = sel.select(256, 256, 256)
    assert out.source == "fallback"
    assert sel.stats.evals >= len(ALL_POLICIES)
