"""Federated tuning: sharded sweep + merge equivalence, last-writer-wins
semantics, cross-worker database hits after federation, torn-write journal
recovery, Bloom/sieve merge validation, and mesh-local fingerprints.

The multi-device CI lane runs this file under
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the mesh tests
skip themselves on fewer devices so the plain tier-1 run stays green."""

import dataclasses
import json

import jax
import pytest

from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.bloom import BloomFilter
from repro.core.federate import (
    MergeReport,
    apply_journal_db,
    federate_selector,
    merge_databases,
    merge_journal_shards,
    merge_records,
    merge_sieves,
    record_payload,
    selection_table,
)
from repro.core.op import Epilogue, GemmOp
from repro.core.opensieve import OpenSieve
from repro.core.selector import KernelSelector
from repro.core.tuner import (
    Tuner,
    TuningDatabase,
    TuningRecord,
    journal_entry,
    shard_targets,
)
from repro.core.policies import ALL_POLICIES

TARGETS = [
    (64, 512, 256),
    (128, 256, 512),
    (32, 1024, 128),
    (48, 640, 320),
    (256, 256, 256),
    (8, 2048, 512),
    GemmOp.plain(96, 384, 256, in_dtype="bfloat16"),
    GemmOp.plain(16, 1536, 896, in_dtype="bfloat16"),
    GemmOp(64, 256, 128, g=8, kind="grouped"),
    GemmOp(8, 768, 640, g=4, kind="grouped"),
    GemmOp.plain(128, 128, 512, epilogue=Epilogue(activation="gelu")),
    GemmOp.plain(32, 640, 256, epilogue=Epilogue(bias=True, activation="silu")),
]


def _key(t):
    return t.key if isinstance(t, GemmOp) else tuple(t)


def _rec(size=(64, 512, 256), policy="dp", tflops=1.0, version=0, g=8, wall=0.0):
    return TuningRecord(
        size=size,
        policy=policy,
        cfg="128x128x128",
        tflops=tflops,
        runner_up_policy="sk_one_tile",
        runner_up_tflops=tflops * 0.9,
        dp_best_tflops=tflops,
        g=g,
        version=version,
        wall=wall,
    )


# -- sharded sweeps ----------------------------------------------------------


def test_shard_targets_disjoint_cover():
    for n in (1, 2, 3, 4, 5):
        slices = [shard_targets(TARGETS, i, n) for i in range(n)]
        seen = [_key(t) for sl in slices for t in sl]
        assert sorted(map(str, seen)) == sorted(str(_key(t)) for t in TARGETS)
        flat = set()
        for sl in slices:
            keys = {str(_key(t)) for t in sl}
            assert not (flat & keys)  # disjoint
            flat |= keys


def test_shard_targets_validates():
    with pytest.raises(ValueError):
        shard_targets(TARGETS, 2, 2)
    with pytest.raises(ValueError):
        shard_targets(TARGETS, -1, 2)
    with pytest.raises(ValueError):
        shard_targets(TARGETS, 0, 0)


@pytest.mark.parametrize("workers", [2, 4])
def test_sharded_sweep_merge_equals_full_sweep(workers, tmp_path):
    """The acceptance bar: N sharded sweeps, journals merged, must yield a
    database, per-fingerprint Selection, and sieve identical to the
    single-worker full sweep."""
    tuner = Tuner()
    full = tuner.tune(TARGETS)
    full_sieve = full.build_sieve()

    paths = []
    shard_dbs = []
    for i in range(workers):
        p = str(tmp_path / f"shard{i}.jsonl")
        shard_dbs.append(tuner.tune(TARGETS, shard=(i, workers), journal=p))
        paths.append(p)
    merged, report = merge_journal_shards(paths)

    # records identical modulo the producers' local commit clocks
    assert set(merged.records) == set(full.records)
    for key in full.records:
        assert record_payload(merged.records[key]) == record_payload(
            full.records[key]
        )
    assert report.conflicts == 0 and report.load_errors == 0
    assert report.examined == len(TARGETS)

    # per-fingerprint Selection (policy, cfg, g) identical through a selector
    merged_sieve = merge_sieves([db.build_sieve() for db in shard_dbs])
    sel_m = KernelSelector(sieve=merged_sieve, db=merged)
    sel_f = KernelSelector(sieve=full_sieve, db=full)
    assert selection_table(sel_m, full.records) == selection_table(
        sel_f, full.records
    )
    for t in TARGETS:
        op = t if isinstance(t, GemmOp) else GemmOp.plain(*t)
        a, b = sel_m.select_op(op), sel_f.select_op(op)
        assert (a.policy, a.cfg, a.g, a.source) == (b.policy, b.cfg, b.g, b.source)
        assert a.source == "tuned"

    # sieve union is byte-identical to the full rebuild: every filter's bits
    # (and therefore every elimination decision) matches exactly
    assert merged_sieve.to_bytes() == full_sieve.to_bytes()
    # the Bloom contract survives the merge: winners never pruned
    assert merged_sieve.validate_true_negative_rate(merged.winners()) == 1.0


def test_merged_sieve_generation_past_every_input():
    s1 = OpenSieve(generation=3)
    s2 = OpenSieve(generation=7)
    assert s1.merge(s2).generation == 8
    assert merge_sieves([s1, s2]).generation == 8
    assert merge_sieves([s1, s2], generation=42).generation == 42


def test_merge_sieves_does_not_alias_inputs():
    db = Tuner().tune(TARGETS[:2])
    s = db.build_sieve()
    before = s.to_bytes()
    out = merge_sieves([s])
    out.insert_winner((9, 9, 9), ALL_POLICIES[0])
    assert s.to_bytes() == before  # input untouched by mutating the union


# -- last-writer-wins --------------------------------------------------------


def test_lww_higher_version_wins_either_order():
    old = _rec(policy="dp", tflops=5.0, version=1)
    new = _rec(policy="sk_one_tile", tflops=4.0, version=2)
    for pair in ([old, new], [new, old]):
        db = TuningDatabase()
        report = merge_records(db, ((r, None) for r in pair))
        assert db.records[old.size].policy == "sk_one_tile"
        assert report.superseded == 1
        assert report.conflicts == 0  # versions differ: ordinary supersede


def test_lww_version_tie_counts_conflict_and_is_deterministic():
    a = _rec(policy="dp", tflops=5.0, version=3)
    b = _rec(policy="sk_one_tile", tflops=6.0, version=3)
    winners = []
    for pair in ([a, b], [b, a]):
        db = TuningDatabase()
        report = merge_records(db, ((r, None) for r in pair))
        assert report.conflicts == 1
        winners.append(db.records[a.size].policy)
    assert winners[0] == winners[1] == "sk_one_tile"  # higher tflops breaks tie


def test_identical_payloads_are_not_conflicts():
    a = _rec(version=2)
    b = _rec(version=2)
    db = TuningDatabase()
    report = merge_records(db, ((r, None) for r in (a, b)))
    assert report.conflicts == 0 and report.superseded == 0


def test_merge_databases_report_and_version_clock():
    d1 = TuningDatabase()
    d1.add_record(_rec(size=(1, 2, 3)))
    d2 = TuningDatabase()
    d2.add_record(_rec(size=(4, 5, 6)))
    d2.add_record(_rec(size=(7, 8, 9)))
    out, report = merge_databases([d1, d2])
    assert isinstance(report, MergeReport)
    assert report.sources == 2 and report.examined == 3 and report.merged == 3
    assert len(out.records) == 3
    # merged clock is past every input, so a post-merge local commit wins LWW
    assert out.version >= max(d1.version, d2.version)
    late = _rec(size=(1, 2, 3), policy="sk_one_tile")
    out.add_record(late)
    assert late.version > d1.records[(1, 2, 3)].version


def test_legacy_versionless_journal_lines_always_lose_merge(tmp_path):
    """Regression: replay used to stamp legacy version-less lines with
    fresh clock values, letting a stale pre-federation shard outrank a
    modern record in last-writer-wins. Legacy lines must stay at version 0
    — same as legacy snapshot records — and lose to any stamped record."""
    key = (64, 512, 256)
    legacy_path = tmp_path / "legacy.jsonl"
    lines = []
    for i, policy in enumerate(["dp", "all_sk"]):
        entry = json.loads(journal_entry(_rec(size=key, policy=policy, tflops=99.0)))
        del entry["record"]["version"]  # pre-federation journal format
        lines.append(json.dumps(entry))
    legacy_path.write_text("\n".join(lines) + "\n")
    legacy = TuningDatabase()
    legacy.replay_journal(str(legacy_path))
    assert legacy.records[key].version == 0  # not promoted to a fresh commit
    assert legacy.records[key].policy == "all_sk"  # later line still wins

    modern = TuningDatabase()
    modern.add_record(_rec(size=key, policy="sk_one_tile", tflops=1.0))
    assert modern.records[key].version == 1
    for order in ([legacy, modern], [modern, legacy]):
        out, _ = merge_databases(order)
        assert out.records[key].policy == "sk_one_tile"  # stamped beats legacy


def test_merge_never_keeps_stale_per_policy_for_new_winner():
    """Regression: the per-policy table must describe the stored record —
    a winner without its own table drops the superseded record's, rather
    than leaving measurements that belong to a different winner."""
    loser = _rec(policy="dp", tflops=1.0, version=1)
    winner = _rec(policy="all_sk", tflops=2.0, version=2)
    db = TuningDatabase()
    merge_records(db, [(loser, {"dp": 1.0})])
    assert db.per_policy[loser.size] == {"dp": 1.0}
    merge_records(db, [(winner, None)])
    assert db.records[winner.size].policy == "all_sk"
    assert winner.size not in db.per_policy  # stale table dropped
    # and a winner WITH a table installs it
    newer = _rec(policy="sk_one_tile", tflops=3.0, version=3)
    merge_records(db, [(newer, {"sk_one_tile": 3.0})])
    assert db.per_policy[newer.size] == {"sk_one_tile": 3.0}


def test_journal_supersedes_snapshot_whatever_the_clocks_say():
    """Regression: version stamps are per-producer counters, so a large
    offline snapshot's clock (resumed at max record version) must NOT
    outrank a fresh worker's low-numbered online commits. A journal
    post-dates the snapshot it accompanies: apply_journal_db overwrites
    unconditionally, the load(path, journal=...) contract."""
    key = (64, 512, 256)
    snapshot = TuningDatabase()
    snapshot.add_record(_rec(size=key, policy="dp", tflops=9.0, version=500))
    assert snapshot.version == 500
    journal_db = TuningDatabase()
    journal_db.add_record(_rec(size=key, policy="all_sk", tflops=3.0, version=3))
    apply_journal_db(snapshot, journal_db)
    assert snapshot.records[key].policy == "all_sk"  # journal wins
    assert snapshot.records[key].version == 3  # producer stamp preserved
    assert snapshot.version >= 500  # clock never rewinds


def test_add_record_preserves_producer_stamp_on_replay():
    db = TuningDatabase()
    stamped = _rec(version=9)
    db.add_record(stamped)
    assert db.records[stamped.size].version == 9
    assert db.version == 9  # clock fast-forwarded, not reset


# -- hybrid (wall, version) commit stamp -------------------------------------


def test_add_record_stamps_hybrid_wall_clock():
    """Fresh commits get both halves of the hybrid stamp; replay
    (stamp=False) preserves whatever the producer wrote — including the
    legacy wall-less 0.0."""
    db = TuningDatabase()
    db.add_record(_rec())
    fresh = db.records[(64, 512, 256)]
    assert fresh.version == 1 and fresh.wall > 0.0
    replayed = TuningDatabase()
    legacy = _rec(size=(1, 2, 3), version=0, wall=0.0)
    replayed.add_record(legacy, stamp=False)
    assert replayed.records[(1, 2, 3)].wall == 0.0
    carried = _rec(size=(4, 5, 6), version=7, wall=123.5)
    replayed.add_record(carried, stamp=False)
    assert replayed.records[(4, 5, 6)].wall == 123.5


def test_lww_newer_wall_beats_higher_version_either_order():
    """The ROADMAP follow-up this stamp exists for: version is a
    per-producer counter, so a long-lived producer's huge clock must not
    outrank a sibling's genuinely newer commit. Wall time orders
    cross-producer merges; merge order never changes the winner."""
    long_lived = _rec(policy="dp", tflops=9.0, version=500, wall=100.0)
    fresh = _rec(policy="all_sk", tflops=3.0, version=3, wall=200.0)
    for order in ([long_lived, fresh], [fresh, long_lived]):
        db = TuningDatabase()
        report = merge_records(db, [(r, None) for r in order])
        assert db.records[fresh.size].policy == "all_sk"
        assert report.conflicts == 0  # stamps differ: ordinary supersede
        assert report.superseded == 1


def test_lww_wall_tie_falls_back_to_producer_version():
    a = _rec(policy="dp", tflops=9.0, version=2, wall=150.0)
    b = _rec(policy="all_sk", tflops=3.0, version=5, wall=150.0)
    for order in ([a, b], [b, a]):
        db = TuningDatabase()
        merge_records(db, [(r, None) for r in order])
        assert db.records[b.size].policy == "all_sk"  # higher version wins


def test_legacy_wall_less_records_lose_to_any_wall_stamped():
    legacy = _rec(policy="dp", tflops=9.0, version=10**6, wall=0.0)
    stamped = _rec(policy="all_sk", tflops=0.5, version=1, wall=1.0)
    for order in ([legacy, stamped], [stamped, legacy]):
        db = TuningDatabase()
        merge_records(db, [(r, None) for r in order])
        assert db.records[stamped.size].policy == "all_sk"


def test_full_stamp_tie_counts_conflict_and_is_deterministic():
    a = _rec(policy="dp", tflops=5.0, version=3, wall=42.0)
    b = _rec(policy="sk_one_tile", tflops=6.0, version=3, wall=42.0)
    winners = set()
    for order in ([a, b], [b, a]):
        db = TuningDatabase()
        report = merge_records(db, [(r, None) for r in order])
        assert report.conflicts == 1
        winners.add(db.records[a.size].policy)
    assert winners == {"sk_one_tile"}  # higher tflops, whatever the order


def test_record_payload_ignores_hybrid_stamp():
    """Sharded-sweep identity: the same tuning result committed by two
    workers at different times is the SAME record — differing stamps must
    not read as a conflict."""
    a = _rec(version=1, wall=10.0)
    b = _rec(version=4, wall=99.0)
    assert record_payload(a) == record_payload(b)
    db = TuningDatabase()
    report = merge_records(db, [(a, None), (b, None)])
    assert report.conflicts == 0


def test_journal_beats_snapshot_with_newer_wall_stamp(tmp_path):
    """Merge-ordering regression at the snapshot/journal boundary: the
    precedence is structural — a snapshot regenerated later (newer wall,
    bigger producer clock) must still lose to the journal records that
    post-date it logically, via both apply_journal_db and the
    load(path, journal=...) path."""
    key = (64, 512, 256)
    snap_rec = _rec(size=key, policy="dp", tflops=9.0, version=500, wall=2e9)
    journal_rec = _rec(size=key, policy="all_sk", tflops=3.0, version=3, wall=1.0)

    snapshot = TuningDatabase()
    snapshot.add_record(snap_rec, stamp=False)
    journal_db = TuningDatabase()
    journal_db.add_record(journal_rec, stamp=False)
    apply_journal_db(snapshot, journal_db)
    assert snapshot.records[key].policy == "all_sk"
    assert snapshot.records[key].wall == 1.0  # producer stamp preserved

    snap_path = tmp_path / "db.json"
    journal_path = tmp_path / "journal.jsonl"
    fresh = TuningDatabase()
    fresh.add_record(snap_rec, stamp=False)
    fresh.save(str(snap_path))
    journal_path.write_text(journal_entry(journal_rec) + "\n")
    loaded = TuningDatabase.load(str(snap_path), journal=str(journal_path))
    assert loaded.records[key].policy == "all_sk"
    # but a *federated* merge of unrelated producers DOES order on wall
    db = TuningDatabase()
    merge_records(db, [(snap_rec, None), (journal_rec, None)])
    assert db.records[key].policy == "dp"


# -- cross-worker federation (the serving-path acceptance criterion) ---------


def _cold_worker():
    db = TuningDatabase()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1))
    return sel, ad


def test_fingerprint_tuned_in_worker_a_hits_in_worker_b_after_merge():
    """A fingerprint tuned in worker A's process state must dispatch as a
    DB hit — no miss, no re-tune — in worker B's selector after the merge."""
    op = GemmOp.plain(40, 768, 384, in_dtype="bfloat16")
    sel_a, ad_a = _cold_worker()
    sel_a.select_op(op)  # miss promotes (threshold 1)...
    ad_a.adapt()  # ...and A tunes it online
    assert sel_a.select_op(op).source == "tuned"

    sel_b, ad_b = _cold_worker()
    assert sel_b.select_op(op).source != "tuned"  # B is cold for it
    misses_before = ad_b.stats.misses
    tunes_before = ad_b.stats.adaptations
    gen_before = sel_b.sieve_generation

    report = federate_selector(sel_b, dbs=[ad_a.db], sieves=[sel_a.sieve])
    assert report.merged >= 1

    got = sel_b.select_op(op)
    assert got.source == "tuned"  # DB hit, not sieve/fallback
    assert ad_b.stats.misses == misses_before  # no miss fed the tuner
    ad_b.adapt()
    assert ad_b.stats.adaptations == tunes_before  # nothing re-tuned
    assert sel_b.sieve_generation > gen_before  # generation bumped
    # and B's pick is exactly the record A committed
    rec = ad_a.db.records[op.key]
    assert (got.policy.name, got.cfg.name, got.g) == (rec.policy, rec.cfg, rec.g)


def test_federate_via_journal_shards_only(tmp_path):
    """Journal shards alone (no shared db/sieve objects) are enough to
    federate: the transport is files, as between real hosts."""
    journal = str(tmp_path / "a.jsonl")
    db_a = TuningDatabase()
    sel_a = KernelSelector(sieve=db_a.build_sieve(), db=db_a)
    ad_a = AdaptiveTuner(
        sel_a, config=AdaptiveConfig(hot_threshold=1), journal=journal
    )
    ops = [GemmOp.plain(24, 512, 256), GemmOp(16, 256, 128, g=4, kind="grouped")]
    for op in ops:
        sel_a.select_op(op)
    ad_a.drain()

    sel_b, ad_b = _cold_worker()
    federate_selector(sel_b, journals=[journal])
    for op in ops:
        assert sel_b.select_op(op).source == "tuned"
    assert ad_b.stats.misses == 0


def test_local_commit_beats_stale_fleet_copy():
    """The worker's own (newer) commit survives a federation that carries a
    sibling's older record for the same key."""
    op = GemmOp.plain(56, 896, 448)
    sel_b, ad_b = _cold_worker()
    sel_b.select_op(op)
    ad_b.adapt()
    mine = ad_b.db.records[op.key]
    stale = dataclasses.replace(mine, policy="dp", tflops=0.1, version=0)
    foreign = TuningDatabase()
    foreign.records[stale.size] = stale
    federate_selector(sel_b, dbs=[foreign])
    assert sel_b.db.records[op.key].policy == mine.policy


# -- torn-write journal recovery (regression: crash during append) -----------


def _journal_bytes(n=3):
    tuner = Tuner()
    lines = []
    for t in TARGETS[:n]:
        rec, pp = tuner.tune_size(t)
        lines.append((journal_entry(rec, pp) + "\n").encode())
    return lines


def test_replay_tolerates_truncated_ascii_final_line(tmp_path):
    lines = _journal_bytes(3)
    path = tmp_path / "torn.jsonl"
    path.write_bytes(b"".join(lines[:2]) + lines[2][:-15])  # no trailing \n
    db = TuningDatabase()
    assert db.replay_journal(str(path)) == 2
    assert db.load_errors == 1
    assert len(db.records) == 2


def test_replay_tolerates_torn_multibyte_final_line(tmp_path):
    """A crash can land mid-UTF-8-sequence; text-mode iteration used to
    raise UnicodeDecodeError before any per-line handler ran."""
    lines = _journal_bytes(2)
    path = tmp_path / "torn_utf8.jsonl"
    path.write_bytes(b"".join(lines) + b'{"key": "1,2,3", "rec\xe2')
    db = TuningDatabase()
    assert db.replay_journal(str(path)) == 2  # must not raise
    assert db.load_errors == 1


def test_replay_warns_final_line_distinctly(tmp_path):
    import logging

    class Collect(logging.Handler):
        def __init__(self):
            super().__init__()
            self.messages = []

        def emit(self, record):
            self.messages.append(record.getMessage())

    lines = _journal_bytes(2)
    path = tmp_path / "torn.jsonl"
    path.write_bytes(lines[0] + lines[1][:20])
    handler = Collect()
    logger = logging.getLogger("repro.tuner")
    logger.addHandler(handler)
    try:
        TuningDatabase().replay_journal(str(path))
    finally:
        logger.removeHandler(handler)
    assert any("crash during append" in m for m in handler.messages)


def test_merge_journal_shards_surfaces_torn_lines(tmp_path):
    lines = _journal_bytes(3)
    good = tmp_path / "good.jsonl"
    torn = tmp_path / "torn.jsonl"
    good.write_bytes(lines[0] + lines[1])
    torn.write_bytes(lines[2][: len(lines[2]) // 2])
    merged, report = merge_journal_shards([str(good), str(torn)])
    assert len(merged.records) == 2
    assert report.load_errors == 1


# -- Bloom/sieve merge validation (regression: silent mismatch accept) -------


def test_bloom_merge_rejects_mismatched_bit_width():
    a = BloomFilter.for_capacity(1_000, 0.01, seed=1)
    b = BloomFilter.for_capacity(4_000, 0.01, seed=1)
    with pytest.raises(ValueError) as ei:
        a.merge(b)
    msg = str(ei.value)
    assert str(a.n_bits) in msg and str(b.n_bits) in msg  # names both configs


def test_bloom_merge_rejects_mismatched_hash_count_and_seed():
    a = BloomFilter(n_bits=1024, n_hashes=5, seed=1)
    with pytest.raises(ValueError, match="n_hashes=5.*n_hashes=3"):
        a.merge(BloomFilter(n_bits=1024, n_hashes=3, seed=1))
    with pytest.raises(ValueError, match="seed=1.*seed=2"):
        a.merge(BloomFilter(n_bits=1024, n_hashes=5, seed=2))


def test_bloom_merge_rejects_truncated_bit_array():
    a = BloomFilter(n_bits=1024, n_hashes=5, seed=1)
    b = BloomFilter(n_bits=1024, n_hashes=5, seed=1)
    b.bits = b.bits[:-4]  # a from_bytes of a truncated blob used to do this
    with pytest.raises(ValueError, match="mismatched bit arrays"):
        a.merge(b)


def test_bloom_from_bytes_rejects_truncated_blob():
    f = BloomFilter.for_capacity(1_000, 0.01, seed=3)
    blob = f.to_bytes()
    with pytest.raises(ValueError, match="bytes"):
        BloomFilter.from_bytes(blob[:-8])
    assert BloomFilter.from_bytes(blob).to_bytes() == blob  # intact roundtrip


def test_sieve_merge_rejects_mismatched_policy_registries():
    s1 = OpenSieve(ALL_POLICIES)
    s2 = OpenSieve(ALL_POLICIES[:3])
    with pytest.raises(ValueError, match="policy registries"):
        s1.merge(s2)


def test_sieve_merge_rejects_mismatched_capacity():
    s1 = OpenSieve(capacity=1_000)
    s2 = OpenSieve(capacity=10_000)
    with pytest.raises(ValueError, match="n_bits"):
        s1.merge(s2)


# -- mesh-aware fingerprints (multi-device CI lane) --------------------------


def test_gemm_div_without_plan_is_empty():
    from repro.dist.sharding import ambient_gemm_div

    assert ambient_gemm_div() == {}


@pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 devices (multi-device CI lane)"
)
def test_mesh_local_fingerprints_federate_across_hosts():
    """Under a (data=2, model=4) mesh plan, two identically-sharded 'hosts'
    produce the same local-MNK fingerprint for the same global problem, so
    a record tuned on host A is an exact DB hit on host B."""
    from repro.dist.sharding import ShardingPlan, ambient_gemm_div, use_plan

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    plan = ShardingPlan(mesh)
    with use_plan(plan):
        div = ambient_gemm_div()
        assert div == {"batch": 2, "model": 4}
        # what models do with the div table: shard M over batch, N over model
        op_host_a = GemmOp.plain(
            64, 2048, 512, divisors=(div["batch"], div["model"], 1)
        )
        op_host_b = GemmOp.plain(
            64, 2048, 512, divisors=(div["batch"], div["model"], 1)
        )
    assert op_host_a.local == (32, 512, 512)  # the per-device problem
    assert op_host_a.key == op_host_b.key

    sel_a, ad_a = _cold_worker()
    sel_a.select_op(op_host_a)
    ad_a.adapt()
    sel_b, ad_b = _cold_worker()
    federate_selector(sel_b, dbs=[ad_a.db])
    assert sel_b.select_op(op_host_b).source == "tuned"
    assert ad_b.stats.misses == 0


@pytest.mark.skipif(
    jax.device_count() < 8, reason="needs 8 devices (multi-device CI lane)"
)
def test_serve_engine_derives_div_from_ambient_plan():
    from conftest import tiny
    from repro.dist.sharding import ShardingPlan, materialize_tree, use_plan
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with use_plan(ShardingPlan(mesh)):
        eng = ServeEngine(model, params, ServeConfig(n_slots=2, max_seq=32, eos=-1))
        assert eng.div == {"batch": 2, "model": 4}
    # explicit div still wins over the ambient plan
    with use_plan(ShardingPlan(mesh)):
        eng2 = ServeEngine(
            model, params, ServeConfig(n_slots=2, max_seq=32, eos=-1), div={}
        )
        assert eng2.div == {}


# -- serve CLI shard helpers -------------------------------------------------


def test_shard_journal_paths_roundtrip(tmp_path):
    from repro.launch.serve import existing_journal_shards, shard_journal_path

    base = str(tmp_path / "j.jsonl")
    assert shard_journal_path(base, 0, 1) == base
    paths = [shard_journal_path(base, w, 3) for w in range(3)]
    assert len(set(paths)) == 3
    for p in paths:
        with open(p, "w") as f:
            f.write(json.dumps({"key": "1,2,3", "record": {}}) + "\n")
    found = existing_journal_shards(base)
    assert found == sorted(paths)
    with open(base, "w") as f:
        f.write("")
    assert existing_journal_shards(base)[0] == base
