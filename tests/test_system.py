"""End-to-end behaviour: the paper's full workflow — tune over a GEMM suite,
build Open-sieve filters, dispatch through the selector inside a training
run — plus the headline claims' direction on the full 923-size suite
(the precise figures live in benchmarks/)."""

import jax
import numpy as np

from conftest import tiny
from repro.configs.gemm_suite import suite
from repro.core.gemm import gemm_context
from repro.core.policies import ALL_POLICIES
from repro.core.selector import KernelSelector
from repro.core.tuner import Tuner
from repro.data import SyntheticLMData
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.optim import make_optimizer, warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state


def test_suite_is_the_papers_923():
    s = suite()
    assert len(s) == 923
    ms = {x[0] for x in s}
    ns = {x[1] for x in s}
    ks = {x[2] for x in s}
    assert min(ms) == 1 and max(ms) <= 8192
    assert min(ns) == 64 and max(ns) <= 8192
    assert min(ks) == 16 and max(ks) <= 65536


def test_full_workflow_tune_sieve_train():
    # 1. tune a subset (fast), build filters
    sizes = suite()[::40]  # ~24 sizes
    db = Tuner().tune(sizes)
    sieve = db.build_sieve()
    assert sieve.validate_true_negative_rate(db.winners()) == 1.0

    # 2. train a tiny model dispatching through the tuned selector
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    opt = make_optimizer("adamw", warmup_cosine(3e-3, 2, 15))
    data = SyntheticLMData(cfg, batch=4, seq_len=32, seed=0)
    sel = KernelSelector(sieve=sieve, db=db)
    with gemm_context(selector=sel) as ctx:
        t = Trainer(model, opt, data, TrainerConfig(total_steps=8, log_every=100))
        t.fit(init_train_state(model, opt, params))
    assert t.history[-1] < t.history[0]
    assert len(ctx.log) > 0  # every projection went through dispatch
    assert sel.stats.lookups > 0


def test_headline_directions_on_sampled_suite():
    """Direction of the paper's claims on a suite sample: DP wins the
    majority; SK-based policies win a non-trivial minority; tolerance
    inclusion grows (full-suite numbers in benchmarks/fig2)."""
    sizes = suite()[::12]  # ~77 sizes
    db = Tuner().tune(sizes)
    total = len(sizes)
    sk_wins = sum(1 for r in db.records.values() if r.policy != "dp")
    assert 0 < sk_wins < total * 0.5  # minority but present

    # tolerance analysis: fraction of sizes where the best SK policy is
    # within 20% of DP must exceed the fraction within 5%
    def within(tol):
        n = 0
        for s, per in db.per_policy.items():
            dp = per["dp"]
            best_sk = max(v for k, v in per.items() if k != "dp")
            if best_sk >= dp * (1 - tol):
                n += 1
        return n / total

    assert within(0.20) >= within(0.05)
    assert within(0.20) > 0.5
