"""GemmOp dispatch API: grouped/batched entry points, epilogue fusion,
backend registry, selector observability, op-fingerprint keying."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.core.gemm import (
    gemm,
    gemm_batched,
    gemm_context,
    gemm_grouped,
    get_backend,
    list_backends,
    register_backend,
)
from repro.core.op import Epilogue, GemmOp, encode_key
from repro.core.policies import ALL_SK, DP, TileConfig
from repro.core.selector import KernelSelector, default_selector
from repro.core.tuner import Tuner


# ---------------------------------------------------------------------------
# grouped / batched entry points
# ---------------------------------------------------------------------------


def test_grouped_matches_einsum():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(4, 8, 32)), jnp.float32)
    w = jnp.asarray(r.normal(size=(4, 32, 16)), jnp.float32)
    with gemm_context(selector=default_selector()) as ctx:
        got = gemm_grouped(x, w, tag="t")
    want = jnp.einsum("gmk,gkn->gmn", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)
    [e] = ctx.log
    assert e.op.g == 4 and e.op.kind == "grouped"
    assert e.op.local == (8, 16, 32)


def test_batched_matches_einsum():
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(3, 5, 16)), jnp.float32)
    w = jnp.asarray(r.normal(size=(3, 16, 8)), jnp.float32)
    with gemm_context(selector=default_selector()) as ctx:
        got = gemm_batched(x, w)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(jnp.einsum("gmk,gkn->gmn", x, w)), rtol=1e-6
    )
    assert ctx.log[0].op.kind == "batched"


def test_grouped_shape_validation():
    with pytest.raises(ValueError):
        gemm_grouped(jnp.ones((2, 4, 8)), jnp.ones((3, 8, 4)))  # G mismatch
    with pytest.raises(ValueError):
        gemm_grouped(jnp.ones((2, 4, 8)), jnp.ones((2, 9, 4)))  # K mismatch
    with pytest.raises(ValueError):
        gemm_grouped(jnp.ones((4, 8)), jnp.ones((8, 4)))  # not stacked


# ---------------------------------------------------------------------------
# epilogue fusion
# ---------------------------------------------------------------------------


def _ref_epilogue(acc, epi: Epilogue, bias=None, operand=None):
    acc = acc.astype(jnp.float32)
    if epi.bias:
        acc = acc + bias.astype(jnp.float32)
    if epi.activation == "gelu":
        acc = jax.nn.gelu(acc)
    elif epi.activation == "silu":
        acc = jax.nn.silu(acc)
    elif epi.activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif epi.activation == "square":
        acc = jnp.square(jnp.maximum(acc, 0.0))
    if epi.binary == "mul_silu":
        acc = acc * jax.nn.silu(operand.astype(jnp.float32))
    elif epi.binary == "add":
        acc = acc + operand.astype(jnp.float32)
    return acc


EPILOGUES = [
    Epilogue(activation="gelu"),
    Epilogue(activation="silu"),
    Epilogue(activation="square"),
    Epilogue(bias=True),
    Epilogue(bias=True, activation="gelu"),
    Epilogue(binary="mul_silu"),
    Epilogue(binary="add"),
]


@pytest.mark.parametrize("epi", EPILOGUES, ids=lambda e: e.name)
@pytest.mark.parametrize("backend", ["xla", "pallas_interpret"])
def test_epilogue_fused_matches_unfused(epi, backend):
    r = np.random.default_rng(2)
    m, n, k = 16, 128, 64
    x = jnp.asarray(r.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(r.normal(size=(k, n)), jnp.float32)
    bias = jnp.asarray(r.normal(size=(n,)), jnp.float32) if epi.bias else None
    operand = (
        jnp.asarray(r.normal(size=(m, n)), jnp.float32)
        if epi.binary != "none"
        else None
    )
    want = _ref_epilogue(jnp.dot(x, w), epi, bias=bias, operand=operand)
    kw = dict(policy=ALL_SK, cfg=TileConfig(8, 128, 128)) if backend.startswith(
        "pallas"
    ) else {}
    with gemm_context(selector=default_selector(), backend=backend):
        got = gemm(x, w, epilogue=epi, bias=bias, operand=operand, **kw)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


def test_epilogue_fused_grouped():
    r = np.random.default_rng(3)
    x = jnp.asarray(r.normal(size=(3, 8, 32)), jnp.float32)
    w = jnp.asarray(r.normal(size=(3, 32, 16)), jnp.float32)
    gate = jnp.asarray(r.normal(size=(3, 8, 16)), jnp.float32)
    with gemm_context(selector=default_selector()):
        got = gemm_grouped(
            x, w, epilogue=Epilogue(binary="mul_silu"), operand=gate
        )
    want = jnp.einsum("gmk,gkn->gmn", x, w) * jax.nn.silu(gate)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


def test_epilogue_operand_mismatch_raises():
    x, w = jnp.ones((4, 8)), jnp.ones((8, 4))
    with pytest.raises(ValueError):
        gemm(x, w, epilogue=Epilogue(bias=True))  # bias spec, no bias operand
    with pytest.raises(ValueError):
        gemm(x, w, bias=jnp.ones((4,)))  # bias operand, no epilogue spec
    with pytest.raises(ValueError):
        gemm(x, w, epilogue=Epilogue(binary="add"))  # missing operand


# ---------------------------------------------------------------------------
# MoE routing: the dense-expert path dispatches grouped ops
# ---------------------------------------------------------------------------


def test_moe_forward_logs_grouped_ops():
    """A MoE forward pass must route its expert GEMMs through the grouped
    dispatch layer: the SelectionLog shows G > 1 entries for every expert
    matmul (router + in/out, + gate when swiglu)."""
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model

    cfg = tiny("olmoe-1b-7b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, cfg.vocab_size, (2, 16)))
    with gemm_context(selector=default_selector()) as ctx:
        logits, _ = model.forward(params, toks)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    grouped = [e for e in ctx.log if e.op.g > 1]
    assert grouped, "no grouped ops in the SelectionLog — experts bypassed dispatch"
    tags = {e.tag for e in grouped}
    assert "moe.in" in tags and "moe.out" in tags
    for e in grouped:
        assert e.op.kind == "grouped"
        assert e.op.g == cfg.n_experts
    # grouped ops key independently of the plain path, and MoE dispatch
    # defaults to the fused one-kernel form (8-part grouped_fused key)
    assert all(len(e.op.key) == 8 for e in grouped)
    assert all(e.op.key[7] == "grouped_fused" for e in grouped)
    assert all(e.op.fused for e in grouped)


def test_moe_epilogue_fusion_matches_unfused_reference():
    """The fused expert MLP (epilogue in the GEMM) equals the hand-written
    einsum + activation reference within dtype tolerance."""
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model
    from repro.models.layers import moe_apply

    cfg = tiny("olmoe-1b-7b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    p0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(2, 8, cfg.d_model)) * 0.3, jnp.float32)
    with gemm_context(selector=default_selector()):
        got, _ = moe_apply(p0, x, cfg, div={})

    # unfused reference: replicate the dispatch math with raw einsums
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    logits = xf.astype(jnp.float32) @ p0["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    cap = max(int(cfg.capacity_factor * t * k / e), min(t, 16), 1)
    e_flat = idx.T.reshape(t * k)
    tok = jnp.tile(jnp.arange(t), k)
    gate_flat = gates.T.reshape(t * k)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
    pos = jnp.max(jnp.cumsum(onehot, axis=0) * onehot - 1, axis=-1)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[e_flat, slot].set(xf[tok], mode="drop")
    expert_in = buf[:, :cap]
    h = jnp.einsum("ecd,edf->ecf", expert_in, p0["w_in"])
    if cfg.mlp_act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", expert_in, p0["w_gate"])
        h = (jax.nn.silu(g.astype(jnp.float32)) * h.astype(jnp.float32)).astype(
            x.dtype
        )
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, p0["w_out"])
    gathered = out_e[e_flat, jnp.minimum(slot, cap - 1)]
    wgt = (gate_flat * keep).astype(jnp.float32)
    want = (
        (gathered.astype(jnp.float32) * wgt[:, None]).reshape(k, t, d).sum(0)
    ).reshape(b, s, d)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4
    )


# ---------------------------------------------------------------------------
# backend registry
# ---------------------------------------------------------------------------


def test_unknown_backend_raises_with_registered_list():
    with pytest.raises(ValueError) as ei:
        with gemm_context(backend="cuda"):
            pass
    msg = str(ei.value)
    assert "cuda" in msg
    for name in ("xla", "pallas", "pallas_interpret"):
        assert name in msg


def test_builtin_backends_registered():
    names = list_backends()
    assert {"xla", "pallas", "pallas_interpret"} <= set(names)
    assert get_backend("xla") is not None


def test_register_backend_pluggable():
    calls = []

    def counting_backend(x, w, *, op, policy, cfg, g, bias, operand):
        calls.append(op)
        return jnp.einsum("gmk,gkn->gmn", x, w).astype(op.out_dtype)

    register_backend("counting_test", counting_backend, overwrite=True)
    x, w = jnp.ones((2, 32)), jnp.ones((32, 8))
    with gemm_context(selector=default_selector(), backend="counting_test"):
        out = gemm(x, w)
    assert out.shape == (2, 8)
    assert len(calls) == 1 and calls[0].global_mnk == (2, 8, 32)
    with pytest.raises(ValueError):
        register_backend("counting_test", counting_backend)  # no overwrite


# ---------------------------------------------------------------------------
# selector observability + op-fingerprint keying
# ---------------------------------------------------------------------------


def test_selector_counts_cache_hits_and_forced():
    sel = default_selector()
    x, w = jnp.ones((4, 32)), jnp.ones((32, 8))
    with gemm_context(selector=sel):
        gemm(x, w)
        gemm(x, w)  # same fingerprint -> memoised
        gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128))  # fully forced
        gemm(x, w, cfg=TileConfig(8, 128, 128))  # partial override
    s = sel.stats
    assert s.lookups == 4
    assert s.cache_hits == 1
    assert s.forced == 2  # full + partial overrides both categorise as forced
    # exactly one category per lookup — per-source fractions stay <= 100%
    assert s.tuned_hits + s.sieve_hits + s.fallbacks + s.cache_hits + s.forced == s.lookups


def test_partial_override_logs_what_actually_ran():
    sel = default_selector()
    x, w = jnp.ones((16, 64)), jnp.ones((64, 128))
    with gemm_context(selector=sel) as ctx:
        gemm(x, w, policy=ALL_SK)  # cfg filled from selection
    [e] = ctx.log
    assert e.selection.policy == ALL_SK  # never the selector's own pick
    assert e.selection.source == "forced"


def test_cached_selection_is_same_object():
    sel = default_selector()
    s0 = sel.select(64, 64, 64)
    assert sel.select(64, 64, 64) is s0
    assert sel.stats.cache_hits == 1


def test_plain_and_grouped_keys_independent():
    plain = GemmOp.plain(64, 128, 256)
    grouped = GemmOp(64, 128, 256, g=8, kind="grouped")
    fused = GemmOp.plain(64, 128, 256, epilogue="gelu")
    assert plain.key == (64, 128, 256)
    assert len(grouped.key) == 7 and len(fused.key) == 7
    keys = {encode_key(plain.key), encode_key(grouped.key), encode_key(fused.key)}
    assert len(keys) == 3  # distinct Bloom encodings


def test_plain_op_encodes_as_legacy_mnk():
    from repro.core.bloom import encode_mnk

    assert GemmOp.plain(8, 16, 32).encode() == encode_mnk(8, 16, 32)


def test_grouped_op_tunes_and_selects_independently():
    op = GemmOp(256, 512, 1024, g=8, kind="grouped")
    db = Tuner().tune([op, (256, 512, 1024)])
    assert op.key in db.records and (256, 512, 1024) in db.records
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    assert sel.select_op(op).source == "tuned"
    assert sel.select(256, 512, 1024).source == "tuned"
    # roundtrip through the JSON codec keeps both key forms
    import os
    import tempfile

    from repro.core.tuner import TuningDatabase

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "db.json")
        db.save(path)
        db2 = TuningDatabase.load(path)
    assert set(db2.records) == set(db.records)


def test_non_f32_plain_ops_read_mnk_artifacts():
    """A bf16 model GEMM must still benefit from dtype-agnostic (M, N, K)
    tuning artifacts (the paper's DBs carry no dtype), while keying its own
    records separately from f32."""
    size = (256, 512, 128)
    db = Tuner().tune([size])
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    bf16_op = GemmOp.plain(*size, in_dtype="bfloat16", out_dtype="bfloat16")
    assert bf16_op.key != size and bf16_op.mnk_compatible
    got = sel.select_op(bf16_op)
    assert got.source == "tuned"
    assert got.policy.name == db.records[size].policy
    # end-to-end: dispatching bf16 operands of the tuned shape hits the DB
    x = jnp.ones((256, 128), jnp.bfloat16)
    w = jnp.ones((128, 512), jnp.bfloat16)
    sel2 = KernelSelector(sieve=db.build_sieve(), db=db)
    with gemm_context(selector=sel2) as ctx:
        gemm(x, w)
    assert ctx.log[0].selection.source == "tuned"
    # but an exact dtype-specific record takes precedence when present
    db2 = Tuner().tune([bf16_op, size])
    assert bf16_op.key in db2.records
    sel3 = KernelSelector(db=db2)
    assert sel3.select_op(bf16_op).source == "tuned"


def test_gemm_divisors_key_local_shape():
    sel = default_selector()
    x = jnp.ones((4, 8, 32), jnp.float32)
    w = jnp.ones((32, 64), jnp.float32)
    with gemm_context(selector=sel) as ctx:
        gemm(x, w, divisors=(4, 2, 1))
    e = ctx.log[0]
    assert e.op.global_mnk == (32, 64, 32)
    assert e.op.local == (8, 32, 32)
    assert e.op.key == (8, 32, 32)  # plain op -> legacy key
