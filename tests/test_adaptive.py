"""Artifact lifecycle + online adaptation: journal roundtrips, generational
sieve rebuilds, selector hot-swap, and the AdaptiveTuner miss loop."""

import json

import pytest

from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.op import Epilogue, GemmOp
from repro.core.selector import KernelSelector
from repro.core.tuner import (
    Tuner,
    TuningDatabase,
    append_journal,
    journal_entry,
)
from repro.core.policies import DEFAULT_TILE_CONFIGS, DP


OPS = [
    GemmOp.plain(256, 512, 128),
    GemmOp.plain(96, 384, 256, in_dtype="bfloat16"),
    GemmOp(64, 256, 128, g=8, kind="grouped"),
    GemmOp.plain(128, 128, 512, epilogue=Epilogue(activation="gelu")),
    GemmOp.plain(32, 640, 256, epilogue=Epilogue(bias=True, activation="silu")),
]


def cold_selector():
    db = TuningDatabase()
    return KernelSelector(sieve=db.build_sieve(), db=db), db


# -- journal / persistence lifecycle ----------------------------------------


def test_add_record_bumps_version():
    db = TuningDatabase()
    rec, pp = Tuner().tune_size(OPS[0])
    assert db.version == 0
    db.add_record(rec, pp)
    assert db.version == 1
    assert db.records[rec.size] is rec
    assert db.per_policy[rec.size] == pp


def test_save_journal_load_roundtrip(tmp_path):
    """Snapshot + journal-append + load reproduces every record, including
    grouped and epilogue-fused fingerprints (extended op keys)."""
    tuner = Tuner()
    db = tuner.tune(OPS[:3])
    snap = str(tmp_path / "db.json")
    journal = str(tmp_path / "journal.jsonl")
    db.save(snap)
    # two more records land after the snapshot, journal-only
    late = {}
    for op in OPS[3:]:
        rec, pp = tuner.tune_size(op)
        append_journal(journal, rec, pp)
        late[rec.size] = rec

    loaded = TuningDatabase.load(snap, journal=journal)
    assert loaded.load_errors == 0
    assert set(loaded.records) == {op.key for op in OPS}
    for op in OPS:
        assert loaded.records[op.key].policy == (
            db.records[op.key].policy
            if op.key in db.records
            else late[op.key].policy
        )
    # grouped / fused keys survived as tuples, not strings
    assert loaded.records[OPS[2].key].size == OPS[2].key
    assert len(OPS[2].key) == 7
    # per-policy tables survive both paths
    for op in OPS:
        assert op.key in loaded.per_policy


def test_load_counts_and_keeps_going_on_bad_keys(tmp_path):
    db = Tuner().tune([OPS[0]])
    path = str(tmp_path / "db.json")
    db.save(path)
    payload = json.load(open(path))
    good = next(iter(payload["records"].values()))
    payload["records"]["not-a-key"] = dict(good)
    payload["records"]["1,2"] = dict(good)
    payload["per_policy"]["also,bad"] = {"dp": 1.0}
    json.dump(payload, open(path, "w"))

    loaded = TuningDatabase.load(path)
    assert set(loaded.records) == set(db.records)  # good records kept
    assert loaded.load_errors == 3  # skew visible, not a silent shrink


def test_journal_replay_skips_malformed_lines(tmp_path):
    path = str(tmp_path / "journal.jsonl")
    rec, pp = Tuner().tune_size(OPS[0])
    with open(path, "w") as f:
        f.write(journal_entry(rec, pp) + "\n")
        f.write("{torn line\n")
        f.write('{"key": "1,2,3", "record": {"nonsense": true}}\n')
    db = TuningDatabase()
    assert db.replay_journal(path) == 1
    assert db.load_errors == 2
    assert db.records[rec.size].policy == rec.policy


def test_journal_replay_missing_file(tmp_path):
    db = TuningDatabase()
    assert db.replay_journal(str(tmp_path / "nope.jsonl"), missing_ok=True) == 0
    with pytest.raises(FileNotFoundError):
        db.replay_journal(str(tmp_path / "nope.jsonl"))


def test_tuner_emits_the_journal_it_consumes(tmp_path):
    """Offline sweeps and journal replay share one format: a database built
    by ``Tuner.tune(journal=...)`` is exactly reproduced by replaying."""
    path = str(tmp_path / "journal.jsonl")
    db = Tuner().tune(OPS, journal=path)
    replayed = TuningDatabase()
    assert replayed.replay_journal(path) == len(OPS)
    assert set(replayed.records) == set(db.records)
    for key, rec in db.records.items():
        assert replayed.records[key] == rec
        assert replayed.per_policy[key] == db.per_policy[key]


# -- generational sieve rebuilds --------------------------------------------


def test_sieve_generation_increments_on_rebuild():
    sel, db = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1, rebuild_every=1))
    assert sel.sieve_generation == 0
    for i, op in enumerate(OPS[:3]):
        sel.select_op(op)
        ad.adapt()
        assert sel.sieve_generation == i + 1  # monotone, one per rebuild
    assert ad.stats.rebuilds == 3
    # the rebuilt sieve actually contains the learned winners
    winners = db.winners()
    assert sel.sieve.validate_true_negative_rate(winners) == 1.0


def test_hot_swap_mid_stream_never_serves_stale_candidate():
    """A memoised sieve/fallback pick must not survive the artifact swap:
    after commit + hot-swap, the very next dispatch resolves from the DB
    with the same winner an offline sweep finds."""
    sel, db = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=2, rebuild_every=1))
    op = OPS[3]
    for _ in range(3):
        pre = sel.select_op(op)
    assert pre.source == "fallback"  # cold: empty sieve prunes everything
    assert ad.pending_hot == 1
    ad.adapt()
    post = sel.select_op(op)
    offline, _ = Tuner().tune_size(op)
    assert post.source == "tuned"
    assert post.policy.name == offline.policy
    assert post.cfg.name == offline.cfg
    # and the memoised repeat stays the tuned one
    assert sel.select_op(op).source == "tuned"


def test_hot_swap_invalidates_only_requested_keys():
    sel, db = cold_selector()
    a, b = OPS[0], OPS[1]
    sel.select_op(a)
    sel.select_op(b)
    assert sel.hot_swap(keys=[a.key]) == 1
    assert a.key not in sel._cache and b.key in sel._cache
    assert sel.hot_swap() == 1  # keys=None clears the rest


# -- the miss-driven adaptation loop ----------------------------------------


def test_hot_threshold_gates_promotion():
    sel, _ = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=3))
    op = OPS[0]
    sel.select_op(op)
    sel.select_op(op)
    assert ad.pending_hot == 0 and ad.stats.misses == 2
    sel.select_op(op)  # third repeated miss crosses the threshold
    assert ad.pending_hot == 1 and ad.stats.promoted == 1
    sel.select_op(op)  # further misses do not re-promote
    assert ad.stats.promoted == 1


def test_miss_table_is_bounded():
    sel, _ = cold_selector()
    ad = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=100, max_pending=8)
    )
    for i in range(40):
        sel.select_op(GemmOp.plain(8 * (i + 1), 128, 128))
    assert ad.tracked <= 8
    assert ad.stats.evicted == 32
    assert ad.stats.misses == 40


def test_hot_queue_is_bounded_at_threshold_one():
    """At hot_threshold=1 (the serving CLI default) every miss promotes, so
    the hot queue needs its own bound — a one-off fingerprint stream must
    not grow tuner state without limit."""
    sel, _ = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1, max_pending=8))
    for i in range(40):
        sel.select_op(GemmOp.plain(8 * (i + 1), 128, 128))
    assert ad.pending_hot <= 8
    assert ad.tracked <= 16  # hot queue + miss table, each bounded
    assert ad.stats.evicted == 32


def test_explicit_db_is_installed_into_selector():
    """An explicitly passed database must be the one selection reads —
    otherwise commits would be invisible to dispatch forever."""
    sel, original = cold_selector()
    fresh = TuningDatabase()
    ad = AdaptiveTuner(
        sel, db=fresh, config=AdaptiveConfig(hot_threshold=1, rebuild_every=1)
    )
    assert sel.db is fresh
    op = OPS[0]
    sel.select_op(op)
    ad.adapt()
    assert op.key in fresh.records and op.key not in original.records
    assert sel.select_op(op).source == "tuned"


def test_budget_cuts_adaptation_round_short():
    sel, db = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1))
    for op in OPS[:3]:
        sel.select_op(op)
    assert ad.pending_hot == 3
    assert ad.adapt(budget_s=0.0) == 0  # no wallclock left: commit nothing
    assert ad.stats.budget_stops == 1
    assert ad.pending_hot == 3  # nothing lost, just deferred
    assert ad.adapt(budget_s=None) == 3  # uncapped round drains them
    assert len(db.records) == 3


def test_forced_dispatches_feed_the_miss_queue():
    sel, db = cold_selector()
    ad = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1))
    cfg = DEFAULT_TILE_CONFIGS[0]
    sel.record_forced(OPS[0], DP, cfg)
    assert ad.stats.misses == 1 and ad.pending_hot == 1
    ad.adapt()
    # once tuned, forced dispatches of the same op are no longer misses
    sel.record_forced(OPS[0], DP, cfg)
    assert ad.stats.misses == 1


def test_drain_flushes_everything_and_rebuilds():
    sel, db = cold_selector()
    ad = AdaptiveTuner(
        sel,
        config=AdaptiveConfig(hot_threshold=1, max_tunes_per_step=2, rebuild_every=100),
    )
    for op in OPS:
        sel.select_op(op)
    assert ad.pending_hot == len(OPS)
    assert ad.drain() == len(OPS)
    assert ad.pending_hot == 0
    assert len(db.records) == len(OPS)
    assert sel.sieve_generation == 1  # final fold-in even below rebuild_every


def test_model_warm_dispatch_promotes_then_tunes():
    """The analytical-first lifecycle: an unseen fingerprint warm-starts
    from the calibrated model (source "model"), still counts as a miss so
    the hot threshold promotes it, adapt() measures and commits — and the
    next dispatch is a real database hit matching the offline sweep."""
    from repro.core.calibrate import CalibratedMachine

    sel, db = cold_selector()
    sel.hot_swap(calibration=CalibratedMachine())  # base-machine fit
    ad = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=2, rebuild_every=1, top_k=3)
    )
    assert ad.tuner.top_k == 3  # the default-built tuner takes the budget
    op = OPS[0]
    pre = sel.select_op(op)
    sel.select_op(op)
    assert pre.source == "model"  # not "fallback": model argmin launched
    assert sel.stats.model_warm == 1
    assert ad.stats.misses == 2 and ad.pending_hot == 1  # warm != tuned
    ad.adapt()
    post = sel.select_op(op)
    offline, _ = Tuner().tune_size(op)
    assert post.source == "tuned"
    assert post.policy.name == offline.policy
    assert post.cfg.name == offline.cfg
    assert db.records[op.key].model_rank >= 1  # budgeted sweep noted rank
    # once tuned, repeat dispatches stop feeding the miss queue
    sel.select_op(op)
    assert ad.stats.misses == 2


def test_adaptive_journal_commits_warm_start_next_run(tmp_path):
    """Records learned while serving survive the restart: replaying the
    journal into a fresh selector turns yesterday's misses into DB hits."""
    journal = str(tmp_path / "journal.jsonl")
    sel, _ = cold_selector()
    ad = AdaptiveTuner(
        sel, config=AdaptiveConfig(hot_threshold=1), journal=journal
    )
    for op in OPS:
        sel.select_op(op)
    ad.drain()

    db2 = TuningDatabase()
    db2.replay_journal(journal)
    sel2 = KernelSelector(sieve=db2.build_sieve(), db=db2)
    assert all(sel2.select_op(op).source == "tuned" for op in OPS)
