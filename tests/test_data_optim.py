"""Data pipeline determinism + optimizer unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny
from repro.data import SyntheticLMData
from repro.optim import Adafactor, AdamW, SGD, constant, warmup_cosine, warmup_linear


def test_pipeline_pure_function_of_step():
    cfg = tiny("granite-8b")
    d1 = SyntheticLMData(cfg, batch=4, seq_len=32, seed=9)
    d2 = SyntheticLMData(cfg, batch=4, seq_len=32, seed=9)
    for step in (0, 5, 17):
        b1, b2 = d1.batch_at(step), d2.batch_at(step)
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k])


def test_pipeline_distinct_steps_and_seeds():
    cfg = tiny("granite-8b")
    d = SyntheticLMData(cfg, batch=4, seq_len=32, seed=9)
    assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])
    d2 = SyntheticLMData(cfg, batch=4, seq_len=32, seed=10)
    assert not np.array_equal(d.batch_at(0)["tokens"], d2.batch_at(0)["tokens"])


def test_pipeline_state_roundtrip():
    cfg = tiny("granite-8b")
    d = SyntheticLMData(cfg, batch=2, seq_len=16, seed=1)
    it = iter(d)
    next(it)
    next(it)
    sd = d.state_dict()
    d2 = SyntheticLMData(cfg, batch=2, seq_len=16, seed=0)
    d2.load_state_dict(sd)
    np.testing.assert_array_equal(d.batch_at(d.state.step)["tokens"],
                                  d2.batch_at(d2.state.step)["tokens"])


def test_labels_are_shifted_tokens():
    cfg = tiny("granite-8b")
    d = SyntheticLMData(cfg, batch=2, seq_len=16, seed=1)
    b = d.batch_at(0)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
    assert (b["loss_mask"][:, -1] == 0).all()


def test_vlm_and_encdec_extras():
    for arch, key in (("llava-next-34b", "patch_embeds"), ("whisper-large-v3", "frames")):
        cfg = tiny(arch)
        d = SyntheticLMData(cfg, batch=2, seq_len=8, seed=0)
        assert key in d.batch_at(0)


# -- optimizers ----------------------------------------------------------------


def _quadratic_losses(opt, steps=120):
    """Minimise ||Wx - y||^2; returns loss history."""
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(16,)), jnp.float32)
    y = jnp.asarray(r.normal(size=(8,)), jnp.float32)
    params = {"w": jnp.asarray(r.normal(size=(8, 16)) * 0.1, jnp.float32)}

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"] @ x - y))

    state = opt.init(params)
    hist = []
    for _ in range(steps):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state, _ = opt.update(g, state, params)
        hist.append(float(loss))
    return hist


@pytest.mark.parametrize(
    "opt",
    [
        AdamW(constant(3e-2), weight_decay=0.0),
        SGD(constant(1e-2)),
        Adafactor(constant(3e-2)),
    ],
    ids=["adamw", "sgd", "adafactor"],
)
def test_optimizers_minimize_quadratic(opt):
    hist = _quadratic_losses(opt)
    assert hist[-1] < hist[0] * 0.1


def test_adamw_master_weights_are_copies():
    params = {"w": jnp.ones((4,), jnp.float32)}
    opt = AdamW(constant(1e-3))
    state = opt.init(params)
    # distinct buffers (donation safety)
    assert state["master"]["w"] is not params["w"]


def test_schedules():
    s = warmup_cosine(1.0, 10, 100)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0, abs=0.05)
    assert float(s(100)) < float(s(50))
    lin = warmup_linear(1.0, 10, 110)
    assert float(lin(110)) == pytest.approx(0.0, abs=1e-6)
    assert float(constant(0.5)(3)) == 0.5


def test_grad_clip():
    from repro.optim import clip_by_global_norm

    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 1.0
    from repro.utils.trees import tree_global_norm

    assert float(tree_global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
