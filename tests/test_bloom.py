"""Bloom filter / Open-sieve tests: the paper's core selection mechanism.

The load-bearing property is the Bloom contract: NO false negatives — the
paper's "100% true negative rate". Hypothesis drives it with arbitrary
problem-size sets.
"""

import numpy as np
import pytest
from hypothesis_compat import given, settings, st  # skips @given tests when hypothesis is absent

from repro.core.bloom import BloomFilter, encode_mnk, murmur3_32, optimal_params
from repro.core.opensieve import OpenSieve
from repro.core.policies import ALL_POLICIES, DP, ALL_SK


def test_murmur3_reference_vectors():
    # canonical MurmurHash3_x86_32 vectors
    assert murmur3_32(b"") == 0x0
    assert murmur3_32(b"", 1) == 0x514E28B7
    assert murmur3_32(b"", 0xFFFFFFFF) == 0x81F16F39
    assert murmur3_32(b"\xff\xff\xff\xff") == 0x76293B50
    assert murmur3_32(b"!Ce\x87") == 0xF55B516B
    assert murmur3_32(b"Hello, world!", 1234) == 0xFAF6CDB3
    assert (
        murmur3_32(b"The quick brown fox jumps over the lazy dog", 0x9747B28C)
        == 0x2FA826CD
    )


sizes_strategy = st.lists(
    st.tuples(
        st.integers(min_value=1, max_value=2**20),
        st.integers(min_value=1, max_value=2**20),
        st.integers(min_value=1, max_value=2**20),
    ),
    min_size=1,
    max_size=200,
    unique=True,
)


@settings(max_examples=50, deadline=None)
@given(sizes_strategy, st.integers(min_value=0, max_value=10))
def test_no_false_negatives(sizes, seed):
    bf = BloomFilter.for_capacity(1000, 0.01, seed=seed)
    for m, n, k in sizes:
        bf.add_mnk(m, n, k)
    for m, n, k in sizes:
        assert bf.query_mnk(m, n, k), "Bloom contract broken: false negative"


def test_false_positive_rate_within_bound():
    bf = BloomFilter.for_capacity(10_000, 0.01, seed=1)
    rng = np.random.default_rng(0)
    inserted = {(int(m), int(n), int(k)) for m, n, k in rng.integers(1, 2**30, (10_000, 3))}
    for m, n, k in inserted:
        bf.add_mnk(m, n, k)
    probes = 20_000
    fp = 0
    for m, n, k in rng.integers(2**30, 2**31, (probes, 3)):
        if bf.query_mnk(int(m), int(n), int(k)):
            fp += 1
    assert fp / probes < 0.05  # 5x headroom over the design point


def test_serialization_roundtrip():
    bf = BloomFilter.for_capacity(100, 0.01, seed=7)
    for i in range(50):
        bf.add_mnk(i, 2 * i + 1, 3 * i + 2)
    bf2 = BloomFilter.from_bytes(bf.to_bytes())
    assert np.array_equal(bf.bits, bf2.bits)
    assert (bf2.n_bits, bf2.n_hashes, bf2.seed, bf2.n_items) == (
        bf.n_bits,
        bf.n_hashes,
        bf.seed,
        bf.n_items,
    )
    for i in range(50):
        assert bf2.query_mnk(i, 2 * i + 1, 3 * i + 2)


def test_merge():
    a = BloomFilter.for_capacity(100, 0.01, seed=3)
    b = BloomFilter.for_capacity(100, 0.01, seed=3)
    a.add_mnk(1, 2, 3)
    b.add_mnk(4, 5, 6)
    c = a.merge(b)
    assert c.query_mnk(1, 2, 3) and c.query_mnk(4, 5, 6)
    with pytest.raises(ValueError):
        a.merge(BloomFilter.for_capacity(100, 0.01, seed=4))


def test_merge_n_items_is_upper_bound_est_items_is_honest():
    """Merging filters with overlapping key sets double-counts ``n_items``
    (dedupe-agnostic OR); the saturation-based ``est_items`` stays close to
    the true distinct-key count, which is what occupancy planning reads."""
    a = BloomFilter.for_capacity(1000, 0.01, seed=3)
    b = BloomFilter.for_capacity(1000, 0.01, seed=3)
    for i in range(200):
        a.add_mnk(i, i + 1, i + 2)
    for i in range(100, 300):  # 100 keys overlap with a
        b.add_mnk(i, i + 1, i + 2)
    c = a.merge(b)
    assert c.n_items == 400  # upper bound: 100 duplicates double-counted
    assert abs(c.est_items - 300) / 300 < 0.1  # dedupe-aware estimate
    # identical merge is the worst case: n_items doubles, est_items doesn't
    d = a.merge(a)
    assert d.n_items == 400
    assert abs(d.est_items - 200) / 200 < 0.1


def test_opensieve_summary_exposes_est_items():
    from repro.core.opensieve import OpenSieve
    from repro.core.policies import ALL_POLICIES, ALL_SK

    sieve = OpenSieve(ALL_POLICIES, capacity=1000)
    for i in range(50):
        sieve.insert_winner((i + 1, 64, 64), ALL_SK)
    s = sieve.summary()[ALL_SK.name]
    assert s["n_items"] == 50
    assert abs(s["est_items"] - 50) / 50 < 0.15


def test_optimal_params_monotone():
    b1, k1 = optimal_params(1000, 0.01)
    b2, k2 = optimal_params(1000, 0.001)
    assert b2 > b1 and k2 >= k1


@settings(max_examples=20, deadline=None)
@given(sizes_strategy)
def test_jax_bloom_bit_exact(sizes):
    """The vectorised jnp murmur/bloom query matches the Python one."""
    import jax.numpy as jnp

    from repro.core.jax_bloom import bloom_query, mnk_to_words, murmur3_32_words

    bf = BloomFilter.for_capacity(500, 0.02, seed=5)
    for m, n, k in sizes[: len(sizes) // 2 or 1]:
        bf.add_mnk(m, n, k)
    ms = jnp.asarray([s[0] for s in sizes])
    ns = jnp.asarray([s[1] for s in sizes])
    ks = jnp.asarray([s[2] for s in sizes])
    # murmur parity on the canonical key encoding
    words = mnk_to_words(ms, ns, ks)
    got_h = np.asarray(murmur3_32_words(words, np.uint32(bf.seed)))
    want_h = np.array(
        [murmur3_32(encode_mnk(*s), bf.seed) for s in sizes], dtype=np.uint32
    )
    np.testing.assert_array_equal(got_h, want_h)
    # full query parity
    got = np.asarray(bloom_query(bf.bits, bf.n_bits, bf.n_hashes, bf.seed, ms, ns, ks))
    want = np.array([bf.query_mnk(*s) for s in sizes])
    np.testing.assert_array_equal(got, want)


def test_opensieve_build_query_tn():
    sieve = OpenSieve(ALL_POLICIES, capacity=1000)
    winners = {}
    rng = np.random.default_rng(0)
    pols = list(ALL_POLICIES)
    for i in range(200):
        size = tuple(int(x) for x in rng.integers(1, 8192, 3))
        winners[size] = pols[i % len(pols)]
    sieve.build_from_winners(winners)
    assert sieve.validate_true_negative_rate(winners) == 1.0
    # every winner policy must be among the candidates for its size
    for size, pol in winners.items():
        cands = sieve.candidates(size)
        assert pol in cands
    assert sieve.stats.elimination_rate > 0.5  # most policies pruned


def test_opensieve_serialization_and_header():
    sieve = OpenSieve(ALL_POLICIES, capacity=100)
    sieve.insert_winner((64, 64, 64), DP)
    sieve.insert_winner((128, 256, 8192), ALL_SK)
    blob = sieve.to_bytes()
    sieve2 = OpenSieve.from_bytes(blob)
    assert DP in sieve2.candidates((64, 64, 64))
    assert ALL_SK in sieve2.candidates((128, 256, 8192))
    hdr = sieve.encode_cpp_header()
    assert "#pragma once" in hdr and "opensieve" in hdr
    assert "dp_bits[]" in hdr and "all_sk_n_hashes" in hdr
