"""Loop-aware HLO cost analysis unit tests (synthetic HLO text)."""

import pytest

from repro.dist.hlo import parse_collectives
from repro.dist.hlo_cost import HloCostModel, analyze

SYNTHETIC = """
HloModule test

%body (p: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %p = (s32[], f32[8,16]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%p), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%dot.1), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]) tuple(%ip, %ar)
}

%cond (pc: (s32[], f32[8,16])) -> pred[] {
  %pc = (s32[], f32[8,16]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(12)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (arg: f32[8,16]) -> f32[8,16] {
  %arg = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[8,16]) tuple(%zero, %arg)
  %loop = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
  %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
  %big = f32[128,256]{1,0} constant({...})
  %ag = f32[128,256]{1,0} all-gather(%out), dimensions={0}
  ROOT %copy = f32[8,16]{1,0} copy(%out)
}
"""


def test_trip_count_multiplies_flops():
    c = analyze(SYNTHETIC)
    # dot: 2 * (8*16) * 16 = 4096 flops, x12 trips
    assert c.flops == pytest.approx(4096 * 12)


def test_collectives_with_loop_multiplier():
    c = analyze(SYNTHETIC)
    # all-reduce in loop: 8*16*4 bytes * 2 (multiplier) * 12 trips
    # all-gather outside: 128*256*4 bytes * 1
    want = 8 * 16 * 4 * 2 * 12 + 128 * 256 * 4
    assert c.coll_bytes == pytest.approx(want)
    assert c.coll_counts["all-reduce"] == 12
    assert c.coll_counts["all-gather"] == 1


def test_symbol_table_resolves_operand_shapes():
    m = HloCostModel(SYNTHETIC)
    tab = m._symtab("body")
    assert tab["x"] == ("f32", (8, 16))
    assert tab["w"] == ("f32", (16, 16))


def test_plain_parser_counts_without_loops():
    stats = parse_collectives(SYNTHETIC)
    # the single-pass parser sees each op once (loop-unaware by design)
    assert stats.per_op["all-reduce"][0] == 1
    assert stats.per_op["all-gather"][0] == 1


NESTED = """
HloModule nested

%inner_body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[4]{0} get-tuple-element(%p), index=1
  %w2 = f32[4,4]{1,0} constant({...})
  %dot.9 = f32[4]{0} dot(%x, %w2), lhs_contracting_dims={0}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[4]) tuple(%ip, %dot.9)
}

%inner_cond (pc: (s32[], f32[4])) -> pred[] {
  %pc = (s32[], f32[4]) parameter(0)
  %ic = s32[] get-tuple-element(%pc), index=0
  %n = s32[] constant(5)
  ROOT %lt = pred[] compare(%ic, %n), direction=LT
}

%outer_body (q: (s32[], f32[4])) -> (s32[], f32[4]) {
  %q = (s32[], f32[4]) parameter(0)
  %j = s32[] get-tuple-element(%q), index=0
  %y = f32[4]{0} get-tuple-element(%q), index=1
  %zero = s32[] constant(0)
  %init = (s32[], f32[4]) tuple(%zero, %y)
  %loop2 = (s32[], f32[4]) while(%init), condition=%inner_cond, body=%inner_body
  %y2 = f32[4]{0} get-tuple-element(%loop2), index=1
  %one = s32[] constant(1)
  %jp = s32[] add(%j, %one)
  ROOT %t = (s32[], f32[4]) tuple(%jp, %y2)
}

%outer_cond (qc: (s32[], f32[4])) -> pred[] {
  %qc = (s32[], f32[4]) parameter(0)
  %jc = s32[] get-tuple-element(%qc), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%jc, %n), direction=LT
}

ENTRY %main (arg: f32[4]) -> f32[4] {
  %arg = f32[4]{0} parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[4]) tuple(%zero, %arg)
  %loop = (s32[], f32[4]) while(%init), condition=%outer_cond, body=%outer_body
  ROOT %out = f32[4]{0} get-tuple-element(%loop), index=1
}
"""


def test_nested_loops_multiply():
    c = analyze(NESTED)
    # inner dot: 2*4*4 = 32 flops; x5 inner x3 outer = 480
    assert c.flops == pytest.approx(32 * 5 * 3)


def test_bf16_shadow_detection():
    from repro.launch.dryrun import _bf16_shadow_bytes

    txt = """
  %a = bf16[8192,8192]{1,0} parameter(0)
  %b = f32[8192,8192]{1,0} convert(%a)
  %c = f32[17,3]{1,0} convert(%x)
"""
    # 8192*8192*4 = 256 MiB > threshold; the (17,3) is below threshold
    assert _bf16_shadow_bytes(txt) == 8192 * 8192 * 4
