import os
import sys

# Tests run on the host's real device count (1 CPU device) — the 512-device
# forcing is dryrun.py-only. Subprocess-based tests set their own XLA_FLAGS.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def tiny(arch: str, **overrides):
    """Reduced config, f32 for exact comparisons."""
    from repro.configs import get_reduced

    cfg = dataclasses.replace(get_reduced(arch), dtype="float32")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def make_batch(cfg, b=2, s=16, seed=0):
    import jax.numpy as jnp

    r = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(r.integers(0, cfg.vocab_size, (b, s))),
        "labels": jnp.asarray(r.integers(0, cfg.vocab_size, (b, s))),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            r.normal(size=(b, cfg.n_patches, cfg.d_model)), jnp.float32
        )
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            r.normal(size=(b, cfg.enc_frames, cfg.d_model)), jnp.float32
        )
    return batch
