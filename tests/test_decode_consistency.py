"""Serving correctness: prefill+decode must reproduce teacher-forced logits
for every architecture family (the KV-cache/state plumbing proof)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny
from repro.dist.sharding import materialize_tree
from repro.models import build_model

FAMS = [
    "granite-8b",  # dense GQA
    "gemma3-27b",  # local:global windowed
    "olmoe-1b-7b",  # MoE
    "mamba2-1.3b",  # SSM state decode
    "zamba2-1.2b",  # hybrid
    "whisper-large-v3",  # enc-dec cross-attention
    "llava-next-34b",  # VLM patch offsets
]


@pytest.mark.parametrize("arch", FAMS)
def test_decode_matches_teacher_forced(arch):
    cfg = tiny(arch)
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, b, s)
    toks = batch["tokens"]

    if cfg.family == "encdec":
        frames = batch["frames"]
        full, _ = model.forward(params, frames, toks)
        logits_p, cache = model.prefill(params, frames, toks[:, : s - 1], max_seq=s)
        logits_d, _ = model.decode_step(
            params, cache, toks[:, s - 1 : s], jnp.full((b,), s - 1)
        )
        last_tok = None
    elif cfg.family == "vlm":
        pe = batch["patch_embeds"]
        p = cfg.n_patches
        full, _ = model.forward(params, toks, patch_embeds=pe)
        logits_p, cache = model.prefill(
            params, toks[:, : s - 1], max_seq=s, patch_embeds=pe
        )
        # sequence position s-1 holds TEXT token s-1-p
        logits_d, _ = model.decode_step(
            params, cache, toks[:, s - 1 - p : s - p], jnp.full((b,), s - 1)
        )
    else:
        full, _ = model.forward(params, toks)
        logits_p, cache = model.prefill(params, toks[:, : s - 1], max_seq=s)
        logits_d, _ = model.decode_step(
            params, cache, toks[:, s - 1 : s], jnp.full((b,), s - 1)
        )

    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0]), np.asarray(full[:, -2]), rtol=2e-3, atol=2e-3
    )
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3
    )


@pytest.mark.parametrize("arch", ["granite-8b", "mamba2-1.3b"])
def test_multi_step_decode_chain(arch):
    """Greedy-decode 6 tokens step by step; re-prefilling the grown prompt
    must give the same next-token logits at every step."""
    cfg = tiny(arch)
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(1))
    b, s0, steps = 1, 8, 6
    r = np.random.default_rng(0)
    prompt = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s0)))
    max_seq = s0 + steps + 1

    logits, cache = model.prefill(params, prompt, max_seq=max_seq)
    toks = prompt
    for i in range(steps):
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        toks = jnp.concatenate([toks, nxt], axis=1)
        # reference: teacher-forced full forward of the grown prompt
        ref_logits, _ = model.forward(params, toks)
        logits_d, cache = model.decode_step(
            params, cache, nxt, jnp.full((b,), s0 + i)
        )
        np.testing.assert_allclose(
            np.asarray(logits_d[:, 0]),
            np.asarray(ref_logits[:, -1]),
            rtol=5e-3,
            atol=5e-3,
        )
        logits = logits_d
