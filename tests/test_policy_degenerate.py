"""Differential coverage for the policy/shape degenerate paths.

Interpret-mode Pallas kernels vs the ``jnp.dot`` oracle, sweeping all 8
policies x epilogues x odd shapes — including the ``rem == 0`` HYBRID(1)
case where ``sk_tile_count`` returns 0 and ``gemm`` silently degrades to a
pure-DP launch — across f32 and bf16 and the swept grid sizes.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.op import Epilogue
from repro.core.policies import ALL_POLICIES, ALL_SK, DP, HYBRIDS, TileConfig
from repro.core.workpart import GemmShape, partition, sk_tile_count
from repro.kernels.dp import ops as dp_ops
from repro.kernels.splitk import ops as splitk_ops
from repro.kernels.streamk import ops as sk_ops

CFG = TileConfig(8, 128, 128)
ODD = (17, 200, 300)  # ragged on every dim: 3x2 tiles, padding everywhere


def _mk(m, n, k, dtype, seed=0):
    r = np.random.default_rng(seed)
    a = jnp.asarray(r.normal(size=(m, k)), dtype)
    b = jnp.asarray(r.normal(size=(k, n)), dtype)
    return a, b


def _tol(dtype):
    return (
        dict(rtol=2e-2, atol=2e-2)
        if dtype == jnp.bfloat16
        else dict(rtol=1e-4, atol=1e-4)
    )


def _oracle(a, b, epilogue=None, bias=None, operand=None):
    acc = jnp.dot(
        a.astype(jnp.float32), b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if epilogue is not None:
        acc = epilogue.apply(
            acc,
            bias=None if bias is None else bias,
            operand=None if operand is None else operand,
        )
    return acc


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("g", [4, 16])
@pytest.mark.parametrize("policy", ALL_POLICIES, ids=lambda p: p.name)
def test_all_policies_all_grids_match_oracle(policy, g, dtype):
    m, n, k = ODD
    a, b = _mk(m, n, k, dtype)
    want = _oracle(a, b)
    got = sk_ops.gemm(
        a, b, policy=policy, cfg=CFG, g=g, interpret=True, out_dtype=jnp.float32
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))


# ---------------------------------------------------------------------------
# rem == 0: HYBRID(1) silently degrades to DP
# ---------------------------------------------------------------------------


def test_hybrid1_rem0_has_no_sk_region():
    # 16x256 with 8x128 tiles -> 2x2 = 4 output tiles; g=4 divides evenly,
    # so HYBRID(1)'s remainder wave is empty and the schedule IS pure DP
    assert sk_tile_count(4, 4, HYBRIDS[0]) == 0
    part = partition(GemmShape(16, 256, 384), CFG, 4, HYBRIDS[0])
    assert part.sk_tiles == 0 and part.dp_tiles == 4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16], ids=["f32", "bf16"])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_hybrid1_rem0_degrades_to_dp_and_matches_oracle(g, dtype):
    """4 tiles, g | 4: sk_tile_count == 0 and the kernel must still produce
    the exact GEMM through the pure-DP fallback launch at that g."""
    m, n, k = 16, 256, 384
    assert sk_tile_count(4, g, HYBRIDS[0]) == 0
    a, b = _mk(m, n, k, dtype, seed=1)
    want = _oracle(a, b)
    got = sk_ops.gemm(
        a, b, policy=HYBRIDS[0], cfg=CFG, g=g, interpret=True, out_dtype=jnp.float32
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **_tol(dtype))
    # ... and matches the DP policy bit-for-bit (same schedule)
    dp = sk_ops.gemm(
        a, b, policy=DP, cfg=CFG, g=g, interpret=True, out_dtype=jnp.float32
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(dp))


# ---------------------------------------------------------------------------
# epilogues x policies x grid sizes
# ---------------------------------------------------------------------------

EPILOGUES = [
    Epilogue(bias=True, activation="gelu"),
    Epilogue(binary="mul_silu"),
    Epilogue(bias=True, activation="silu", binary="add"),
]


@pytest.mark.parametrize("g", [4, 16])
@pytest.mark.parametrize("epi", EPILOGUES, ids=lambda e: e.name)
@pytest.mark.parametrize(
    "policy", [DP, ALL_SK, HYBRIDS[0], HYBRIDS[3]], ids=lambda p: p.name
)
def test_epilogue_fusion_across_policies_and_grids(policy, epi, g):
    m, n, k = 24, 384, 640  # 3x3 tiles over g=4: quantized remainder wave
    a, b = _mk(m, n, k, jnp.float32, seed=2)
    r = np.random.default_rng(3)
    bias = jnp.asarray(r.normal(size=(n,)), jnp.float32) if epi.bias else None
    operand = (
        jnp.asarray(r.normal(size=(m, n)), jnp.float32)
        if epi.binary != "none"
        else None
    )
    want = _oracle(a, b, epilogue=epi, bias=bias, operand=operand)
    got = sk_ops.gemm(
        a,
        b,
        policy=policy,
        cfg=CFG,
        g=g,
        interpret=True,
        out_dtype=jnp.float32,
        epilogue=epi,
        bias=bias,
        operand=operand,
    )
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4
    )


# ---------------------------------------------------------------------------
# g threads into the dp / splitk baseline packages too
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g", [0, 3, 4, 16])
def test_dp_ops_wave_grid_matches_oracle(g):
    a, b = _mk(*ODD, jnp.float32, seed=4)
    got = dp_ops.gemm(a, b, cfg=CFG, g=g, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a, b)), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("g", [0, 3, 8])
def test_splitk_ops_wave_grid_matches_oracle(g):
    a, b = _mk(24, 256, 512, jnp.float32, seed=5)
    got = splitk_ops.gemm(a, b, cfg=CFG, s=2, g=g, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(_oracle(a, b)), rtol=1e-4, atol=1e-4
    )
