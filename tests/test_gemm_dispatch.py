"""GEMM dispatch API: correctness, selection logging, backend routing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gemm import current_log, gemm, gemm_context
from repro.core.policies import ALL_SK, DP, TileConfig
from repro.core.selector import KernelSelector, default_selector
from repro.core.tuner import Tuner


def test_gemm_matches_dot():
    r = np.random.default_rng(0)
    x = jnp.asarray(r.normal(size=(3, 7, 32)), jnp.float32)
    w = jnp.asarray(r.normal(size=(32, 16)), jnp.float32)
    with gemm_context(selector=default_selector()):
        got = gemm(x, w)
    want = jnp.dot(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_dispatch_logs_local_shape():
    x = jnp.ones((4, 8, 32), jnp.float32)
    w = jnp.ones((32, 64), jnp.float32)
    with gemm_context(selector=default_selector()) as ctx:
        gemm(x, w, divisors=(4, 2, 1), tag="t")
    [e] = ctx.log
    assert e.global_mnk == (32, 64, 32)
    assert e.local_mnk == (8, 32, 32)
    assert e.tag == "t"


def test_forced_policy_bypasses_selector():
    x = jnp.ones((2, 32), jnp.float32)
    w = jnp.ones((32, 8), jnp.float32)
    with gemm_context(selector=default_selector()) as ctx:
        gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128))
    assert ctx.log[0].selection.source == "forced"
    assert ctx.log[0].selection.policy == ALL_SK


def test_pallas_interpret_backend():
    r = np.random.default_rng(1)
    x = jnp.asarray(r.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(r.normal(size=(64, 128)), jnp.float32)
    with gemm_context(selector=default_selector(), backend="pallas_interpret"):
        got = gemm(x, w, policy=ALL_SK, cfg=TileConfig(8, 128, 128))
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.dot(x, w)), rtol=1e-4, atol=1e-4)


def test_pallas_backend_uses_tuned_selection():
    sizes = [(16, 128, 64)]
    db = Tuner().tune(sizes)
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    r = np.random.default_rng(2)
    x = jnp.asarray(r.normal(size=(16, 64)), jnp.float32)
    w = jnp.asarray(r.normal(size=(64, 128)), jnp.float32)
    with gemm_context(selector=sel, backend="xla") as ctx:
        got = gemm(x, w)
    assert ctx.log[0].selection.source == "tuned"
    np.testing.assert_allclose(np.asarray(got), np.asarray(jnp.dot(x, w)), rtol=1e-5)


def test_contraction_mismatch_raises():
    with pytest.raises(ValueError):
        gemm(jnp.ones((4, 8)), jnp.ones((9, 2)))


def test_gemm_under_jit_traces_once():
    sel = default_selector()

    @jax.jit
    def f(x, w):
        with gemm_context(selector=sel):
            return gemm(x, w)

    x = jnp.ones((4, 32))
    w = jnp.ones((32, 8))
    f(x, w)
    lookups = sel.stats.lookups
    f(x * 2, w)  # cached trace: no new selection
    assert sel.stats.lookups == lookups
