"""Perf-variant implementations must be numerically equivalent to their
baselines (the §Perf contract: scheduling/sharding changes, never semantics).
"""

import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny
from repro.dist.sharding import materialize_tree
from repro.models import build_model


def test_moe_hinted_equals_global():
    cfg = tiny("olmoe-1b-7b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32)
    l1, _ = model.loss_fn(params, batch)
    m2 = build_model(dataclasses.replace(cfg, moe_impl="hinted"))
    l2, _ = m2.loss_fn(params, batch)
    assert abs(float(l1) - float(l2)) < 1e-4


def test_mha_expand_equals_gqa():
    cfg = tiny("llava-next-34b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    f1, _ = model.forward(params, batch["tokens"], patch_embeds=batch["patch_embeds"])
    m2 = build_model(dataclasses.replace(cfg, attn_impl="mha_expand"))
    f2, _ = m2.forward(params, batch["tokens"], patch_embeds=batch["patch_embeds"])
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4, atol=1e-4)


def test_attn_remat_bitwise_grads():
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    g1 = jax.grad(lambda p: model.loss_fn(p, batch)[0])(params)
    m2 = build_model(dataclasses.replace(cfg, attn_remat=True))
    g2 = jax.grad(lambda p: m2.loss_fn(p, batch)[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_attn_chunk_invariance():
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 64)
    f1, _ = model.forward(params, batch["tokens"])
    m2 = build_model(dataclasses.replace(cfg, attn_chunk=16))
    f2, _ = m2.forward(params, batch["tokens"])
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=2e-4, atol=2e-4)


SHARD_MAP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "@SRC@")
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_reduced
from repro.models import build_model
from repro.dist.sharding import ShardingPlan, materialize_tree, use_plan
from repro.models.layers import moe_apply

cfg = dataclasses.replace(get_reduced("olmoe-1b-7b"), dtype="float32")
model = build_model(cfg)
params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
p0 = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
r = np.random.default_rng(0)
x = jnp.asarray(r.normal(size=(8, 16, cfg.d_model)) * 0.3, jnp.float32)
ref, _ = moe_apply(p0, x, cfg, div={})
mesh = jax.make_mesh((4, 2), ("data", "model"))
cfg2 = dataclasses.replace(cfg, moe_impl="shard_map")
with use_plan(ShardingPlan(mesh)):
    got, _ = jax.jit(lambda p, x: moe_apply(p, x, cfg2, div={"batch": 4, "model": 2}))(p0, x)
    g1 = jax.grad(lambda p: jnp.sum(moe_apply(p, x, cfg, div={})[0] ** 2))(p0)
    g2 = jax.jit(jax.grad(lambda p: jnp.sum(moe_apply(p, x, cfg2, div={"batch": 4, "model": 2})[0] ** 2)))(p0)
err = float(jnp.max(jnp.abs(got - ref)))
gerr = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)))
assert err < 2e-3, err
assert gerr < 1e-3, gerr
print("OK", err, gerr)
"""


def test_shard_map_moe_on_8dev_mesh():
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    r = subprocess.run(
        [sys.executable, "-c", SHARD_MAP_SCRIPT.replace("@SRC@", src)],
        capture_output=True,
        text=True,
        timeout=560,
    )
    assert r.returncode == 0, r.stderr[-3000:]
    assert "OK" in r.stdout


def test_int8_kv_cache_decode_close_to_fp():
    """Quantized KV cache (decode memory-term optimization): decode logits
    within 5% relative of the fp cache path."""
    cfg = tiny("granite-8b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    r = np.random.default_rng(0)
    b, s = 2, 16
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)))
    full, _ = model.forward(params, toks)
    m8 = build_model(dataclasses.replace(cfg, kv_cache_dtype="int8"))
    _, cache = m8.prefill(params, toks[:, : s - 1], max_seq=s)
    ld, _ = m8.decode_step(params, cache, toks[:, s - 1 : s], jnp.full((b,), s - 1))
    rel = float(jnp.max(jnp.abs(ld[:, 0] - full[:, -1]))) / float(
        jnp.max(jnp.abs(full[:, -1]))
    )
    assert rel < 0.05, rel


def test_windowed_cache_decode_exact():
    """gemma3-style windowed ring caches: decode chain from an empty cache
    must reproduce the teacher-forced forward exactly (window masking ==
    ring buffer semantics)."""
    from repro.dist.sharding import ArraySpec

    cfg = tiny("gemma3-27b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    mw = build_model(dataclasses.replace(cfg, window_cache=True))
    b, s = 2, 16
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)))
    full, _ = model.forward(params, toks)
    cache = jax.tree.map(
        lambda sp: jnp.zeros(sp.shape, sp.dtype),
        mw.cache_specs(b, s),
        is_leaf=lambda x: isinstance(x, ArraySpec),
    )
    for t in range(s):
        logits, cache = mw.decode_step(
            params, cache, toks[:, t : t + 1], jnp.full((b,), t)
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )


def test_windowed_cache_prefill_handoff():
    """Uniform prefill -> windowed_cache_from_uniform -> windowed decode
    must equal teacher-forced logits (the production serving handoff)."""
    cfg = tiny("gemma3-27b")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    mw = build_model(dataclasses.replace(cfg, window_cache=True))
    b, s = 2, 16
    r = np.random.default_rng(0)
    toks = jnp.asarray(r.integers(0, cfg.vocab_size, (b, s)))
    full, _ = model.forward(params, toks)
    p0 = s - 4
    _, ucache = model.prefill(params, toks[:, :p0], max_seq=s)
    wcache = mw.windowed_cache_from_uniform(ucache, p0)
    for t in range(p0, s):
        logits, wcache = mw.decode_step(
            params, wcache, toks[:, t : t + 1], jnp.full((b,), t)
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, t]), rtol=2e-3, atol=2e-3
        )
