"""Pallas TPU kernels for the compute hot-spots the paper optimizes:

  * ``streamk``  -- the Stream-K++ work-centric GEMM (all seven policies),
  * ``dp``       -- the conventional data-parallel tiled GEMM baseline,
  * ``splitk``   -- the split-K baseline Stream-K generalises.

Each subpackage ships the raw ``pl.pallas_call`` kernel, an ``ops.py`` jit'd
wrapper (padding, partition plumbing, fix-up composition) and a ``ref.py``
pure-jnp oracle the tests assert against.
"""
