"""jit'd wrapper for the split-K baseline."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.policies import TileConfig
from repro.core.workpart import cdiv
from repro.kernels.common import pad_to, prep_scale, prep_scale_a, unpad
from repro.kernels.splitk.splitk_gemm import splitk_partials


@functools.partial(
    jax.jit, static_argnames=("cfg", "s", "g", "interpret", "out_dtype", "b_bits")
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    cfg: TileConfig = TileConfig(128, 128, 128),
    s: int = 2,
    g: int = 0,
    interpret: bool = False,
    out_dtype=None,
    scale: jax.Array = None,
    scale_a: jax.Array = None,
    b_bits: int = 8,
) -> jax.Array:
    """``a @ b`` with a fixed split-K factor ``s``. ``g`` > 0 launches the
    tile dimension in whole waves of ``g`` programs (the tuned grid size).
    ``scale`` (N,) is an int8-weight op's per-output-channel dequant vector
    and ``scale_a`` (M,) its int8-activation per-row partner; split-K's
    epilogue IS the partial-sum reduction, so both apply there — once,
    after the splits combine (linearity makes per-split scaling equivalent
    but ``s`` times the multiplies). ``b_bits == 4``: ``b`` is int4-packed
    (ceil(K/2), N); K comes from ``a`` and each kernel block is unpacked in
    the prologue."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"bad gemm operands {a.shape} @ {b.shape}")
    k_rows = (a.shape[1] + 1) // 2 if b_bits == 4 else a.shape[1]
    if b.shape[0] != k_rows:
        raise ValueError(
            f"bad gemm operands {a.shape} @ {b.shape} (b_bits={b_bits})"
        )
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    # pad K so that the k-iteration count divides s
    k_unit = cfg.bk * s
    ap = pad_to(a, (cfg.bm, k_unit))
    bp = pad_to(b, (k_unit // 2 if b_bits == 4 else k_unit, cfg.bn))
    parts = splitk_partials(ap, bp, cfg, s, interpret=interpret, g=g, b_bits=b_bits)
    cp = jnp.sum(parts, axis=0)
    scale_ap = prep_scale_a(scale_a, m, cfg.bm)
    if scale_ap is not None:
        cp = cp * scale_ap
    scalep = prep_scale(scale, n, cfg.bn)
    if scalep is not None:
        cp = cp * scalep
    return unpad(cp.astype(out_dtype), (m, n))
