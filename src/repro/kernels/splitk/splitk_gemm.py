"""Split-K GEMM Pallas kernel — the pre-Stream-K strategy (§2 of the paper):
the K dimension is split by a *fixed* factor ``s`` and each split's partial
C is reduced afterwards. Stream-K generalises this (the split adapts to the
work instead of being a fixed hyper-parameter); it is implemented here as a
baseline the benchmarks compare against.

Grid ``(m_tiles * n_tiles, s, k_per_split)``: each (tile, split) pair
accumulates its K-range into ``partials[s]``; the wrapper reduces over
``s`` (a tiny XLA reduction, exactly the "separate partial result
accumulation step" the paper describes split-K needing).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policies import TileConfig
from repro.core.quant import unpack_int4
from repro.core.workpart import cdiv
from repro.kernels.common import CompilerParams, mixed_dot, record_launch


def _splitk_kernel(a_ref, b_ref, p_ref, acc_ref, *, kps: int, b_bits: int = 8):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    b_blk = b_ref[...]
    if b_bits == 4:
        # packed (bk/2, bn) int4 block -> (bk, bn) int8 in the prologue
        b_blk = unpack_int4(b_blk)
    acc_ref[...] += mixed_dot(a_ref[...], b_blk)

    @pl.when(k == kps - 1)
    def _flush():
        p_ref[0] = acc_ref[...]


def splitk_partials(
    a,
    b,
    cfg: TileConfig,
    s: int,
    *,
    interpret: bool = False,
    g: int = 0,
    b_bits: int = 8,
):
    """Returns partials (s, Mp, Np) f32; caller reduces over axis 0.

    a, b already padded; K must split into s * k_per_split * bk.
    ``b_bits == 4``: ``b`` is int4-packed (Kp/2, Np) and each block is
    unpacked in the kernel prologue (same k-block index map — the packed
    block count equals the logical one for even bk).
    ``g`` > 0 pads the tile dimension up to whole waves of ``g`` programs
    (surplus programs redundantly recompute the last tile — deterministic,
    same value); 0 keeps the exact legacy one-program-per-tile grid.
    """
    mp, kp = a.shape
    _, np_ = b.shape
    bk_b = cfg.bk // 2 if b_bits == 4 else cfg.bk
    m_tiles, n_tiles = mp // cfg.bm, np_ // cfg.bn
    ipt = kp // cfg.bk
    assert ipt % s == 0, "split factor must divide k-iterations"
    kps = ipt // s
    n_total = m_tiles * n_tiles
    n_prog = cdiv(n_total, g) * g if g > 0 else n_total

    def tm(i):
        i = jnp.minimum(i, n_total - 1) if n_prog != n_total else i
        return i // n_tiles

    def tn(i):
        i = jnp.minimum(i, n_total - 1) if n_prog != n_total else i
        return i % n_tiles

    record_launch(f"splitk_gemm_{cfg.name}_s{s}")
    return pl.pallas_call(
        functools.partial(_splitk_kernel, kps=kps, b_bits=b_bits),
        grid=(n_prog, s, kps),
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), lambda i, sp, k: (tm(i), sp * kps + k)),
            pl.BlockSpec((bk_b, cfg.bn), lambda i, sp, k: (sp * kps + k, tn(i))),
        ],
        out_specs=pl.BlockSpec(
            (1, cfg.bm, cfg.bn), lambda i, sp, k: (sp, tm(i), tn(i))
        ),
        out_shape=jax.ShapeDtypeStruct((s, mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((cfg.bm, cfg.bn), jnp.float32)],
        interpret=interpret,
        compiler_params=CompilerParams(
            # surplus programs of a padded grid alias the final tile's
            # partials slot: that dim must drop to ARBITRARY (see dp_gemm)
            dimension_semantics=(
                pltpu.ARBITRARY if n_prog != n_total else pltpu.PARALLEL,
                pltpu.PARALLEL,
                pltpu.ARBITRARY,
            )
        ),
        name=f"splitk_gemm_{cfg.name}_s{s}",
    )(a, b)
