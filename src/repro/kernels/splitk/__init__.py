"""Split-K GEMM kernel family (K-sliced partials + reduction pass)."""

from repro.kernels.splitk import ops, ref
from repro.kernels.splitk.splitk_gemm import splitk_partials

__all__ = ["ops", "ref", "splitk_partials"]
