"""Conventional data-parallel tiled GEMM (the paper's comparison baseline),
as a Pallas TPU kernel.

Grid ``(n_region_tiles, iters_per_tile)``: the first dimension walks output
tiles (optionally starting at ``tile_offset`` — that is how the Stream-K++
HYBRID policies run their data-parallel region over tiles the Stream-K sweep
did not claim), the second streams the K dimension. The f32 accumulator
lives in VMEM scratch and is copied into the output block on the last
k-step, so the C dtype can be narrower than the accumulator.

Epilogue operands (bias column vector, binary operand matrix for
swiglu-mul / residual-add) stream in as extra blocked inputs and are applied
to the accumulator in the flush — fused, never a separate HBM pass.

With ``tile_offset > 0`` the kernel runs with ``input_output_aliases`` so the
tiles it does not visit keep the values already present in the aliased C
buffer (the fixed-up Stream-K tiles).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policies import TileConfig
from repro.core.workpart import cdiv
from repro.core.quant import unpack_int4
from repro.kernels.common import (
    CompilerParams,
    apply_epilogue,
    mixed_dot,
    record_launch,
)


def _dp_kernel(
    a_ref,
    b_ref,
    *rest,
    ipt: int,
    epilogue="none",
    has_scale: bool = False,
    has_scale_a: bool = False,
    has_bias: bool = False,
    has_operand: bool = False,
    b_bits: int = 8,
):
    """rest = [scale_ref?, scale_a_ref?, bias_ref?, operand_ref?, c_in_ref?]
    + (c_ref, acc_ref).

    ``b_bits == 4``: the B block arrives packed ``(bk/2, bn)`` (two int4
    nibbles per byte along K) and is unpacked to int8 in the prologue —
    the unpack lives in VMEM, so HBM still only moved half a byte per
    element. ``c_in_ref`` (the aliased C input under ``tile_offset > 0``)
    is never read — aliasing alone preserves unvisited tiles."""
    c_ref, acc_ref = rest[-2], rest[-1]
    extras = list(rest[:-2])
    scale_ref = extras.pop(0) if has_scale else None
    scale_a_ref = extras.pop(0) if has_scale_a else None
    bias_ref = extras.pop(0) if has_bias else None
    operand_ref = extras.pop(0) if has_operand else None

    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    b_blk = b_ref[...]
    if b_bits == 4:
        b_blk = unpack_int4(b_blk)
    acc_ref[...] += mixed_dot(a_ref[...], b_blk)

    @pl.when(k == ipt - 1)
    def _flush():
        out = apply_epilogue(
            acc_ref[...],
            epilogue,
            bias=None if bias_ref is None else bias_ref[...],
            operand=None if operand_ref is None else operand_ref[...],
            scale=None if scale_ref is None else scale_ref[...],
            scale_a=None if scale_a_ref is None else scale_a_ref[...],
        )
        c_ref[...] = out.astype(c_ref.dtype)


def dp_gemm_region(
    a,
    b,
    cfg: TileConfig,
    *,
    tile_offset: int = 0,
    c_init=None,
    out_dtype=None,
    interpret: bool = False,
    epilogue="none",
    bias=None,
    operand=None,
    scale=None,
    scale_a=None,
    b_bits: int = 8,
    g: int = 0,
):
    """Tiled GEMM over output tiles [tile_offset, m_tiles*n_tiles).

    a: (Mp, Kp), b: (Kp, Np) — already padded to tile multiples; so are the
    optional epilogue operands ``bias`` (1, Np), ``operand`` (Mp, Np), the
    int8-weight dequant row vector ``scale`` (1, Np) and the int8-activation
    dequant column vector ``scale_a`` (Mp, 1), applied to the accumulator at
    the flush before the other epilogue stages. ``b_bits == 4``: ``b`` is
    int4-packed ``(Kp/2, Np)`` — two nibbles per byte along K, padded to
    ``bk/2`` multiples — and each block is unpacked in the kernel prologue.
    ``c_init``: existing C buffer whose tiles < tile_offset must be kept
    (required iff tile_offset > 0).

    ``g`` > 0 launches the region in whole waves of ``g`` programs (the
    tuned grid size): the tile dimension is padded up to a multiple of ``g``
    and the surplus programs redundantly recompute the final tile (their
    index maps clamp to it, so every write is the same deterministic value).
    This makes wave quantization — what the cost model scores ``g`` on — a
    real property of the launched grid. ``g`` == 0 keeps the exact legacy
    one-program-per-tile grid.

    Cost of padding: up to ``g - 1`` redundant tile recomputes, and the
    padded tile dim drops to sequential (ARBITRARY) semantics because the
    surplus programs alias the final tile. The analytical model does not
    price that serialization — but on hardware the tuner's
    ``measure_wallclock`` times this exact kernel per swept ``g``, so a
    ``g`` whose padding costs more than its quantization win loses the
    sweep where it matters.
    """
    mp, kp = a.shape
    kp2, np_ = b.shape
    bk_b = cfg.bk // 2 if b_bits == 4 else cfg.bk
    assert kp2 == (kp // 2 if b_bits == 4 else kp), (a.shape, b.shape, b_bits)
    m_tiles, n_tiles = mp // cfg.bm, np_ // cfg.bn
    ipt = kp // cfg.bk
    n_total = m_tiles * n_tiles
    n_region = n_total - tile_offset
    assert n_region > 0, "empty DP region"
    out_dtype = out_dtype or a.dtype
    n_prog = cdiv(n_region, g) * g if g > 0 else n_region

    def tm(i):
        i = jnp.minimum(i, n_region - 1) if n_prog != n_region else i
        return (i + tile_offset) // n_tiles

    def tn(i):
        i = jnp.minimum(i, n_region - 1) if n_prog != n_region else i
        return (i + tile_offset) % n_tiles

    a_spec = pl.BlockSpec((cfg.bm, cfg.bk), lambda i, k: (tm(i), k))
    # packed-int4 B keeps the SAME k-block index map: ceil(ceil(K/2)/(bk/2))
    # == ceil(K/bk) for even bk, so packed block k covers logical k-block k.
    b_spec = pl.BlockSpec((bk_b, cfg.bn), lambda i, k: (k, tn(i)))
    c_spec = pl.BlockSpec((cfg.bm, cfg.bn), lambda i, k: (tm(i), tn(i)))
    scratch = [pltpu.VMEM((cfg.bm, cfg.bn), jnp.float32)]
    # A padded grid clamps its surplus programs onto the final tile, so the
    # tile dim no longer writes disjoint blocks — it must be ARBITRARY
    # (sequential, last identical write wins), not PARALLEL.
    tile_sem = pltpu.ARBITRARY if n_prog != n_region else pltpu.PARALLEL
    params = CompilerParams(
        dimension_semantics=(tile_sem, pltpu.ARBITRARY)
    )
    out_shape = jax.ShapeDtypeStruct((mp, np_), out_dtype)

    operands = [a, b]
    in_specs = [a_spec, b_spec]
    if scale is not None:
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, cfg.bn), lambda i, k: (0, tn(i))))
    if scale_a is not None:
        operands.append(scale_a)
        in_specs.append(pl.BlockSpec((cfg.bm, 1), lambda i, k: (tm(i), 0)))
    if bias is not None:
        operands.append(bias)
        in_specs.append(pl.BlockSpec((1, cfg.bn), lambda i, k: (0, tn(i))))
    if operand is not None:
        operands.append(operand)
        in_specs.append(c_spec)
    kernel = functools.partial(
        _dp_kernel,
        ipt=ipt,
        epilogue=epilogue,
        has_scale=scale is not None,
        has_scale_a=scale_a is not None,
        has_bias=bias is not None,
        has_operand=operand is not None,
        b_bits=b_bits,
    )

    record_launch(f"dp_gemm_{cfg.name}")
    if tile_offset == 0:
        return pl.pallas_call(
            kernel,
            grid=(n_prog, ipt),
            in_specs=in_specs,
            out_specs=c_spec,
            out_shape=out_shape,
            scratch_shapes=scratch,
            interpret=interpret,
            compiler_params=params,
            name=f"dp_gemm_{cfg.name}",
        )(*operands)

    assert c_init is not None, "tile_offset > 0 requires c_init"
    operands.append(c_init.astype(out_dtype))
    in_specs.append(c_spec)
    return pl.pallas_call(
        kernel,
        grid=(n_prog, ipt),
        in_specs=in_specs,
        out_specs=c_spec,
        out_shape=out_shape,
        scratch_shapes=scratch,
        input_output_aliases={len(operands) - 1: 0},
        interpret=interpret,
        compiler_params=params,
        name=f"dp_gemm_region_{cfg.name}",
    )(*operands)
