"""Pure-jnp oracle for the data-parallel GEMM baseline."""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a, b, out_dtype=None):
    """f32-accumulated ``a @ b`` cast to ``out_dtype`` (defaults to a.dtype)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)
