"""Data-parallel baseline GEMM kernel family (one workgroup per tile)."""

from repro.kernels.dp import ops, ref
from repro.kernels.dp.dp_gemm import dp_gemm_region

__all__ = ["ops", "ref", "dp_gemm_region"]
