"""jit'd wrapper for the data-parallel tiled GEMM baseline."""

from __future__ import annotations

import functools

import jax

from repro.core.policies import TileConfig
from repro.kernels.common import pad_to, prep_scale, prep_scale_a, unpad
from repro.kernels.dp.dp_gemm import dp_gemm_region


@functools.partial(
    jax.jit, static_argnames=("cfg", "g", "interpret", "out_dtype", "b_bits")
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    cfg: TileConfig = TileConfig(128, 128, 128),
    g: int = 0,
    interpret: bool = False,
    out_dtype=None,
    scale: jax.Array = None,
    scale_a: jax.Array = None,
    b_bits: int = 8,
) -> jax.Array:
    """``a @ b`` with the conventional output-tile decomposition.

    ``g`` > 0 launches whole waves of ``g`` programs (the tuned grid size);
    0 keeps the legacy one-program-per-tile grid. ``scale`` (N,) fuses an
    int8-weight op's per-output-channel dequant into the tile flush;
    ``scale_a`` (M,) its int8-activation per-row partner (the rank-1
    rescale of an int8xint8 op). ``b_bits == 4``: ``b`` is int4-packed
    (ceil(K/2), N) — K is taken from ``a`` and each kernel prologue unpacks
    its block."""
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"bad gemm operands {a.shape} @ {b.shape}")
    k_rows = (a.shape[1] + 1) // 2 if b_bits == 4 else a.shape[1]
    if b.shape[0] != k_rows:
        raise ValueError(
            f"bad gemm operands {a.shape} @ {b.shape} (b_bits={b_bits})"
        )
    m, _ = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    ap = pad_to(a, (cfg.bm, cfg.bk))
    bp = pad_to(b, (cfg.bk // 2 if b_bits == 4 else cfg.bk, cfg.bn))
    scalep = prep_scale(scale, n, cfg.bn)
    scale_ap = prep_scale_a(scale_a, m, cfg.bm)
    cp = dp_gemm_region(
        ap,
        bp,
        cfg,
        out_dtype=out_dtype,
        interpret=interpret,
        g=g,
        scale=scalep,
        scale_a=scale_ap,
        b_bits=b_bits,
    )
    return unpad(cp, (m, n))
