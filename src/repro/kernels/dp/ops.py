"""jit'd wrapper for the data-parallel tiled GEMM baseline."""

from __future__ import annotations

import functools

import jax

from repro.core.policies import TileConfig
from repro.kernels.common import pad_to, prep_scale, unpad
from repro.kernels.dp.dp_gemm import dp_gemm_region


@functools.partial(jax.jit, static_argnames=("cfg", "g", "interpret", "out_dtype"))
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    cfg: TileConfig = TileConfig(128, 128, 128),
    g: int = 0,
    interpret: bool = False,
    out_dtype=None,
    scale: jax.Array = None,
) -> jax.Array:
    """``a @ b`` with the conventional output-tile decomposition.

    ``g`` > 0 launches whole waves of ``g`` programs (the tuned grid size);
    0 keeps the legacy one-program-per-tile grid. ``scale`` (N,) fuses an
    int8-weight op's per-output-channel dequant into the tile flush."""
    if a.ndim != 2 or b.ndim != 2 or a.shape[1] != b.shape[0]:
        raise ValueError(f"bad gemm operands {a.shape} @ {b.shape}")
    m, _ = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype
    ap = pad_to(a, (cfg.bm, cfg.bk))
    bp = pad_to(b, (cfg.bk, cfg.bn))
    scalep = prep_scale(scale, n, cfg.bn)
    cp = dp_gemm_region(
        ap, bp, cfg, out_dtype=out_dtype, interpret=interpret, g=g, scale=scalep
    )
    return unpad(cp, (m, n))
