"""Shared helpers for the Pallas GEMM kernels."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.workpart import cdiv


def pad_to(x, mults):
    """Zero-pad each dim of ``x`` up to a multiple of ``mults``. Zero padding
    is exact for GEMM (contributes 0 to every dot product)."""
    pads = []
    needs = False
    for dim, mult in zip(x.shape, mults):
        target = cdiv(dim, mult) * mult
        pads.append((0, target - dim))
        needs = needs or target != dim
    return jnp.pad(x, pads) if needs else x


def unpad(x, shape):
    """Slice back to an original (unpadded) shape."""
    if tuple(x.shape) == tuple(shape):
        return x
    slices = tuple(slice(0, d) for d in shape)
    return x[slices]


import jax


EPILOGUES = ("none", "relu", "silu", "gelu", "square")


def apply_epilogue(acc, epilogue: str):
    """Activation epilogue applied to the f32 accumulator before the final
    cast/store — the Composable-Kernel-style fusion the paper's library is
    built from (CK composes GEMM + epilogue functors; ours compose the same
    way on the fix-up/flush path, so the activation costs zero extra HBM
    round-trips)."""
    if epilogue == "none":
        return acc
    if epilogue == "relu":
        return jax.numpy.maximum(acc, 0.0)
    if epilogue == "silu":
        return jax.nn.silu(acc)
    if epilogue == "gelu":
        return jax.nn.gelu(acc)
    if epilogue == "square":  # squared-ReLU (nemotron-4 MLP)
        return jax.numpy.square(jax.numpy.maximum(acc, 0.0))
    raise ValueError(f"unknown epilogue {epilogue!r}")
