"""Shared helpers for the Pallas GEMM kernels: compiler-params compat,
padding, the fused epilogue applier, mixed-dtype MACs, and trace-time
``pallas_call`` launch counting (how tests assert the fused grouped path
really issues ONE kernel for all G expert groups)."""

from __future__ import annotations

from contextlib import contextmanager
from typing import Iterator, List, Optional

import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.workpart import cdiv

#: active launch log (None when counting is off); see :func:`count_launches`.
_launch_log: Optional[List[str]] = None


def record_launch(name: str) -> None:
    """Note one ``pallas_call`` built by a kernel wrapper.

    Called at *trace time* (when the wrapper function body runs under jit
    tracing), so it counts launches per compiled executable — the trace/
    launch cost the dispatcher pays — not per device invocation. No-op
    unless a :func:`count_launches` scope is active. Because jit caches
    traces, a wrapper re-invoked at an identical static signature does not
    re-trace: counting tests use fresh shapes or ``jax.clear_caches()``."""
    if _launch_log is not None:
        _launch_log.append(name)


@contextmanager
def count_launches() -> Iterator[List[str]]:
    """Collect kernel-launch names traced within the scope.

    >>> with count_launches() as launches:
    ...     jax.eval_shape(fn, *args)   # or run fn; tracing records
    >>> len(launches)
    """
    global _launch_log
    prev = _launch_log
    _launch_log = log = []
    try:
        yield log
    finally:
        _launch_log = prev

#: jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; resolve
#: whichever this install ships so the kernels run on both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def pad_to(x, mults):
    """Zero-pad each dim of ``x`` up to a multiple of ``mults``. Zero padding
    is exact for GEMM (contributes 0 to every dot product)."""
    pads = []
    needs = False
    for dim, mult in zip(x.shape, mults):
        target = cdiv(dim, mult) * mult
        pads.append((0, target - dim))
        needs = needs or target != dim
    return jnp.pad(x, pads) if needs else x


def unpad(x, shape):
    """Slice back to an original (unpadded) shape."""
    if tuple(x.shape) == tuple(shape):
        return x
    slices = tuple(slice(0, d) for d in shape)
    return x[slices]


import jax

from repro.core.op import Epilogue, as_epilogue


def apply_epilogue(acc, epilogue, bias=None, operand=None, scale=None, scale_a=None):
    """Epilogue applied to the f32 accumulator before the final cast/store —
    the Composable-Kernel-style fusion the paper's library is built from (CK
    composes GEMM + epilogue functors; ours compose the same way on the
    fix-up/flush path, so the epilogue costs zero extra HBM round-trips).

    ``epilogue`` is an :class:`repro.core.op.Epilogue` (legacy bare
    activation strings still accepted). ``bias``/``operand`` are the already
    block-sliced extra inputs for bias-add and binary (swiglu-mul /
    residual-add) epilogues. ``scale`` is the per-output-channel dequant
    row vector of an int8-weight op (see :mod:`repro.core.quant`): it
    multiplies the raw accumulator FIRST — restoring the real-valued
    product ``(A @ V) * s == A @ (V * s)`` — so bias/activation/binary
    stages compose on dequantized values exactly as they do for dense
    weights. ``scale_a`` is the per-M-row activation dequant column vector
    of an int8xint8 op: applied alongside ``scale`` it forms the rank-1
    rescale ``s_a (x) s_b`` on the raw integer-accumulated product.
    """
    spec: Epilogue = as_epilogue(epilogue)
    if scale_a is not None:
        acc = acc * scale_a.astype(jnp.float32)
    if scale is not None:
        acc = acc * scale.astype(jnp.float32)
    return spec.apply(acc, bias=bias, operand=operand)


def prep_scale(scale, n, bn):
    """Per-output-channel dequant vector -> the padded (1, Np) f32 row the
    flush/fix-up kernels block-slice (one definition of the layout for all
    three kernel families). ``scale``: (N,) or (1, N)."""
    if scale is None:
        return None
    return pad_to(scale.reshape(1, n).astype(jnp.float32), (1, bn))


def prep_scale_a(scale_a, m, bm):
    """Per-M-row activation dequant vector -> the padded (Mp, 1) f32 column
    the flush/fix-up kernels block-slice as ``(bm, 1)`` tiles (the rank-1
    partner of :func:`prep_scale`'s row). ``scale_a``: (M,) or (M, 1)."""
    if scale_a is None:
        return None
    return pad_to(scale_a.reshape(m, 1).astype(jnp.float32), (bm, 1))


def mixed_dot(a_blk, b_blk):
    """One k-iteration MAC handling mixed activation x weight dtypes.

    Same-dtype float blocks keep the legacy MXU path (bf16 x bf16 /
    f32 x f32, f32 accumulation) bit-for-bit. Both-integer blocks — int8
    activations against int8 weights — accumulate on the integer MXU path
    (``preferred_element_type=int32``) and convert the k-step partial to
    f32: each partial is bounded by ``bk * 127^2`` (<= 16.5M for the
    largest bk=1024 tile), well under both int32 range and f32's 2^24
    exact-integer window, so the conversion is exact and the f32
    accumulator chain stays identical to the float families'. Mixed blocks
    — f32/bf16 activations against int8 weight tiles — widen both operands
    to f32 in VMEM before the dot: the int8 tile already paid its 1-byte
    HBM fare (the point of weight quantization), and int8 -> f32
    conversion is exact, so the MAC is numerically the dense f32 MAC on
    dequant-without-scale values."""
    if jnp.issubdtype(a_blk.dtype, jnp.integer) and jnp.issubdtype(
        b_blk.dtype, jnp.integer
    ):
        return jnp.dot(a_blk, b_blk, preferred_element_type=jnp.int32).astype(
            jnp.float32
        )
    if a_blk.dtype != b_blk.dtype:
        a_blk = a_blk.astype(jnp.float32)
        b_blk = b_blk.astype(jnp.float32)
    return jnp.dot(a_blk, b_blk, preferred_element_type=jnp.float32)
