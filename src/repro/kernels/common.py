"""Shared helpers for the Pallas GEMM kernels."""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.workpart import cdiv

#: jax renamed TPUCompilerParams -> CompilerParams across 0.4/0.5; resolve
#: whichever this install ships so the kernels run on both.
CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def pad_to(x, mults):
    """Zero-pad each dim of ``x`` up to a multiple of ``mults``. Zero padding
    is exact for GEMM (contributes 0 to every dot product)."""
    pads = []
    needs = False
    for dim, mult in zip(x.shape, mults):
        target = cdiv(dim, mult) * mult
        pads.append((0, target - dim))
        needs = needs or target != dim
    return jnp.pad(x, pads) if needs else x


def unpad(x, shape):
    """Slice back to an original (unpadded) shape."""
    if tuple(x.shape) == tuple(shape):
        return x
    slices = tuple(slice(0, d) for d in shape)
    return x[slices]


import jax

from repro.core.op import Epilogue, as_epilogue


def apply_epilogue(acc, epilogue, bias=None, operand=None):
    """Epilogue applied to the f32 accumulator before the final cast/store —
    the Composable-Kernel-style fusion the paper's library is built from (CK
    composes GEMM + epilogue functors; ours compose the same way on the
    fix-up/flush path, so the epilogue costs zero extra HBM round-trips).

    ``epilogue`` is an :class:`repro.core.op.Epilogue` (legacy bare
    activation strings still accepted). ``bias``/``operand`` are the already
    block-sliced extra inputs for bias-add and binary (swiglu-mul /
    residual-add) epilogues.
    """
    spec: Epilogue = as_epilogue(epilogue)
    return spec.apply(acc, bias=bias, operand=operand)
