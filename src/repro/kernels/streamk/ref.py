"""Pure-jnp oracle for the Stream-K++ GEMM.

The result of any scheduling policy must equal a plain f32-accumulated
matmul — scheduling is performance-only, never semantics. The tests sweep
every policy x shape x dtype against this.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm_ref(a, b, out_dtype=None):
    """f32-accumulated ``a @ b`` cast to ``out_dtype`` (defaults to a.dtype)."""
    out_dtype = out_dtype or a.dtype
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(out_dtype)


def streamk_partition_ref(a, b, part):
    """Emulates Algorithm 1 in pure numpy-style jnp: computes each
    workgroup's partial contributions independently and reduces them — the
    oracle for the *partials workspace* itself (not just the final C).

    Returns (partials[sk_tiles, max_contrib+1, bm, bn], c_sk[sk_tiles, bm, bn]).
    """
    import numpy as np

    cfg = part.cfg
    ipt = part.iters_per_tile
    mc = part.max_contributors
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    partials = np.zeros((part.sk_tiles, mc + 1, cfg.bm, cfg.bn), np.float32)
    for r in part.sk_ranges:
        for it in range(r.start, r.end):
            tile, local_k = it // ipt, it % ipt
            tm, tn = part.tile_mn(tile)
            first_wg = (tile * ipt) // (max(1, -(-part.sk_total_iters // part.g)))
            slot = min(max(r.wg - first_wg, 0), mc - 1)
            a_blk = a[tm * cfg.bm : (tm + 1) * cfg.bm, local_k * cfg.bk : (local_k + 1) * cfg.bk]
            b_blk = b[local_k * cfg.bk : (local_k + 1) * cfg.bk, tn * cfg.bn : (tn + 1) * cfg.bn]
            partials[tile, slot] += a_blk @ b_blk
    c_sk = partials.sum(axis=1)
    return jnp.asarray(partials), jnp.asarray(c_sk)
