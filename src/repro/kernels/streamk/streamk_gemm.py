"""Stream-K++ GEMM as Pallas TPU kernels (Algorithm 1, TPU-adapted).

Two kernels compose one GEMM:

**Phase 1 — work-centric sweep** (``_streamk_kernel``). The Pallas grid is
``(g, iters_per_wg)``: program row ``x`` is one persistent workgroup of
Algorithm 1, step ``j`` is one flattened MAC iteration of its contiguous
range ``[x*ipw, min((x+1)*ipw, total))``. The BlockSpec index maps perform
Algorithm 1 lines 9-12 *in the index computation*: flattened iteration ->
(output tile, local k-iter) -> (A block row, k block) / (k block, B block
col). The f32 accumulator lives in the *output block* and exploits Pallas
revisiting semantics: consecutive steps of one program that land in the same
output tile keep the block in VMEM; the block is flushed to HBM exactly when
the program crosses a tile boundary — the TPU-idiomatic replacement for the
paper's per-tile epilogue.

Partial tiles: a GPU Stream-K workgroup resolves split tiles with
``atomic_add`` (Algorithm 1 line 17). TPUs have no HBM float atomics, so we
use the deterministic two-phase reduction the paper itself recommends in
§5.3: every contributor writes its partial accumulator to a workspace slot
``partials[tile, x - first_wg(tile)]`` — slots are disjoint by construction
because workgroup ranges are contiguous and sorted, so no synchronisation is
needed at all.

**Phase 2 — fix-up reduction** (``_fixup_kernel``). Grid ``(sk_tiles,)``;
tile ``t`` masks-and-sums its contributor slots (the count is pure integer
math on ``t``, computed in-kernel) and writes the final C tile. Data-parallel
region tiles (``tile >= sk_tiles`` under HYBRID policies) never touch the
workspace: a third classic tiled kernel (``dp`` package) handles them
directly, scheduled after the Stream-K sweep so its compute overlaps the
fix-up traffic (§4.1 of the paper).

Numerics: inputs bf16/f32, accumulation f32 (`preferred_element_type`), C in
the caller's dtype. Deterministic: unlike GPU atomics, the reduction order is
fixed, so results are bit-reproducible run-to-run.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import unpack_int4
from repro.core.workpart import Partition, cdiv
from repro.kernels.common import (
    CompilerParams,
    apply_epilogue,
    mixed_dot,
    record_launch,
)


def _range_math(part: Partition):
    """Static integers the index maps close over."""
    ipt = part.iters_per_tile
    total = part.sk_total_iters
    ipw = cdiv(total, part.g) if total else 1
    mc = part.max_contributors
    return ipt, total, ipw, mc


def _flat_iter(x, j, ipw, total):
    """Clamped flattened iteration for grid point (x, j)."""
    it = x * ipw + j
    return jnp.minimum(it, total - 1)


# --------------------------------------------------------------------------
# Phase 1: the Stream-K sweep
# --------------------------------------------------------------------------


def _streamk_kernel(a_ref, b_ref, partials_ref, *, part: Partition, b_bits: int = 8):
    ipt, total, ipw, mc = _range_math(part)
    x = pl.program_id(0)
    j = pl.program_id(1)
    it_raw = x * ipw + j
    my_end = jnp.minimum((x + 1) * ipw, total)
    valid = it_raw < my_end

    it = jnp.minimum(it_raw, total - 1)
    local_k = it % ipt

    # Fresh tile for this program: first step of the program or first k-iter
    # of a tile inside its range. Trash steps (invalid) also re-init — they
    # only ever touch the dedicated trash slot.
    is_start = jnp.logical_or(j == 0, local_k == 0)

    @pl.when(is_start)
    def _init():
        partials_ref[...] = jnp.zeros(partials_ref.shape, partials_ref.dtype)

    @pl.when(valid)
    def _mac():
        b_blk = b_ref[...]
        if b_bits == 4:
            # packed (bk/2, bn) int4 block -> (bk, bn) int8 in the prologue
            b_blk = unpack_int4(b_blk)
        acc = mixed_dot(a_ref[...], b_blk)
        partials_ref[...] += acc[None, None]


def _sk_block_indices(x, j, part: Partition):
    """(tile, slot) for grid point (x, j); invalid steps -> trash slot."""
    ipt, total, ipw, mc = _range_math(part)
    it_raw = x * ipw + j
    my_end = jnp.minimum((x + 1) * ipw, total)
    valid = it_raw < my_end
    it = jnp.minimum(it_raw, total - 1)
    tile = it // ipt
    first_wg = (tile * ipt) // ipw
    slot = jnp.clip(x - first_wg, 0, mc - 1)
    tile = jnp.where(valid, tile, part.sk_tiles - 1)
    slot = jnp.where(valid, slot, mc)  # trash slot
    return tile, slot


def streamk_phase1(a, b, part: Partition, *, interpret: bool = False, b_bits: int = 8):
    """Run the Stream-K sweep; returns partials[sk_tiles, mc+1, bm, bn] f32.

    ``a``/``b`` must already be padded to tile multiples. ``b_bits == 4``:
    ``b`` is int4-packed (Kp/2, Np), padded to ``bk/2`` multiples, and the
    kernel unpacks each block in its prologue (the packed k-block count
    equals the logical one for even bk, so the index maps are unchanged).
    """
    cfg = part.cfg
    ipt, total, ipw, mc = _range_math(part)
    assert part.sk_tiles > 0
    bk_b = cfg.bk // 2 if b_bits == 4 else cfg.bk

    def a_index(x, j):
        tile, _ = _sk_block_indices(x, j, part)
        it = _flat_iter(x, j, ipw, total)
        return (tile // part.n_tiles, it % ipt)

    def b_index(x, j):
        tile, _ = _sk_block_indices(x, j, part)
        it = _flat_iter(x, j, ipw, total)
        return (it % ipt, tile % part.n_tiles)

    def out_index(x, j):
        tile, slot = _sk_block_indices(x, j, part)
        return (tile, slot, 0, 0)

    out_shape = jax.ShapeDtypeStruct(
        (part.sk_tiles, mc + 1, cfg.bm, cfg.bn), jnp.float32
    )
    kernel = functools.partial(_streamk_kernel, part=part, b_bits=b_bits)
    record_launch(f"streamk_p1_{cfg.name}_g{part.g}")
    return pl.pallas_call(
        kernel,
        grid=(part.g, ipw),
        in_specs=[
            pl.BlockSpec((cfg.bm, cfg.bk), a_index),
            pl.BlockSpec((bk_b, cfg.bn), b_index),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, cfg.bm, cfg.bn), out_index
        ),
        out_shape=out_shape,
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL, pltpu.ARBITRARY),
        ),
        name=f"streamk_p1_{cfg.name}_g{part.g}",
    )(a, b)


# --------------------------------------------------------------------------
# Phase 2: deterministic fix-up reduction
# --------------------------------------------------------------------------


def _fixup_kernel(
    partials_ref,
    *rest,
    part: Partition,
    epilogue="none",
    has_scale: bool = False,
    has_scale_a: bool = False,
    has_bias: bool = False,
    has_operand: bool = False,
):
    """rest = [scale_ref?, scale_a_ref?, bias_ref?, operand_ref?] + (c_ref,)."""
    c_ref = rest[-1]
    extras = list(rest[:-1])
    scale_ref = extras.pop(0) if has_scale else None
    scale_a_ref = extras.pop(0) if has_scale_a else None
    bias_ref = extras.pop(0) if has_bias else None
    operand_ref = extras.pop(0) if has_operand else None
    ipt, total, ipw, mc = _range_math(part)
    t = pl.program_id(0)
    first_wg = (t * ipt) // ipw
    last_wg = ((t + 1) * ipt - 1) // ipw
    n_contrib = last_wg - first_wg + 1
    # Mask garbage slots (>= n_contrib) before reducing. (2-D iota: TPU has
    # no 1-D iota.)
    n_slots = partials_ref.shape[1]
    slots = jax.lax.broadcasted_iota(jnp.int32, (n_slots, 1, 1), 0)
    mask = slots < n_contrib
    acc = jnp.sum(
        jnp.where(mask, partials_ref[0], 0.0), axis=0, dtype=jnp.float32
    )
    out = apply_epilogue(
        acc,
        epilogue,
        bias=None if bias_ref is None else bias_ref[...],
        operand=None if operand_ref is None else operand_ref[...],
        scale=None if scale_ref is None else scale_ref[...],
        scale_a=None if scale_a_ref is None else scale_a_ref[...],
    )
    c_ref[0] = out.astype(c_ref.dtype)


def streamk_fixup(
    partials, part: Partition, out_dtype, *, interpret: bool = False,
    epilogue="none", bias=None, operand=None, scale=None, scale_a=None,
):
    """Reduce contributor slots per SK tile -> C tiles, shaped
    (sk_tiles, bm, bn). The epilogue (activation, bias-add, swiglu-mul /
    residual operand) fuses here — after the full accumulation — so it costs
    no extra HBM pass; an int8-weight op's dequant ``scale`` (1, Np) and an
    int8-activation op's per-row ``scale_a`` (Mp, 1) apply to the reduced
    accumulator first — together the rank-1 rescale of an int8xint8 op (see
    ``apply_epilogue``). ``bias`` (1, Np) / ``operand`` (Mp, Np) are padded
    full-size arrays; their blocks are gathered per SK tile in row-major
    tile order (matching ``_scatter_sk_tiles``)."""
    cfg = part.cfg
    nt = part.n_tiles
    kernel = functools.partial(
        _fixup_kernel,
        part=part,
        epilogue=epilogue,
        has_scale=scale is not None,
        has_scale_a=scale_a is not None,
        has_bias=bias is not None,
        has_operand=operand is not None,
    )
    operands = [partials]
    in_specs = [
        pl.BlockSpec(
            (1, partials.shape[1], cfg.bm, cfg.bn), lambda t: (t, 0, 0, 0)
        )
    ]
    if scale is not None:
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, cfg.bn), lambda t: (0, t % nt)))
    if scale_a is not None:
        operands.append(scale_a)
        in_specs.append(pl.BlockSpec((cfg.bm, 1), lambda t: (t // nt, 0)))
    if bias is not None:
        operands.append(bias)
        in_specs.append(pl.BlockSpec((1, cfg.bn), lambda t: (0, t % nt)))
    if operand is not None:
        operands.append(operand)
        in_specs.append(
            pl.BlockSpec((cfg.bm, cfg.bn), lambda t: (t // nt, t % nt))
        )
    record_launch(f"streamk_fixup_{cfg.name}")
    return pl.pallas_call(
        kernel,
        grid=(part.sk_tiles,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, cfg.bm, cfg.bn), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (part.sk_tiles, cfg.bm, cfg.bn), out_dtype
        ),
        interpret=interpret,
        compiler_params=CompilerParams(
            dimension_semantics=(pltpu.PARALLEL,),
        ),
        name=f"streamk_fixup_{cfg.name}",
    )(*operands)
