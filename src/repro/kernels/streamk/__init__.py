from repro.kernels.streamk import ops, ref
from repro.kernels.streamk.streamk_gemm import streamk_fixup, streamk_phase1

__all__ = ["ops", "ref", "streamk_fixup", "streamk_phase1"]
