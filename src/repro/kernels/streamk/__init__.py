"""Stream-K kernel family: persistent-grid sweep + fix-up, the jit'd public
wrapper (:mod:`ops`), the XLA reference (:mod:`ref`), and the one-kernel
grouped MoE form (:mod:`grouped`)."""

from repro.kernels.streamk import grouped, ops, ref
from repro.kernels.streamk.grouped import gemm_grouped_streamk
from repro.kernels.streamk.streamk_gemm import streamk_fixup, streamk_phase1

__all__ = [
    "gemm_grouped_streamk",
    "grouped",
    "ops",
    "ref",
    "streamk_fixup",
    "streamk_phase1",
]
