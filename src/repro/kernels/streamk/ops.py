"""jit'd public wrapper for the Stream-K++ GEMM.

Composes the policy's phases (§4.1 of the paper):
  1. Stream-K sweep over the SK region (``streamk_phase1``),
  2. deterministic fix-up writing SK tiles into C (``streamk_fixup``,
     in-place via input/output aliasing),
  3. data-parallel region over remaining tiles (``dp_gemm_region``, aliased
     into the same C) — on hardware this phase overlaps the fix-up traffic.

Also owns padding (inputs are zero-padded to tile multiples — exact for
GEMM) and policy routing: a DP policy skips phases 1-2 entirely; ALL_SK has
no phase 3.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.policies import DP, Policy, PolicyKind, TileConfig
from repro.core.workpart import GemmShape, cdiv, partition
from repro.kernels.common import pad_to, prep_scale, prep_scale_a, unpad
from repro.kernels.dp.dp_gemm import dp_gemm_region
from repro.kernels.streamk.streamk_gemm import streamk_fixup, streamk_phase1


def _scatter_sk_tiles(sk_tiles_out, part, out_dtype, interpret):
    """Write fixed-up SK tiles into a fresh padded C via the fix-up kernel's
    aliasing path; here done with pure reshapes (no data-dependent scatter):
    tile t -> C[tm*bm:(tm+1)*bm, tn*bn:(tn+1)*bn] in row-major tile order."""
    cfg = part.cfg
    mt, nt = part.m_tiles, part.n_tiles
    n_total = mt * nt
    pad_tiles = n_total - part.sk_tiles
    grid = sk_tiles_out
    if pad_tiles:
        grid = jnp.concatenate(
            [grid, jnp.zeros((pad_tiles, cfg.bm, cfg.bn), grid.dtype)], axis=0
        )
    c = grid.reshape(mt, nt, cfg.bm, cfg.bn).transpose(0, 2, 1, 3)
    return c.reshape(mt * cfg.bm, nt * cfg.bn).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "cfg", "g", "interpret", "out_dtype", "epilogue", "b_bits",
    ),
)
def gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    policy: Policy = DP,
    cfg: TileConfig = TileConfig(128, 128, 128),
    g: int = 8,
    interpret: bool = False,
    out_dtype=None,
    epilogue="none",
    bias: jax.Array = None,
    operand: jax.Array = None,
    scale: jax.Array = None,
    scale_a: jax.Array = None,
    b_bits: int = 8,
) -> jax.Array:
    """``a @ b`` under a Stream-K++ scheduling policy, with an optional fused
    epilogue (Composable-Kernel style: applied post-accumulation in the
    fix-up / DP flush — zero extra HBM passes).

    a: (M, K), b: (K, N) -> (M, N). Accumulation is always f32. ``epilogue``
    is an :class:`repro.core.op.Epilogue` or legacy activation string;
    ``bias`` (N,) and ``operand`` (M, N) feed its bias-add / binary stages.
    ``scale`` (N,) is the per-output-channel dequant vector of an
    int8-weight op (``b`` int8): it enters every policy's flush/fix-up as
    an extra blocked operand ahead of the other epilogue stages, so the
    kernels accumulate raw int8 weights and never materialise a dense
    dequantized B. ``scale_a`` (M,) is the per-row activation dequant of an
    int8xint8 op (``a`` int8 too): together they form the rank-1 rescale
    ``s_a (x) s_b`` on the f32 accumulator. ``b_bits == 4``: ``b`` is
    int4-packed (ceil(K/2), N) — K comes from ``a``, and every kernel
    unpacks its packed block in the prologue (B HBM traffic is 0.5
    bytes/element).
    """
    if a.ndim != 2 or b.ndim != 2:
        raise ValueError(f"bad gemm operands {a.shape} @ {b.shape}")
    k_rows = (a.shape[1] + 1) // 2 if b_bits == 4 else a.shape[1]
    if b.shape[0] != k_rows:
        raise ValueError(
            f"bad gemm operands {a.shape} @ {b.shape} (b_bits={b_bits})"
        )
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or a.dtype

    ap = pad_to(a, (cfg.bm, cfg.bk))
    bp = pad_to(b, (cfg.bk // 2 if b_bits == 4 else cfg.bk, cfg.bn))
    biasp = None if bias is None else pad_to(bias.reshape(1, n), (1, cfg.bn))
    operandp = None if operand is None else pad_to(operand, (cfg.bm, cfg.bn))
    scalep = prep_scale(scale, n, cfg.bn)
    scale_ap = prep_scale_a(scale_a, m, cfg.bm)
    part = partition(GemmShape(m, n, k), cfg, g, policy)
    epi = dict(
        epilogue=epilogue,
        bias=biasp,
        operand=operandp,
        scale=scalep,
        scale_a=scale_ap,
    )

    if part.sk_tiles == 0:
        # policy degraded to pure DP (DP itself, or a HYBRID whose remainder
        # wave is empty at this g): the DP region still launches in waves of
        # the selected grid size
        cp = dp_gemm_region(
            ap, bp, cfg, out_dtype=out_dtype, interpret=interpret, g=g,
            b_bits=b_bits, **epi,
        )
        return unpad(cp, (m, n))

    partials = streamk_phase1(ap, bp, part, interpret=interpret, b_bits=b_bits)
    sk_c = streamk_fixup(
        partials, part, out_dtype, interpret=interpret, **epi
    )
    c_sk = _scatter_sk_tiles(sk_c, part, out_dtype, interpret)

    if part.dp_tiles == 0:
        return unpad(c_sk, (m, n))

    cp = dp_gemm_region(
        ap,
        bp,
        cfg,
        tile_offset=part.sk_tiles,
        c_init=c_sk,
        out_dtype=out_dtype,
        interpret=interpret,
        g=g,
        b_bits=b_bits,
        **epi,
    )
    return unpad(cp, (m, n))
