"""One-kernel ragged grouped (MoE) GEMM: Stream-K over the *concatenated*
expert tile space.

The per-group dispatch loop (``core/gemm.py``'s loop backend) launches one
``pallas_call`` per expert group: trace cost, launch overhead and wave
quantization all scale with G. This module collapses the whole grouped
product into ONE persistent-grid ``pallas_call`` by flattening every group's
output tiles into a single concatenated tile space:

* Group ``i`` owns ``rows_i = ceil(sizes_i / bm)`` row-blocks of A; the
  groups' row-blocks are concatenated into ``A_cat`` of shape
  ``(R * bm, Kp)`` with ``R = sum(rows_i)`` (each group zero-padded to its
  own row-block boundary, so ragged group sizes never share a tile).
* The concatenated tile space is ``T = R * nt`` output tiles
  (``nt = Np / bn``): tile ``t`` covers global row-block ``r = t // nt``
  and column-block ``tn = t % nt``.
* A scalar-prefetch table ``blk_group[r] -> i`` (shape ``(R,)`` int32,
  computed on the host from the static group sizes) lets the B / bias /
  scale index maps gather the right expert's operand block: B is the
  stacked ``(G, Kp, Np)`` weight tensor indexed with block
  ``(blk_group[r], lk, tn)``. A, C and the binary epilogue operand are
  concatenated like ``A_cat`` and never need the table.

Two launch forms, selected by policy:

**Stream-K form** (ALL_SK and every HYBRID). Grid ``(g, ipw)`` with
``ipw = ceil(T * ipt / g)`` — Algorithm 1's persistent workgroups, but over
the concatenated tile space, so one grid covers all experts and the
quantization remainder is amortised once instead of per group. Both grid
dimensions are ARBITRARY (sequential): the flattened step ``it = x*ipw + j``
is monotone, so a single VMEM accumulator carries partial sums across
workgroup boundaries — tiles split between workgroups finish without a
partials workspace or fix-up kernel. (That sequential carry is exactly why
this stays ONE kernel; a HYBRID policy has no separate DP region here and
degenerates to ALL_SK — the cost model scores them identically for fused
grouped ops.)

**DP form** (DP policy). Grid ``(ceil(T/g)*g, ipt)``: classic tiled GEMM
over the concatenated tile space, wave-padded to the tuned grid size with
clamped index maps (surplus programs deterministically recompute the last
tile, as in ``dp_gemm_region``).

Numerics match the per-group loop bit-for-bit in f32 accumulation: each
output tile's MAC order over k is identical, padding contributes exact
zeros, and the fused epilogue (dequant scale -> bias -> activation/binary)
applies per tile at the flush exactly as the loop kernels apply it.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.policies import ALL_SK, Policy, PolicyKind, TileConfig
from repro.core.quant import unpack_int4
from repro.core.workpart import cdiv
from repro.kernels.common import (
    CompilerParams,
    apply_epilogue,
    mixed_dot,
    pad_to,
    record_launch,
)


def _extras_split(rest, has_scale, has_scale_a, has_bias, has_operand):
    """Unpack [scale?, scale_a?, bias?, operand?] + (c_ref, acc_ref) tail."""
    c_ref, acc_ref = rest[-2], rest[-1]
    extras = list(rest[:-2])
    scale_ref = extras.pop(0) if has_scale else None
    scale_a_ref = extras.pop(0) if has_scale_a else None
    bias_ref = extras.pop(0) if has_bias else None
    operand_ref = extras.pop(0) if has_operand else None
    return scale_ref, scale_a_ref, bias_ref, operand_ref, c_ref, acc_ref


def _unpack_b(b_blk, b_bits):
    """Prologue unpack: packed (bk/2, bn) int4 block -> (bk, bn) int8."""
    return unpack_int4(b_blk) if b_bits == 4 else b_blk


# --------------------------------------------------------------------------
# Stream-K form: grid (g, ipw), sequential carry across workgroup boundaries
# --------------------------------------------------------------------------


def _sk_kernel(
    tab_ref,
    a_ref,
    b_ref,
    *rest,
    ipt: int,
    ipw: int,
    total: int,
    epilogue="none",
    has_scale: bool = False,
    has_scale_a: bool = False,
    has_bias: bool = False,
    has_operand: bool = False,
    b_bits: int = 8,
):
    """One flattened MAC step of the concatenated-tile-space sweep.

    Executes strictly sequentially (both grid dims ARBITRARY), so the
    accumulator scratch carries a split tile's partial sum from the end of
    workgroup ``x`` into the start of workgroup ``x+1`` — no fix-up pass.
    Steps past ``total`` clamp onto the final tile's last k-iteration: MAC
    and init are guarded off and the flush harmlessly rewrites the same
    finished value.
    """
    scale_ref, scale_a_ref, bias_ref, operand_ref, c_ref, acc_ref = _extras_split(
        rest, has_scale, has_scale_a, has_bias, has_operand
    )
    del tab_ref  # only the index maps consume the group table
    x = pl.program_id(0)
    j = pl.program_id(1)
    it_raw = x * ipw + j
    valid = it_raw < total
    it = jnp.minimum(it_raw, total - 1)
    lk = it % ipt

    # `valid &` matters when ipt == 1: a clamped trash step has lk == 0 AND
    # lk == ipt-1, and must not zero the accumulator before its flush.
    @pl.when(jnp.logical_and(valid, lk == 0))
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    @pl.when(valid)
    def _mac():
        acc_ref[...] += mixed_dot(a_ref[...], _unpack_b(b_ref[0], b_bits))

    @pl.when(lk == ipt - 1)
    def _flush():
        out = apply_epilogue(
            acc_ref[...],
            epilogue,
            bias=None if bias_ref is None else bias_ref[...],
            operand=None if operand_ref is None else operand_ref[...],
            scale=None if scale_ref is None else scale_ref[...],
            scale_a=None if scale_a_ref is None else scale_a_ref[...],
        )
        c_ref[...] = out.astype(c_ref.dtype)


# --------------------------------------------------------------------------
# DP form: grid (wave-padded T, ipt), one program per concatenated tile
# --------------------------------------------------------------------------


def _dp_kernel(
    tab_ref,
    a_ref,
    b_ref,
    *rest,
    ipt: int,
    epilogue="none",
    has_scale: bool = False,
    has_scale_a: bool = False,
    has_bias: bool = False,
    has_operand: bool = False,
    b_bits: int = 8,
):
    """Classic tiled-GEMM body over the concatenated tile space."""
    scale_ref, scale_a_ref, bias_ref, operand_ref, c_ref, acc_ref = _extras_split(
        rest, has_scale, has_scale_a, has_bias, has_operand
    )
    del tab_ref
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    acc_ref[...] += mixed_dot(a_ref[...], _unpack_b(b_ref[0], b_bits))

    @pl.when(k == ipt - 1)
    def _flush():
        out = apply_epilogue(
            acc_ref[...],
            epilogue,
            bias=None if bias_ref is None else bias_ref[...],
            operand=None if operand_ref is None else operand_ref[...],
            scale=None if scale_ref is None else scale_ref[...],
            scale_a=None if scale_a_ref is None else scale_a_ref[...],
        )
        c_ref[...] = out.astype(c_ref.dtype)


def _fused_call(
    tab,
    a_cat,
    b_pad,
    *,
    policy: Policy,
    cfg: TileConfig,
    g: int,
    nt: int,
    ipt: int,
    n_tiles: int,
    out_dtype,
    interpret: bool,
    epilogue,
    bias,
    operand,
    scale,
    scale_a,
    b_bits: int = 8,
):
    """Build and issue THE single ``pallas_call`` over the concatenated tile
    space. ``tab``: (R,) int32 row-block -> group table (scalar-prefetched);
    ``a_cat``: (R*bm, Kp); ``b_pad``: (G, Kp, Np) — or (G, Kp/2, Np) packed
    int4 when ``b_bits == 4``, unpacked per block in the kernel prologue;
    optional ``bias``/``scale`` (G, Np), ``scale_a`` (R*bm, 1) concatenated
    like A, and ``operand`` (R*bm, Np). Returns C_cat (R*bm, Np)."""
    total = n_tiles * ipt
    rp, np_ = a_cat.shape[0], b_pad.shape[2]
    bk_b = cfg.bk // 2 if b_bits == 4 else cfg.bk
    sk_form = policy.kind != PolicyKind.DP

    if sk_form:
        ipw = cdiv(total, g)
        grid = (g, ipw)

        def _tile(x, j):
            it = jnp.minimum(x * ipw + j, total - 1)
            return it // ipt, it % ipt

        def a_index(x, j, tab):
            t, lk = _tile(x, j)
            return (t // nt, lk)

        def b_index(x, j, tab):
            t, lk = _tile(x, j)
            return (tab[t // nt], lk, t % nt)

        def c_index(x, j, tab):
            t, _ = _tile(x, j)
            return (t // nt, t % nt)

        def vec_index(x, j, tab):
            t, _ = _tile(x, j)
            return (tab[t // nt], t % nt)

        def row_index(x, j, tab):
            t, _ = _tile(x, j)
            return (t // nt, 0)

        kernel = functools.partial(
            _sk_kernel,
            ipt=ipt,
            ipw=ipw,
            total=total,
            epilogue=epilogue,
            has_scale=scale is not None,
            has_scale_a=scale_a is not None,
            has_bias=bias is not None,
            has_operand=operand is not None,
            b_bits=b_bits,
        )
        # Both dims sequential: the accumulator carry across workgroup
        # boundaries is only sound under a strict flattened execution order.
        semantics = (pltpu.ARBITRARY, pltpu.ARBITRARY)
        name = f"grouped_sk_{cfg.name}_g{g}"
    else:
        n_prog = cdiv(n_tiles, g) * g if g > 0 else n_tiles
        grid = (n_prog, ipt)

        def _tile_dp(i):
            if n_prog != n_tiles:
                i = jnp.minimum(i, n_tiles - 1)
            return i

        def a_index(i, k, tab):
            return (_tile_dp(i) // nt, k)

        def b_index(i, k, tab):
            t = _tile_dp(i)
            return (tab[t // nt], k, t % nt)

        def c_index(i, k, tab):
            t = _tile_dp(i)
            return (t // nt, t % nt)

        def vec_index(i, k, tab):
            t = _tile_dp(i)
            return (tab[t // nt], t % nt)

        def row_index(i, k, tab):
            t = _tile_dp(i)
            return (t // nt, 0)

        kernel = functools.partial(
            _dp_kernel,
            ipt=ipt,
            epilogue=epilogue,
            has_scale=scale is not None,
            has_scale_a=scale_a is not None,
            has_bias=bias is not None,
            has_operand=operand is not None,
            b_bits=b_bits,
        )
        tile_sem = pltpu.ARBITRARY if n_prog != n_tiles else pltpu.PARALLEL
        semantics = (tile_sem, pltpu.ARBITRARY)
        name = f"grouped_dp_{cfg.name}"

    operands = [a_cat, b_pad]
    in_specs = [
        pl.BlockSpec((cfg.bm, cfg.bk), a_index),
        pl.BlockSpec((1, bk_b, cfg.bn), b_index),
    ]
    if scale is not None:
        operands.append(scale)
        in_specs.append(pl.BlockSpec((1, cfg.bn), vec_index))
    if scale_a is not None:
        operands.append(scale_a)
        in_specs.append(pl.BlockSpec((cfg.bm, 1), row_index))
    if bias is not None:
        operands.append(bias)
        in_specs.append(pl.BlockSpec((1, cfg.bn), vec_index))
    if operand is not None:
        operands.append(operand)
        in_specs.append(pl.BlockSpec((cfg.bm, cfg.bn), c_index))

    record_launch(name)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((cfg.bm, cfg.bn), c_index),
            scratch_shapes=[pltpu.VMEM((cfg.bm, cfg.bn), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((rp, np_), out_dtype),
        interpret=interpret,
        compiler_params=CompilerParams(dimension_semantics=semantics),
        name=name,
    )(tab, *operands)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "cfg", "g", "interpret", "out_dtype", "epilogue",
        "group_sizes", "b_bits",
    ),
)
def gemm_grouped_streamk(
    a: jax.Array,
    b: jax.Array,
    *,
    policy: Policy = ALL_SK,
    cfg: TileConfig = TileConfig(128, 128, 128),
    g: int = 8,
    interpret: bool = False,
    out_dtype=None,
    epilogue="none",
    bias: Optional[jax.Array] = None,
    operand: Optional[jax.Array] = None,
    scale: Optional[jax.Array] = None,
    scale_a: Optional[jax.Array] = None,
    group_sizes: Optional[Tuple[int, ...]] = None,
    b_bits: int = 8,
) -> jax.Array:
    """Batched-by-expert GEMM ``c[i] = a[i] @ b[i]`` in ONE ``pallas_call``.

    a: (G, M, K) activations, b: (G, K, N) per-expert weights -> (G, M, N).
    ``group_sizes`` (static tuple, default ``(M,) * G``) gives each expert's
    real row count for ragged MoE batches: only the first ``sizes[i]`` rows
    of group ``i`` participate; output rows beyond them are zero. A size of
    0 (expert received no tokens) contributes no tiles at all.

    Epilogue operands are per-expert: ``bias`` (G, N), ``scale`` (G, N) —
    the int8-weight dequant rows — ``scale_a`` (G, M) per-row activation
    dequant columns (int8xint8 ops), and ``operand`` (G, M, N) for binary
    stages. ``b_bits == 4``: ``b`` is int4-packed (G, ceil(K/2), N), each
    kernel block unpacked in the prologue. Accumulation is f32; policies
    other than DP run the Stream-K persistent form (HYBRID degenerates to
    ALL_SK — one launch admits no separate DP region).
    """
    if a.ndim != 3 or b.ndim != 3 or a.shape[0] != b.shape[0]:
        raise ValueError(f"bad grouped operands {a.shape} @ {b.shape}")
    k_rows = (a.shape[2] + 1) // 2 if b_bits == 4 else a.shape[2]
    if b.shape[1] != k_rows:
        raise ValueError(
            f"bad grouped operands {a.shape} @ {b.shape} (b_bits={b_bits})"
        )
    n_groups, m, k = a.shape
    n = b.shape[2]
    out_dtype = out_dtype or a.dtype
    sizes = group_sizes if group_sizes is not None else (m,) * n_groups
    if len(sizes) != n_groups or any(s < 0 or s > m for s in sizes):
        raise ValueError(f"bad group_sizes {sizes} for M={m}, G={n_groups}")

    row_blocks = [cdiv(s, cfg.bm) for s in sizes]
    r_total = sum(row_blocks)
    if r_total == 0:
        return jnp.zeros((n_groups, m, n), out_dtype)

    kp = cdiv(k, cfg.bk) * cfg.bk
    np_pad = cdiv(n, cfg.bn) * cfg.bn
    nt = np_pad // cfg.bn
    ipt = kp // cfg.bk

    # Concatenate each expert's live rows, padded to its own row-block
    # boundary — ragged boundaries never share a tile.
    a_parts = [
        pad_to(a[i, : sizes[i], :], (cfg.bm, cfg.bk))
        for i in range(n_groups)
        if row_blocks[i]
    ]
    a_cat = jnp.concatenate(a_parts, axis=0) if len(a_parts) > 1 else a_parts[0]
    b_pad = pad_to(b, (1, cfg.bk // 2 if b_bits == 4 else cfg.bk, cfg.bn))
    tab = jnp.asarray(
        np.repeat(np.arange(n_groups, dtype=np.int32), row_blocks)
    )

    biasp = None if bias is None else pad_to(
        bias.reshape(n_groups, n), (1, cfg.bn)
    )
    scalep = None if scale is None else pad_to(
        scale.reshape(n_groups, n).astype(jnp.float32), (1, cfg.bn)
    )
    scale_ap = None
    if scale_a is not None:
        # concatenated like A: group i's live rows padded to its row-block
        # boundary -> an (R*bm, 1) column the tiles slice by row-block
        sa_parts = [
            pad_to(
                scale_a[i, : sizes[i]].reshape(-1, 1).astype(jnp.float32),
                (cfg.bm, 1),
            )
            for i in range(n_groups)
            if row_blocks[i]
        ]
        scale_ap = (
            jnp.concatenate(sa_parts, axis=0)
            if len(sa_parts) > 1
            else sa_parts[0]
        )
    operandp = None
    if operand is not None:
        op_parts = [
            pad_to(operand[i, : sizes[i], :], (cfg.bm, cfg.bn))
            for i in range(n_groups)
            if row_blocks[i]
        ]
        operandp = (
            jnp.concatenate(op_parts, axis=0)
            if len(op_parts) > 1
            else op_parts[0]
        )

    c_cat = _fused_call(
        tab,
        a_cat,
        b_pad,
        policy=policy,
        cfg=cfg,
        g=g,
        nt=nt,
        ipt=ipt,
        n_tiles=r_total * nt,
        out_dtype=out_dtype,
        interpret=interpret,
        epilogue=epilogue,
        bias=biasp,
        operand=operandp,
        scale=scalep,
        scale_a=scale_ap,
        b_bits=b_bits,
    )

    # Scatter concatenated rows back to the dense (G, M, N) layout; padding
    # rows (and empty experts) come back as zeros.
    outs = []
    off = 0
    for i in range(n_groups):
        rb = row_blocks[i]
        if rb == 0:
            outs.append(jnp.zeros((m, n), out_dtype))
            continue
        blk = c_cat[off * cfg.bm : (off + rb) * cfg.bm, :n][: sizes[i]]
        if sizes[i] < m:
            blk = jnp.pad(blk, ((0, m - sizes[i]), (0, 0)))
        outs.append(blk)
        off += rb
    return jnp.stack(outs, axis=0)
