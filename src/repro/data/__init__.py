from repro.data.pipeline import PipelineState, SyntheticLMData, input_specs

__all__ = ["PipelineState", "SyntheticLMData", "input_specs"]
