"""Deterministic synthetic data pipeline with checkpointable iterator state.

Real clusters stream tokenised documents; here the stream is a seeded
counter-mode generator (Philox via numpy) so that (a) every batch is a pure
function of (seed, step) — a crashed-and-restarted trainer reproduces the
exact token stream, which the fault-tolerance tests assert bitwise; (b) no
host state needs to survive a preemption except the integer step.

The "document" stream packs variable-length documents into fixed-length
rows with EOS separators and a loss mask — the realistic shape of an LM
pipeline — and the modality stubs (patch/frame embeddings) are generated
the same counter-mode way.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig, ShapeConfig


@dataclass
class PipelineState:
    seed: int
    step: int = 0

    def to_dict(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d):
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLMData:
    """Packed-document LM batches, derived purely from (seed, step)."""

    def __init__(
        self,
        cfg: ModelConfig,
        batch: int,
        seq_len: int,
        seed: int = 0,
        mean_doc_len: int = 512,
        eos: int = 0,
    ):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.state = PipelineState(seed=seed)
        self.mean_doc_len = mean_doc_len
        self.eos = eos

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.Generator(
            np.random.Philox(key=self.state.seed, counter=step)
        )

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step — the checkpointable contract."""
        cfg = self.cfg
        rng = self._rng(step)
        b, s = self.batch, self.seq_len
        tokens = np.empty((b, s), np.int32)
        mask = np.ones((b, s), np.float32)
        # pack documents with EOS boundaries
        for row in range(b):
            pos = 0
            while pos < s:
                dl = int(rng.geometric(1.0 / self.mean_doc_len))
                dl = max(1, min(max(dl, 4), s - pos))
                # mildly-structured tokens (arithmetic progressions mod vocab)
                start = rng.integers(1, cfg.vocab_size)
                stride = rng.integers(1, 7)
                tokens[row, pos : pos + dl] = (
                    start + stride * np.arange(dl)
                ) % cfg.vocab_size
                if pos + dl < s:
                    tokens[row, pos + dl - 1] = self.eos
                pos += dl
        labels = np.roll(tokens, -1, axis=1)
        mask[:, -1] = 0.0  # no target for the last position
        out = {"tokens": tokens, "labels": labels.astype(np.int32), "loss_mask": mask}
        if cfg.family == "vlm":
            out["patch_embeds"] = rng.standard_normal(
                (b, cfg.n_patches, cfg.d_model), np.float32
            ).astype(np.float32)
        if cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, cfg.enc_frames, cfg.d_model), np.float32
            ).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            batch = self.batch_at(self.state.step)
            self.state.step += 1
            yield batch

    # -- checkpointing ------------------------------------------------------
    def state_dict(self):
        return self.state.to_dict()

    def load_state_dict(self, d):
        self.state = PipelineState.from_dict(d)


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of a (arch, shape)
    cell — the dry-run contract (weak-type-correct, shardable, no device
    allocation)."""
    import jax
    import jax.numpy as jnp

    b, s = shape.global_batch, shape.seq_len
    f32, i32 = jnp.float32, jnp.int32
    if shape.kind == "train":
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
            "loss_mask": jax.ShapeDtypeStruct((b, s), f32),
        }
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), f32
            )
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model), f32)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            specs["patch_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.n_patches, cfg.d_model), f32
            )
        if cfg.family == "encdec":
            specs["frames"] = jax.ShapeDtypeStruct((b, cfg.enc_frames, cfg.d_model), f32)
        return specs
    # decode: one new token against a seq_len-deep cache
    return {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cur_pos": jax.ShapeDtypeStruct((b,), i32),
    }
