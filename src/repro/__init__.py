"""repro — Stream-K++ on TPU.

Adaptive GEMM kernel scheduling (7 Stream-K++ policies) and Bloom-filter
kernel selection (Open-sieve), reproduced from Sadasivan et al. (AI4S'24)
and deployed as the dispatch layer of a multi-pod JAX training/serving
framework. See DESIGN.md / EXPERIMENTS.md at the repository root.
"""

__version__ = "1.0.0"
