"""Learning-rate schedules (pure functions of the step counter)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        frac = jnp.clip(frac, 0.0, 1.0)
        cos = peak_lr * (min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule


def warmup_linear(peak_lr: float, warmup_steps: int, total_steps: int):
    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        frac = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        lin = peak_lr * jnp.clip(1.0 - frac, 0.0, 1.0)
        return jnp.where(step < warmup_steps, warm, lin)

    return schedule


def constant(lr: float):
    def schedule(step):
        return jnp.full((), lr, jnp.float32)

    return schedule
