"""Optimizers (pure JAX; no optax).

All optimizers keep f32 master weights when params are low-precision
(mixed-precision training at scale), and their states are plain pytrees
mirroring the param tree — so the FSDP shardings derived for params apply
1:1 to optimizer state (ZeRO-style optimizer-state sharding falls out for
free from GSPMD).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.utils.trees import tree_global_norm

Schedule = Callable[[jax.Array], jax.Array]


def clip_by_global_norm(grads, max_norm: float):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


@dataclass(frozen=True)
class AdamW:
    schedule: Schedule
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    max_grad_norm: float = 1.0

    def init(self, params) -> Dict[str, Any]:
        # copy=True: with f32 params astype would alias the same buffer and
        # break train-step donation (same buffer donated twice)
        f32 = lambda p: jnp.array(p, dtype=jnp.float32, copy=True)
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": jax.tree.map(f32, params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        lr = self.schedule(count)
        b1c = 1 - self.b1 ** count.astype(jnp.float32)
        b2c = 1 - self.b2 ** count.astype(jnp.float32)

        mu = jax.tree.map(lambda m, g: self.b1 * m + (1 - self.b1) * g, state["mu"], grads)
        nu = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g), state["nu"], grads
        )

        def step(master, m, v):
            upd = (m / b1c) / (jnp.sqrt(v / b2c) + self.eps)
            upd = upd + self.weight_decay * master
            return master - lr * upd

        master = jax.tree.map(step, state["master"], mu, nu)
        new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
        new_state = {"mu": mu, "nu": nu, "master": master, "count": count}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


@dataclass(frozen=True)
class SGD:
    schedule: Schedule
    momentum: float = 0.9
    max_grad_norm: float = 1.0

    def init(self, params):
        return {
            "vel": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "master": jax.tree.map(lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        lr = self.schedule(count)
        vel = jax.tree.map(lambda v, g: self.momentum * v + g, state["vel"], grads)
        master = jax.tree.map(lambda mp, v: mp - lr * v, state["master"], vel)
        new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"vel": vel, "master": master, "count": count}, {
            "grad_norm": gnorm,
            "lr": lr,
        }


@dataclass(frozen=True)
class Adafactor:
    """Factored second moments (Shazeer & Stern) — the memory-lean choice at
    scale: O(m+n) state per (m, n) matrix instead of O(mn)."""

    schedule: Schedule
    eps: float = 1e-30
    clip_threshold: float = 1.0
    max_grad_norm: float = 1.0

    def init(self, params):
        def factored(p):
            if p.ndim >= 2:
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {
            "v": jax.tree.map(factored, params),
            "master": jax.tree.map(lambda p: jnp.array(p, dtype=jnp.float32, copy=True), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.max_grad_norm)
        count = state["count"] + 1
        lr = self.schedule(count)
        decay = 1.0 - count.astype(jnp.float32) ** -0.8

        def upd(g, v, master):
            g2 = jnp.square(g) + self.eps
            if g.ndim >= 2:
                vr = decay * v["vr"] + (1 - decay) * jnp.mean(g2, axis=-1)
                vc = decay * v["vc"] + (1 - decay) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), self.eps)
                vhat = vr[..., None] * vc[..., None, :] / denom[..., None]
                new_v = {"vr": vr, "vc": vc}
            else:
                vhat = decay * v["v"] + (1 - decay) * g2
                new_v = {"v": vhat}
            u = g / jnp.sqrt(vhat + self.eps)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + self.eps)
            u = u / jnp.maximum(1.0, rms / self.clip_threshold)
            return master - lr * u, new_v

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["master"])
        flat_v = treedef.flatten_up_to(state["v"])
        new_m, new_v = [], []
        for g, v, m in zip(flat_g, flat_v, flat_m):
            nm, nv = upd(g, v, m)
            new_m.append(nm)
            new_v.append(nv)
        master = jax.tree.unflatten(treedef, new_m)
        vstate = jax.tree.unflatten(treedef, new_v)
        new_params = jax.tree.map(lambda mp, p: mp.astype(p.dtype), master, params)
        return new_params, {"v": vstate, "master": master, "count": count}, {
            "grad_norm": gnorm,
            "lr": lr,
        }


def make_optimizer(name: str, schedule: Schedule, **kw):
    name = name.lower()
    if name == "adamw":
        return AdamW(schedule, **kw)
    if name == "sgd":
        return SGD(schedule, **kw)
    if name == "adafactor":
        return Adafactor(schedule, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
