from repro.optim.optimizers import AdamW, Adafactor, SGD, clip_by_global_norm, make_optimizer
from repro.optim.schedules import constant, warmup_cosine, warmup_linear

__all__ = [
    "AdamW",
    "Adafactor",
    "SGD",
    "clip_by_global_norm",
    "make_optimizer",
    "constant",
    "warmup_cosine",
    "warmup_linear",
]
