"""Distribution layer: logical-axis sharding plans, gradient compression,
pipeline parallelism, and HLO collective/cost analysis."""
