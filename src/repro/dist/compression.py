"""Gradient compression with error feedback (1-bit-Adam-style residuals).

``quantize_int8`` is per-tensor symmetric int8: the communicated payload is
1/4 the f32 bytes (+ one scale). ``ErrorFeedback`` keeps the quantisation
residual locally and re-adds it before the next step's compression, so the
*accumulated* applied update converges to the accumulated true gradient —
the standard unbiasedness repair for aggressive compressors.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric quantisation: returns (int8 values, f32 scale)."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Quantise-dequantise roundtrip; returns (xhat, residual = x - xhat)."""
    q, scale = quantize_int8(x)
    xhat = q.astype(jnp.float32) * scale
    return xhat.astype(x.dtype), (x.astype(jnp.float32) - xhat).astype(x.dtype)


class ErrorFeedback:
    """Tree-level error-feedback state helpers (residual per parameter)."""

    @staticmethod
    def init(params):
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )

    @staticmethod
    def apply(grads, residuals):
        """Compress ``grads + residuals``; returns (ghat, new_residuals)."""
        pairs = jax.tree.map(
            lambda g, r: compress_decompress(g.astype(jnp.float32) + r),
            grads,
            residuals,
        )
        ghat = jax.tree.map(lambda t: t[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda t: t[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return ghat, res
