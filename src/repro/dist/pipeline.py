"""GPipe pipeline parallelism.

``split_stages`` folds the stacked-layer axis (L, ...) into (S, L/S, ...).
``pipeline_apply`` runs the classic GPipe schedule: microbatch ``m`` is
processed by stage ``s`` at step ``s + m``; activations move one stage
forward per step, so the whole batch drains in ``M + S - 1`` steps.

Two executions of the same schedule:

* **mesh path** (``mesh``/``axis`` given, stage count divisible by the axis
  size): ``shard_map`` pins each mesh slice to its own contiguous block of
  stages and moves activations with an explicit ``ppermute`` ring — the
  canonical pipeline formulation (explicit point-to-point, no partitioner
  guessing). Differentiable end-to-end (``ppermute`` transposes to the
  reverse ring).
* **fallback** (no mesh): a scanned rotating buffer computes every stage
  each step via ``vmap``; warm-up/cool-down garbage never reaches the
  output (clamped write indices are overwritten by the first valid write).

Both are exactly equal to sequential layer application — same
floating-point order per microbatch.
"""

from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax


def split_stages(params, n_stages: int):
    """(L, ...) stacked params -> (S, L/S, ...) staged params."""

    def split(a):
        l = a.shape[0]
        assert l % n_stages == 0, f"{l} layers not divisible into {n_stages} stages"
        return a.reshape(n_stages, l // n_stages, *a.shape[1:])

    return jax.tree.map(split, params)


def _pipeline_local(stage_fn, stage_params, x):
    """Single-device GPipe: rotating buffer over a scanned schedule."""
    s = jax.tree.leaves(stage_params)[0].shape[0]
    m = x.shape[0]
    apply_stages = jax.vmap(stage_fn)

    def step(carry, t):
        buf, outs = carry
        feed = x[jnp.clip(t, 0, m - 1)]
        shifted = jnp.concatenate([feed[None], buf[:-1]], axis=0)
        newbuf = apply_stages(stage_params, shifted)
        outs = lax.dynamic_update_index_in_dim(
            outs, newbuf[-1], jnp.clip(t - (s - 1), 0, m - 1), 0
        )
        return (newbuf, outs), None

    buf0 = jnp.zeros((s, *x.shape[1:]), x.dtype)
    (_, outs), _ = lax.scan(step, (buf0, jnp.zeros_like(x)), jnp.arange(m + s - 1))
    return outs


def pipeline_apply(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params,
    x: jax.Array,  # (M, MB, ...) microbatches
    *,
    mesh=None,
    axis: Optional[str] = None,
):
    """Run ``stage_fn`` over all stages in GPipe order; returns (M, MB, ...)."""
    s = jax.tree.leaves(stage_params)[0].shape[0]
    m = x.shape[0]
    if mesh is None or axis is None or axis not in mesh.shape or s % mesh.shape[axis]:
        return _pipeline_local(stage_fn, stage_params, x)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    n = mesh.shape[axis]  # pipeline ranks; each owns s // n stages
    s_loc = s // n
    ring = [(i, (i + 1) % n) for i in range(n)]

    def local(sp, xfull):
        # sp: (s_loc, ...) this rank's stages; xfull: (M, MB, ...) replicated
        j = lax.axis_index(axis)

        def chain(h):
            for i in range(s_loc):
                h = stage_fn(jax.tree.map(lambda a: a[i], sp), h)
            return h

        def step(carry, t):
            recv, outs = carry
            feed = jnp.where(j == 0, xfull[jnp.clip(t, 0, m - 1)], recv)
            h = chain(feed)
            outs = lax.dynamic_update_index_in_dim(
                outs, h, jnp.clip(t - (n - 1), 0, m - 1), 0
            )
            recv_next = lax.ppermute(h, axis, ring)
            return (recv_next, outs), None

        recv0 = jnp.zeros(xfull.shape[1:], xfull.dtype)
        (_, outs), _ = lax.scan(
            step, (recv0, jnp.zeros_like(xfull)), jnp.arange(m + n - 1)
        )
        # only the last rank holds finished microbatches; psum replicates
        outs = jnp.where(j == n - 1, outs, jnp.zeros_like(outs))
        return lax.psum(outs, axis)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            jax.tree.map(lambda a: P(*((axis,) + (None,) * (a.ndim - 1))), stage_params),
            P(*((None,) * x.ndim)),
        ),
        out_specs=P(*((None,) * x.ndim)),
        check_rep=False,
    )(stage_params, x)
