"""Loop-aware HLO cost analysis.

XLA's own ``cost_analysis`` counts a while-loop body once; for decode loops
that under-reports FLOPs and collective traffic by the trip count. This
parser walks the HLO text, recovers trip counts from canonical counter
loops (``i = 0; while (i < N) i += 1`` — the form XLA emits for
``lax.scan``/``fori_loop``), and multiplies body costs through, nesting
included.

Costs counted per instruction:
  * ``dot``      — 2 * prod(result_dims) * contracted_size FLOPs,
  * collectives  — payload bytes x algorithmic multiplier (all-reduce moves
    ~2x its buffer in a ring; gather/scatter/permute ~1x) x trip count.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.dist.hlo import COLLECTIVE_OPS, _DTYPE_BYTES

_COMP_HEAD = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{\s*$")
_SHAPED = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\w+)\[([0-9,]*)\]")
_BYTES_MULT = {"all-reduce": 2}


def _dims(s: str) -> Tuple[int, ...]:
    return tuple(int(d) for d in s.split(",") if d)


def _prod(t) -> int:
    out = 1
    for d in t:
        out *= int(d)
    return out


@dataclass
class CostResult:
    flops: float = 0.0
    coll_bytes: float = 0.0
    coll_counts: Dict[str, int] = field(default_factory=dict)

    def _add(self, other: "CostResult", mult: int) -> None:
        self.flops += other.flops * mult
        self.coll_bytes += other.coll_bytes * mult
        for op, c in other.coll_counts.items():
            self.coll_counts[op] = self.coll_counts.get(op, 0) + c * mult


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur: Optional[str] = None
        for raw in hlo_text.splitlines():
            line = raw.rstrip()
            m = _COMP_HEAD.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                self.computations[cur] = []
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.computations[cur].append(line.strip())

    # -- shape resolution ---------------------------------------------------
    def _symtab(self, comp: str) -> Dict[str, Tuple[str, Tuple[int, ...]]]:
        tab: Dict[str, Tuple[str, Tuple[int, ...]]] = {}
        for instr in self.computations.get(comp, ()):
            m = _SHAPED.match(instr)
            if m:
                tab[m.group(1)] = (m.group(2), _dims(m.group(3)))
        return tab

    # -- loop trip counts ---------------------------------------------------
    def _trip_count(self, cond_comp: str) -> int:
        """Canonical counter loop: ROOT compare(%i, %n) LT with %n constant
        (the form XLA emits for lax.scan / fori_loop). Operands may carry
        inline type annotations in compiled HLO. Unrecognised conditions
        conservatively count as one trip."""
        instrs = self.computations.get(cond_comp, ())
        consts: Dict[str, int] = {}
        for instr in instrs:
            m = re.match(
                r"(?:ROOT\s+)?%([\w.\-]+)\s*=\s*s32\[\]\s*constant\((\d+)\)", instr
            )
            if m:
                consts[m.group(1)] = int(m.group(2))
        for instr in instrs:
            m = re.search(
                r"compare\((?:\S+\s+)?%([\w.\-]+),\s*(?:\S+\s+)?%([\w.\-]+)\)"
                r".*direction=LT",
                instr,
            )
            if m and m.group(2) in consts:
                return consts[m.group(2)]
        return 1

    # -- cost ---------------------------------------------------------------
    def cost(self, comp: Optional[str] = None) -> CostResult:
        comp = comp or self.entry
        out = CostResult()
        if comp is None:
            return out
        tab = self._symtab(comp)
        for instr in self.computations.get(comp, ()):
            shaped = _SHAPED.match(instr)
            m = re.search(
                r"while\(.*\),\s*condition=%([\w.\-]+),\s*body=%([\w.\-]+)", instr
            )
            if m:
                trips = self._trip_count(m.group(1))
                out._add(self._cost_cached(m.group(2)), trips)
                continue
            # pre-optimisation HLO references bare %operands; compiled HLO
            # annotates each operand with its type inline — accept both,
            # preferring the inline lhs shape over the symbol table
            m = re.search(
                r"\bdot\((?:(\w+)\[([0-9,]*)\]\S*\s+)?%([\w.\-]+),.*"
                r"lhs_contracting_dims={([0-9,]*)}",
                instr,
            )
            if m and shaped:
                if m.group(2) is not None:
                    lhs_dims = _dims(m.group(2))
                else:
                    lhs = tab.get(m.group(3))
                    if lhs is None:
                        continue
                    lhs_dims = lhs[1]
                k = _prod(lhs_dims[d] for d in _dims(m.group(4)))
                out.flops += 2.0 * _prod(_dims(shaped.group(3))) * k
                continue
            hit_coll = False
            for op in COLLECTIVE_OPS:
                if re.search(rf"\b{op}\(", instr) and shaped:
                    nbytes = _prod(_dims(shaped.group(3))) * _DTYPE_BYTES.get(
                        shaped.group(2), 4
                    )
                    out.coll_bytes += nbytes * _BYTES_MULT.get(op, 1)
                    out.coll_counts[op] = out.coll_counts.get(op, 0) + 1
                    hit_coll = True
                    break
            if hit_coll:
                continue
            # fusions/calls hide dots in sub-computations (compiled CPU HLO)
            m = re.search(r"\b(?:fusion|call)\(.*?(?:calls|to_apply)=%([\w.\-]+)", instr)
            if m:
                out._add(self._cost_cached(m.group(1)), 1)
        return out

    def _cost_cached(self, comp: str) -> CostResult:
        cache = getattr(self, "_cost_cache", None)
        if cache is None:
            cache = self._cost_cache = {}
        if comp not in cache:
            cache[comp] = self.cost(comp)
        return cache[comp]


def analyze(hlo_text: str) -> CostResult:
    """Entry-computation cost with while-loop trip multipliers applied."""
    return HloCostModel(hlo_text).cost()
