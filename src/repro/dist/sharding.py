"""Logical-axis sharding: ArraySpec pytrees -> PartitionSpecs via named rules.

Model code never names mesh axes. Parameters and activations carry *logical*
axis names (``"embed"``, ``"heads"``, ``"batch"``, ...) in ``ArraySpec``s;
a ``ShardingPlan`` binds those names to the axes of a concrete ``jax.Mesh``
through a rule table (``DEFAULT_RULES`` + per-cell overrides). The solver
demotes an axis to replication when

  * the rule maps to mesh axes absent from this mesh (e.g. ``pod`` on a
    single-pod mesh),
  * every mapped mesh axis has size 1 (sharding would be a no-op),
  * the dim is not divisible by the mapped axis product (GSPMD would pad), or
  * a mesh axis was already consumed by an earlier dim of the same array
    (an axis may shard at most one dim).

``constrain``/``constrain_uneven`` are the activation-side entry points: they
are no-ops unless a plan is installed via ``use_plan`` (so model code runs
unchanged in single-device tests), and ``constrain_uneven`` skips the
divisibility demotion for cases where GSPMD padding is intended (e.g. 56
heads over 16 devices).
"""

from __future__ import annotations

import math
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

#: logical axis -> mesh axis (or tuple of mesh axes, outermost first).
#: ``batch`` spans the pure data-parallel axes; tensor-parallel dims ride
#: ``model``; ``embed`` is FSDP-sharded over ``data``.
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "embed": "data",
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "vocab": "model",
    "experts": "model",
    "ssm_inner": "model",
    "frames": None,
    "seq": None,
    "kv_seq": None,
    "stack": None,
}


@dataclass
class ArraySpec:
    """Shape + dtype + logical sharding axes (+ init) for one array."""

    shape: Tuple[int, ...]
    dtype: str = "float32"
    axes: Tuple[Optional[str], ...] = ()
    init: str = "normal"  # "normal" | "zeros" | "ones"

    def __post_init__(self):
        self.shape = tuple(int(d) for d in self.shape)
        self.axes = tuple(self.axes)
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes/shape rank mismatch: {self.axes} vs {self.shape}"
            )

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, jnp.dtype(self.dtype))


def _is_spec(x) -> bool:
    return isinstance(x, ArraySpec)


class ShardingPlan:
    """Binds logical axis names to the axes of a concrete mesh."""

    def __init__(self, mesh: Mesh, rules: Optional[Mapping[str, Any]] = None):
        self.mesh = mesh
        self.rules: Dict[str, Any] = dict(DEFAULT_RULES)
        if rules:
            self.rules.update(rules)

    # -- solving -----------------------------------------------------------
    def _mesh_axes_for(self, logical: Optional[str]) -> Tuple[str, ...]:
        """Mesh axes (present in this mesh, size > 1) a logical axis maps to."""
        if logical is None:
            return ()
        rule = self.rules.get(logical)
        if rule is None:
            return ()
        names = (rule,) if isinstance(rule, str) else tuple(rule)
        return tuple(
            a for a in names if a in self.mesh.shape and self.mesh.shape[a] > 1
        )

    def axis_divisor(self, logical: str) -> int:
        """Sharding factor a logical axis implies on this mesh."""
        return math.prod(
            (self.mesh.shape[a] for a in self._mesh_axes_for(logical)), start=1
        )

    def gemm_div(self) -> Dict[str, int]:
        """Per-shard GEMM divisor table for this mesh — the ``div`` dict
        model layers thread into dispatch (``div.get("batch")`` /
        ``div.get("model")``). Tokens shard over the batch axes (``pod`` x
        ``data``); tensor-parallel weight dims (heads/ffn/vocab/experts)
        ride the mesh's ``model`` axis. Dividing the global MNK by these is
        what makes a :class:`~repro.core.op.GemmOp` fingerprint the *local*
        per-device problem the Pallas kernel actually sees under
        ``shard_map`` — so a tuning record produced on one host is an exact
        database hit on every identically-sharded host, which is the
        invariant federated tuning (``repro.core.federate``) relies on.

        Caveat: this is the mesh-level table the model layers already
        thread by hand; like those hand-built tables it does not see
        :meth:`spec_for`'s per-array divisibility demotion. A weight dim
        the solver demotes to replication (e.g. an odd vocab on a model=4
        mesh) executes at its global size while the fingerprint still
        divides. Call sites that know the concrete arrays should probe
        :meth:`demoted_dims` and demote the table accordingly — the serve
        engine does (``repro.serve.engine.serve_gemm_div``), so serving
        fingerprints never claim a split the weights don't execute."""
        return {
            "batch": self.axis_divisor("batch"),
            "model": int(self.mesh.shape.get("model", 1)),
        }

    def demoted_dims(self, specs, mesh_axis: str = "model"):
        """Per-array divisibility probe: every (shape, axes, dim_index, dim)
        in the ArraySpec tree whose logical axis maps onto ``mesh_axis``
        but which :meth:`spec_for`'s solver would demote to replication
        (non-divisible dim, same demotion rule, non-uneven path). Empty
        means the mesh-level :meth:`gemm_div` entry for that axis is exact
        for every array in the tree."""
        out = []

        def visit(s: ArraySpec):
            used: set = set()
            for i, (dim, logical) in enumerate(zip(s.shape, s.axes)):
                axes = tuple(
                    a for a in self._mesh_axes_for(logical) if a not in used
                )
                if not axes:
                    continue
                div = math.prod(self.mesh.shape[a] for a in axes)
                if dim % div:
                    if mesh_axis in axes:
                        out.append((s.shape, s.axes, i, dim))
                else:
                    used.update(axes)
            return s

        jax.tree.map(visit, specs, is_leaf=_is_spec)
        return out

    def spec_for(self, spec: ArraySpec, *, uneven: bool = False) -> P:
        """PartitionSpec for one array, with demotion (see module doc)."""
        used: set = set()
        entries = []
        for dim, logical in zip(spec.shape, spec.axes):
            axes = tuple(a for a in self._mesh_axes_for(logical) if a not in used)
            if axes:
                div = math.prod(self.mesh.shape[a] for a in axes)
                if not uneven and dim % div:
                    axes = ()
            used.update(axes)
            if not axes:
                entries.append(None)
            elif len(axes) == 1:
                entries.append(axes[0])
            else:
                entries.append(axes)
        return P(*entries)

    # -- trees -------------------------------------------------------------
    def sharding_for(self, spec: ArraySpec) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec_for(spec))

    def tree_shardings(self, tree):
        return jax.tree.map(self.sharding_for, tree, is_leaf=_is_spec)


# -- ambient plan -----------------------------------------------------------

_plan_state = threading.local()


def current_plan() -> Optional[ShardingPlan]:
    return getattr(_plan_state, "plan", None)


@contextmanager
def use_plan(plan: Optional[ShardingPlan]):
    old = current_plan()
    _plan_state.plan = plan
    try:
        yield plan
    finally:
        _plan_state.plan = old


def _constrain(x: jax.Array, axes: Sequence[Optional[str]], uneven: bool):
    plan = current_plan()
    if plan is None:
        return x
    spec = ArraySpec(tuple(x.shape), str(x.dtype), tuple(axes))
    pspec = plan.spec_for(spec, uneven=uneven)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(plan.mesh, pspec)
    )


def ambient_gemm_div() -> Dict[str, int]:
    """GEMM divisor table of the installed plan (see
    :meth:`ShardingPlan.gemm_div`); empty — every divisor 1, fingerprints
    key on global shapes — when no plan is installed, so single-device
    tests and examples run unchanged."""
    plan = current_plan()
    return plan.gemm_div() if plan is not None else {}


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Sharding hint by logical axis names; no-op without an installed plan."""
    return _constrain(x, axes, uneven=False)


def constrain_uneven(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Like :func:`constrain` but keeps axes whose dim is not divisible —
    GSPMD pads (e.g. 56 heads over a 16-way model axis)."""
    return _constrain(x, axes, uneven=True)


# -- materialization ---------------------------------------------------------


def abstract_tree(tree):
    """ArraySpec tree -> ShapeDtypeStruct tree (for eval_shape/lowering)."""
    return jax.tree.map(lambda s: s.abstract(), tree, is_leaf=_is_spec)


def _init_leaf(spec: ArraySpec, key) -> jax.Array:
    dtype = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    # fan-in-scaled normal; the stacked-layer axis (leading) never counts as
    # fan-in because specs are stacked after the per-layer shape is fixed.
    if len(spec.shape) >= 2:
        fan_in = spec.shape[-2]
    else:
        fan_in = spec.shape[-1]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)


def materialize_tree(tree, key):
    """Instantiate an ArraySpec tree with deterministic per-leaf RNG."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=_is_spec)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = [_init_leaf(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, out)
