"""Single-pass HLO-text collective accounting (loop-unaware by design —
``repro.dist.hlo_cost`` owns trip-count multiplication).

Parses compiled HLO for collective ops and sums payload bytes from the
instruction's result shape. Used by the dry-run to report per-cell
collective traffic.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1,
    "s8": 1,
    "u8": 1,
    "s16": 2,
    "u16": 2,
    "bf16": 2,
    "f16": 2,
    "s32": 4,
    "u32": 4,
    "f32": 4,
    "s64": 8,
    "u64": 8,
    "f64": 8,
}

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_INSTR = re.compile(
    r"=\s*(\w+)\[([0-9,]*)\]\S*\s+(" + "|".join(COLLECTIVE_OPS) + r")\("
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclass
class CollectiveStats:
    #: op name -> (count, total payload bytes)
    per_op: Dict[str, Tuple[int, int]] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(b for _, b in self.per_op.values())

    @property
    def total_count(self) -> int:
        return sum(c for c, _ in self.per_op.values())

    def summary(self) -> Dict[str, Dict[str, int]]:
        return {
            op: {"count": c, "bytes": b} for op, (c, b) in sorted(self.per_op.items())
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for m in _INSTR.finditer(hlo_text):
        dtype, dims, op = m.group(1), m.group(2), m.group(3)
        c, b = stats.per_op.get(op, (0, 0))
        stats.per_op[op] = (c + 1, b + _shape_bytes(dtype, dims))
    return stats
