from repro.train.trainer import (
    Trainer,
    TrainerConfig,
    StragglerMonitor,
    init_train_state,
    make_train_step,
    train_gemm_div,
)

__all__ = [
    "Trainer",
    "TrainerConfig",
    "StragglerMonitor",
    "init_train_state",
    "make_train_step",
    "train_gemm_div",
]
