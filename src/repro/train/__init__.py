from repro.train.trainer import (
    Trainer,
    TrainerConfig,
    StragglerMonitor,
    init_train_state,
    make_train_step,
)

__all__ = [
    "Trainer",
    "TrainerConfig",
    "StragglerMonitor",
    "init_train_state",
    "make_train_step",
]
