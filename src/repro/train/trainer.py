"""Fault-tolerant training loop.

Responsibilities beyond ``train_step``:
  * checkpoint/restart (exact resume: params + optimizer + data-iterator +
    step — the restart test asserts a bitwise-identical loss trajectory),
  * preemption (SIGTERM -> final checkpoint),
  * straggler monitoring (per-step wall-time EWMA; steps > mean + k*sigma are
    logged and counted — on a fleet this feeds the re-dispatch policy),
  * microbatch gradient accumulation (sequential ``lax.scan`` over
    microbatches — the standard way to hold global batch while scaling
    nodes down),
  * optional int8 gradient compression with error feedback (cross-pod DCN
    traffic; see dist/compression.py),
  * simulated failure injection for the fault-tolerance tests.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager, install_sigterm_handler
from repro.data import SyntheticLMData
from repro.dist.compression import ErrorFeedback
from repro.utils.logging import get_logger
from repro.utils.timing import EWMA, Timer

log = get_logger("train")


def train_gemm_div(
    model, batch: Optional[int] = None, plan=None
) -> Dict[str, int]:
    """Per-array-aware ambient GEMM divisor table for the train path.

    ``ShardingPlan.gemm_div`` is mesh-level: it cannot see the per-array
    divisibility demotion ``spec_for`` applies (an odd vocab on a model=4
    mesh executes replicated while the mesh table still claims the split).
    ``serve_gemm_div`` closed that gap for serving; this is the same probe
    at the trainer call site — the other place the mesh-level table used to
    be threaded verbatim (ROADMAP item 6's leftover). Every parameter spec
    runs through the plan's own solver (:meth:`ShardingPlan.demoted_dims`);
    when any tensor-parallel weight dim would be demoted to replication the
    table's ``model`` entry drops to 1, and ``batch`` drops to 1 when the
    global batch is not divisible by the data-parallel factor — so train
    fingerprints never claim splits the arrays don't execute.

    ``plan`` defaults to the ambient :func:`~repro.dist.sharding.current_plan`;
    pass it explicitly when building the step before installing the plan.
    Returns ``{}`` when no plan is active (unsharded training)."""
    from repro.dist.sharding import current_plan

    if plan is None:
        plan = current_plan()
    if plan is None:
        return {}
    div = dict(plan.gemm_div())
    tp = div.get("model", 1)
    if tp > 1:
        offenders = plan.demoted_dims(model.param_specs(), mesh_axis="model")
        if offenders:
            shown = ", ".join(
                f"dim {d} ({ax or '?'}) of {sh}" for sh, ax, _, d in offenders[:3]
            )
            log.warning(
                "train fingerprints demote model divisor %d -> 1: %d weight "
                "dim(s) fail the plan's divisibility solver and execute "
                "replicated (e.g. %s); a mesh-level divisor would fingerprint "
                "local shapes the kernels never see",
                tp,
                len(offenders),
                shown,
            )
            div["model"] = 1
    db = div.get("batch", 1)
    if batch is not None and db > 1 and batch % db:
        log.warning(
            "train fingerprints demote batch divisor %d -> 1: global batch "
            "%d is not divisible, so activations execute replicated",
            db,
            batch,
        )
        div["batch"] = 1
    return div


@dataclass
class TrainerConfig:
    total_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    async_ckpt: bool = True
    microbatches: int = 1
    grad_compression: bool = False
    straggler_k: float = 3.0
    handle_sigterm: bool = False


def make_train_step(
    model,
    optimizer,
    *,
    div: Optional[Dict[str, int]] = None,
    microbatches: int = 1,
    grad_compression: bool = False,
    extra_shardings=None,
):
    """Build the jit'd train step: (state, batch) -> (state, metrics).

    state = {params, opt, step} (+ "ef" residuals when compression is on).
    With ``microbatches > 1`` the global batch is split on axis 0 and
    gradients are accumulated with a sequential scan.
    """

    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch, div=div)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def compute_grads(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        split = lambda x: x.reshape(microbatches, x.shape[0] // microbatches, *x.shape[1:])
        mb = jax.tree.map(split, batch)

        def body(carry, b):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, b)
            acc = jax.tree.map(jnp.add, acc, grads)
            return (acc, loss_acc + loss), metrics

        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gacc, loss_sum), metrics = jax.lax.scan(body, (zero, 0.0), mb)
        grads = jax.tree.map(lambda g: g / microbatches, gacc)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss_sum / microbatches, metrics, grads

    def step_fn(state, batch):
        params = state["params"]
        loss, metrics, grads = compute_grads(params, batch)
        if grad_compression:
            grads, residuals = ErrorFeedback.apply(grads, state["ef"])
        new_params, opt_state, opt_metrics = optimizer.update(
            grads, state["opt"], params
        )
        new_state = {
            "params": new_params,
            "opt": opt_state,
            "step": state["step"] + 1,
        }
        if grad_compression:
            new_state["ef"] = residuals
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return new_state, metrics

    return step_fn


def init_train_state(model, optimizer, params, grad_compression: bool = False):
    state = {
        "params": params,
        "opt": optimizer.init(params),
        "step": jnp.zeros((), jnp.int32),
    }
    if grad_compression:
        state["ef"] = ErrorFeedback.init(params)
    return state


@dataclass
class StragglerMonitor:
    ewma: EWMA = field(default_factory=EWMA)
    k: float = 3.0
    flagged: int = 0

    def observe(self, seconds: float) -> bool:
        outlier = self.ewma.is_outlier(seconds, self.k)
        self.ewma.update(seconds)
        if outlier:
            self.flagged += 1
            log.warning(
                "straggler step: %.3fs (mean %.3fs, std %.3fs)",
                seconds,
                self.ewma.mean,
                self.ewma.std,
            )
        return outlier


class Trainer:
    def __init__(
        self,
        model,
        optimizer,
        data: SyntheticLMData,
        cfg: TrainerConfig,
        *,
        div: Optional[Dict[str, int]] = None,
        jit: bool = True,
        failure_injector: Optional[Callable[[int], None]] = None,
    ):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.cfg = cfg
        if div is None:
            # default to the probed ambient table (no-op when no plan is
            # installed) so direct Trainer users get the per-array demotion
            # without threading the table themselves
            div = train_gemm_div(model) or None
        self.div = div
        self.failure_injector = failure_injector
        step_fn = make_train_step(
            model,
            optimizer,
            div=div,
            microbatches=cfg.microbatches,
            grad_compression=cfg.grad_compression,
        )
        self.step_fn = jax.jit(step_fn, donate_argnums=(0,)) if jit else step_fn
        self.ckpt = CheckpointManager(cfg.ckpt_dir, cfg.ckpt_keep) if cfg.ckpt_dir else None
        self.monitor = StragglerMonitor(k=cfg.straggler_k)
        self.history: list = []

    # -- checkpoint plumbing ------------------------------------------------
    def _save(self, state, blocking=True):
        if not self.ckpt:
            return
        step = int(state["step"])
        self.ckpt.save(
            step,
            state,
            extra={"data": self.data.state_dict()},
            blocking=blocking,
        )

    def maybe_restore(self, state):
        if not self.ckpt or self.ckpt.latest_step() is None:
            return state, 0
        restored, step = self.ckpt.restore(state)
        extra = self.ckpt.read_extra(step)
        if "data" in extra:
            self.data.load_state_dict(extra["data"])
        log.info("resumed from checkpoint step %d", step)
        return restored, step

    # -- main loop --------------------------------------------------------------
    def fit(self, state):
        cfg = self.cfg
        state, start = self.maybe_restore(state)
        if cfg.handle_sigterm and self.ckpt:
            install_sigterm_handler(lambda: self._save(state, blocking=True))
        step = start
        while step < cfg.total_steps:
            batch = {k: jnp.asarray(v) for k, v in self.data.batch_at(step).items()}
            if self.failure_injector:
                self.failure_injector(step)  # may raise to simulate a crash
            with Timer() as t:
                state, metrics = self.step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            self.monitor.observe(t.seconds)
            step += 1
            self.data.state.step = step
            loss = float(metrics["loss"])
            self.history.append(loss)
            if step % cfg.log_every == 0 or step == cfg.total_steps:
                log.info(
                    "step %d loss %.4f grad_norm %.3f (%.3fs)",
                    step,
                    loss,
                    float(metrics.get("grad_norm", 0.0)),
                    t.seconds,
                )
            if self.ckpt and (step % cfg.ckpt_every == 0 or step == cfg.total_steps):
                self._save(state, blocking=not cfg.async_ckpt)
        if self.ckpt:
            self.ckpt.wait()
        return state
