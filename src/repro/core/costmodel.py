"""Analytical TPU GEMM cost model (the tuner's measurement oracle on CPU).

The paper tunes by wall-clocking kernels on an MI250X. This container has no
accelerator, so the ckProfiler-analogue tuner measures against this
calibrated analytical model instead; on real hardware the measurement
function is swapped for wall-clock timing (``tuner.measure_wallclock``) with
zero changes elsewhere — the model IS the hardware-adaptation layer.

Machine model (TPU v5e):
  * ``peak_flops``  — 197 TFLOP/s bf16 per chip (MXU).
  * ``hbm_bw``      — 819 GB/s.
  * ``lanes`` (C)   — number of concurrent tile slots; the TPU analogue of
    the paper's "CU count" (GPU: 104 CUs). A v5e TensorCore has 4 MXUs x 2
    pipeline slots -> C = 8 by default. Output-tile schedules quantize into
    ``ceil(T / C)`` waves exactly like GPU wavefront rounds — this is the
    pathology Stream-K removes.
  * MXU tiles are *padded*: a (BM, BN, BK) tile costs the full
    2*BM*BN*BK FLOPs even when M < BM (systolic array shape is fixed) — this
    is why tile-config selection matters for skinny GEMMs and why the tuner
    sweeps configs jointly with policies.

Grid size ``g`` (number of persistent workgroups the flattened iteration
space is split over) is a *tuning axis*, not a hardware constant: the
original Stream-K paper shows performance is highly sensitive to it. The
model keeps ``g`` distinct from ``lanes``: ``g`` workgroups time-share the
``lanes`` physical slots, so every wave of ``g`` programs costs
``ceil(g / lanes)`` lane-rounds. ``g == lanes`` reproduces the legacy
one-program-per-lane schedule exactly; ``g != lanes`` changes the HYBRID
remainder wave (``T mod g``), the split-tile fix-up plan, and DP wave
quantization — which is why the tuner sweeps it jointly with (policy, tile).

Dtype awareness: every timing term is keyed on the *actual* operand
byte-widths (:class:`DtypeBytes`) — A/B input widths drive the HBM term of
each k-iteration, the output width drives the C writeback, and the f32
accumulator width drives fix-up traffic and VMEM feasibility. f32, bf16 and
int8 ops of the same MNK therefore score (and can select) differently. The
module-level default stays the paper's fp16-suite 2-byte profile so bare
(M, N, K) scoring is unchanged.

Timing terms:
  t_tile  = max(tile_flops / lane_flops, tile_bytes / lane_bw)
  DP      : ceil(T/g) * mult * t_tile                            (wave rounds)
  ALL_SK  : ceil(total_iters/g) * mult * t_iter + fixup          (Algorithm 1)
  HYBRID_b: sk_body + max(dp_waves * mult * t_tile, fixup)       (overlap §4.1)
  mult    = ceil(g / lanes)                       (lane multiplexing rounds)

Fix-up (TPU two-phase reduction replacing GPU atomics): every split tile's
non-owning contributors round-trip a BM*BN f32 partial through HBM, plus a
per-split-tile serialization latency (the analogue of the paper's
"thousands of clock cycles" atomic-add tail).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from functools import lru_cache
from typing import Optional, Tuple

from repro.core.policies import (
    ALL_POLICIES,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
)
from repro.core.workpart import (
    GemmShape,
    GroupedGemmShape,
    Partition,
    PartitionStats,
    cdiv,
    partition_stats,
)


@dataclass(frozen=True)
class Machine:
    """Hardware constants; defaults are TPU v5e."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip (MXU)
    hbm_bw: float = 819e9  # B/s
    lanes: int = 8  # concurrent tile slots (virtual CUs)
    ici_bw: float = 50e9  # B/s per link (used by the roofline module)
    launch_overhead_s: float = 2e-6  # kernel launch + grid setup
    fixup_serial_s: float = 1.2e-6  # per-split-tile reduction tail
    vmem_bytes: int = 16 * 2 ** 20  # ~16 MiB usable VMEM per lane's working set

    @property
    def lane_flops(self) -> float:
        """Peak FLOP/s available to one lane (virtual CU)."""
        return self.peak_flops / self.lanes

    @property
    def lane_bw(self) -> float:
        """HBM bandwidth share of one lane (B/s)."""
        return self.hbm_bw / self.lanes


V5E = Machine()


def default_grid_sizes(mach: Machine = V5E) -> Tuple[int, ...]:
    """The swept grid sizes: {lanes/2, lanes, 2*lanes}, deduped, ascending —
    the "additional tuning parameter" axis the tuner/selector sweep jointly
    with (policy, tile)."""
    lanes = mach.lanes
    return tuple(sorted({max(1, lanes // 2), lanes, 2 * lanes}))


# ---------------------------------------------------------------------------
# Dtype byte-width profiles
# ---------------------------------------------------------------------------

_WIDTHS = {
    "float64": 8,
    "float32": 4,
    "float16": 2,
    "bfloat16": 2,
    "int32": 4,
    "uint32": 4,
    "int16": 2,
    "uint16": 2,
    "int8": 1,
    "uint8": 1,
    # packed sub-byte dtypes: two nibbles per byte along K, so each element
    # moves half a byte through HBM (fractional widths are what the tile-time
    # bytes terms multiply by — they never index an array dtype directly)
    "int4": 0.5,
    "uint4": 0.5,
}


def dtype_width(name: str) -> float:
    """Byte width of a dtype fingerprint component (e.g. ``"bfloat16"``).
    Sub-byte packed dtypes are fractional (``int4`` -> 0.5). Unknown names
    fall back to the bit-count embedded in the name (so ``float8_e4m3fn``
    -> 1) and finally to 4 bytes."""
    w = _WIDTHS.get(name)
    if w is not None:
        return w
    m = re.search(r"(\d+)", name)
    if m:
        return max(1, int(m.group(1)) // 8)
    return 4


@dataclass(frozen=True)
class DtypeBytes:
    """Operand byte-widths one GEMM dispatch actually moves.

    ``a``/``b`` are the input widths (distinct, so mixed bf16-activation x
    int8-weight ops model their real A/B traffic; fractional for packed
    sub-byte weights — int4 B moves 0.5 bytes/element), ``out`` the C
    width, and ``acc`` the accumulator width (f32 partials in every kernel
    here — fix-up traffic and VMEM accumulators are ``acc``-wide regardless
    of the input dtype)."""

    a: float = 2
    b: float = 2
    out: float = 2
    acc: float = 4


#: module default: the paper's fp16 benchmark suite moves 2-byte operands;
#: bare (M, N, K) scoring keeps this profile so legacy artifacts are stable.
DEFAULT_DTYPES = DtypeBytes()


#: floor for the inferred C width when the op fingerprint has no out_dtype:
#: no kernel here stores integer-width outputs — the epilogue rescales the
#: f32 accumulator and casts to a float dtype at least 2 bytes wide, so an
#: int8*int8 op must not score a 1-byte C write.
_MIN_STORE_WIDTH = 2


def profile_for(in_dtype: str, out_dtype: Optional[str] = None) -> DtypeBytes:
    """DtypeBytes for a :class:`~repro.core.op.GemmOp`'s dtype fingerprints.
    ``in_dtype`` may be the mixed ``"<a_dtype>*<b_dtype>"`` form. When the
    op carries no out_dtype the C width is inferred from the inputs but
    clamped to :data:`_MIN_STORE_WIDTH` — low-precision inputs shrink A/B
    traffic, never the stored output."""
    if "*" in in_dtype:
        a_name, b_name = in_dtype.split("*", 1)
    else:
        a_name = b_name = in_dtype
    a = dtype_width(a_name)
    b = dtype_width(b_name)
    out = dtype_width(out_dtype) if out_dtype else max(a, b, _MIN_STORE_WIDTH)
    return DtypeBytes(a=a, b=b, out=out)


def op_dtypes(op) -> DtypeBytes:
    """Profile for a GemmOp (duck-typed: anything with in_dtype/out_dtype)."""
    return profile_for(op.in_dtype, op.out_dtype)


def op_shape(op) -> GemmShape:
    """Shape the cost model should score for an op fingerprint.

    A fused grouped op scores as a :class:`GroupedGemmShape` over the
    concatenated tile space of its local group count — one launch, one
    persistent grid, G-independent trace cost. Everything else (plain ops,
    loop-form grouped/batched ops, whose backend launches per group and
    whose selection covers one group's local problem) scores the plain
    per-group shape, exactly as before."""
    m, n, k = op.local
    if getattr(op, "fused", False):
        return GroupedGemmShape(m, n, k, groups=op.g_local)
    return GemmShape(m, n, k)


# ---------------------------------------------------------------------------
# Timing terms
# ---------------------------------------------------------------------------


def _tile_times(mach: Machine, cfg: TileConfig, dt: DtypeBytes = DEFAULT_DTYPES):
    """t_single_k_iter for one lane."""
    # One k-iteration moves an A (BM,BK) and B (BK,BN) tile HBM->VMEM and
    # issues 2*BM*BN*BK MACs on the MXU; A and B widths differ for mixed
    # activation x weight dtypes.
    iter_flops = 2 * cfg.bm * cfg.bn * cfg.bk
    iter_bytes = cfg.bm * cfg.bk * dt.a + cfg.bk * cfg.bn * dt.b
    t_iter = max(iter_flops / mach.lane_flops, iter_bytes / mach.lane_bw)
    return t_iter


def _fixup_time(
    mach: Machine, st: PartitionStats, cfg: TileConfig, dt: DtypeBytes = DEFAULT_DTYPES
) -> float:
    """Two-phase reduction cost: partial write + read + final write, plus a
    serialization tail per split tile. Partials are accumulator-width."""
    acc_bytes = cfg.bm * cfg.bn * dt.acc
    bytes_moved = st.extra_contributors * acc_bytes * 2  # write + read back
    return bytes_moved / mach.hbm_bw + st.n_split_tiles * mach.fixup_serial_s


def _output_time(
    mach: Machine, st: PartitionStats, cfg: TileConfig, dt: DtypeBytes = DEFAULT_DTYPES
) -> float:
    return (st.n_tiles_total * cfg.bm * cfg.bn * dt.out) / mach.hbm_bw


def vmem_working_set(cfg: TileConfig, dt: DtypeBytes = DEFAULT_DTYPES) -> int:
    """Dtype-aware VMEM claim: ``TileConfig.vmem_bytes`` at the profile's
    real A/B/accumulator widths (one source of truth for the formula)."""
    return cfg.vmem_bytes(
        in_dtype_bytes=dt.a, acc_dtype_bytes=dt.acc, b_dtype_bytes=dt.b
    )


@lru_cache(maxsize=200_000)
def gemm_time_s(
    shape: GemmShape,
    cfg: TileConfig,
    policy: Policy,
    mach: Machine = V5E,
    g: Optional[int] = None,
    dt: DtypeBytes = DEFAULT_DTYPES,
) -> float:
    """Modeled execution time of one GEMM under (cfg, policy, g, dtypes)."""
    g = g or mach.lanes
    st = partition_stats(shape, cfg, g, policy)
    t_iter = _tile_times(mach, cfg, dt)
    t_tile = st.iters_per_tile * t_iter
    # g workgroups time-share `lanes` physical slots: each wave of g programs
    # costs ceil(g/lanes) lane-rounds (mult == 1 for the legacy g == lanes).
    mult = cdiv(g, mach.lanes)

    t = mach.launch_overhead_s + _output_time(mach, st, cfg, dt)
    if st.sk_tiles:
        sk_body = cdiv(st.sk_total_iters, g) * mult * t_iter
        fixup = _fixup_time(mach, st, cfg, dt)
        dp = st.dp_waves * mult * t_tile
        if st.dp_tiles:
            # SK scheduled first; fix-up latency hidden under the DP phase
            # (§4.1 "strategic overlap of execution").
            t += sk_body + max(dp, fixup)
        else:
            t += sk_body + fixup
    else:
        t += st.dp_waves * mult * t_tile
    return t


def gemm_tflops(
    shape: GemmShape,
    cfg: TileConfig,
    policy: Policy,
    mach: Machine = V5E,
    g: Optional[int] = None,
    dt: DtypeBytes = DEFAULT_DTYPES,
) -> float:
    """Modeled effective TFLOP/s (true FLOPs / modeled time) — the tuner's
    objective, matching ckProfiler's reporting."""
    return shape.flops / gemm_time_s(shape, cfg, policy, mach, g, dt) / 1e12


@lru_cache(maxsize=50_000)
def rank_candidates(
    shape: GemmShape,
    mach: Machine = V5E,
    policies: Tuple[Policy, ...] = ALL_POLICIES,
    tile_configs: Tuple[TileConfig, ...] = DEFAULT_TILE_CONFIGS,
    grid_sizes: Optional[Tuple[int, ...]] = None,
    dt: DtypeBytes = DEFAULT_DTYPES,
) -> Tuple[Tuple[Policy, TileConfig, int, float], ...]:
    """The full (policy, cfg, g) candidate list ordered by modeled time.

    This is THE ranking primitive of analytical-first selection: the tuner's
    budgeted top-k sweeps measure a prefix of it, the selector's ``"model"``
    dispatch source launches its head, and the regret benchmark compares its
    order against measured reality. Each entry is
    ``(policy, cfg, g, modeled_time_s)``, ascending (fastest first); VMEM
    feasibility is checked at the profile's real byte-widths. Exact modeled
    ties preserve the sweep's (policy, g, cfg) iteration order — the same
    deterministic order the legacy strict-argmax resolved them in, so
    refactoring to rank-then-take-head changes no winner.

    The cache keys on every argument *including the (frozen, hashable)
    ``Machine``* — swapping in a calibrated machine must never read scores
    memoised under the default ``V5E`` constants.
    """
    grids = grid_sizes if grid_sizes is not None else default_grid_sizes(mach)
    out = []
    for pol in policies:
        for g in grids:
            for cfg in tile_configs:
                if vmem_working_set(cfg, dt) > mach.vmem_bytes:
                    continue
                t = gemm_time_s(shape, cfg, pol, mach, g, dt)
                out.append((pol, cfg, g, t))
    if not out:
        raise AssertionError("no tile config fits VMEM")
    out.sort(key=lambda c: c[3])  # stable: ties keep iteration order
    return tuple(out)


def best_config(
    shape: GemmShape,
    policy: Policy,
    mach: Machine = V5E,
    tile_configs=DEFAULT_TILE_CONFIGS,
    g: Optional[int] = None,
    dt: DtypeBytes = DEFAULT_DTYPES,
) -> tuple[TileConfig, float]:
    """Best tile config for a fixed (policy, g): the argmin of
    :func:`rank_candidates` restricted to that policy and grid size. VMEM
    feasibility uses the op's real byte-widths: a config that fits bf16
    operands can overflow for f32."""
    ranked = rank_candidates(
        shape,
        mach,
        (policy,),
        tuple(tile_configs),
        (g or mach.lanes,),
        dt,
    )
    _, cfg, g_win, t = ranked[0]
    return cfg, shape.flops / t / 1e12


def dp_baseline_tflops(
    shape: GemmShape, mach: Machine = V5E, dt: DtypeBytes = DEFAULT_DTYPES
) -> float:
    """The paper's comparison baseline: best data-parallel configuration."""
    return best_config(shape, DP, mach, dt=dt)[1]
