"""Analytical TPU GEMM cost model (the tuner's measurement oracle on CPU).

The paper tunes by wall-clocking kernels on an MI250X. This container has no
accelerator, so the ckProfiler-analogue tuner measures against this
calibrated analytical model instead; on real hardware the measurement
function is swapped for wall-clock timing (``tuner.measure_wallclock``) with
zero changes elsewhere — the model IS the hardware-adaptation layer.

Machine model (TPU v5e):
  * ``peak_flops``  — 197 TFLOP/s bf16 per chip (MXU).
  * ``hbm_bw``      — 819 GB/s.
  * ``lanes`` (C)   — number of concurrent tile slots; the TPU analogue of
    the paper's "CU count" (GPU: 104 CUs). A v5e TensorCore has 4 MXUs x 2
    pipeline slots -> C = 8 by default. Output-tile schedules quantize into
    ``ceil(T / C)`` waves exactly like GPU wavefront rounds — this is the
    pathology Stream-K removes.
  * MXU tiles are *padded*: a (BM, BN, BK) tile costs the full
    2*BM*BN*BK FLOPs even when M < BM (systolic array shape is fixed) — this
    is why tile-config selection matters for skinny GEMMs and why the tuner
    sweeps configs jointly with policies.

Timing terms:
  t_tile  = max(tile_flops / lane_flops, tile_bytes / lane_bw)
  DP      : ceil(T/C) * t_tile                                  (wave rounds)
  ALL_SK  : ceil(total_iters/C) * t_iter + fixup                (Algorithm 1)
  HYBRID_b: sk_body + max(dp_waves * t_tile, fixup)             (overlap §4.1)

Fix-up (TPU two-phase reduction replacing GPU atomics): every split tile's
non-owning contributors round-trip a BM*BN f32 partial through HBM, plus a
per-split-tile serialization latency (the analogue of the paper's
"thousands of clock cycles" atomic-add tail).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

from repro.core.policies import (
    ALL_POLICIES,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
)
from repro.core.workpart import (
    GemmShape,
    Partition,
    PartitionStats,
    cdiv,
    partition_stats,
)


@dataclass(frozen=True)
class Machine:
    """Hardware constants; defaults are TPU v5e."""

    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # B/s
    lanes: int = 8  # concurrent tile slots (virtual CUs)
    ici_bw: float = 50e9  # B/s per link (used by the roofline module)
    launch_overhead_s: float = 2e-6  # kernel launch + grid setup
    fixup_serial_s: float = 1.2e-6  # per-split-tile reduction tail
    vmem_bytes: int = 16 * 2 ** 20  # ~16 MiB usable VMEM per lane's working set

    @property
    def lane_flops(self) -> float:
        return self.peak_flops / self.lanes

    @property
    def lane_bw(self) -> float:
        return self.hbm_bw / self.lanes


V5E = Machine()


def _tile_times(mach: Machine, cfg: TileConfig, in_bytes: int = 2):
    """(t_full_tile, t_single_k_iter) for one lane."""
    # One k-iteration moves an A (BM,BK) and B (BK,BN) tile HBM->VMEM and
    # issues 2*BM*BN*BK MACs on the MXU.
    iter_flops = 2 * cfg.bm * cfg.bn * cfg.bk
    iter_bytes = (cfg.bm * cfg.bk + cfg.bk * cfg.bn) * in_bytes
    t_iter = max(iter_flops / mach.lane_flops, iter_bytes / mach.lane_bw)
    return t_iter


def _fixup_time(mach: Machine, st: PartitionStats, cfg: TileConfig) -> float:
    """Two-phase reduction cost: partial write + read + final write, plus a
    serialization tail per split tile."""
    acc_bytes = cfg.bm * cfg.bn * 4  # f32 partials
    bytes_moved = st.extra_contributors * acc_bytes * 2  # write + read back
    return bytes_moved / mach.hbm_bw + st.n_split_tiles * mach.fixup_serial_s


def _output_time(mach: Machine, st: PartitionStats, cfg: TileConfig, out_bytes: int = 2) -> float:
    return (st.n_tiles_total * cfg.bm * cfg.bn * out_bytes) / mach.hbm_bw


@lru_cache(maxsize=200_000)
def gemm_time_s(
    shape: GemmShape,
    cfg: TileConfig,
    policy: Policy,
    mach: Machine = V5E,
    g: int | None = None,
) -> float:
    """Modeled execution time of one GEMM under (cfg, policy)."""
    g = g or mach.lanes
    st = partition_stats(shape, cfg, g, policy)
    t_iter = _tile_times(mach, cfg)
    t_tile = st.iters_per_tile * t_iter

    t = mach.launch_overhead_s + _output_time(mach, st, cfg)
    if st.sk_tiles:
        sk_body = cdiv(st.sk_total_iters, g) * t_iter
        fixup = _fixup_time(mach, st, cfg)
        dp = st.dp_waves * t_tile
        if st.dp_tiles:
            # SK scheduled first; fix-up latency hidden under the DP phase
            # (§4.1 "strategic overlap of execution").
            t += sk_body + max(dp, fixup)
        else:
            t += sk_body + fixup
    else:
        t += st.dp_waves * t_tile
    return t


def gemm_tflops(
    shape: GemmShape,
    cfg: TileConfig,
    policy: Policy,
    mach: Machine = V5E,
    g: int | None = None,
) -> float:
    """Modeled effective TFLOP/s (true FLOPs / modeled time) — the tuner's
    objective, matching ckProfiler's reporting."""
    return shape.flops / gemm_time_s(shape, cfg, policy, mach, g) / 1e12


def best_config(
    shape: GemmShape,
    policy: Policy,
    mach: Machine = V5E,
    tile_configs=DEFAULT_TILE_CONFIGS,
) -> tuple[TileConfig, float]:
    """Best tile config for a fixed policy (what ckProfiler sweeps per
    GEMM instance)."""
    best = None
    for cfg in tile_configs:
        if cfg.vmem_bytes() > mach.vmem_bytes:
            continue
        tf = gemm_tflops(shape, cfg, policy, mach)
        if best is None or tf > best[1]:
            best = (cfg, tf)
    assert best is not None, "no tile config fits VMEM"
    return best


def dp_baseline_tflops(shape: GemmShape, mach: Machine = V5E) -> float:
    """The paper's comparison baseline: best data-parallel configuration."""
    return best_config(shape, DP, mach)[1]
