"""JAX-native Bloom-filter queries.

The Python ``BloomFilter`` is the build-time artifact; at dispatch time inside
a jit-compiled serving loop we may want to query thousands of (M, N, K) keys
without leaving the device. This module re-implements MurmurHash3_x86_32 with
uint32 jnp arithmetic so a *batch* of keys can be queried against the packed
filter bits vectorised/jit'd. Bit-exactness vs. the Python implementation is a
test invariant (``tests/test_bloom.py``).

Keys here are the canonical 24-byte `<3q` encoding of (m, n, k), i.e. six
little-endian uint32 words per key — fixed length, so the murmur block loop
unrolls statically and there is no tail to handle.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

_C1 = np.uint32(0xCC9E2D51)
_C2 = np.uint32(0x1B873593)


def _rotl32(x, r: int):
    return (x << r) | (x >> (32 - r))


def _mix(h, k):
    k = k * _C1
    k = _rotl32(k, 15)
    k = k * _C2
    h = h ^ k
    h = _rotl32(h, 13)
    return h * np.uint32(5) + np.uint32(0xE6546B64)


def murmur3_32_words(words, seed):
    """MurmurHash3_x86_32 over a fixed-length word array.

    words: uint32[..., W] little-endian words (W*4-byte keys, no tail).
    seed:  uint32[...] broadcastable to words[..., 0].
    """
    words = words.astype(jnp.uint32)
    h = jnp.broadcast_to(jnp.asarray(seed, jnp.uint32), words.shape[:-1])
    w = words.shape[-1]
    for i in range(w):
        h = _mix(h, words[..., i])
    h = h ^ jnp.uint32(w * 4)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(0xC2B2AE35)
    return h ^ (h >> 16)


def mnk_to_words(m, n, k):
    """(..., ) int arrays -> uint32[..., 6] matching struct.pack('<3q').

    Avoids uint64 (unavailable without jax x64): GEMM dims are < 2**31, so
    the high word of each little-endian int64 is statically zero.
    """
    m = jnp.asarray(m)
    n = jnp.asarray(n)
    k = jnp.asarray(k)
    zero = jnp.zeros(jnp.broadcast_shapes(m.shape, n.shape, k.shape), jnp.uint32)
    lo = lambda v: jnp.broadcast_to(v.astype(jnp.uint32), zero.shape)
    return jnp.stack([lo(m), zero, lo(n), zero, lo(k), zero], axis=-1)


def bloom_query(bits_u8, n_bits: int, n_hashes: int, seed: int, m, n, k):
    """Vectorised membership query.

    bits_u8: uint8[n_bits//8] — the packed filter (``BloomFilter.bits``).
    m, n, k: broadcastable integer arrays of problem sizes.
    Returns bool array: True = "possibly present", False = "definitely absent".
    """
    words = mnk_to_words(m, n, k)
    h1 = murmur3_32_words(words, np.uint32(seed))
    h2 = murmur3_32_words(words, h1 ^ jnp.uint32(0x9747B28C)) | jnp.uint32(1)
    bits = jnp.asarray(bits_u8, jnp.uint8)
    hit = jnp.ones(h1.shape, dtype=bool)
    for i in range(n_hashes):
        p = (h1 + jnp.uint32(i) * h2) % jnp.uint32(n_bits)
        byte = bits[(p >> 3).astype(jnp.int32)]
        bit = (byte >> (p & jnp.uint32(7)).astype(jnp.uint8)) & jnp.uint8(1)
        hit = hit & (bit == 1)
    return hit


def query_filters(filters, m, n, k):
    """Query a list of python BloomFilters, returns bool[..., n_filters]."""
    outs = [
        bloom_query(f.bits, f.n_bits, f.n_hashes, f.seed, m, n, k) for f in filters
    ]
    return jnp.stack(outs, axis=-1)
