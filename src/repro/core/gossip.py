"""Streaming journal gossip: continuous cross-worker tuning exchange.

Federation (:mod:`repro.core.federate`) is a batch operation — a worker
folds the fleet's artifacts in once, typically at startup. A long-running
fleet keeps learning *after* that point: every worker's
:class:`~repro.core.adaptive.AdaptiveTuner` appends fresh commits to its own
journal shard, and without a live exchange those commits only reach
siblings on the next restart. This module closes the loop:

  * :class:`JournalTail` reads one sibling's shard *incrementally* — it
    remembers a byte offset and only parses lines appended since the last
    poll. A torn final line (a producer crashed or is mid-``append_journal``
    — possibly mid-multi-byte-UTF-8-sequence, which is why the tail reads
    bytes and splits on newlines before decoding) is NOT consumed: the
    offset stays put so the completed line is read whole on the next poll,
    exactly mirroring ``replay_journal``'s crash tolerance. Complete but
    malformed lines are skipped and counted, and a shard that shrank
    (rotation/truncation) restarts from byte 0.
  * :class:`GossipExchange` folds every tail's new entries into the live
    selector: entries stage into a scratch database through the same tagged
    registry ``replay_journal`` uses (:func:`repro.core.tuner.apply_journal_entry`
    — unknown future tags skip-and-count), merge under per-arch-class
    last-writer-wins (a local commit newer than a sibling's stands), and
    land via one atomic ``hot_swap(state=...)`` with a generation-bumped
    sieve. Same-class sibling commits become direct database hits on the
    very next dispatch; other-class commits surface as ``"xarch"`` warm
    seeds — so a gossiping fleet converges to zero cross-worker misses with
    no restart anywhere.

Wire it into serving with ``--gossip-every N`` (``launch/serve.py``): every
N engine steps each worker polls its siblings' shards. Polling an
append-only file is deliberately humble infrastructure — no broker, no
sockets — matching the journal's crash-tolerance story: the file IS the
protocol.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import List, Optional, Sequence

from repro.core.federate import merge_databases
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import TuningDatabase, apply_journal_entry
from repro.utils.logging import get_logger

log = get_logger("gossip")


@dataclass
class GossipStats:
    """Lifetime counters of one :class:`GossipExchange` (observability)."""

    rounds: int = 0  # exchange() calls
    polls: int = 0  # individual shard polls across rounds
    entries: int = 0  # journal entries applied from siblings
    swaps: int = 0  # hot_swaps installed (rounds that found news)
    load_errors: int = 0  # malformed lines + unknown-tag skips observed


class JournalTail:
    """Incremental reader over one append-only JSONL journal shard.

    ``poll()`` returns the decoded entries appended since the previous
    poll, advancing a byte offset past exactly the lines it consumed. The
    final line is only consumed when newline-terminated: a torn tail (torn
    anywhere, including inside a multi-byte UTF-8 sequence) stays
    unconsumed so the next poll — after the producer finishes the append —
    reads it complete. A complete line that fails to decode is counted in
    ``load_errors`` and skipped permanently (it will never repair itself).
    """

    def __init__(self, path: str, missing_ok: bool = True):
        self.path = path
        self.missing_ok = missing_ok
        self.offset = 0
        self.load_errors = 0

    def poll(self) -> List[dict]:
        """Decode every complete line appended since the last poll."""
        try:
            f = open(self.path, "rb")
        except FileNotFoundError:
            if self.missing_ok:
                return []  # shard not created yet: nothing new
            raise
        with f:
            size = f.seek(0, os.SEEK_END)
            if size < self.offset:
                # the shard shrank (rotated or truncated): our offset points
                # past the end, so the only safe resume is a full re-read
                log.warning(
                    "%s shrank below the tail offset (%d < %d); re-reading",
                    self.path,
                    size,
                    self.offset,
                )
                self.offset = 0
            f.seek(self.offset)
            buf = f.read()
        out: List[dict] = []
        consumed = 0
        while True:
            nl = buf.find(b"\n", consumed)
            if nl < 0:
                break  # torn/in-progress tail: leave it for the next poll
            raw = buf[consumed:nl]
            consumed = nl + 1
            if not raw.strip():
                continue
            try:
                out.append(json.loads(raw.decode("utf-8")))
            except ValueError as e:
                # complete but malformed — unlike a torn tail this can never
                # heal, so it is consumed (offset moves past it) and counted
                self.load_errors += 1
                log.warning("%s: skipping malformed journal line: %s", self.path, e)
        self.offset += consumed
        return out


class GossipExchange:
    """Periodically folds sibling journal shards into a live selector.

    One instance per worker: ``peers`` are the *other* workers' shard
    paths (a worker must not gossip its own shard — its commits are already
    in its database, and re-applying stamped copies is wasted work).
    ``exchange()`` is cheap when nothing changed: N ``seek``/``read`` calls
    finding zero new bytes install nothing.
    """

    def __init__(
        self,
        selector: KernelSelector,
        peers: Sequence[str],
        missing_ok: bool = True,
        sieve_capacity: Optional[int] = None,
        sieve_fp_rate: Optional[float] = None,
    ):
        self.selector = selector
        self.tails = [JournalTail(p, missing_ok=missing_ok) for p in peers]
        self.sieve_capacity = sieve_capacity
        self.sieve_fp_rate = sieve_fp_rate
        self.stats = GossipStats()

    def _stage(self) -> Optional[TuningDatabase]:
        """Poll every tail into one staging database (None when no news).

        Staging adopts the selector's arch class, so a sibling's stamped
        records route exactly as a direct replay would: same class into
        ``records``, foreign classes into ``xarch``. Unknown-tag entries
        (future producers) skip-and-count, mirroring ``replay_journal``."""
        staged: Optional[TuningDatabase] = None
        for tail in self.tails:
            self.stats.polls += 1
            before = tail.load_errors
            for entry in tail.poll():
                if staged is None:
                    staged = TuningDatabase(arch=self.selector.arch)
                try:
                    if apply_journal_entry(staged, entry):
                        self.stats.entries += 1
                    else:
                        staged.load_errors += 1  # unknown tag: forward compat
                        self.stats.load_errors += 1
                except (ValueError, IndexError, TypeError, KeyError) as e:
                    staged.load_errors += 1
                    self.stats.load_errors += 1
                    log.warning(
                        "%s: skipping malformed journal entry: %s", tail.path, e
                    )
            self.stats.load_errors += tail.load_errors - before
        return staged

    def exchange(self) -> int:
        """One gossip round. Returns the number of sibling entries applied.

        New entries merge into the selector's database under per-class
        last-writer-wins (``merge_databases`` — a local commit newer than a
        sibling's copy stands), the sieve rebuilds one generation up with
        the worker's installed geometry, and everything lands in one atomic
        ``hot_swap(state=...)``. No news -> no swap: memoised picks survive
        quiet rounds untouched."""
        self.stats.rounds += 1
        staged = self._stage()
        if staged is None or (
            staged.n_records() == 0
            and staged.calibration is None
            and not staged.xarch_calibrations
            and not staged.arch_profiles
        ):
            return 0
        sel = self.selector
        base = sel.db if sel.db is not None else TuningDatabase(arch=sel.arch)
        merge_databases([staged], into=base)
        capacity = self.sieve_capacity
        if capacity is None:
            capacity = getattr(sel.sieve, "capacity", None) or 10_000
        fp_rate = self.sieve_fp_rate
        if fp_rate is None:
            fp_rate = getattr(sel.sieve, "fp_rate", None) or 0.01
        sieve = base.build_sieve(
            capacity=capacity,
            fp_rate=fp_rate,
            generation=sel.sieve_generation + 1,
        )
        calibration = (
            base.calibration if base.calibration is not None else sel.calibration
        )
        sel.hot_swap(
            state=SelectorState(
                db=base, sieve=sieve, calibration=calibration, arch=sel.arch
            ),
            keys=None,
        )
        self.stats.swaps += 1
        applied = staged.n_records()
        log.info(
            "gossip round %d: %d sibling records folded in, sieve generation %d",
            self.stats.rounds,
            applied,
            sieve.generation,
        )
        return applied
