"""Open-sieve: the paper's per-policy Bloom-filter registry.

One Bloom filter per Stream-K++ policy (plus the DP baseline). A one-time
preprocessing step encodes the tuned winner for every benchmarked problem
size into the corresponding filter; at dispatch, querying all filters with
(M, N, K) prunes every policy whose filter answers "definitely absent" — the
paper measures up to ~95.8% of policy evaluations eliminated at a 100%
true-negative rate (inherent to Bloom filters).

The paper ships the filters as a generated C++ header (~1 byte per problem
size); ``encode_cpp_header`` reproduces that artifact and
``to_bytes``/``from_bytes`` provide the binary codec the framework itself
uses.
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple

from repro.core.arch import DEFAULT_ARCH
from repro.core.bloom import BloomFilter
from repro.core.op import GemmOp, encode_key
from repro.core.policies import ALL_POLICIES, Policy, policy_from_name

MNK = Tuple[int, int, int]


def _as_key_bytes(key, arch: str = DEFAULT_ARCH) -> bytes:
    """Canonical filter bytes for any key form: raw bytes, a GemmOp, a bare
    (M, N, K), or an extended op-key tuple.

    Non-default arch classes prefix the class string so winners measured on
    different machine classes occupy disjoint filter keyspaces (a probe for
    one class never aliases another class's insertions beyond the ordinary
    Bloom fp rate). ``"default"``-class keys keep the legacy encoding, which
    is what keeps single-class sieve bytes identical to the pre-arch format.
    """
    if isinstance(key, bytes):
        kb = key
    elif isinstance(key, GemmOp):
        kb = key.encode()
    else:
        kb = encode_key(tuple(key))
    if arch != DEFAULT_ARCH:
        kb = arch.encode("utf-8") + b"\x00" + kb
    return kb


@dataclass
class QueryStats:
    """Counters backing the paper's elimination-rate claim."""

    queries: int = 0
    candidate_evals: int = 0  # policy evaluations NOT pruned
    pruned_evals: int = 0  # policy evaluations skipped thanks to the filters

    @property
    def elimination_rate(self) -> float:
        """Fraction of policy evaluations the filters pruned away."""
        tot = self.candidate_evals + self.pruned_evals
        return self.pruned_evals / tot if tot else 0.0


class OpenSieve:
    """Registry: policy name -> BloomFilter, with query bookkeeping.

    ``generation`` is the sieve's build version: Bloom filters cannot delete,
    so online adaptation never mutates a live sieve — it builds a fresh one
    from the grown database under ``generation + 1`` and hot-swaps it in
    (the old sieve keeps serving lookups until the swap, which is a single
    atomic reference assignment in the selector).
    """

    def __init__(
        self,
        policies: Sequence[Policy] = ALL_POLICIES,
        capacity: int = 10_000,
        fp_rate: float = 0.01,
        generation: int = 0,
    ):
        self.policies: Tuple[Policy, ...] = tuple(policies)
        self.generation = generation
        # Remembered so federation/gossip rebuilds inherit the worker's
        # installed geometry instead of silently re-deriving from defaults
        # (None after ``from_bytes`` — the wire format predates these).
        self.capacity: Optional[int] = capacity
        self.fp_rate: Optional[float] = fp_rate
        # One distinct hash family (seed) per filter — "7 distinct hash
        # functions, one for each filter" in the paper.
        self.filters: Dict[str, BloomFilter] = {
            p.name: BloomFilter.for_capacity(capacity, fp_rate, seed=i + 1)
            for i, p in enumerate(self.policies)
        }
        self.stats = QueryStats()

    # -- build ----------------------------------------------------------------
    def insert_winner(self, key, policy: Policy, arch: str = DEFAULT_ARCH) -> None:
        """``key``: (M, N, K), an extended op key, a GemmOp, or raw bytes."""
        if policy.name not in self.filters:
            raise KeyError(f"policy {policy.name} not registered")
        self.filters[policy.name].add(_as_key_bytes(key, arch))

    def build_from_winners(self, winners: Mapping, arch: str = DEFAULT_ARCH) -> "OpenSieve":
        """Bulk-insert a {key -> winning Policy} map; returns self."""
        for key, pol in winners.items():
            self.insert_winner(key, pol, arch=arch)
        return self

    # -- query ------------------------------------------------------------------
    def _query(self, key, arch: str = DEFAULT_ARCH) -> List[Policy]:
        """Uncounted filter probe (key forms as in :meth:`insert_winner`)."""
        kb = _as_key_bytes(key, arch)
        return [p for p in self.policies if kb in self.filters[p.name]]

    def candidates_any(self, *keys, arch: str = DEFAULT_ARCH) -> List[Policy]:
        """First non-empty candidate set across alternative key encodings
        for ONE dispatch (e.g. an op's exact fingerprint, then the
        dtype-agnostic legacy (M, N, K)). Accounted as a single
        consultation in ``QueryStats`` — the counters back the paper's
        elimination-rate claim, so one dispatch must count once however
        many key forms it probes."""
        out: List[Policy] = []
        for key in keys:
            out = self._query(key, arch)
            if out:
                break
        self.stats.queries += 1
        self.stats.candidate_evals += len(out)
        self.stats.pruned_evals += len(self.policies) - len(out)
        return out

    def candidates(self, key, arch: str = DEFAULT_ARCH) -> List[Policy]:
        """Policies whose filter answers "possibly present" for this key."""
        return self.candidates_any(key, arch=arch)

    def validate_true_negative_rate(self, winners: Mapping[MNK, Policy]) -> float:
        """Assert the Bloom contract on a winner map: the true winner is never
        pruned. Returns the measured TN rate over non-winner (size, policy)
        pairs (1.0 == every "absent" answer was correct; Bloom guarantees the
        converse direction, this checks our plumbing end-to-end)."""
        for size, pol in winners.items():
            key = _as_key_bytes(size)
            if key not in self.filters[pol.name]:
                raise AssertionError(
                    f"false negative for {size}/{pol.name} — Bloom contract broken"
                )
        # TN rate: of all negative answers, how many are genuinely negative.
        # By construction every negative is genuine (no false negatives), so
        # this is 1.0 unless plumbing is broken; we still measure it honestly.
        negatives = genuine = 0
        for size in winners:
            key = _as_key_bytes(size)
            for p in self.policies:
                if key not in self.filters[p.name]:
                    negatives += 1
                    if winners[size].name != p.name:
                        genuine += 1
        return genuine / negatives if negatives else 1.0

    # -- federation -----------------------------------------------------------
    def merge(
        self, other: "OpenSieve", generation: Optional[int] = None
    ) -> "OpenSieve":
        """Union of two sieves built over the SAME policy registry and
        filter parameterisation — the federated-merge path: N workers each
        encode their shard's winners, and the bitwise-OR union answers
        queries exactly like a sieve built from the merged winner map
        (inserting a key sets the same bits whichever worker's filter it
        lands in, so the union is bit-identical to the full rebuild).

        The result's ``generation`` defaults to ``max(ours, theirs) + 1`` —
        a merge is a new build version, so every
        :meth:`~repro.core.selector.KernelSelector.hot_swap` consumer
        re-resolves against the union rather than trusting picks memoised
        under either input. Mismatched policy registries or filter
        parameters raise descriptively (see :meth:`BloomFilter.merge`)."""
        mine = {p.name for p in self.policies}
        theirs = {p.name for p in other.policies}
        if mine != theirs:
            raise ValueError(
                "cannot merge OpenSieves over different policy registries: "
                f"{sorted(mine)} vs {sorted(theirs)}"
            )
        out = OpenSieve.__new__(OpenSieve)
        out.policies = self.policies
        out.capacity = self.capacity
        out.fp_rate = self.fp_rate
        out.filters = {
            name: f.merge(other.filters[name]) for name, f in self.filters.items()
        }
        out.stats = QueryStats()
        out.generation = (
            generation
            if generation is not None
            else max(self.generation, other.generation) + 1
        )
        return out

    # -- codec ---------------------------------------------------------------
    def to_bytes(self) -> bytes:
        """Serialise all per-policy filters to the ``OSV1`` wire format."""
        blobs = [(name.encode(), f.to_bytes()) for name, f in self.filters.items()]
        out = [struct.pack("<4sI", b"OSV1", len(blobs))]
        for name, blob in blobs:
            out.append(struct.pack("<II", len(name), len(blob)))
            out.append(name)
            out.append(blob)
        return b"".join(out)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "OpenSieve":
        """Inverse of :meth:`to_bytes` (generation restored separately)."""
        magic, n = struct.unpack_from("<4sI", blob)
        if magic != b"OSV1":
            raise ValueError("not an OpenSieve blob")
        off = 8
        filters: Dict[str, BloomFilter] = {}
        for _ in range(n):
            ln, lb = struct.unpack_from("<II", blob, off)
            off += 8
            name = blob[off : off + ln].decode()
            off += ln
            filters[name] = BloomFilter.from_bytes(blob[off : off + lb])
            off += lb
        sieve = cls.__new__(cls)
        sieve.policies = tuple(policy_from_name(n) for n in filters)
        sieve.filters = filters
        sieve.stats = QueryStats()
        sieve.generation = 0
        # The OSV1 wire format predates geometry bookkeeping; bit/hash
        # counts survive in the filters themselves, the nominal knobs don't.
        sieve.capacity = None
        sieve.fp_rate = None
        return sieve

    def encode_cpp_header(self) -> str:
        """The paper's artifact: a compact generated C++ header embedding the
        filters (~1 byte of information per problem size once amortised)."""
        lines = [
            "// Auto-generated by Open-sieve (Stream-K++ reproduction).",
            "#pragma once",
            "#include <cstdint>",
            "namespace opensieve {",
        ]
        for name, f in self.filters.items():
            arr = ",".join(str(b) for b in f.bits.tobytes())
            lines += [
                f"inline constexpr uint32_t {name}_n_bits = {f.n_bits};",
                f"inline constexpr uint32_t {name}_n_hashes = {f.n_hashes};",
                f"inline constexpr uint32_t {name}_seed = {f.seed};",
                f"inline constexpr uint8_t {name}_bits[] = {{{arr}}};",
            ]
        lines.append("}  // namespace opensieve")
        return "\n".join(lines)

    # -- info -----------------------------------------------------------------
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-filter occupancy stats. ``n_items`` is the raw add-counter —
        after ``BloomFilter.merge`` it is only an upper bound on distinct
        keys — so capacity planning reads the saturation-derived
        ``est_items`` instead."""
        return {
            name: {
                "n_items": f.n_items,
                "est_items": f.est_items,
                "n_bits": f.n_bits,
                "n_hashes": f.n_hashes,
                "saturation": f.saturation,
                "est_fp_rate": f.est_fp_rate,
            }
            for name, f in self.filters.items()
        }
