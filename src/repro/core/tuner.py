"""ckProfiler analogue: exhaustive (policy x tile-config) tuning over a GEMM
problem-size suite, producing the winner database that Open-sieve encodes.

``measure_fn(shape, policy, cfg) -> tflops`` is injected:
  * default: the calibrated analytical model (CPU-only container);
  * ``measure_wallclock``: times the real kernel (used on TPU hardware; the
    paper's 50 warm-up + 50 timed launches protocol).
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field, asdict
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.op import GemmOp, OpKey, key_from_str, key_to_str
from repro.core.opensieve import OpenSieve
from repro.core.policies import (
    ALL_POLICIES,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
    policy_from_name,
)
from repro.core.workpart import GemmShape

MNK = Tuple[int, int, int]
MeasureFn = Callable[[GemmShape, Policy, TileConfig], float]


def _as_key(entry) -> OpKey:
    """Normalise a tuning target to its database key: a GemmOp keys on its
    fingerprint, a bare 3-sequence on the legacy (M, N, K) tuple."""
    if isinstance(entry, GemmOp):
        return entry.key
    return tuple(entry)


def _key_local(key: OpKey) -> MNK:
    return (key[0], key[1], key[2])


@dataclass
class TuningRecord:
    size: OpKey  # legacy (M, N, K) or extended op-fingerprint key
    policy: str  # winner policy name
    cfg: str  # winner tile config name
    tflops: float
    runner_up_policy: str
    runner_up_tflops: float
    dp_best_tflops: float  # paper's baseline for tolerance analysis

    @property
    def gain_over_runner_up(self) -> float:
        if self.runner_up_tflops <= 0:
            return 0.0
        return self.tflops / self.runner_up_tflops - 1.0

    @property
    def slowdown_vs_dp_of_best_sk(self) -> float:  # pragma: no cover - legacy
        return 0.0


@dataclass
class TuningDatabase:
    records: Dict[OpKey, TuningRecord] = field(default_factory=dict)
    #: per-key best tflops for every policy (policy name -> tflops); kept so
    #: the Fig-2 tolerance analysis does not need to re-measure.
    per_policy: Dict[OpKey, Dict[str, float]] = field(default_factory=dict)

    def winners(self) -> Dict[OpKey, Policy]:
        return {s: policy_from_name(r.policy) for s, r in self.records.items()}

    def build_sieve(self, capacity: int = 10_000, fp_rate: float = 0.01) -> OpenSieve:
        sieve = OpenSieve(ALL_POLICIES, capacity=capacity, fp_rate=fp_rate)
        return sieve.build_from_winners(self.winners())

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        payload = {
            "records": {key_to_str(s): asdict(r) for s, r in self.records.items()},
            "per_policy": {
                key_to_str(s): pp for s, pp in self.per_policy.items()
            },
        }
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(cls, path: str) -> "TuningDatabase":
        with open(path) as f:
            payload = json.load(f)
        db = cls()
        for key, rec in payload["records"].items():
            size = key_from_str(key)
            rec["size"] = size
            db.records[size] = TuningRecord(**rec)
        for key, pp in payload.get("per_policy", {}).items():
            db.per_policy[key_from_str(key)] = pp
        return db


def measure_model(mach: costmodel.Machine = costmodel.V5E) -> MeasureFn:
    """Measurement oracle backed by the analytical cost model."""

    def fn(shape: GemmShape, policy: Policy, cfg: TileConfig) -> float:
        return costmodel.gemm_tflops(shape, cfg, policy, mach)

    return fn


def measure_wallclock(
    warmup: int = 50, iters: int = 50, interpret: bool = False
) -> MeasureFn:
    """The paper's protocol on real hardware: 50 warm-up launches, then the
    average of 50 timed launches. Uses the Pallas kernels via ops.gemm."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.streamk import ops as sk_ops

    def fn(shape: GemmShape, policy: Policy, cfg: TileConfig) -> float:
        key = jax.random.PRNGKey(0)
        a = jax.random.normal(key, (shape.m, shape.k), jnp.bfloat16)
        b = jax.random.normal(key, (shape.k, shape.n), jnp.bfloat16)
        call = jax.jit(
            lambda a, b: sk_ops.gemm(a, b, policy=policy, cfg=cfg, interpret=interpret)
        )
        for _ in range(warmup):
            call(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = call(a, b)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / iters
        return shape.flops / dt / 1e12

    return fn


class Tuner:
    """Sweep (policy x tile config) per problem size; record winner and
    runner-up (runner-up = best config of the *second-best policy*, which is
    what the paper's Fig. 3 violin compares against)."""

    def __init__(
        self,
        policies: Sequence[Policy] = ALL_POLICIES,
        tile_configs: Sequence[TileConfig] = DEFAULT_TILE_CONFIGS,
        measure_fn: Optional[MeasureFn] = None,
        mach: costmodel.Machine = costmodel.V5E,
    ):
        self.policies = tuple(policies)
        self.tile_configs = tuple(tile_configs)
        self.measure = measure_fn or measure_model(mach)
        self.mach = mach

    def tune_size(self, size) -> Tuple[TuningRecord, Dict[str, float]]:
        """Sweep one tuning target — a bare (M, N, K) or a full GemmOp
        (grouped / fused ops tune per-group on their local shape and record
        under their op-fingerprint key)."""
        key = _as_key(size)
        shape = GemmShape(*_key_local(key))
        per_policy: Dict[str, float] = {}
        per_policy_cfg: Dict[str, str] = {}
        for pol in self.policies:
            best = -1.0
            best_cfg = self.tile_configs[0]
            for cfg in self.tile_configs:
                if cfg.vmem_bytes() > self.mach.vmem_bytes:
                    continue
                tf = self.measure(shape, pol, cfg)
                if tf > best:
                    best, best_cfg = tf, cfg
            per_policy[pol.name] = best
            per_policy_cfg[pol.name] = best_cfg.name
        ranked = sorted(per_policy.items(), key=lambda kv: kv[1], reverse=True)
        w_name, w_tf = ranked[0]
        # runner-up = best policy with strictly lower modeled performance
        # (the deterministic cost model produces exact ties between sibling
        # schedules — e.g. HYBRID(b) variants whose extra batches are moot —
        # which real-hardware noise would separate; Fig.3 compares against
        # the next *distinct* configuration)
        r_name, r_tf = ranked[1]
        for name, tf in ranked[1:]:
            if tf < w_tf * (1 - 1e-9):
                r_name, r_tf = name, tf
                break
        rec = TuningRecord(
            size=key,
            policy=w_name,
            cfg=per_policy_cfg[w_name],
            tflops=w_tf,
            runner_up_policy=r_name,
            runner_up_tflops=r_tf,
            dp_best_tflops=per_policy.get(DP.name, 0.0),
        )
        return rec, per_policy

    def tune(self, sizes: Sequence, progress_every: int = 0) -> TuningDatabase:
        """Tune a suite of targets (bare (M, N, K) sizes and/or GemmOps)."""
        db = TuningDatabase()
        for i, size in enumerate(sizes):
            rec, per_policy = self.tune_size(size)
            db.records[rec.size] = rec
            db.per_policy[rec.size] = per_policy
            if progress_every and (i + 1) % progress_every == 0:  # pragma: no cover
                print(f"tuned {i + 1}/{len(sizes)}")
        return db
