"""ckProfiler analogue: exhaustive (policy x tile-config x grid-size) tuning
over a GEMM problem-size suite, producing the winner database that Open-sieve
encodes.

``measure_fn(shape, policy, cfg, g, dt) -> tflops`` is injected:
  * default: the calibrated analytical model (CPU-only container);
  * ``measure_wallclock``: times the real kernel (used on TPU hardware; the
    paper's 50 warm-up + 50 timed launches protocol).

The sweep covers the paper's (policy x tile) space jointly with the grid
size ``g`` the flattened iteration space is split over (``grid_sizes``,
default {lanes/2, lanes, 2*lanes}) — the "additional tuning parameters"
extension the framework was built for. Measurement is keyed on the target's
*actual* operand byte-widths: a :class:`~repro.core.op.GemmOp` target tunes
under its own dtype profile, a bare (M, N, K) under the f32 profile (the
bare key exact-matches f32 plain ops — see ``_BARE_KEY_DTYPES``), so
f32/int8/bf16 ops of the same MNK can record different winners.

Artifact lifecycle: ``TuningDatabase.save``/``load`` snapshot the full
database (``artifacts/tuning_db.json``); incremental results — offline
sweeps and serve-time :class:`repro.core.adaptive.AdaptiveTuner` commits
alike — stream through an append-only JSONL *journal*
(``artifacts/tuning_journal.jsonl``) that ``load``/``replay_journal``
re-applies on startup, so records learned while serving survive restarts
and warm-start the next run. ``version`` counts in-place appends, the
monotone clock the generational sieve rebuilds key on.

Backward compatibility: records/journal lines written before ``g`` became a
tuning axis carry no ``g`` field — they parse with ``g = LEGACY_GRID`` (8,
the grid every legacy kernel launch used), so old artifacts load and
dispatch identically. Likewise records written before federation carry no
``version``/``wall`` hybrid stamp — they parse with ``version = 0`` /
``wall = 0.0`` and lose last-writer-wins merges against any stamped record
(see :mod:`repro.core.federate`).

Federated sweeps: ``Tuner.tune(shard=(i, n))`` tunes only the ``i``-th of
``n`` deterministic, disjoint slices of the target list (strided, so the
suite's size-correlated cost balances across workers). Each worker journals
to its own shard file; :func:`repro.core.federate.merge_journal_shards`
reassembles the union, which is record-identical to the single-worker full
sweep because a fingerprint is always tuned whole by exactly one worker.
"""

from __future__ import annotations

import json
import time
from dataclasses import dataclass, field, asdict, replace
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.arch import DEFAULT_ARCH, ArchProfile
from repro.core.costmodel import DtypeBytes
from repro.core.op import (
    GROUPED_FUSED_MARKER,
    GemmOp,
    OpKey,
    key_from_str,
    key_to_str,
)
from repro.core.opensieve import OpenSieve
from repro.core.policies import (
    ALL_POLICIES,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
    policy_from_name,
)
from repro.core.workpart import GemmShape, GroupedGemmShape
from repro.utils.logging import get_logger

log = get_logger("tuner")

MNK = Tuple[int, int, int]
MeasureFn = Callable[[GemmShape, Policy, TileConfig, int, DtypeBytes], float]

#: grid size every record/journal line implied before ``g`` was swept —
#: the old kernels launched with g=8 unconditionally.
LEGACY_GRID = 8


def _as_key(entry) -> OpKey:
    """Normalise a tuning target to its database key: a GemmOp keys on its
    fingerprint, a bare 3-sequence on the legacy (M, N, K) tuple."""
    if isinstance(entry, GemmOp):
        return entry.key
    return tuple(entry)


def _key_local(key: OpKey) -> MNK:
    return (key[0], key[1], key[2])


def _key_shape(entry, key: OpKey) -> GemmShape:
    """Shape a tuning target sweeps. A GemmOp defers to
    :func:`costmodel.op_shape` (fused grouped ops measure their whole
    concatenated expert tile space); a raw 8-part ``grouped_fused`` key —
    e.g. replayed from a journal — reconstructs the same GroupedGemmShape;
    everything else sweeps the bare local (M, N, K) per group."""
    if isinstance(entry, GemmOp):
        return costmodel.op_shape(entry)
    if len(key) == 8 and key[7] == GROUPED_FUSED_MARKER:
        return GroupedGemmShape(key[0], key[1], key[2], groups=key[3])
    return GemmShape(*_key_local(key))


#: bare (M, N, K) targets tune under the float32 profile: a bare key is the
#: *exact-match* dispatch key of float32 plain ops (``GemmOp.is_plain``), so
#: the record must be honest for that owner — scoring it at 2-byte widths
#: would hand every f32 dispatch a bf16-optimal winner, the exact
#: mis-selection bug this module exists to avoid. bf16/f16 shape-only ops
#: consult bare records only as the paper's dtype-agnostic *fallback*
#: (selector ``_db_record``) until adaptation tunes their own fingerprint.
_BARE_KEY_DTYPES = costmodel.profile_for("float32", "float32")


def _target_dtypes(entry) -> DtypeBytes:
    """Byte-width profile a tuning target measures under: a GemmOp's real
    dtypes, or the f32 profile for bare (M, N, K) sizes (whose key
    exact-matches f32 plain ops)."""
    if isinstance(entry, GemmOp):
        return costmodel.op_dtypes(entry)
    return _BARE_KEY_DTYPES


@dataclass
class TuningRecord:
    """One tuned winner: the sweep result the database/journals persist."""

    size: OpKey  # legacy (M, N, K) or extended op-fingerprint key
    policy: str  # winner policy name
    cfg: str  # winner tile config name
    tflops: float
    runner_up_policy: str
    runner_up_tflops: float
    dp_best_tflops: float  # paper's baseline for tolerance analysis
    #: winner grid size; defaults to LEGACY_GRID so g-less records written
    #: before the grid sweep existed keep dispatching exactly as they did
    g: int = LEGACY_GRID
    #: producer commit clock: stamped by ``TuningDatabase.add_record`` (the
    #: database's monotone ``version`` at commit time) and carried through
    #: journals/snapshots, so federated merges can apply last-writer-wins
    #: per key. Pre-federation artifacts parse with 0 (always superseded).
    version: int = 0
    #: wall-clock half of the hybrid commit stamp (unix seconds, stamped by
    #: ``add_record`` alongside ``version``): per-producer version counters
    #: are not comparable across producers, so cross-producer
    #: last-writer-wins orders on ``(wall, version)`` — the wall clock
    #: makes it a true time order between producers, the producer clock
    #: breaks sub-resolution ties within one. Artifacts written before this
    #: field parse with 0.0 and lose to any wall-stamped record.
    wall: float = 0.0
    #: 1-based rank the cost model gave the measured winner at sweep time
    #: (1 = the model's own argmin won the measurement). The calibration
    #: drift signal: a healthy calibration keeps this small, a drifting one
    #: pushes winners deep into the ranking. ``-1`` on records written
    #: before top-k sweeps existed (or when the rank was not computed).
    model_rank: int = -1
    #: architecture class this record was measured on (see
    #: :mod:`repro.core.arch`). Records federate last-writer-wins only
    #: *within* a class; a different class is never a direct database hit,
    #: only an ``"xarch"`` re-ranked warm seed. Arch-less legacy artifacts
    #: parse into ``"default"`` and dispatch exactly as before.
    arch: str = DEFAULT_ARCH

    @property
    def gain_over_runner_up(self) -> float:
        """Relative win over the next distinct policy (Fig. 3's quantity)."""
        if self.runner_up_tflops <= 0:
            return 0.0
        return self.tflops / self.runner_up_tflops - 1.0

    @property
    def slowdown_vs_dp_of_best_sk(self) -> float:  # pragma: no cover - legacy
        """Deprecated placeholder kept for old artifact readers."""
        return 0.0


@dataclass
class TuningDatabase:
    """Keyed store of tuned winners + per-policy sweep results, with
    snapshot/journal persistence and federation stamps.

    The store partitions per architecture class (:mod:`repro.core.arch`):
    ``records`` holds only this database's own class (``arch``), foreign
    classes live in ``xarch`` keyed by class string. Every ingestion path
    (``add_record``, journal replay, snapshot load, federated merges)
    routes by the *record's* stamped class, so a sibling generation's
    winner can never masquerade as a local measurement — it stays visible
    to the selector only as an ``"xarch"`` re-ranked warm seed."""

    records: Dict[OpKey, TuningRecord] = field(default_factory=dict)
    #: per-key best tflops for every policy (policy name -> tflops); kept so
    #: the Fig-2 tolerance analysis does not need to re-measure.
    per_policy: Dict[OpKey, Dict[str, float]] = field(default_factory=dict)
    #: monotone append counter: bumps on every in-place ``add_record`` /
    #: journal replay, so callers (the adaptive tuner, sieve rebuilds) can
    #: cheaply detect "the database grew since I last looked".
    version: int = 0
    #: records dropped because their key/payload failed to parse (load +
    #: journal replay) — a format skew must be visible, not a silent shrink.
    load_errors: int = 0
    #: installed :class:`~repro.core.calibrate.CalibratedMachine` (or None):
    #: the fitted cost-model constants this database's producer learned from
    #: its journal. Persists through snapshot/journal like records and
    #: federates under the same hybrid (wall, version) LWW stamp.
    calibration: Optional[object] = None
    #: architecture class this database's OWN records were measured on;
    #: anything stamped with a different class routes to ``xarch``.
    arch: str = DEFAULT_ARCH
    #: foreign-class records: class string -> {key -> record}. Never direct
    #: dispatch hits — the selector re-ranks their policies under the local
    #: machine (the ``"xarch"`` source).
    xarch: Dict[str, Dict[OpKey, TuningRecord]] = field(default_factory=dict)
    #: foreign-class calibrations (class string -> CalibratedMachine):
    #: carried for re-federation, never installed as the local scoring fit
    #: — a sibling generation's constants would poison model-first dispatch.
    xarch_calibrations: Dict[str, object] = field(default_factory=dict)
    #: known arch-profile coordinates per class string (from ``{"arch":...}``
    #: journal entries) — observability for merged fleets.
    arch_profiles: Dict[str, ArchProfile] = field(default_factory=dict)

    def winners(self) -> Dict[OpKey, Policy]:
        """{key -> winning Policy} of the OWN arch class — what this
        class's Bloom filters are built from."""
        return {s: policy_from_name(r.policy) for s, r in self.records.items()}

    def build_sieve(
        self,
        capacity: int = 10_000,
        fp_rate: float = 0.01,
        generation: int = 0,
    ) -> OpenSieve:
        """Fresh OpenSieve populated with this database's winners — own
        class under its (legacy-byte-identical) key encoding, foreign
        classes under their class-prefixed encodings, so queries in one
        class never alias another's winners."""
        sieve = OpenSieve(
            ALL_POLICIES, capacity=capacity, fp_rate=fp_rate, generation=generation
        )
        sieve.build_from_winners(self.winners(), arch=self.arch)
        for cls_name, recs in self.xarch.items():
            sieve.build_from_winners(
                {s: policy_from_name(r.policy) for s, r in recs.items()},
                arch=cls_name,
            )
        return sieve

    def n_records(self) -> int:
        """Total records across every arch class (own + foreign)."""
        return len(self.records) + sum(len(v) for v in self.xarch.values())

    def xarch_records_for(self, key: OpKey) -> List[Tuple[str, TuningRecord]]:
        """Foreign-class records for one fingerprint, in deterministic
        class order — the selector's ``"xarch"`` warm-seed source."""
        return [
            (cls_name, recs[key])
            for cls_name, recs in sorted(self.xarch.items())
            if key in recs
        ]

    def note_arch_profile(self, profile: ArchProfile) -> None:
        """Record the coordinates behind an arch class string (idempotent;
        the class string is derived from the profile, so two producers of
        one class cannot disagree)."""
        self.arch_profiles[profile.cls] = profile

    def add_record(
        self,
        rec: TuningRecord,
        per_policy: Optional[Dict[str, float]] = None,
        stamp: bool = True,
    ) -> None:
        """In-place record append (the online-adaptation commit path).
        Overwrites any existing record for the same key and bumps
        ``version`` so sieve-generation machinery sees the change.

        ``stamp`` controls the hybrid commit stamp: fresh local commits
        (the default) arriving unstamped get ``version = clock + 1`` plus
        the current wall clock in ``wall``; replay paths pass
        ``stamp=False`` so a record keeps exactly the (wall, version) its
        producer wrote — in particular a legacy stamp-less journal line
        stays at (0.0, 0) and always loses a federated last-writer-wins
        merge, the same as legacy snapshot records. Already-stamped records
        keep their stamp either way and fast-forward the local clock, so a
        later local commit always outranks them.

        Routing is by the *record's* arch class: own-class records land in
        ``records`` (with their per-policy table), foreign-class records in
        ``xarch`` — the journal-over-snapshot structural precedence
        (unconditional overwrite) holds per class."""
        if stamp and rec.version <= 0:
            rec.version = self.version + 1
            if rec.wall <= 0.0:
                rec.wall = time.time()
        if rec.arch == self.arch:
            self.records[rec.size] = rec
            if per_policy is not None:
                self.per_policy[rec.size] = per_policy
        else:
            self.xarch.setdefault(rec.arch, {})[rec.size] = rec
        self.version = max(self.version + 1, rec.version)

    def set_calibration(self, cm, stamp: bool = True, force: bool = False) -> bool:
        """Install a :class:`~repro.core.calibrate.CalibratedMachine`.

        Mirrors :meth:`add_record`'s stamp semantics: a fresh unstamped
        calibration (the local fit-and-commit path) is stamped with this
        producer's ``(wall, version)`` hybrid clock; replay/merge paths pass
        ``stamp=False`` so the producer's stamp survives. Unless ``force``,
        an incumbent calibration only yields under the deterministic LWW
        order (:func:`repro.core.calibrate.better_calibration`) — the same
        contract records merge under. Returns True when the installed
        calibration changed (bumping ``version`` so sieve-generation
        machinery and adaptive rebuilds see it).

        A calibration stamped with a *foreign* arch class never installs as
        the local fit — it routes to ``xarch_calibrations`` (LWW within its
        class), because a sibling generation's fitted constants would steer
        every local model-first dispatch wrong."""
        from repro.core.calibrate import better_calibration

        cm_arch = getattr(cm, "arch", DEFAULT_ARCH)
        if cm_arch != self.arch:
            cur = self.xarch_calibrations.get(cm_arch)
            new = cm if force else better_calibration(cur, cm)
            if new is cur:
                return False
            self.xarch_calibrations[cm_arch] = new
            return True
        if stamp and cm.version <= 0:
            cm = replace(
                cm,
                version=self.version + 1,
                wall=cm.wall if cm.wall > 0.0 else time.time(),
            )
        if not force and self.calibration is not None:
            cm = better_calibration(self.calibration, cm)
        if cm is self.calibration:
            return False
        self.calibration = cm
        self.version = max(self.version + 1, cm.version)
        return True

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> None:
        """Write the full JSON snapshot (string-keyed records + sweeps).

        Arch sections (``arch``, ``xarch``, ``arch_profiles``,
        ``xarch_calibrations``) are written only when non-default/non-empty,
        so a single-class default fleet's snapshot stays byte-identical to
        the pre-arch format — and loads under pre-arch readers."""
        payload = {
            "records": {
                key_to_str(s): _record_payload_dict(r)
                for s, r in self.records.items()
            },
            "per_policy": {
                key_to_str(s): pp for s, pp in self.per_policy.items()
            },
        }
        if self.calibration is not None:
            from repro.core.calibrate import calibration_to_json

            payload["calibration"] = calibration_to_json(self.calibration)
        if self.arch != DEFAULT_ARCH:
            payload["arch"] = self.arch
        if self.xarch:
            payload["xarch"] = {
                cls_name: {
                    key_to_str(s): _record_payload_dict(r)
                    for s, r in recs.items()
                }
                for cls_name, recs in self.xarch.items()
            }
        if self.arch_profiles:
            payload["arch_profiles"] = {
                cls_name: p.to_json() for cls_name, p in self.arch_profiles.items()
            }
        if self.xarch_calibrations:
            from repro.core.calibrate import calibration_to_json

            payload["xarch_calibrations"] = {
                cls_name: calibration_to_json(cm)
                for cls_name, cm in self.xarch_calibrations.items()
            }
        with open(path, "w") as f:
            json.dump(payload, f)

    @classmethod
    def load(
        cls,
        path: str,
        journal: Optional[str] = None,
        arch: Optional[str] = None,
    ) -> "TuningDatabase":
        """Load a snapshot, then optionally replay an append-only journal on
        top (records learned after the last snapshot win). Records whose key
        or payload fails to parse are skipped with a warning and counted in
        ``load_errors`` — never silently dropped. Snapshots written before
        the grid sweep carry no ``g``: they parse with ``g = LEGACY_GRID``;
        snapshots written before arch classes parse into ``"default"``.

        ``arch`` overrides the loading process's own class (default: the
        class the snapshot declares). Every record routes by its *own*
        stamped class, so loading another class's snapshot under a local
        class lands its records in ``xarch`` — warm seeds, not direct hits."""
        with open(path) as f:
            payload = json.load(f)
        own = arch if arch is not None else payload.get("arch", DEFAULT_ARCH)
        db = cls(arch=own)
        sections = [(None, payload["records"])]
        sections += [
            (cls_name, recs)
            for cls_name, recs in payload.get("xarch", {}).items()
        ]
        for section_arch, records in sections:
            for key, rec in records.items():
                try:
                    size = key_from_str(key)
                    rec["size"] = size
                    rec.setdefault(
                        "arch",
                        section_arch
                        if section_arch is not None
                        else payload.get("arch", DEFAULT_ARCH),
                    )
                    parsed = TuningRecord(**rec)
                except (ValueError, IndexError, TypeError) as e:
                    db.load_errors += 1
                    log.warning("dropping unparsable tuning record %r: %s", key, e)
                    continue
                if parsed.arch == db.arch:
                    db.records[size] = parsed
                else:
                    db.xarch.setdefault(parsed.arch, {})[size] = parsed
        for key, pp in payload.get("per_policy", {}).items():
            try:
                db.per_policy[key_from_str(key)] = pp
            except (ValueError, IndexError) as e:
                db.load_errors += 1
                log.warning("dropping unparsable per-policy key %r: %s", key, e)
        if payload.get("calibration") is not None:
            from repro.core.calibrate import calibration_from_json

            try:
                # routed by its own arch class: a snapshot re-keyed to a
                # different local class must not install foreign constants
                db.set_calibration(
                    calibration_from_json(payload["calibration"]), stamp=False
                )
            except (ValueError, KeyError, TypeError) as e:
                db.load_errors += 1
                log.warning("dropping unparsable calibration: %s", e)
        for cls_name, cal in payload.get("xarch_calibrations", {}).items():
            from repro.core.calibrate import calibration_from_json

            try:
                db.set_calibration(calibration_from_json(cal), stamp=False)
            except (ValueError, KeyError, TypeError) as e:
                db.load_errors += 1
                log.warning(
                    "dropping unparsable %s calibration: %s", cls_name, e
                )
        for cls_name, prof in payload.get("arch_profiles", {}).items():
            try:
                db.note_arch_profile(ArchProfile.from_json(prof))
            except (ValueError, TypeError) as e:
                db.load_errors += 1
                log.warning("dropping unparsable arch profile %r: %s", cls_name, e)
        if db.load_errors:
            log.warning(
                "%s: dropped %d unparsable entries (kept %d records) — "
                "journal/db format skew?",
                path,
                db.load_errors,
                len(db.records),
            )
        # resume the producer's commit clock so post-load commits outrank
        # every loaded record in a federated merge
        db.version = max((r.version for r in db.records.values()), default=0)
        if db.calibration is not None:
            db.version = max(db.version, db.calibration.version)
        if journal is not None:
            db.replay_journal(journal, missing_ok=True)
        return db

    def replay_journal(self, path: str, missing_ok: bool = False) -> int:
        """Re-apply an append-only JSONL journal (see :func:`journal_entry`)
        in order; later lines win. Returns the number of entries applied;
        malformed lines are warned about and counted in ``load_errors``.
        Legacy g-less lines replay with ``g = LEGACY_GRID``; arch-less
        lines into the ``"default"`` class.

        Entries route through the tagged-entry registry
        (:data:`JOURNAL_ENTRY_HANDLERS`): an entry whose tag no handler
        claims — a *future* producer's type — is skipped and counted in
        ``load_errors`` but NOT warned as malformed (forward compatibility
        is not corruption).

        Crash tolerance: a process dying mid-``append_journal`` leaves a
        truncated final line — possibly ending inside a multi-byte UTF-8
        sequence, which is why the file is read as bytes and decoded per
        line (text-mode iteration would raise ``UnicodeDecodeError`` before
        any handler ran). The torn line is skipped with a warning and
        counted in ``load_errors``; every complete line before it replays
        normally."""
        try:
            f = open(path, "rb")
        except FileNotFoundError:
            if missing_ok:
                return 0
            raise
        with f:
            raw_lines = f.read().split(b"\n")
        last_lineno = max(
            (i for i, raw in enumerate(raw_lines, 1) if raw.strip()), default=0
        )
        applied = 0
        for lineno, raw in enumerate(raw_lines, 1):
            if not raw.strip():
                continue
            try:
                entry = json.loads(raw.decode("utf-8"))
                if apply_journal_entry(self, entry):
                    applied += 1
                else:
                    # unknown tag: a future entry type, not corruption —
                    # counted (the shrink stays visible) but not warned
                    self.load_errors += 1
                    log.debug(
                        "%s:%d: skipping journal entry with unknown tag "
                        "(keys %s)",
                        path,
                        lineno,
                        sorted(entry)[:4] if isinstance(entry, dict) else "-",
                    )
            except (ValueError, IndexError, TypeError, KeyError) as e:
                self.load_errors += 1
                if lineno == last_lineno:
                    log.warning(
                        "%s:%d: skipping truncated final journal line "
                        "(crash during append?): %s",
                        path,
                        lineno,
                        e,
                    )
                else:
                    log.warning(
                        "%s:%d: skipping malformed journal line: %s",
                        path,
                        lineno,
                        e,
                    )
        return applied


def _entry_record(entry: dict) -> Tuple[TuningRecord, Optional[Dict[str, float]]]:
    """(record, per_policy) from a decoded record-type journal entry."""
    size = key_from_str(entry["key"])
    rec = dict(entry["record"])
    rec.pop("size", None)
    return TuningRecord(size=size, **rec), entry.get("per_policy")


def _record_payload_dict(rec: TuningRecord) -> dict:
    """Record dict for snapshots/journals: the ``arch`` field is omitted
    for default-class records, so a single-class default fleet's artifact
    bytes stay identical to the pre-arch formats (and stay readable by
    pre-arch consumers, which reject unknown record fields)."""
    d = asdict(rec)
    if rec.arch == DEFAULT_ARCH:
        d.pop("arch", None)
    return d


# -- tagged journal entries -------------------------------------------------
#
# The journal grew entry types organically ({"record": ...} in PR 2,
# {"calibration": ...} in PR 8, {"arch": ...} now); this registry makes the
# codec table-driven: one handler per tag, checked in registration order
# (``"record"`` first — record entries also carry "key"/"per_policy" keys).
# Producers of NEW types register here; consumers built before a type
# existed skip-and-count it instead of warning (see ``replay_journal``).


def _apply_record_entry(db: "TuningDatabase", entry: dict) -> None:
    rec, per_policy = _entry_record(entry)
    # stamp=False: replay reconstructs producer state — legacy version-less
    # lines must stay 0 (and lose merges), not become fresh local commits
    db.add_record(rec, per_policy, stamp=False)


def _apply_calibration_entry(db: "TuningDatabase", entry: dict) -> None:
    from repro.core.calibrate import calibration_from_json

    # replayed under the same LWW order as merges, producer stamp preserved
    db.set_calibration(calibration_from_json(entry["calibration"]), stamp=False)


def _apply_arch_entry(db: "TuningDatabase", entry: dict) -> None:
    db.note_arch_profile(ArchProfile.from_json(entry["arch"]))


#: tag -> handler(db, entry). Insertion order is match order.
JOURNAL_ENTRY_HANDLERS: Dict[str, Callable[["TuningDatabase", dict], None]] = {
    "record": _apply_record_entry,
    "calibration": _apply_calibration_entry,
    "arch": _apply_arch_entry,
}


def register_journal_entry(
    tag: str, handler: Callable[["TuningDatabase", dict], None]
) -> None:
    """Register a journal entry type: ``handler(db, entry)`` is called for
    every journal line whose decoded object carries ``tag`` as a key.
    Raising from the handler marks the line malformed (warn + count);
    see :meth:`TuningDatabase.replay_journal`."""
    JOURNAL_ENTRY_HANDLERS[tag] = handler


def apply_journal_entry(db: "TuningDatabase", entry) -> bool:
    """Apply ONE decoded journal entry through the tag registry.

    Returns True when a handler claimed and applied it, False for an
    unknown tag (the caller decides how to count forward-compat skips).
    Raises — like the handlers — on a malformed payload. Shared by
    ``replay_journal`` and the streaming :mod:`repro.core.gossip` reader,
    so both consume exactly the same entry table."""
    if not isinstance(entry, dict):
        raise ValueError(f"journal entry is not an object: {type(entry).__name__}")
    for tag, handler in JOURNAL_ENTRY_HANDLERS.items():
        if tag in entry:
            handler(db, entry)
            return True
    return False


def parse_journal_line(line: str) -> Tuple[TuningRecord, Optional[Dict[str, float]]]:
    """Parse one record-type journal line into (record, per_policy). Raises
    on any malformed input (``replay_journal`` / shard mergers decide whether
    that is fatal). Legacy lines parse with ``g = LEGACY_GRID``/``version =
    0``. Calibration entries are not records — ``replay_journal`` routes
    them to :meth:`TuningDatabase.set_calibration` instead."""
    return _entry_record(json.loads(line))


def journal_entry(
    rec: TuningRecord, per_policy: Optional[Dict[str, float]] = None
) -> str:
    """One journal line: the shared format the offline ``Tuner`` emits and
    the serve-time adaptive tuner appends — ``TuningDatabase.replay_journal``
    consumes both identically. Default-class records serialize without the
    ``arch`` field (byte-identical to pre-arch lines)."""
    payload = _record_payload_dict(rec)
    payload.pop("size")
    entry = {"key": key_to_str(rec.size), "record": payload}
    if per_policy is not None:
        entry["per_policy"] = per_policy
    return json.dumps(entry)


def append_journal(
    path: str, rec: TuningRecord, per_policy: Optional[Dict[str, float]] = None
) -> None:
    """Append one record to the JSONL journal (crash-safe: one line per
    commit, flushed before close; a torn final line is skipped on replay)."""
    with open(path, "a") as f:
        f.write(journal_entry(rec, per_policy) + "\n")


def measure_model(mach: costmodel.Machine = costmodel.V5E) -> MeasureFn:
    """Measurement oracle backed by the analytical cost model."""

    def fn(
        shape: GemmShape,
        policy: Policy,
        cfg: TileConfig,
        g: int,
        dt: DtypeBytes,
    ) -> float:
        return costmodel.gemm_tflops(shape, cfg, policy, mach, g, dt)

    return fn


def measure_wallclock(
    warmup: int = 50, iters: int = 50, interpret: bool = False, dtype=None
) -> MeasureFn:
    """The paper's protocol on real hardware: 50 warm-up launches, then the
    average of 50 timed launches. Uses the Pallas kernels via ops.gemm.
    Operand dtypes follow the target's :class:`DtypeBytes` profile (so an
    f32 fingerprint really times f32 kernels); ``dtype`` forces one operand
    dtype for both A and B instead. The swept grid size threads straight
    into the kernel launch."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.streamk import ops as sk_ops

    width_to_dtype = {
        1: jnp.int8,
        2: jnp.bfloat16,
        4: jnp.float32,
        8: jnp.float64,
    }

    def _dt_dtype(width):
        if width == 8 and not jax.config.jax_enable_x64:
            # without x64, f64 operands silently downcast to f32 — measure
            # what will actually run and say so, instead of recording an
            # "f64" winner that never timed f64 kernels
            log.warning(
                "jax x64 disabled: measuring 8-byte fingerprint at float32"
            )
            return jnp.float32
        if width < 1:
            # sub-byte packed fingerprints (int4 at 0.5 bytes/element): no
            # jnp array dtype moves half bytes, so the measurement times the
            # int8 stand-in — an upper bound on the packed kernel's B
            # traffic; honest on compute, conservative on bandwidth
            log.warning(
                "measuring sub-byte (%.1f-byte) fingerprint with int8 "
                "operands — timings upper-bound the packed kernel",
                width,
            )
            return jnp.int8
        if width == 1:
            # byte-wide fingerprints (int8, fp8 variants) all time the int8
            # stand-in; fp8 records therefore reflect int8 kernel timing
            log.warning("measuring 1-byte fingerprint with int8 operands")
        return width_to_dtype.get(width, jnp.float32)

    def fn(
        shape: GemmShape,
        policy: Policy,
        cfg: TileConfig,
        g: int,
        dt: DtypeBytes,
    ) -> float:
        a_dtype = dtype or _dt_dtype(dt.a)
        b_dtype = dtype or _dt_dtype(dt.b)
        out_dtype = dtype or _dt_dtype(dt.out)
        key = jax.random.PRNGKey(0)
        groups = getattr(shape, "groups", 1)
        if groups > 1:
            # fused grouped target: time the one-kernel concatenated form
            # with stacked per-expert operands — the kernel the dispatcher
            # actually launches for this fingerprint
            from repro.kernels.streamk.grouped import gemm_grouped_streamk

            a = jax.random.normal(
                key, (groups, shape.m, shape.k)
            ).astype(a_dtype)
            b = jax.random.normal(
                key, (groups, shape.k, shape.n)
            ).astype(b_dtype)
            call = jax.jit(
                lambda a, b: gemm_grouped_streamk(
                    a, b, policy=policy, cfg=cfg, g=g, interpret=interpret,
                    out_dtype=out_dtype,
                )
            )
        else:
            a = jax.random.normal(key, (shape.m, shape.k)).astype(a_dtype)
            b = jax.random.normal(key, (shape.k, shape.n)).astype(b_dtype)
            call = jax.jit(
                lambda a, b: sk_ops.gemm(
                    a, b, policy=policy, cfg=cfg, g=g, interpret=interpret,
                    out_dtype=out_dtype,
                )
            )
        for _ in range(warmup):
            call(a, b).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(iters):
            out = call(a, b)
        out.block_until_ready()
        dt_s = (time.perf_counter() - t0) / iters
        return shape.flops / dt_s / 1e12

    return fn


def shard_targets(sizes: Sequence, index: int, n_shards: int) -> List:
    """Worker ``index``'s slice of a sweep: every ``n_shards``-th target
    starting at ``index``. Strided (not contiguous) so the suite's
    size-sorted cost profile balances across workers; the ``n_shards``
    slices are disjoint and cover ``sizes`` exactly, which is what makes a
    federated merge record-identical to the single-worker full sweep."""
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not 0 <= index < n_shards:
        raise ValueError(f"shard index {index} outside [0, {n_shards})")
    return list(sizes)[index::n_shards]


class Tuner:
    """Sweep (policy x tile config x grid size) per problem size; record
    winner and runner-up (runner-up = best configuration of the *second-best
    policy*, which is what the paper's Fig. 3 violin compares against).

    Two sweep budgets:

      * ``top_k=None`` (default) — the exhaustive oracle: every feasible
        (policy, cfg, g) is measured, exactly the classic ckProfiler sweep.
      * ``top_k=k`` — the analytical-first budget: only the cost model's
        top-k ranked candidates (:func:`costmodel.rank_candidates`, under
        the installed ``calibration``'s machine when one is set) are
        measured, plus DP's best-ranked candidate (so ``dp_best_tflops``
        stays honest) and at least one candidate of a second policy (so the
        runner-up field stays meaningful) — ~k+2 measurements instead of
        ~|policies| x |cfgs| x |grids|. Each record notes the measured
        winner's model rank, the drift signal calibration quality is judged
        by.

    ``measurements`` counts every ``measure_fn`` call across the tuner's
    lifetime — the budget the top-k acceptance criterion compares."""

    def __init__(
        self,
        policies: Sequence[Policy] = ALL_POLICIES,
        tile_configs: Sequence[TileConfig] = DEFAULT_TILE_CONFIGS,
        measure_fn: Optional[MeasureFn] = None,
        mach: costmodel.Machine = costmodel.V5E,
        grid_sizes: Optional[Sequence[int]] = None,
        top_k: Optional[int] = None,
        calibration=None,
        arch: str = DEFAULT_ARCH,
    ):
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        #: arch class stamped onto every record this tuner measures (the
        #: machine class the measurements describe — see repro.core.arch)
        self.arch = arch
        self.policies = tuple(policies)
        self.tile_configs = tuple(tile_configs)
        self.measure = measure_fn or measure_model(mach)
        self.mach = mach
        self.grid_sizes = (
            tuple(grid_sizes)
            if grid_sizes is not None
            else costmodel.default_grid_sizes(mach)
        )
        self.top_k = top_k
        self.calibration = calibration
        self.measurements = 0

    def _rank_machine(self, dt: DtypeBytes) -> costmodel.Machine:
        """Machine the model ranks candidates under: the calibration's
        per-profile fit when installed, the nominal machine otherwise."""
        if self.calibration is not None:
            return self.calibration.machine_for(dt)
        return self.mach

    def _ranked(self, shape: GemmShape, dt: DtypeBytes):
        return costmodel.rank_candidates(
            shape,
            self._rank_machine(dt),
            self.policies,
            self.tile_configs,
            self.grid_sizes,
            dt,
        )

    def _model_rank(
        self, shape: GemmShape, dt: DtypeBytes, policy: str, cfg: str, g: int
    ) -> int:
        """1-based model rank of a (policy, cfg, g) pick (-1 if unranked)."""
        for i, (pol, c, gg, _) in enumerate(self._ranked(shape, dt), 1):
            if pol.name == policy and c.name == cfg and gg == g:
                return i
        return -1

    @staticmethod
    def _runner_up(ranked_pols: List[Tuple[str, float]]) -> Tuple[str, float]:
        """Runner-up = best policy with strictly lower measured performance
        (the deterministic cost model produces exact ties between sibling
        schedules — e.g. HYBRID(b) variants whose extra batches are moot —
        which real-hardware noise would separate; Fig.3 compares against
        the next *distinct* configuration)."""
        w_name, w_tf = ranked_pols[0]
        r_name, r_tf = ranked_pols[1] if len(ranked_pols) > 1 else (w_name, 0.0)
        for name, tf in ranked_pols[1:]:
            if tf < w_tf * (1 - 1e-9):
                r_name, r_tf = name, tf
                break
        return r_name, r_tf

    def _record(
        self,
        key: OpKey,
        shape: GemmShape,
        dt: DtypeBytes,
        per_policy: Dict[str, float],
        per_policy_cfg: Dict[str, str],
        per_policy_g: Dict[str, int],
    ) -> TuningRecord:
        ranked = sorted(per_policy.items(), key=lambda kv: kv[1], reverse=True)
        w_name, w_tf = ranked[0]
        r_name, r_tf = self._runner_up(ranked)
        return TuningRecord(
            size=key,
            policy=w_name,
            cfg=per_policy_cfg[w_name],
            tflops=w_tf,
            runner_up_policy=r_name,
            runner_up_tflops=r_tf,
            dp_best_tflops=per_policy.get(DP.name, 0.0),
            g=per_policy_g[w_name],
            model_rank=self._model_rank(
                shape, dt, w_name, per_policy_cfg[w_name], per_policy_g[w_name]
            ),
            arch=self.arch,
        )

    def _tune_size_full(
        self, key: OpKey, shape: GemmShape, dt: DtypeBytes
    ) -> Tuple[TuningRecord, Dict[str, float]]:
        """The exhaustive oracle sweep: every feasible (policy, cfg, g)."""
        per_policy: Dict[str, float] = {}
        per_policy_cfg: Dict[str, str] = {}
        per_policy_g: Dict[str, int] = {}
        for pol in self.policies:
            best = -1.0
            best_cfg = self.tile_configs[0]
            best_g = self.grid_sizes[0]
            for g in self.grid_sizes:
                for cfg in self.tile_configs:
                    if costmodel.vmem_working_set(cfg, dt) > self.mach.vmem_bytes:
                        continue
                    tf = self.measure(shape, pol, cfg, g, dt)
                    self.measurements += 1
                    if tf > best:
                        best, best_cfg, best_g = tf, cfg, g
            per_policy[pol.name] = best
            per_policy_cfg[pol.name] = best_cfg.name
            per_policy_g[pol.name] = best_g
        rec = self._record(key, shape, dt, per_policy, per_policy_cfg, per_policy_g)
        return rec, per_policy

    def _tune_size_topk(
        self, key: OpKey, shape: GemmShape, dt: DtypeBytes
    ) -> Tuple[TuningRecord, Dict[str, float]]:
        """The budgeted model-first sweep: measure only the cost model's
        top-k candidates (+ DP's best-ranked, + one second-policy candidate
        when the head is single-policy)."""
        ranked = self._ranked(shape, dt)
        cand = list(ranked[: self.top_k])
        have = {(c[0].name, c[1].name, c[2]) for c in cand}
        pols = {c[0].name for c in cand}
        # dp_best_tflops is the paper's tolerance baseline — always measure
        # DP's best-ranked candidate even when it falls outside the head
        if DP in self.policies and DP.name not in pols:
            dp_c = next((c for c in ranked if c[0] is DP), None)
            if dp_c is not None and (DP.name, dp_c[1].name, dp_c[2]) not in have:
                cand.append(dp_c)
                have.add((DP.name, dp_c[1].name, dp_c[2]))
                pols.add(DP.name)
        # a meaningful runner-up needs a second distinct policy in budget
        if len(pols) < 2:
            alt = next((c for c in ranked if c[0].name not in pols), None)
            if alt is not None:
                cand.append(alt)
                pols.add(alt[0].name)
        per_policy: Dict[str, float] = {}
        per_policy_cfg: Dict[str, str] = {}
        per_policy_g: Dict[str, int] = {}
        for pol, cfg, g, _ in cand:
            tf = self.measure(shape, pol, cfg, g, dt)
            self.measurements += 1
            if tf > per_policy.get(pol.name, -1.0):
                per_policy[pol.name] = tf
                per_policy_cfg[pol.name] = cfg.name
                per_policy_g[pol.name] = g
        rec = self._record(key, shape, dt, per_policy, per_policy_cfg, per_policy_g)
        return rec, per_policy

    def tune_size(self, size) -> Tuple[TuningRecord, Dict[str, float]]:
        """Sweep one tuning target — a bare (M, N, K) or a full GemmOp
        (grouped / fused ops tune per-group on their local shape and record
        under their op-fingerprint key, measured at their real operand
        byte-widths). ``top_k`` picks the budget (see class docstring)."""
        key = _as_key(size)
        shape = _key_shape(size, key)
        dt = _target_dtypes(size)
        if self.top_k is not None:
            return self._tune_size_topk(key, shape, dt)
        return self._tune_size_full(key, shape, dt)

    def tune(
        self,
        sizes: Sequence,
        progress_every: int = 0,
        journal: Optional[str] = None,
        shard: Optional[Tuple[int, int]] = None,
    ) -> TuningDatabase:
        """Tune a suite of targets (bare (M, N, K) sizes and/or GemmOps).
        With ``journal``, each record is also appended to the JSONL journal
        as it lands — the same format the online adaptive tuner emits, so an
        offline sweep and a serving run can share one warm-start artifact.

        ``shard=(i, n)`` restricts the sweep to worker ``i``'s slice of the
        target list (see :func:`shard_targets`): n workers each tune their
        own slice — journaling to their own shard file — and
        :func:`repro.core.federate.merge_journal_shards` reassembles the
        exact database the unsharded sweep would have produced."""
        if shard is not None:
            sizes = shard_targets(sizes, *shard)
        db = TuningDatabase(arch=self.arch)
        for i, size in enumerate(sizes):
            rec, per_policy = self.tune_size(size)
            db.add_record(rec, per_policy)
            if journal is not None:
                append_journal(journal, rec, per_policy)
            if progress_every and (i + 1) % progress_every == 0:  # pragma: no cover
                print(f"tuned {i + 1}/{len(sizes)}")
        return db
