"""The paper's contribution: Stream-K++ scheduling policies, work-centric
GEMM partitioning, Bloom-filter policy selection (Open-sieve), the
ckProfiler-analogue tuner, and the GemmOp dispatch API.

Dispatch surface: :func:`gemm` / :func:`gemm_grouped` / :func:`gemm_batched`
build a :class:`GemmOp` fingerprint (local shape, group count, dtypes, fused
:class:`Epilogue`), the :class:`KernelSelector` keys on it (tuned DB ->
Bloom sieve -> cost model), and a pluggable backend registry
(:func:`register_backend`) executes — see ``repro.core.gemm``."""

from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DEFAULT_TILE_CONFIGS,
    DP,
    HYBRIDS,
    STREAMKPP_POLICIES,
    Policy,
    PolicyKind,
    TileConfig,
    policy_from_name,
)
from repro.core.workpart import (
    GemmShape,
    Partition,
    TileContribution,
    WorkRange,
    cdiv,
    partition,
    validate_partition,
    wave_quantization_efficiency,
)
from repro.core.arch import DEFAULT_ARCH, ArchProfile, append_arch, detect_arch
from repro.core.bloom import BloomFilter, encode_mnk, murmur3_32
from repro.core.op import Epilogue, GemmOp, encode_key, encode_op
from repro.core.opensieve import OpenSieve
from repro.core.costmodel import (
    DtypeBytes,
    Machine,
    V5E,
    best_config,
    default_grid_sizes,
    gemm_tflops,
    gemm_time_s,
    profile_for,
)
from repro.core.tuner import (
    LEGACY_GRID,
    Tuner,
    TuningDatabase,
    TuningRecord,
    append_journal,
    apply_journal_entry,
    journal_entry,
    parse_journal_line,
    register_journal_entry,
    shard_targets,
)
from repro.core.federate import (
    MergeReport,
    apply_journal_db,
    federate_selector,
    merge_databases,
    merge_journal_shards,
    merge_sieves,
)
from repro.core.quant import (
    QuantizedTensor,
    is_quantized,
    quantize_lm_params,
    quantize_weight,
)
from repro.core.selector import (
    KernelSelector,
    Selection,
    SelectorState,
    default_selector,
)
from repro.core.adaptive import AdaptiveConfig, AdaptiveStats, AdaptiveTuner
from repro.core.gossip import GossipExchange, GossipStats, JournalTail
from repro.core.gemm import (
    current_log,
    gemm,
    gemm_batched,
    gemm_context,
    gemm_grouped,
    get_backend,
    list_backends,
    register_backend,
)

__all__ = [
    "ALL_POLICIES",
    "ALL_SK",
    "DEFAULT_TILE_CONFIGS",
    "DP",
    "HYBRIDS",
    "STREAMKPP_POLICIES",
    "Policy",
    "PolicyKind",
    "TileConfig",
    "policy_from_name",
    "GemmShape",
    "Partition",
    "TileContribution",
    "WorkRange",
    "cdiv",
    "partition",
    "validate_partition",
    "wave_quantization_efficiency",
    "ArchProfile",
    "DEFAULT_ARCH",
    "append_arch",
    "detect_arch",
    "BloomFilter",
    "encode_mnk",
    "murmur3_32",
    "OpenSieve",
    "Machine",
    "V5E",
    "DtypeBytes",
    "profile_for",
    "default_grid_sizes",
    "gemm_tflops",
    "gemm_time_s",
    "best_config",
    "LEGACY_GRID",
    "Tuner",
    "TuningDatabase",
    "TuningRecord",
    "append_journal",
    "apply_journal_entry",
    "journal_entry",
    "parse_journal_line",
    "register_journal_entry",
    "shard_targets",
    "MergeReport",
    "apply_journal_db",
    "federate_selector",
    "merge_databases",
    "merge_journal_shards",
    "merge_sieves",
    "QuantizedTensor",
    "is_quantized",
    "quantize_lm_params",
    "quantize_weight",
    "KernelSelector",
    "Selection",
    "SelectorState",
    "default_selector",
    "AdaptiveConfig",
    "AdaptiveStats",
    "AdaptiveTuner",
    "GossipExchange",
    "GossipStats",
    "JournalTail",
    "Epilogue",
    "GemmOp",
    "encode_key",
    "encode_op",
    "gemm",
    "gemm_grouped",
    "gemm_batched",
    "gemm_context",
    "current_log",
    "register_backend",
    "get_backend",
    "list_backends",
]
