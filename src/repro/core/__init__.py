"""The paper's contribution: Stream-K++ scheduling policies, work-centric
GEMM partitioning, Bloom-filter policy selection (Open-sieve), the
ckProfiler-analogue tuner, and the GEMM dispatch API."""

from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DEFAULT_TILE_CONFIGS,
    DP,
    HYBRIDS,
    STREAMKPP_POLICIES,
    Policy,
    PolicyKind,
    TileConfig,
    policy_from_name,
)
from repro.core.workpart import (
    GemmShape,
    Partition,
    TileContribution,
    WorkRange,
    cdiv,
    partition,
    validate_partition,
    wave_quantization_efficiency,
)
from repro.core.bloom import BloomFilter, encode_mnk, murmur3_32
from repro.core.opensieve import OpenSieve
from repro.core.costmodel import Machine, V5E, gemm_tflops, gemm_time_s, best_config
from repro.core.tuner import Tuner, TuningDatabase, TuningRecord
from repro.core.selector import KernelSelector, Selection, default_selector
from repro.core.gemm import gemm, gemm_context, current_log

__all__ = [
    "ALL_POLICIES",
    "ALL_SK",
    "DEFAULT_TILE_CONFIGS",
    "DP",
    "HYBRIDS",
    "STREAMKPP_POLICIES",
    "Policy",
    "PolicyKind",
    "TileConfig",
    "policy_from_name",
    "GemmShape",
    "Partition",
    "TileContribution",
    "WorkRange",
    "cdiv",
    "partition",
    "validate_partition",
    "wave_quantization_efficiency",
    "BloomFilter",
    "encode_mnk",
    "murmur3_32",
    "OpenSieve",
    "Machine",
    "V5E",
    "gemm_tflops",
    "gemm_time_s",
    "best_config",
    "Tuner",
    "TuningDatabase",
    "TuningRecord",
    "KernelSelector",
    "Selection",
    "default_selector",
    "gemm",
    "gemm_context",
    "current_log",
]
