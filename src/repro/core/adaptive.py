"""Online adaptation: miss-driven autotuning in the serving path.

The offline :class:`~repro.core.tuner.Tuner` covers the problem sizes someone
thought to sweep ahead of time; any :class:`~repro.core.op.GemmOp`
fingerprint outside that set (a new model config, a new dtype/epilogue combo,
a resharded MoE group size) falls through to the heuristic forever. The
:class:`AdaptiveTuner` closes that gap at runtime:

  1. it registers as the :class:`~repro.core.selector.KernelSelector` miss
     hook, so every dispatch that did NOT resolve from the tuning database
     increments a bounded miss-frequency table keyed on the op fingerprint;
  2. fingerprints whose miss count crosses ``hot_threshold`` are promoted to
     a FIFO of *hot* tuning candidates;
  3. :meth:`AdaptiveTuner.adapt` — called from the serving loop between
     decode steps (``ServeEngine(adapt_every=...)``) — sweeps
     (policy, tile, grid-size) candidates at the fingerprint's real operand
     byte-widths for a few hot fingerprints under an optional wallclock
     budget and commits each winner as an incremental
     :class:`~repro.core.tuner.TuningRecord`;
  4. commits append to the shared JSONL journal (restart-safe warm start),
     invalidate the selector's memoised pick for that key, and — every
     ``rebuild_every`` commits — rebuild the Bloom sieve from the grown
     database under the next *generation* and hot-swap it in (Bloom filters
     cannot delete, so adaptation never mutates a live sieve).

Measurement is injected via the ``Tuner``: the default analytical cost model
works anywhere; pass ``Tuner(measure_fn=measure_wallclock(...))`` to opt in
to real on-device timing, bounded by ``budget_s`` per adaptation round so
tuning never starves the decode loop.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, replace
from typing import Dict, List, Optional

from repro.core.op import GemmOp, OpKey
from repro.core.selector import KernelSelector, Selection
from repro.core.tuner import Tuner, TuningDatabase, append_journal


@dataclass(frozen=True)
class AdaptiveConfig:
    """Knobs of the online adaptation loop (thresholds, bounds, budget)."""

    #: misses before a fingerprint is promoted to a tuning candidate
    hot_threshold: int = 3
    #: bound on the miss-frequency table (coldest entries evicted first)
    max_pending: int = 256
    #: hot fingerprints tuned per ``adapt()`` round (keeps rounds short)
    max_tunes_per_step: int = 4
    #: commits between generational sieve rebuilds
    rebuild_every: int = 8
    #: wallclock budget (seconds) per ``adapt()`` round; ``None`` = no cap.
    #: Matters when measurement is real hardware timing rather than the
    #: analytical model — adaptation must never starve the decode loop.
    budget_s: Optional[float] = None
    #: parameters for rebuilt sieves
    sieve_capacity: int = 10_000
    sieve_fp_rate: float = 0.01
    #: budgeted adaptation sweeps: measure only the cost model's top-k
    #: ranked candidates per hot fingerprint (see ``Tuner(top_k=...)``)
    #: instead of the exhaustive (policy x cfg x grid) oracle sweep.
    #: ``None`` keeps the full sweep. Only applies to the default-built
    #: Tuner — an explicitly passed ``tuner`` keeps its own budget.
    top_k: Optional[int] = None


@dataclass
class AdaptiveStats:
    """Lifetime counters of one :class:`AdaptiveTuner` (observability)."""

    misses: int = 0  # miss-hook notifications observed
    promoted: int = 0  # fingerprints that crossed hot_threshold
    evicted: int = 0  # cold fingerprints dropped by the bound
    adaptations: int = 0  # TuningRecords committed to the database
    rebuilds: int = 0  # generational sieve rebuilds + hot-swaps
    budget_stops: int = 0  # adapt() rounds cut short by budget_s


class AdaptiveTuner:
    """Watches a selector's misses and tunes the hottest fingerprints online.

    The tuner owns (or adopts) the selector's :class:`TuningDatabase`;
    committed records are immediately visible to the selector (exact-key DB
    hit), journal-persisted when ``journal`` is set, and folded into the
    Bloom sieve on the next generational rebuild.
    """

    def __init__(
        self,
        selector: KernelSelector,
        db: Optional[TuningDatabase] = None,
        tuner: Optional[Tuner] = None,
        config: Optional[AdaptiveConfig] = None,
        journal: Optional[str] = None,
    ):
        self.selector = selector
        self.db = (
            db
            if db is not None
            else (selector.db or TuningDatabase(arch=selector.arch))
        )
        if selector.db is not self.db:
            # the tuner owns the selector's database: commits must be the
            # records selection reads, so an explicitly passed db replaces
            # whatever the selector held (memoised picks dropped — they were
            # resolved against the old database)
            selector.hot_swap(state=replace(selector.state, db=self.db))
        self.cfg = config or AdaptiveConfig()
        self.tuner = tuner or Tuner(
            policies=selector.policies, tile_configs=selector.tile_configs,
            mach=selector.mach, grid_sizes=selector.grid_sizes,
            top_k=self.cfg.top_k, calibration=selector.calibration,
            arch=selector.arch,
        )
        self.journal = journal
        self.stats = AdaptiveStats()
        self._miss_counts: Dict[OpKey, int] = {}
        self._miss_ops: Dict[OpKey, GemmOp] = {}
        self._hot: List[OpKey] = []  # FIFO of promoted, not-yet-tuned keys
        self._commits_since_rebuild = 0
        selector.on_miss = self.observe

    # -- miss ingestion (runs on the trace path; must stay cheap) ----------
    def observe(self, op: GemmOp, sel: Selection) -> None:
        """Selector miss hook: one call per dispatch that did not resolve
        from the tuning database."""
        key = op.key
        if key in self.db.records:
            return  # tuned between memoisation and notification
        self.stats.misses += 1
        count = self._miss_counts.get(key, 0) + 1
        self._miss_counts[key] = count
        self._miss_ops.setdefault(key, op)
        if count == self.cfg.hot_threshold:
            self._hot.append(key)
            self.stats.promoted += 1
        self._evict_overflow()

    def _evict_overflow(self) -> None:
        # the hot queue is bounded too (at hot_threshold=1 every miss
        # promotes, so the miss-table bound alone would be inert): when it
        # overflows, the stalest promotion goes first — a fingerprint that
        # waited max_pending promotions without being tuned is cold traffic
        while len(self._hot) > self.cfg.max_pending:
            stale = self._hot.pop(0)
            self._forget(stale)
            self.stats.evicted += 1
        while len(self._miss_counts) > self.cfg.max_pending + len(self._hot):
            coldest = None
            for key, count in self._miss_counts.items():
                if count >= self.cfg.hot_threshold:
                    continue  # promoted entries evict only via the hot bound
                if coldest is None or count < self._miss_counts[coldest]:
                    coldest = key
            if coldest is None:
                return
            self._forget(coldest)
            self.stats.evicted += 1

    def _forget(self, key: OpKey) -> None:
        self._miss_counts.pop(key, None)
        self._miss_ops.pop(key, None)

    # -- introspection ------------------------------------------------------
    @property
    def pending_hot(self) -> int:
        """Promoted fingerprints waiting for an adaptation round."""
        return len(self._hot)

    @property
    def tracked(self) -> int:
        """Distinct untuned fingerprints currently in the miss table."""
        return len(self._miss_counts)

    # -- adaptation rounds ---------------------------------------------------
    def adapt(self, budget_s: Optional[float] = None) -> int:
        """One adaptation round: tune up to ``max_tunes_per_step`` hot
        fingerprints (oldest promotion first) within the wallclock budget,
        commit the winners, and rebuild the sieve generation when due.
        Returns the number of records committed this round."""
        budget = budget_s if budget_s is not None else self.cfg.budget_s
        deadline = None if budget is None else time.perf_counter() + budget
        committed = 0
        while self._hot and committed < self.cfg.max_tunes_per_step:
            if deadline is not None and time.perf_counter() >= deadline:
                self.stats.budget_stops += 1
                break
            key = self._hot.pop(0)
            op = self._miss_ops.get(key)
            if op is None or key in self.db.records:
                self._forget(key)
                continue
            self._commit(op)
            committed += 1
        if self._commits_since_rebuild >= self.cfg.rebuild_every:
            self.rebuild_sieve()
        return committed

    def _commit(self, op: GemmOp) -> None:
        rec, per_policy = self.tuner.tune_size(op)
        self.db.add_record(rec, per_policy)
        if self.journal is not None:
            append_journal(self.journal, rec, per_policy)
        # drop the stale memoised sieve/fallback pick so the very next
        # dispatch of this fingerprint resolves from the database
        self.selector.hot_swap(keys=[rec.size])
        self._forget(rec.size)
        self.stats.adaptations += 1
        self._commits_since_rebuild += 1

    def drain(self, budget_s: Optional[float] = None) -> int:
        """Tune every pending hot fingerprint (end-of-run flush), then fold
        any un-sieved commits into a final generational rebuild."""
        total = 0
        while self._hot:
            n = self.adapt(budget_s=budget_s)
            if n == 0:
                break  # budget exhausted or nothing tunable
            total += n
        if self._commits_since_rebuild:
            self.rebuild_sieve()
        return total

    def rebuild_sieve(self) -> int:
        """Build a fresh sieve from the grown database under the next
        generation and hot-swap it into the selector (old sieve serves until
        the atomic swap; memoised non-tuned picks are dropped so no stale
        eliminated candidate survives the generation bump). Returns the new
        generation number."""
        generation = self.selector.sieve_generation + 1
        sieve = self.db.build_sieve(
            capacity=self.cfg.sieve_capacity,
            fp_rate=self.cfg.sieve_fp_rate,
            generation=generation,
        )
        # full cache invalidation: sieve/fallback picks memoised under the
        # old generation must not survive it, and tuned picks re-resolve
        # from the database for the cost of one dict hit
        self.selector.hot_swap(state=replace(self.selector.state, sieve=sieve), keys=None)
        self.stats.rebuilds += 1
        self._commits_since_rebuild = 0
        return generation
