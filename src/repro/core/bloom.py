"""Bloom filters over GEMM problem sizes (the paper's Open-sieve core).

The paper uses the mmh3 MurmurHash3 implementation to key (M, N, K) into
per-policy Bloom filters sized for 10,000 problem sizes each. mmh3 is not
installed in this container, so ``murmur3_32`` below is a from-scratch,
bit-exact reimplementation of MurmurHash3_x86_32 (validated against the
published reference vectors in tests). Filters use the standard Kirsch-
Mitzenmacher double-hashing scheme h_i = h1 + i*h2 so two murmur calls give
all k probes.

Bloom-filter contract exploited by the paper: NO false negatives ("100% true
negative rate") — if a filter answers "absent", the policy is definitely not
a tuned winner for that size and its evaluation can be skipped; false
positives only cost a redundant evaluation, never a wrong kernel result.
"""

from __future__ import annotations

import math
import struct
from dataclasses import dataclass
from typing import Iterable, Tuple

import numpy as np

_U32 = 0xFFFFFFFF


def _rotl32(x: int, r: int) -> int:
    return ((x << r) | (x >> (32 - r))) & _U32


def murmur3_32(data: bytes, seed: int = 0) -> int:
    """MurmurHash3_x86_32, bit-exact vs. the canonical C++/mmh3 (unsigned)."""
    c1, c2 = 0xCC9E2D51, 0x1B873593
    h = seed & _U32
    n_blocks = len(data) // 4
    for i in range(n_blocks):
        k = struct.unpack_from("<I", data, i * 4)[0]
        k = (k * c1) & _U32
        k = _rotl32(k, 15)
        k = (k * c2) & _U32
        h ^= k
        h = _rotl32(h, 13)
        h = (h * 5 + 0xE6546B64) & _U32
    # tail
    tail = data[n_blocks * 4 :]
    k = 0
    if len(tail) >= 3:
        k ^= tail[2] << 16
    if len(tail) >= 2:
        k ^= tail[1] << 8
    if len(tail) >= 1:
        k ^= tail[0]
        k = (k * c1) & _U32
        k = _rotl32(k, 15)
        k = (k * c2) & _U32
        h ^= k
    # finalization
    h ^= len(data)
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & _U32
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & _U32
    h ^= h >> 16
    return h


def encode_mnk(m: int, n: int, k: int) -> bytes:
    """Canonical little-endian key for a GEMM problem size."""
    return struct.pack("<3q", m, n, k)


def optimal_params(capacity: int, fp_rate: float) -> Tuple[int, int]:
    """(n_bits, n_hashes) for a target capacity and false-positive rate."""
    if capacity < 1 or not (0.0 < fp_rate < 1.0):
        raise ValueError("bad bloom parameters")
    n_bits = math.ceil(-capacity * math.log(fp_rate) / (math.log(2) ** 2))
    n_bits = max(64, n_bits)
    n_hashes = max(1, round((n_bits / capacity) * math.log(2)))
    return n_bits, n_hashes


@dataclass
class BloomFilter:
    """Fixed-size Bloom filter backed by a numpy uint8 bit array.

    ``seed`` makes each policy's filter an independent hash family — the
    paper's "7 distinct hash functions, one for each filter".
    """

    n_bits: int
    n_hashes: int
    seed: int = 0

    def __post_init__(self):
        if self.n_bits % 8:
            self.n_bits += 8 - self.n_bits % 8
        self.bits = np.zeros(self.n_bits // 8, dtype=np.uint8)
        self.n_items = 0

    @classmethod
    def for_capacity(cls, capacity: int = 10_000, fp_rate: float = 0.01, seed: int = 0):
        """Size a filter for ``capacity`` keys at a target FP rate."""
        n_bits, n_hashes = optimal_params(capacity, fp_rate)
        return cls(n_bits=n_bits, n_hashes=n_hashes, seed=seed)

    # -- probe schedule ----------------------------------------------------
    def _probes(self, key: bytes) -> Iterable[int]:
        h1 = murmur3_32(key, self.seed)
        h2 = murmur3_32(key, h1 ^ 0x9747B28C) | 1  # odd => full-cycle stride
        for i in range(self.n_hashes):
            # uint32 wraparound BEFORE the modulo: keeps the probe schedule
            # bit-identical to the C++/jnp uint32 implementations
            yield ((h1 + i * h2) & _U32) % self.n_bits

    # -- set ops -------------------------------------------------------------
    def add(self, key: bytes) -> None:
        """Insert a raw key (sets ``n_hashes`` bits; never fails)."""
        for p in self._probes(key):
            self.bits[p >> 3] |= 1 << (p & 7)
        self.n_items += 1

    def __contains__(self, key: bytes) -> bool:
        return all(self.bits[p >> 3] & (1 << (p & 7)) for p in self._probes(key))

    def add_mnk(self, m: int, n: int, k: int) -> None:
        """Insert a GEMM problem size under its canonical byte key."""
        self.add(encode_mnk(m, n, k))

    def query_mnk(self, m: int, n: int, k: int) -> bool:
        """Probe a GEMM problem size (True == "possibly present")."""
        return encode_mnk(m, n, k) in self

    # -- stats / codec ---------------------------------------------------------
    @property
    def saturation(self) -> float:
        """Fraction of set bits (FP rate ~= saturation ** n_hashes)."""
        return float(np.unpackbits(self.bits).mean())

    @property
    def est_items(self) -> float:
        """Distinct-key estimate from the bit saturation — the standard
        ``-(m/k) * ln(1 - X/m)`` Bloom cardinality estimator. Unlike
        ``n_items`` (an add-counter that double-counts duplicates, and after
        ``merge`` only an upper bound) this is dedupe-aware, so capacity
        planning should read this, not ``n_items``."""
        sat = self.saturation
        if sat >= 1.0:
            return float("inf")
        return -(self.n_bits / self.n_hashes) * math.log(1.0 - sat)

    @property
    def est_fp_rate(self) -> float:
        """Current false-positive probability estimate (saturation^k)."""
        return self.saturation**self.n_hashes

    def to_bytes(self) -> bytes:
        """Serialise to the versioned ``BLM1`` wire format."""
        head = struct.pack("<4sIIII", b"BLM1", self.n_bits, self.n_hashes, self.seed, self.n_items)
        return head + self.bits.tobytes()

    @classmethod
    def from_bytes(cls, blob: bytes) -> "BloomFilter":
        """Inverse of :meth:`to_bytes`; validates magic and payload length."""
        magic, n_bits, n_hashes, seed, n_items = struct.unpack_from("<4sIIII", blob)
        if magic != b"BLM1":
            raise ValueError("not a serialized BloomFilter")
        f = cls(n_bits=n_bits, n_hashes=n_hashes, seed=seed)
        if len(blob) - 20 != f.n_bits // 8:
            # a truncated blob would otherwise produce a filter whose bit
            # array is shorter than n_bits claims — every probe past the end
            # then raises IndexError, and merge would silently mis-combine
            raise ValueError(
                f"BloomFilter blob payload is {len(blob) - 20} bytes but "
                f"n_bits={f.n_bits} requires {f.n_bits // 8}"
            )
        f.bits = np.frombuffer(blob[20:], dtype=np.uint8).copy()
        f.n_items = n_items
        return f

    def params_str(self) -> str:
        """Human-readable parameter fingerprint (for mismatch diagnostics)."""
        return (
            f"BloomFilter(n_bits={self.n_bits}, n_hashes={self.n_hashes}, "
            f"seed={self.seed})"
        )

    def merge(self, other: "BloomFilter") -> "BloomFilter":
        """Bitwise-OR union of two filters over the SAME parameterisation.

        Filters are only unionable when n_bits, n_hashes, and seed all match
        — otherwise the probe schedules differ and the OR would answer
        "possibly present" for keys neither filter ever saw *and* lose the
        no-false-negative contract. Mismatches raise up front with both
        configurations named (a silent shape-broadcast or an IndexError deep
        in a later query is how this used to surface)."""
        if (self.n_bits, self.n_hashes, self.seed) != (
            other.n_bits,
            other.n_hashes,
            other.seed,
        ):
            raise ValueError(
                "cannot merge BloomFilters with mismatched parameters: "
                f"{self.params_str()} vs {other.params_str()}"
            )
        if self.bits.shape != other.bits.shape:
            raise ValueError(
                "cannot merge BloomFilters with mismatched bit arrays: "
                f"{self.bits.shape[0]} vs {other.bits.shape[0]} bytes "
                f"(both claim n_bits={self.n_bits})"
            )
        out = BloomFilter(self.n_bits, self.n_hashes, self.seed)
        out.bits = self.bits | other.bits
        # The merge is dedupe-agnostic (bitwise OR cannot tell how many keys
        # the two filters shared), so the summed count is only an UPPER
        # bound on distinct keys — overlapping key sets double-count. Read
        # ``est_items`` (saturation-based) for occupancy/capacity planning.
        out.n_items = self.n_items + other.n_items
        return out
