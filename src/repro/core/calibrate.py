"""Calibration: fit the analytical cost model against journaled wall clocks.

The cost model (:mod:`repro.core.costmodel`) ships with nominal TPU-v5e
constants. Real machines differ — and the tuning journal already holds
thousands of ``(fingerprint, policy, cfg, g) -> wall`` measurements (every
:class:`~repro.core.tuner.TuningRecord` stores the winner's measured
TFLOP/s, from which the wall clock is ``flops / (tflops * 1e12)``). This
module closes the loop: decompose each record's modeled time into the four
machine terms —

  * lane FLOP/s            (``Machine.peak_flops``),
  * lane HBM bandwidth     (``Machine.hbm_bw``),
  * launch overhead        (``Machine.launch_overhead_s``),
  * fix-up serialization   (``Machine.fixup_serial_s``),

— and solve the robust weighted least-squares problem ``wall_i ≈ C_i · θ``
per *dtype profile* (a mixed ``f32*int8`` op moves different bytes than an
f32 one, so its bandwidth term calibrates separately). The model's
``max(compute, memory)`` per-iteration roofline and the HYBRID
fix-up/DP-overlap ``max`` are handled by active-set iteration: branches are
chosen under the current estimate, the resulting *linear* system is solved
(inverse-parameterised, Huber-weighted on relative residuals), and the loop
repeats until the branch set stabilises.

The result is a :class:`CalibratedMachine`: one fitted
:class:`~repro.core.costmodel.Machine` per dtype profile plus a base
fallback. It is hashable/frozen — scoring caches key on the Machine
instance, so installing a calibration can never read stale default-``V5E``
scores — and it persists as its own journal entry type
(:func:`calibration_entry` / ``TuningDatabase.replay_journal``), merged
across a fleet in :mod:`repro.core.federate` under the same hybrid
``(wall, version)`` last-writer-wins stamps as tuning records.

Fitting refuses to run under :data:`MIN_RECORDS` usable records — a fit on
a handful of points would happily produce garbage coefficients that then
steer every model-first dispatch.
"""

from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import costmodel
from repro.core.costmodel import DtypeBytes, Machine, V5E
from repro.core.policies import TileConfig, policy_from_name
from repro.core.tuner import TuningRecord, _key_shape
from repro.core.workpart import GemmShape, cdiv, partition_stats
from repro.utils.logging import get_logger

log = get_logger("calibrate")

#: minimum usable records per dtype profile before a fit is attempted —
#: below this the solver is refused outright (CalibrationError), because a
#: sparse fit produces confident nonsense that model-first dispatch would
#: then launch.
MIN_RECORDS = 16

#: Huber threshold on *relative* residuals: records within 10% of the model
#: get full weight, outliers are down-weighted proportionally.
_HUBER_DELTA = 0.1

_CFG_CACHE: Dict[str, TileConfig] = {}


class CalibrationError(ValueError):
    """Raised when a fit is refused (too few records, no usable walls)."""


def profile_key(dt: DtypeBytes) -> str:
    """Canonical string key of a byte-width profile (``"a:b:out:acc"``)."""
    return f"{dt.a}:{dt.b}:{dt.out}:{dt.acc}"


def key_dtypes(key) -> DtypeBytes:
    """Byte-width profile a database key measured under: bare (M, N, K)
    keys tuned at the f32 profile (the tuner's ``_BARE_KEY_DTYPES``
    contract), extended keys carry their dtypes in positions 4/5."""
    if len(key) == 3:
        return costmodel.profile_for("float32", "float32")
    return costmodel.profile_for(key[4], key[5])


def record_wall_s(key, rec: TuningRecord) -> Optional[float]:
    """Measured wall clock one record encodes (``flops / tflops``), or
    ``None`` when the record carries no usable measurement."""
    if rec.tflops <= 0:
        return None
    shape = _key_shape(key, key)
    return shape.flops / (rec.tflops * 1e12)


def _cfg(name: str) -> TileConfig:
    cfg = _CFG_CACHE.get(name)
    if cfg is None:
        bm, bn, bk = (int(x) for x in name.split("x"))
        cfg = _CFG_CACHE.setdefault(name, TileConfig(bm, bn, bk))
    return cfg


# ---------------------------------------------------------------------------
# CalibratedMachine
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CalibratedMachine:
    """Per-dtype-profile fitted machines + the base fallback.

    Frozen and hashable: resolving ``machine_for(dt)`` yields a plain
    (frozen) :class:`Machine` that participates in every scoring-cache key,
    so two calibrations can never alias each other's memoised scores.
    ``(wall, version)`` is the hybrid federation stamp — identical
    semantics to :class:`~repro.core.tuner.TuningRecord`'s."""

    base: Machine = V5E
    #: sorted (profile_key, fitted Machine) pairs
    profiles: Tuple[Tuple[str, Machine], ...] = ()
    n_records: int = 0  # journal records the fit consumed
    residual: float = 0.0  # median |relative residual| across fitted profiles
    wall: float = 0.0  # hybrid LWW stamp (see TuningRecord.wall)
    version: int = 0
    #: arch class the walls behind this fit were measured on (see
    #: :mod:`repro.core.arch`): ``TuningDatabase.set_calibration`` installs
    #: same-class fits locally and routes foreign-class ones to the
    #: per-class side table — a sibling generation's constants must never
    #: steer local model-first dispatch. Legacy fits parse as "default".
    arch: str = "default"

    def machine_for(self, dt: DtypeBytes) -> Machine:
        """Fitted machine for a byte-width profile (base when unfitted)."""
        key = profile_key(dt)
        for k, m in self.profiles:
            if k == key:
                return m
        return self.base

    @property
    def fitted_profiles(self) -> Tuple[str, ...]:
        """Profile keys that actually fitted (vs. falling back to base)."""
        return tuple(k for k, _ in self.profiles)


# ---------------------------------------------------------------------------
# feature decomposition (mirrors costmodel.gemm_time_s term by term)
# ---------------------------------------------------------------------------


def _features(
    shape: GemmShape,
    cfg: TileConfig,
    policy,
    g: int,
    dt: DtypeBytes,
    mach: Machine,
) -> np.ndarray:
    """One record's design row ``[c_invpeak, c_invbw, c_launch, c_fixup]``
    such that modeled time = row · (1/peak_flops, 1/hbm_bw, launch, fixup).

    The two ``max`` nonlinearities in the model (per-iteration roofline,
    HYBRID fix-up/DP overlap) are resolved under ``mach`` — the caller's
    current estimate — making the system linear for one active-set step."""
    st = partition_stats(shape, cfg, g, policy)
    mult = cdiv(g, mach.lanes)
    iter_flops = 2 * cfg.bm * cfg.bn * cfg.bk
    iter_bytes = cfg.bm * cfg.bk * dt.a + cfg.bk * cfg.bn * dt.b
    # one lane's iteration cost = max(iter_flops*lanes/peak, iter_bytes*lanes/bw)
    compute_bound = (
        iter_flops * mach.lanes / mach.peak_flops
        >= iter_bytes * mach.lanes / mach.hbm_bw
    )
    per_iter = np.zeros(4)
    if compute_bound:
        per_iter[0] = iter_flops * mach.lanes
    else:
        per_iter[1] = iter_bytes * mach.lanes

    row = np.zeros(4)
    row[2] = 1.0  # launch overhead
    row[1] += st.n_tiles_total * cfg.bm * cfg.bn * dt.out  # output writeback

    fix = np.zeros(4)
    fix[1] = st.extra_contributors * cfg.bm * cfg.bn * dt.acc * 2
    fix[3] = st.n_split_tiles

    if st.sk_tiles:
        row += cdiv(st.sk_total_iters, g) * mult * per_iter
        dp_units = st.dp_waves * mult * st.iters_per_tile
        if st.dp_tiles:
            # overlap: the slower of (DP phase, fix-up) under current mach
            t_iter = max(
                iter_flops / mach.lane_flops, iter_bytes / mach.lane_bw
            )
            dp_t = dp_units * t_iter
            fix_t = fix[1] / mach.hbm_bw + fix[3] * mach.fixup_serial_s
            row += dp_units * per_iter if dp_t >= fix_t else fix
        else:
            row += fix
    else:
        row += st.dp_waves * mult * st.iters_per_tile * per_iter
    return row


def _theta(mach: Machine) -> np.ndarray:
    return np.array(
        [
            1.0 / mach.peak_flops,
            1.0 / mach.hbm_bw,
            mach.launch_overhead_s,
            mach.fixup_serial_s,
        ]
    )


def _machine(theta: np.ndarray, base: Machine) -> Machine:
    return dataclasses.replace(
        base,
        peak_flops=float(1.0 / theta[0]),
        hbm_bw=float(1.0 / theta[1]),
        launch_overhead_s=float(max(theta[2], 0.0)),
        fixup_serial_s=float(max(theta[3], 0.0)),
    )


def fit_profile(
    samples: Sequence[Tuple[GemmShape, TileConfig, object, int]],
    walls: Sequence[float],
    dt: DtypeBytes,
    base: Machine = V5E,
    max_iters: int = 12,
    min_records: int = MIN_RECORDS,
) -> Tuple[Machine, float]:
    """Fit one dtype profile's machine terms against measured walls.

    Active-set IRLS: resolve the model's ``max`` branches under the current
    estimate, solve the weighted linear system (weights ``1/wall²`` so
    microsecond decode GEMMs count as much as millisecond trainers, times a
    Huber factor on relative residuals), repeat until the estimate is
    stable. Terms the data cannot identify (e.g. ``fixup_serial_s`` when no
    record has split tiles) are pinned to ``base``'s values. Returns the
    fitted machine and the median |relative residual|."""
    if len(samples) < min_records:
        raise CalibrationError(
            f"refusing to fit on {len(samples)} records (< {min_records})"
        )
    y = np.asarray(walls, dtype=np.float64)
    theta = _theta(base)
    rel = np.zeros(len(y))
    for _ in range(max_iters):
        mach = _machine(theta, base)
        C = np.stack(
            [_features(s, cfg, pol, g, dt, mach) for s, cfg, pol, g in samples]
        )
        w = 1.0 / np.maximum(y, 1e-12)
        huber = np.where(
            np.abs(rel) <= _HUBER_DELTA,
            1.0,
            _HUBER_DELTA / np.maximum(np.abs(rel), 1e-12),
        )
        w = w * np.sqrt(huber)
        # identifiability: pin columns the data never excites to base
        col_scale = np.abs(C * w[:, None]).sum(axis=0)
        active = col_scale > 1e-9 * max(col_scale.max(), 1e-300)
        y_eff = y - C[:, ~active] @ theta[~active]
        sol, *_ = np.linalg.lstsq(
            C[:, active] * w[:, None], y_eff * w, rcond=None
        )
        new = theta.copy()
        new[active] = sol
        # positivity: rate terms must stay invertible, additive terms >= 0
        new[0] = max(new[0], 1e-18)
        new[1] = max(new[1], 1e-15)
        new[2] = max(new[2], 0.0)
        new[3] = max(new[3], 0.0)
        pred = C @ new
        rel = (pred - y) / np.maximum(y, 1e-12)
        if np.all(np.abs(new - theta) <= 1e-9 * np.maximum(np.abs(theta), 1e-30)):
            theta = new
            break
        theta = new
    return _machine(theta, base), float(np.median(np.abs(rel)))


def calibrate_records(
    records: Iterable[Tuple[object, TuningRecord]],
    base: Machine = V5E,
    min_records: int = MIN_RECORDS,
    arch: str = "default",
) -> CalibratedMachine:
    """Fit a :class:`CalibratedMachine` from ``(key, record)`` pairs.

    Records group by dtype profile; each group with at least
    ``min_records`` usable walls fits its own machine, smaller groups fall
    back to ``base`` at resolve time. Raises :class:`CalibrationError` when
    *no* profile reaches the floor — the caller must not install an
    unfitted calibration believing it learned something."""
    groups: Dict[str, List] = {}
    walls: Dict[str, List[float]] = {}
    n_used = 0
    for key, rec in records:
        wall = record_wall_s(key, rec)
        if wall is None:
            continue
        try:
            shape = _key_shape(key, key)
            cfg = _cfg(rec.cfg)
            pol = policy_from_name(rec.policy)
        except (ValueError, TypeError):
            continue
        dt = key_dtypes(key)
        pk = profile_key(dt)
        groups.setdefault(pk, []).append((shape, cfg, pol, rec.g))
        walls.setdefault(pk, []).append(wall)
        n_used += 1
    profiles: List[Tuple[str, Machine]] = []
    residuals: List[float] = []
    for pk in sorted(groups):
        if len(groups[pk]) < min_records:
            log.info(
                "profile %s: %d records < %d floor, falling back to base",
                pk,
                len(groups[pk]),
                min_records,
            )
            continue
        dt = DtypeBytes(*(int(x) for x in pk.split(":")))
        mach, resid = fit_profile(
            groups[pk], walls[pk], dt, base=base, min_records=min_records
        )
        profiles.append((pk, mach))
        residuals.append(resid)
        log.info(
            "profile %s: fitted on %d records (peak %.1f TF/s, bw %.0f GB/s, "
            "launch %.2fus, fixup %.2fus, median |rel resid| %.3f)",
            pk,
            len(groups[pk]),
            mach.peak_flops / 1e12,
            mach.hbm_bw / 1e9,
            mach.launch_overhead_s * 1e6,
            mach.fixup_serial_s * 1e6,
            resid,
        )
    if not profiles:
        raise CalibrationError(
            f"no dtype profile reached {min_records} usable records "
            f"({n_used} total across {len(groups)} profiles)"
        )
    return CalibratedMachine(
        base=base,
        profiles=tuple(profiles),
        n_records=n_used,
        residual=float(np.median(residuals)),
        arch=arch,
    )


def calibrate_db(
    db, base: Machine = V5E, min_records: int = MIN_RECORDS
) -> CalibratedMachine:
    """Fit from a :class:`~repro.core.tuner.TuningDatabase`'s OWN-class
    records (foreign-class ``xarch`` records measured other hardware —
    folding their walls in would corrupt the local constants); the fit is
    stamped with the database's arch class."""
    return calibrate_records(
        db.records.items(), base=base, min_records=min_records, arch=db.arch
    )


def calibrate_journal(
    path: str,
    base: Machine = V5E,
    min_records: int = MIN_RECORDS,
    arch: str = "default",
) -> CalibratedMachine:
    """Fit from an append-only tuning journal (replayed, later lines win).
    ``arch`` is the local class: only same-class journal records feed the
    fit (they land in ``records``; foreign lines route to ``xarch``)."""
    from repro.core.tuner import TuningDatabase

    db = TuningDatabase(arch=arch)
    db.replay_journal(path)
    return calibrate_db(db, base=base, min_records=min_records)


# ---------------------------------------------------------------------------
# persistence: the calibration journal entry type
# ---------------------------------------------------------------------------


def machine_to_json(mach: Machine) -> dict:
    """JSON form of a Machine (plain field dict)."""
    return dataclasses.asdict(mach)


def machine_from_json(d: dict, base: Machine = V5E) -> Machine:
    """Inverse of :func:`machine_to_json`; unknown fields are rejected so a
    format skew fails loudly, missing fields inherit ``base``."""
    names = {f.name for f in dataclasses.fields(Machine)}
    extra = set(d) - names
    if extra:
        raise ValueError(f"unknown Machine fields {sorted(extra)}")
    return dataclasses.replace(base, **d)


def calibration_to_json(cm: CalibratedMachine) -> dict:
    """JSON payload of a calibration (the journal entry body).
    Default-class fits serialize without the ``arch`` field, byte-identical
    to the pre-arch format."""
    out = {
        "base": machine_to_json(cm.base),
        "profiles": {k: machine_to_json(m) for k, m in cm.profiles},
        "n_records": cm.n_records,
        "residual": cm.residual,
        "wall": cm.wall,
        "version": cm.version,
    }
    if cm.arch != "default":
        out["arch"] = cm.arch
    return out


def calibration_from_json(d: dict) -> CalibratedMachine:
    """Inverse of :func:`calibration_to_json` (arch-less legacy payloads
    parse into the ``"default"`` class)."""
    base = machine_from_json(d["base"])
    return CalibratedMachine(
        base=base,
        profiles=tuple(
            (k, machine_from_json(m, base=base))
            for k, m in sorted(d.get("profiles", {}).items())
        ),
        n_records=int(d.get("n_records", 0)),
        residual=float(d.get("residual", 0.0)),
        wall=float(d.get("wall", 0.0)),
        version=int(d.get("version", 0)),
        arch=str(d.get("arch", "default")),
    )


def better_calibration(
    a: Optional[CalibratedMachine], b: Optional[CalibratedMachine]
) -> Optional[CalibratedMachine]:
    """Deterministic last-writer-wins winner between two calibrations.

    Orders on the hybrid ``(wall, version)`` stamp first — the same order
    tuning records federate under — then ``n_records`` (more data wins a
    stamp tie), then the serialized payload as the final
    arbitrary-but-stable arbiter, so merges commute whatever order shards
    arrive in. ``None`` loses to anything."""
    if a is None:
        return b
    if b is None:
        return a

    def _key(cm: CalibratedMachine):
        return (
            cm.wall,
            cm.version,
            cm.n_records,
            json.dumps(calibration_to_json(cm), sort_keys=True),
        )

    return a if _key(a) >= _key(b) else b


def calibration_entry(cm: CalibratedMachine) -> str:
    """One journal line carrying a calibration — the second entry type the
    tuning journal understands (``TuningDatabase.replay_journal`` applies
    it under last-writer-wins against any calibration already installed)."""
    return json.dumps({"calibration": calibration_to_json(cm)})


def append_calibration(path: str, cm: CalibratedMachine) -> None:
    """Append a calibration entry to the JSONL journal."""
    with open(path, "a") as f:
        f.write(calibration_entry(cm) + "\n")
