"""Quantized weight tensors for the int8-weight serving path.

The cost model has scored mixed activation x weight profiles (``"a*w"``
dtype fingerprints) since the dtype-aware PR, but until now no kernel could
*execute* them: every low-precision fingerprint the selector could reason
about was a scenario the system could not serve. :class:`QuantizedTensor`
closes that gap — a weight matrix stored as int8 values plus per-output-
channel f32 scales (symmetric, zero-point-free), dequantized inside the
GEMM kernels as a fused epilogue stage:

    C = (A @ V) * s        # s broadcast over the N (output-channel) axis

which is exact algebra for per-output-channel scales — ``A @ (V * s)``
factors column-wise — so the kernel accumulates the raw int8 weights (B
operand moves 1 byte/element through HBM, the actual serving win in the
skinny-M decode regime) and applies ``s`` once per output tile at the
DP-flush / Stream-K fix-up, composing in front of the existing
bias/activation/binary epilogues.

Layout contract: weights are stored ``(..., K, N)`` — contraction axis
second-to-last — matching every projection in ``repro.models`` (attention
``(d, h*dh)``, MLP ``(d, f)``/``(f, d)``, stacked MoE experts ``(E, d, f)``
and scan-stacked ``(L, ..., K, N)``). Scales drop exactly the K axis:
``scales.shape == values.shape[:-2] + values.shape[-1:]``.

``QuantizedTensor`` is a registered JAX pytree whose leading axes slice
consistently across both leaves, so scan-stacked layer parameters, pytree
donation, and ``jax.tree.map``-based cache/parameter surgery all work
unchanged — a quantized weight leaf is a drop-in replacement for the dense
array anywhere it feeds :func:`repro.core.gemm.gemm`.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

#: int8 symmetric range: +-127 (the -128 code is unused so the range is
#: symmetric and negation is exact).
_QMAX = 127.0

#: parameter-tree keys :func:`quantize_lm_params` converts: the dense
#: projection weights every ``repro.models`` architecture routes through
#: ``repro.core.gemm`` with a (..., K, N) layout. Routers, norms and the
#: embedding table stay full precision (tiny, precision-critical, or used
#: as a gather table / transposed tied head rather than a GEMM B operand).
QUANT_WEIGHT_NAMES = frozenset(
    {"wq", "wk", "wv", "wo", "w_in", "w_out", "w_gate", "lm_head"}
)


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """Symmetric per-output-channel int8 weight: ``values`` (..., K, N) int8
    + ``scales`` (..., N) f32. ``dequantize()`` reconstructs the dense
    weight; the GEMM kernels never do — they fuse the scale into their
    flush/fix-up epilogue instead."""

    def __init__(self, values: jax.Array, scales: jax.Array):
        values_shape = jnp.shape(values)
        scales_shape = jnp.shape(scales)
        if len(values_shape) < 2:
            raise ValueError(
                f"QuantizedTensor values must be at least 2-D (..., K, N); "
                f"got shape {values_shape}"
            )
        want = values_shape[:-2] + values_shape[-1:]
        if tuple(scales_shape) != tuple(want):
            raise ValueError(
                f"scale shape {scales_shape} does not match values "
                f"{values_shape}: per-output-channel scales must drop "
                f"exactly the contraction axis -> expected {want}"
            )
        self.values = values
        self.scales = scales

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        """Pytree leaves: (values, scales); no static aux data."""
        return (self.values, self.scales), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from pytree leaves without re-validating shapes."""
        values, scales = children
        # jit/scan internals flatten through with tracers/placeholder leaves
        # whose shapes may be unavailable mid-transform: rebuild without
        # re-validating (construction already validated the concrete tree)
        obj = cls.__new__(cls)
        obj.values = values
        obj.scales = scales
        return obj

    # -- array-like surface (what gemm/model plumbing touches) -------------
    @property
    def shape(self) -> Tuple[int, ...]:
        """Shape of the int8 values (what GEMM plumbing sizes against)."""
        return tuple(self.values.shape)

    @property
    def ndim(self) -> int:
        """Rank of the int8 values."""
        return self.values.ndim

    @property
    def dtype(self):
        """Storage dtype of the values (int8) — NOT the compute dtype."""
        return self.values.dtype

    def __repr__(self) -> str:
        return (
            f"QuantizedTensor(values={self.values.shape}:{self.values.dtype}, "
            f"scales={self.scales.shape})"
        )

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Dense reconstruction ``V * s`` — the reference the differential
        numerics harness compares the fused kernels against."""
        w = self.values.astype(jnp.float32) * self.scales[..., None, :].astype(
            jnp.float32
        )
        return w.astype(dtype)


def is_quantized(x: Any) -> bool:
    """True iff ``x`` is a :class:`QuantizedTensor` weight leaf."""
    return isinstance(x, QuantizedTensor)


def quantize_weight(w: jax.Array, *, axis: int = -2) -> QuantizedTensor:
    """Symmetric per-output-channel int8 quantization of a (..., K, N)
    weight; ``axis`` is the contraction axis the scale reduces over.

    Round-to-nearest: the worst-case elementwise reconstruction error is
    ``scale / 2`` where ``scale = amax / 127`` per output channel — the
    bound the property tests assert and the differential tolerances build
    on."""
    if w.ndim < 2:
        raise ValueError(f"quantize_weight expects a matrix, got shape {w.shape}")
    axis = axis % w.ndim
    if axis != w.ndim - 2:
        raise ValueError(
            f"contraction axis must be -2 in the (..., K, N) layout; got "
            f"axis {axis} for shape {w.shape}"
        )
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis)
    scales = jnp.maximum(amax, 1e-8) / _QMAX
    q = jnp.clip(
        jnp.round(wf / scales[..., None, :]), -_QMAX, _QMAX
    ).astype(jnp.int8)
    return QuantizedTensor(q, scales)


def quantize_lm_params(
    params: Dict[str, Any], names: frozenset = QUANT_WEIGHT_NAMES
) -> Tuple[Dict[str, Any], int]:
    """One-shot weight quantization at model load (the serve CLI's
    ``--quantize int8``): every dense float leaf under a key in ``names``
    becomes a :class:`QuantizedTensor`; everything else is untouched.
    Returns (new tree, number of leaves quantized). Scan-stacked leaves
    ``(L, ..., K, N)`` quantize per layer per output channel — the leading
    axes ride along in the scale shape, so ``lax.scan`` slices both leaves
    coherently."""
    n_quantized = 0

    def walk(node):
        nonlocal n_quantized
        if isinstance(node, dict):
            out = {}
            for key, sub in node.items():
                if (
                    key in names
                    and not isinstance(sub, dict)
                    and not is_quantized(sub)
                    and hasattr(sub, "ndim")
                    and sub.ndim >= 2
                    and jnp.issubdtype(jnp.asarray(sub).dtype, jnp.floating)
                ):
                    out[key] = quantize_weight(sub)
                    n_quantized += 1
                else:
                    out[key] = walk(sub)
            return out
        return node

    return walk(params), n_quantized
