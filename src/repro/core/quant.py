"""Quantized weight tensors for the low-precision serving ladder.

The cost model has scored mixed activation x weight profiles (``"a*w"``
dtype fingerprints) since the dtype-aware PR, but until now no kernel could
*execute* them: every low-precision fingerprint the selector could reason
about was a scenario the system could not serve. :class:`QuantizedTensor`
closes that gap — a weight matrix stored as int8 values plus per-output-
channel f32 scales (symmetric, zero-point-free), dequantized inside the
GEMM kernels as a fused epilogue stage:

    C = (A @ V) * s        # s broadcast over the N (output-channel) axis

which is exact algebra for per-output-channel scales — ``A @ (V * s)``
factors column-wise — so the kernel accumulates the raw int8 weights (B
operand moves 1 byte/element through HBM, the actual serving win in the
skinny-M decode regime) and applies ``s`` once per output tile at the
DP-flush / Stream-K fix-up, composing in front of the existing
bias/activation/binary epilogues.

The ladder has three rungs below dense:

* ``bits=8`` (PR 5): int8 weights, float activations — ``"<act>*int8"``
  fingerprints.
* ``bits=8, act_bits=8``: int8 weights AND dynamically quantized int8
  activations (symmetric per-row scales, computed at dispatch time by
  :func:`quantize_activations`). The kernels accumulate int8 x int8 on the
  MXU in int32 and apply the rank-1 rescale ``s_a (x) s_b`` on the f32
  accumulator at the flush — ``"int8*int8"`` fingerprints, halving A
  traffic too.
* ``bits=4``: weights packed two nibbles per byte along K
  (:func:`pack_int4` / :func:`unpack_int4`); the kernels unpack each
  ``(bk/2, bn)`` packed block to int8 in the prologue, so B moves 0.5
  bytes/element through HBM — ``"<act>*int4"`` fingerprints.

Layout contract: weights are stored ``(..., K, N)`` — contraction axis
second-to-last — matching every projection in ``repro.models`` (attention
``(d, h*dh)``, MLP ``(d, f)``/``(f, d)``, stacked MoE experts ``(E, d, f)``
and scan-stacked ``(L, ..., K, N)``). Scales drop exactly the K axis:
``scales.shape == values.shape[:-2] + values.shape[-1:]`` (for ``bits=4``
the stored K axis is the packed ``ceil(K/2)``; :attr:`QuantizedTensor.shape`
reports the logical K).

``QuantizedTensor`` is a registered JAX pytree whose leading axes slice
consistently across both leaves (``bits``/``act_bits``/logical K travel as
static aux data), so scan-stacked layer parameters, pytree donation, and
``jax.tree.map``-based cache/parameter surgery all work unchanged — a
quantized weight leaf is a drop-in replacement for the dense array anywhere
it feeds :func:`repro.core.gemm.gemm`.
"""

from __future__ import annotations

import logging
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

log = logging.getLogger(__name__)

#: int8 symmetric range: +-127 (the -128 code is unused so the range is
#: symmetric and negation is exact).
_QMAX = 127.0

#: int4 symmetric range: +-7 (the -8 nibble is unused, mirroring int8).
_QMAX4 = 7.0

#: parameter-tree keys :func:`quantize_lm_params` converts: the dense
#: projection weights every ``repro.models`` architecture routes through
#: ``repro.core.gemm`` with a (..., K, N) layout. Routers, norms and the
#: embedding table stay full precision (tiny, precision-critical, or used
#: as a gather table / transposed tied head rather than a GEMM B operand).
QUANT_WEIGHT_NAMES = frozenset(
    {"wq", "wk", "wv", "wo", "w_in", "w_out", "w_gate", "lm_head"}
)


# ---------------------------------------------------------------------------
# int4 nibble packing (two values per byte along K)
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack an int8 array of int4-range values ``(..., K, N)`` into
    ``(..., ceil(K/2), N)`` bytes: even-k values in the low nibble, odd-k in
    the high nibble. Odd K zero-pads one trailing k row (exact for GEMM)."""
    if q.ndim < 2:
        raise ValueError(f"pack_int4 expects (..., K, N), got shape {q.shape}")
    k = q.shape[-2]
    if k % 2:
        pads = [(0, 0)] * (q.ndim - 2) + [(0, 1), (0, 0)]
        q = jnp.pad(q, pads)
    lo = q[..., 0::2, :].astype(jnp.int32) & 0xF
    hi = (q[..., 1::2, :].astype(jnp.int32) & 0xF) << 4
    # (lo | hi) spans 0..255; the int8 cast truncates to the raw byte.
    return (lo | hi).astype(jnp.int8)


def unpack_int4(p: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`: ``(..., K2, N)`` packed bytes ->
    ``(..., 2*K2, N)`` int8 values in [-8, 7]. Pure jnp (shift + interleave),
    so the same function runs on the host AND inside the kernel prologues —
    one definition of the nibble layout everywhere."""
    p32 = p.astype(jnp.int32)
    lo = (p32 << 28) >> 28  # arithmetic shifts sign-extend each nibble
    hi = (p32 << 24) >> 28
    stacked = jnp.stack([lo, hi], axis=-2)  # (..., K2, 2, N)
    k2, n = p.shape[-2], p.shape[-1]
    out = stacked.reshape(*p.shape[:-2], 2 * k2, n)
    return out.astype(jnp.int8)


@jax.tree_util.register_pytree_node_class
class QuantizedTensor:
    """Symmetric per-output-channel quantized weight.

    ``bits=8``: ``values`` (..., K, N) int8 + ``scales`` (..., N) f32.
    ``bits=4``: ``values`` (..., ceil(K/2), N) int8 — two nibbles per byte
    along K (see :func:`pack_int4`) — with the logical contraction length
    carried as static ``k``. ``act_bits=8`` requests dynamic per-row int8
    activation quantization at dispatch time (the int8 x int8 MXU rung).

    ``dequantize()`` reconstructs the dense weight; the GEMM kernels never
    do — they unpack packed nibbles in the prologue and fuse the scale into
    their flush/fix-up epilogue instead."""

    def __init__(
        self,
        values: jax.Array,
        scales: jax.Array,
        *,
        bits: int = 8,
        act_bits: Optional[int] = None,
        k: Optional[int] = None,
    ):
        if bits not in (8, 4):
            raise ValueError(f"QuantizedTensor supports bits in (8, 4), got {bits}")
        if act_bits not in (None, 8):
            raise ValueError(f"act_bits must be None or 8, got {act_bits}")
        values_shape = jnp.shape(values)
        scales_shape = jnp.shape(scales)
        if len(values_shape) < 2:
            raise ValueError(
                f"QuantizedTensor values must be at least 2-D (..., K, N); "
                f"got shape {values_shape}"
            )
        if bits == 4:
            if k is None:
                raise ValueError(
                    "bits=4 stores the packed ceil(K/2) axis; pass the "
                    "logical contraction length k="
                )
            if (k + 1) // 2 != values_shape[-2]:
                raise ValueError(
                    f"packed values K axis {values_shape[-2]} does not match "
                    f"ceil(k/2) for logical k={k}"
                )
        else:
            k = int(values_shape[-2])
        want = values_shape[:-2] + values_shape[-1:]
        if tuple(scales_shape) != tuple(want):
            raise ValueError(
                f"scale shape {scales_shape} does not match values "
                f"{values_shape}: per-output-channel scales must drop "
                f"exactly the contraction axis -> expected {want}"
            )
        self.values = values
        self.scales = scales
        self.bits = int(bits)
        self.act_bits = act_bits
        self.k = int(k)

    # -- pytree protocol ---------------------------------------------------
    def tree_flatten(self):
        """Pytree leaves: (values, scales); (bits, act_bits, k) are static."""
        return (self.values, self.scales), (self.bits, self.act_bits, self.k)

    @classmethod
    def tree_unflatten(cls, aux, children):
        """Rebuild from pytree leaves without re-validating shapes."""
        values, scales = children
        # jit/scan internals flatten through with tracers/placeholder leaves
        # whose shapes may be unavailable mid-transform: rebuild without
        # re-validating (construction already validated the concrete tree)
        obj = cls.__new__(cls)
        obj.values = values
        obj.scales = scales
        if aux is None:  # trees flattened by pre-int4 producers
            aux = (8, None, None)
        obj.bits, obj.act_bits, obj.k = aux
        return obj

    # -- array-like surface (what gemm/model plumbing touches) -------------
    @property
    def shape(self) -> Tuple[int, ...]:
        """LOGICAL weight shape (..., K, N) — for ``bits=4`` the stored
        values axis is the packed ``ceil(K/2)``, but GEMM plumbing sizes
        against the contraction length the kernels actually reduce over."""
        vs = tuple(self.values.shape)
        if self.bits == 4:
            return vs[:-2] + (self.k, vs[-1])
        return vs

    @property
    def ndim(self) -> int:
        """Rank of the values (leading axes are shared with scales)."""
        return self.values.ndim

    @property
    def dtype(self):
        """Storage dtype of the values (int8 bytes) — NOT the compute dtype."""
        return self.values.dtype

    @property
    def dtype_name(self) -> str:
        """Fingerprint dtype component: ``"int4"`` for packed nibbles, else
        the storage dtype name (``"int8"``)."""
        return "int4" if self.bits == 4 else str(self.values.dtype)

    def __repr__(self) -> str:
        return (
            f"QuantizedTensor(values={self.values.shape}:{self.values.dtype}, "
            f"scales={self.scales.shape}, bits={self.bits}"
            + (f", act_bits={self.act_bits}" if self.act_bits else "")
            + ")"
        )

    def dequantize(self, dtype=jnp.float32) -> jax.Array:
        """Dense reconstruction ``V * s`` — the reference the differential
        numerics harness compares the fused kernels against. ``bits=4``
        unpacks the nibbles and drops the zero-pad row of an odd K."""
        v = self.values
        if self.bits == 4:
            v = unpack_int4(v)[..., : self.k, :]
        w = v.astype(jnp.float32) * self.scales[..., None, :].astype(jnp.float32)
        return w.astype(dtype)


def is_quantized(x: Any) -> bool:
    """True iff ``x`` is a :class:`QuantizedTensor` weight leaf."""
    return isinstance(x, QuantizedTensor)


def quantize_weight(
    w: jax.Array,
    *,
    axis: int = -2,
    bits: int = 8,
    act_bits: Optional[int] = None,
) -> QuantizedTensor:
    """Symmetric per-output-channel quantization of a (..., K, N) weight;
    ``axis`` is the contraction axis the scale reduces over.

    Round-to-nearest: the worst-case elementwise reconstruction error is
    ``scale / 2`` where ``scale = amax / qmax`` per output channel
    (``qmax`` 127 for int8, 7 for int4) — the bound the property tests
    assert and the differential tolerances build on. ``bits=4`` packs two
    nibbles per byte along K; ``act_bits=8`` marks the weight for dynamic
    int8 activation quantization at dispatch time."""
    if w.ndim < 2:
        raise ValueError(f"quantize_weight expects a matrix, got shape {w.shape}")
    axis = axis % w.ndim
    if axis != w.ndim - 2:
        raise ValueError(
            f"contraction axis must be -2 in the (..., K, N) layout; got "
            f"axis {axis} for shape {w.shape}"
        )
    qmax = _QMAX if bits == 8 else _QMAX4
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=axis)
    scales = jnp.maximum(amax, 1e-8) / qmax
    q = jnp.clip(jnp.round(wf / scales[..., None, :]), -qmax, qmax).astype(
        jnp.int8
    )
    if bits == 4:
        return QuantizedTensor(
            pack_int4(q), scales, bits=4, act_bits=act_bits, k=int(w.shape[-2])
        )
    return QuantizedTensor(q, scales, bits=8, act_bits=act_bits)


def quantize_activations(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Dynamic symmetric per-row int8 activation quantization.

    ``x`` (..., K) float -> (int8 values of the same shape, f32 scales
    (...,)). The scale is per M row (``amax / 127`` over the contraction
    axis), so the GEMM rescale is the rank-1 outer product ``s_a (x) s_b``
    applied to the f32 accumulator at the flush. Runs at dispatch/trace
    time — a handful of VPU elementwise ops, paid back by halving A's HBM
    traffic and running the MAC on the int8 MXU path."""
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scales = jnp.maximum(amax, 1e-8) / _QMAX
    q = jnp.clip(jnp.round(xf / scales[..., None]), -_QMAX, _QMAX).astype(
        jnp.int8
    )
    return q, scales


def quantize_lm_params(
    params: Dict[str, Any],
    names: frozenset = QUANT_WEIGHT_NAMES,
    *,
    bits: int = 8,
    act_bits: Optional[int] = None,
) -> Tuple[Dict[str, Any], int, int]:
    """One-shot weight quantization at model load (the serve CLI's
    ``--quantize {int8,int8-dynamic,int4}``): every dense float leaf under a
    key in ``names`` becomes a :class:`QuantizedTensor`; everything else is
    untouched. Returns (new tree, leaves quantized, float leaves SKIPPED
    under a matching key). Scan-stacked leaves ``(L, ..., K, N)`` quantize
    per layer per output channel — the leading axes ride along in the scale
    shape, so ``lax.scan`` slices both leaves coherently.

    The walk recurses dicts AND sequences (list/tuple-nested parameter
    subtrees — e.g. per-layer lists — previously fell through untouched and
    were silently served dense). A float leaf that sits under a matching key
    but is not an eligible projection (ndim < 2) counts as skipped and is
    logged, so partial quantization is loud instead of silent."""
    n_quantized = 0
    n_skipped = 0

    def _is_float(leaf) -> bool:
        return hasattr(leaf, "dtype") and jnp.issubdtype(
            jnp.asarray(leaf).dtype, jnp.floating
        )

    def walk(node, named: bool = False):
        nonlocal n_quantized, n_skipped
        if isinstance(node, dict):
            return {key: walk(sub, named=key in names) for key, sub in node.items()}
        if isinstance(node, (list, tuple)):
            walked = [walk(item, named=named) for item in node]
            if isinstance(node, tuple) and hasattr(node, "_fields"):
                return type(node)(*walked)  # namedtuple
            return type(node)(walked)
        if named and not is_quantized(node):
            if hasattr(node, "ndim") and node.ndim >= 2 and _is_float(node):
                n_quantized += 1
                return quantize_weight(node, bits=bits, act_bits=act_bits)
            if _is_float(node):
                n_skipped += 1
        return node

    out = walk(params)
    if n_skipped:
        log.warning(
            "quantize_lm_params skipped %d float leaf/leaves under "
            "quantizable keys (not eligible (..., K, N) projections) — "
            "they will be served dense",
            n_skipped,
        )
    return out, n_quantized, n_skipped
