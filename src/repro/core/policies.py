"""Stream-K++ scheduling policies.

The paper expands Stream-K's three schedules to seven distinct policies:

  * ``ALL_SK``          — Algorithm 1: the whole flattened MAC-iteration space
                          is split evenly across ``g`` workgroups.
  * ``HYBRID(b)``, b=1..6 — ``b`` Stream-K *batches* scheduled FIRST (so their
                          fix-up latency overlaps the data-parallel phase),
                          followed by conventional data-parallel tile waves
                          for the remaining output tiles.

``DP`` (zero Stream-K batches) is the conventional data-parallel baseline the
paper compares against; it is selectable but is not one of the seven
Stream-K++ policies.

A "batch" is one round of ``g`` workgroup-sized work quanta (Fig. 1 / §3.2 of
the paper): HYBRID(1) covers the quantized remainder wave Stream-K-style,
HYBRID(b>1) additionally converts ``b-1`` full tile waves into Stream-K work.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass
from typing import Tuple


class PolicyKind(enum.Enum):
    """The three schedule families: DP baseline, ALL_SK, HYBRID."""

    DP = "dp"
    ALL_SK = "all_sk"
    HYBRID = "hybrid"


@dataclass(frozen=True, order=True)
class Policy:
    """A Stream-K++ scheduling policy.

    ``sk_batches`` is meaningful only for ``HYBRID``; by convention we store
    0 for DP and -1 for ALL_SK so that policies order naturally.
    """

    kind: PolicyKind
    sk_batches: int = 0

    def __post_init__(self):
        if self.kind == PolicyKind.HYBRID and not (1 <= self.sk_batches <= 6):
            raise ValueError(f"HYBRID requires 1..6 sk_batches, got {self.sk_batches}")
        if self.kind == PolicyKind.DP and self.sk_batches != 0:
            raise ValueError("DP has no Stream-K batches")
        if self.kind == PolicyKind.ALL_SK and self.sk_batches != -1:
            raise ValueError("ALL_SK must use sk_batches=-1 sentinel")

    @property
    def name(self) -> str:
        """Canonical artifact name: ``dp`` / ``all_sk`` / ``sk{b}dp``."""
        if self.kind == PolicyKind.DP:
            return "dp"
        if self.kind == PolicyKind.ALL_SK:
            return "all_sk"
        return f"sk{self.sk_batches}dp"

    @property
    def is_streamk(self) -> bool:
        """True for the seven Stream-K++ policies (everything but DP)."""
        return self.kind != PolicyKind.DP

    def __str__(self) -> str:  # pragma: no cover - repr sugar
        return self.name


DP = Policy(PolicyKind.DP, 0)
ALL_SK = Policy(PolicyKind.ALL_SK, -1)
HYBRIDS: Tuple[Policy, ...] = tuple(
    Policy(PolicyKind.HYBRID, b) for b in range(1, 7)
)

#: The seven Stream-K++ policies of the paper.
STREAMKPP_POLICIES: Tuple[Policy, ...] = (ALL_SK,) + HYBRIDS

#: Everything the dispatcher may choose between (baseline included).
ALL_POLICIES: Tuple[Policy, ...] = (DP,) + STREAMKPP_POLICIES

_BY_NAME = {p.name: p for p in ALL_POLICIES}


def policy_from_name(name: str) -> Policy:
    """Inverse of :attr:`Policy.name` (artifact deserialisation)."""
    try:
        return _BY_NAME[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; valid: {sorted(_BY_NAME)}") from None


@dataclass(frozen=True, order=True)
class TileConfig:
    """MXU-aligned output/reduction tile sizes (BlockSpec shapes).

    TPU adaptation: the lane dimension is 128-wide and the MXU is a 128x128
    systolic array, so BN and BK are multiples of 128 and BM a multiple of 8
    (sublane granularity for f32 accumulators).
    """

    bm: int = 128
    bn: int = 128
    bk: int = 128

    def __post_init__(self):
        if self.bm % 8 or self.bn % 128 or self.bk % 128:
            raise ValueError(f"misaligned tile config {self}")

    @property
    def name(self) -> str:
        """Canonical artifact name, e.g. ``256x128x128``."""
        return f"{self.bm}x{self.bn}x{self.bk}"

    def vmem_bytes(
        self,
        in_dtype_bytes: int = 2,
        acc_dtype_bytes: int = 4,
        b_dtype_bytes: int | None = None,
    ) -> int:
        """Working-set claim: A tile + B tile + accumulator (double-buffered
        inputs, matching the pipelined BlockSpec the kernels use).
        ``b_dtype_bytes`` lets mixed activation x weight ops claim distinct
        A/B widths; it defaults to ``in_dtype_bytes``."""
        a = self.bm * self.bk * in_dtype_bytes
        b = self.bk * self.bn * (
            b_dtype_bytes if b_dtype_bytes is not None else in_dtype_bytes
        )
        acc = self.bm * self.bn * acc_dtype_bytes
        return 2 * (a + b) + acc


#: Candidate tile configs swept by the tuner (all fit comfortably in the
#: ~16 MiB v5e VMEM budget per TileConfig.vmem_bytes).
#:
#: Tile arithmetic intensity is bm*bn/(bm+bn) FLOP/byte vs. the v5e ridge
#: point of 240 (197 TFLOP/s / 819 GB/s): 512x512 tiles (intensity 256) are
#: compute-bound, 256x256 (128) and below are HBM-bound — the sweep spans
#: both regimes plus skinny-M decode shapes.
DEFAULT_TILE_CONFIGS: Tuple[TileConfig, ...] = (
    TileConfig(128, 128, 128),
    TileConfig(256, 128, 128),
    TileConfig(128, 256, 128),
    TileConfig(256, 256, 128),
    TileConfig(512, 256, 128),
    TileConfig(256, 512, 128),
    TileConfig(512, 512, 128),
    TileConfig(512, 512, 256),
    TileConfig(64, 128, 256),
    TileConfig(128, 128, 512),
    TileConfig(8, 128, 512),
    TileConfig(8, 256, 1024),
)
