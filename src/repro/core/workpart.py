"""Work-centric GEMM partitioning (Algorithm 1 of the paper, generalised to
the seven Stream-K++ policies).

All of this is *static* integer math over (M, N, K, tile config, grid size,
policy): given those, every workgroup's iteration range, every tile's set of
contributing workgroups, and the fix-up plan are fully determined at trace /
compile time. That is what lets the TPU adaptation replace GPU atomics with a
deterministic two-phase reduction — the fix-up schedule is a compile-time
constant table, not a runtime discovery.

Glossary (matches Algorithm 1):
  iters_per_tile = ceil(K / BK)           (k-iterations per output tile)
  total_iters    = n_tiles * iters_per_tile
  g              = grid size (number of persistent workgroups / Pallas
                   programs); on TPU this is the virtual-lane count.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

from repro.core.policies import ALL_SK, DP, Policy, PolicyKind, TileConfig


def cdiv(a: int, b: int) -> int:
    """Ceiling division (number of size-``b`` tiles covering ``a``)."""
    return -(-a // b)


@dataclass(frozen=True)
class GemmShape:
    """One GEMM problem size (local / per-shard dims the kernel executes)."""

    m: int
    n: int
    k: int

    def __post_init__(self):
        if min(self.m, self.n, self.k) < 1:
            raise ValueError(f"degenerate GEMM shape {self}")

    @property
    def flops(self) -> int:
        """True MAC FLOPs of the problem (2*M*N*K)."""
        return 2 * self.m * self.n * self.k

    def key(self) -> Tuple[int, int, int]:
        """Legacy (M, N, K) tuple form."""
        return (self.m, self.n, self.k)


@dataclass(frozen=True)
class GroupedGemmShape(GemmShape):
    """``groups`` same-shape GEMMs executed as ONE fused kernel over the
    concatenated tile space (the grouped Stream-K op form).

    Subclassing :class:`GemmShape` keeps every existing signature —
    ``partition_stats``, the cost model, ``MeasureFn`` — unchanged: code
    that does not care about groups sees a plain shape, and groups-aware
    code reads ``getattr(shape, "groups", 1)``. Distinct type identity
    (dataclass ``__eq__`` is class-strict) keeps fused and per-group
    entries separate in the cost model's memo cache.
    """

    groups: int = 1

    def __post_init__(self):
        super().__post_init__()
        if self.groups < 1:
            raise ValueError(f"grouped shape needs groups >= 1, got {self.groups}")

    @property
    def flops(self) -> int:
        """True FLOPs across all groups (groups * 2*M*N*K)."""
        return self.groups * 2 * self.m * self.n * self.k


@dataclass(frozen=True)
class WorkRange:
    """A contiguous range of flattened MAC iterations owned by one workgroup."""

    wg: int
    start: int  # inclusive, in flattened iteration space
    end: int  # exclusive

    @property
    def size(self) -> int:
        """Number of MAC iterations in this range."""
        return self.end - self.start


@dataclass(frozen=True)
class TileContribution:
    """Which workgroups contribute to one output tile in the SK region.

    ``first_wg..last_wg`` is always contiguous because workgroup iteration
    ranges are contiguous and sorted — the property the fix-up kernel relies
    on to reduce partials with a static gather.
    """

    tile: int
    first_wg: int
    last_wg: int  # inclusive

    @property
    def num_contributors(self) -> int:
        """How many workgroups write partials for this tile."""
        return self.last_wg - self.first_wg + 1

    @property
    def is_split(self) -> bool:
        """True when the tile needs a fix-up reduction (>1 contributor)."""
        return self.num_contributors > 1


@dataclass(frozen=True)
class Partition:
    """Complete static schedule for one (shape, tile config, g, policy)."""

    shape: GemmShape
    cfg: TileConfig
    g: int
    policy: Policy
    m_tiles: int
    n_tiles: int
    iters_per_tile: int
    sk_tiles: int  # tiles [0, sk_tiles) are Stream-K; rest data-parallel
    sk_ranges: Tuple[WorkRange, ...]
    contributions: Tuple[TileContribution, ...]  # one per SK tile

    @property
    def n_tiles_total(self) -> int:
        """Total output tiles (SK region + data-parallel region)."""
        return self.m_tiles * self.n_tiles

    @property
    def dp_tiles(self) -> int:
        """Output tiles scheduled conventionally (one workgroup each)."""
        return self.n_tiles_total - self.sk_tiles

    @property
    def dp_waves(self) -> int:
        """Full ``g``-wide waves needed for the data-parallel region."""
        return cdiv(self.dp_tiles, self.g)

    @property
    def sk_total_iters(self) -> int:
        """Flattened MAC iterations in the Stream-K region."""
        return self.sk_tiles * self.iters_per_tile

    @property
    def n_split_tiles(self) -> int:
        """SK tiles with >1 contributor — the fix-up kernel's workload."""
        return sum(1 for c in self.contributions if c.is_split)

    @property
    def max_contributors(self) -> int:
        """Worst-case contributors to any tile (partials workspace depth)."""
        return max((c.num_contributors for c in self.contributions), default=1)

    def tile_mn(self, tile: int) -> Tuple[int, int]:
        """Output-tile coordinates for a flattened tile index (row-major)."""
        return tile // self.n_tiles, tile % self.n_tiles


def sk_tile_count(n_tiles_total: int, g: int, policy: Policy) -> int:
    """How many output tiles the Stream-K region covers under a policy.

    HYBRID(1) covers exactly the quantized remainder wave ("data-parallel
    followed by one-batch Stream-K" in the original paper, except Stream-K++
    schedules the SK region FIRST). HYBRID(b) additionally converts ``b-1``
    full waves. When the tile count divides the grid evenly there is no
    remainder pathology, so HYBRID(b) converts ``b-1`` full waves only.
    """
    if policy.kind == PolicyKind.DP:
        return 0
    if policy.kind == PolicyKind.ALL_SK:
        return n_tiles_total
    rem = n_tiles_total % g
    base = rem if rem else 0
    extra = (policy.sk_batches - 1) * g
    return min(n_tiles_total, base + extra)


def partition(
    shape: GemmShape, cfg: TileConfig, g: int, policy: Policy
) -> Partition:
    """Build the full static schedule (Algorithm 1 lines 2-13, both regions)."""
    if g < 1:
        raise ValueError("grid size must be >= 1")
    m_tiles = cdiv(shape.m, cfg.bm)
    n_tiles = cdiv(shape.n, cfg.bn)
    ipt = cdiv(shape.k, cfg.bk)
    n_total = m_tiles * n_tiles

    sk_tiles = sk_tile_count(n_total, g, policy)
    sk_total = sk_tiles * ipt

    # Algorithm 1 line 4: iters_per_wg = ceil(total_iters / g); workgroup x
    # owns [x*ipw, min((x+1)*ipw, total)). Workgroups past the end own nothing.
    ranges: List[WorkRange] = []
    if sk_total:
        ipw = cdiv(sk_total, g)
        for x in range(g):
            s = min(x * ipw, sk_total)
            e = min(s + ipw, sk_total)
            ranges.append(WorkRange(x, s, e))
    else:
        ranges = [WorkRange(x, 0, 0) for x in range(g)]

    # Static contribution table: tile t spans flattened iterations
    # [t*ipt, (t+1)*ipt); its contributors are the wgs whose range intersects.
    contribs: List[TileContribution] = []
    if sk_total:
        ipw = cdiv(sk_total, g)
        for t in range(sk_tiles):
            t0, t1 = t * ipt, (t + 1) * ipt
            first = t0 // ipw
            last = (t1 - 1) // ipw
            contribs.append(TileContribution(t, first, last))
    return Partition(
        shape=shape,
        cfg=cfg,
        g=g,
        policy=policy,
        m_tiles=m_tiles,
        n_tiles=n_tiles,
        iters_per_tile=ipt,
        sk_tiles=sk_tiles,
        sk_ranges=tuple(ranges),
        contributions=tuple(contribs),
    )


def validate_partition(p: Partition) -> None:
    """Invariants the hypothesis tests drive; raises AssertionError on breach.

    1. SK ranges tile [0, sk_total_iters) exactly (disjoint, complete, sorted).
    2. Load balance: every non-empty range has ceil(sk_total/g) iters except
       possibly the last non-empty one.
    3. Every SK tile's contributor span is contiguous & within [0, g).
    4. Tile regions partition the tile index space: sk + dp == total.
    """
    total = p.sk_total_iters
    cursor = 0
    ipw = cdiv(total, p.g) if total else 0
    for r in p.sk_ranges:
        assert r.start == min(cursor, total), (r, cursor)
        assert r.end >= r.start
        assert r.size <= ipw
        cursor = r.end if r.size else cursor
    assert cursor == total, (cursor, total)
    for c in p.contributions:
        assert 0 <= c.first_wg <= c.last_wg < p.g
        # every contributor in the span genuinely intersects the tile
        t0, t1 = c.tile * p.iters_per_tile, (c.tile + 1) * p.iters_per_tile
        for wg in range(c.first_wg, c.last_wg + 1):
            r = p.sk_ranges[wg]
            assert max(r.start, t0) < min(r.end, t1), (c, r)
    assert 0 <= p.sk_tiles <= p.n_tiles_total
    assert p.sk_tiles + p.dp_tiles == p.n_tiles_total


@dataclass(frozen=True)
class PartitionStats:
    """O(g) aggregate view of a partition — everything the cost model needs
    without materialising per-tile contribution lists (the full
    ``partition`` is O(tiles) and exists for the kernels; hypothesis tests
    assert these aggregates agree with it)."""

    m_tiles: int
    n_tiles: int
    iters_per_tile: int
    n_tiles_total: int
    sk_tiles: int
    sk_total_iters: int
    dp_tiles: int
    dp_waves: int
    n_split_tiles: int
    extra_contributors: int  # sum over tiles of (num_contributors - 1)


def partition_stats(
    shape: GemmShape, cfg: TileConfig, g: int, policy: Policy
) -> PartitionStats:
    """O(g) aggregates for one (shape, cfg, g, policy) schedule.

    A :class:`GroupedGemmShape` with ``groups > 1`` models the fused
    single-kernel grouped form: the tile space is the *concatenation* of
    every group's tiles (``groups * m_tiles * n_tiles``), owned by one
    persistent grid. Under any Stream-K policy the whole concatenated space
    runs work-centric (HYBRID degenerates to ALL_SK — the single fused
    launch has no separate data-parallel region to hand tiles to), and the
    sequential-carry kernel resolves ragged tile boundaries in VMEM, so
    there is no partials round-trip: ``n_split_tiles`` and
    ``extra_contributors`` are 0 by construction. What Stream-K buys here
    is iteration-level (instead of tile-level) wave quantization over the
    concatenated space — exactly the paper's core claim, applied across
    expert boundaries."""
    groups = getattr(shape, "groups", 1)
    m_tiles = cdiv(shape.m, cfg.bm)
    n_tiles = cdiv(shape.n, cfg.bn)
    ipt = cdiv(shape.k, cfg.bk)
    if groups > 1:
        n_total = groups * m_tiles * n_tiles
        sk_tiles = 0 if policy.kind == PolicyKind.DP else n_total
        return PartitionStats(
            m_tiles=m_tiles,
            n_tiles=n_tiles,
            iters_per_tile=ipt,
            n_tiles_total=n_total,
            sk_tiles=sk_tiles,
            sk_total_iters=sk_tiles * ipt,
            dp_tiles=n_total - sk_tiles,
            dp_waves=cdiv(n_total - sk_tiles, g),
            n_split_tiles=0,
            extra_contributors=0,
        )
    n_total = m_tiles * n_tiles
    sk_tiles = sk_tile_count(n_total, g, policy)
    sk_total = sk_tiles * ipt
    dp_tiles = n_total - sk_tiles
    dp_waves = cdiv(dp_tiles, g)

    n_split = extra = 0
    if sk_total:
        ipw = cdiv(sk_total, g)
        n_ranges = cdiv(sk_total, ipw)
        split_tiles = set()
        for j in range(1, n_ranges):
            b = j * ipw  # interior boundary between wg j-1 and j
            if b % ipt:
                split_tiles.add(b // ipt)
                extra += 1
        n_split = len(split_tiles)
    return PartitionStats(
        m_tiles=m_tiles,
        n_tiles=n_tiles,
        iters_per_tile=ipt,
        n_tiles_total=n_total,
        sk_tiles=sk_tiles,
        sk_total_iters=sk_total,
        dp_tiles=dp_tiles,
        dp_waves=dp_waves,
        n_split_tiles=n_split,
        extra_contributors=extra,
    )


def iter_to_tile(it: int, iters_per_tile: int) -> Tuple[int, int]:
    """Algorithm 1 lines 9-12: flattened iteration -> (tile index, local k-iter)."""
    return it // iters_per_tile, it % iters_per_tile


def wave_quantization_efficiency(n_tiles: int, lanes: int) -> float:
    """Utilization of a pure data-parallel schedule: tiles / (waves * lanes).

    This is the inefficiency Stream-K attacks — e.g. 9 tiles on 8 lanes run
    in 2 waves at 56% utilization.
    """
    if n_tiles == 0:
        return 1.0
    waves = cdiv(n_tiles, lanes)
    return n_tiles / (waves * lanes)
