"""Public GEMM dispatch API — the paper's technique as a first-class framework
feature.

Every projection in ``repro.models`` routes through :func:`gemm`. At trace
time the dispatcher:

  1. computes the *local* (per-shard) (M, N, K) the MXU will actually see —
     callers pass the sharding divisors their GSPMD spec implies;
  2. asks the :class:`KernelSelector` (tuned DB -> Bloom filters -> cost
     model) for a (policy, tile config);
  3. executes via the chosen backend:
       * ``xla``               — jnp.dot (CPU / dry-run lowering; selection
                                 still exercised + logged),
       * ``pallas``            — the Stream-K++ Pallas kernel (TPU),
       * ``pallas_interpret``  — same kernel, interpret mode (CPU-validated).

Backend and selector are ambient (context-managed) so model code stays
declarative. Every decision is appended to the active ``SelectionLog`` for
tests/benchmarks to introspect.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.policies import Policy, TileConfig
from repro.core.selector import KernelSelector, Selection, default_selector

_state = threading.local()


@dataclass
class SelectionLogEntry:
    global_mnk: Tuple[int, int, int]
    local_mnk: Tuple[int, int, int]
    selection: Selection
    tag: str = ""


@dataclass
class GemmContext:
    selector: KernelSelector
    backend: str = "xla"  # "xla" | "pallas" | "pallas_interpret"
    log: List[SelectionLogEntry] = field(default_factory=list)


def _ctx() -> GemmContext:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        ctx = GemmContext(selector=default_selector())
        _state.ctx = ctx
    return ctx


@contextmanager
def gemm_context(
    selector: Optional[KernelSelector] = None, backend: Optional[str] = None
):
    """Install a dispatch context for the duration of a trace/eval."""
    old = getattr(_state, "ctx", None)
    base = old or _ctx()
    _state.ctx = GemmContext(
        selector=selector if selector is not None else base.selector,
        backend=backend if backend is not None else base.backend,
    )
    try:
        yield _state.ctx
    finally:
        _state.ctx = old


def current_log() -> List[SelectionLogEntry]:
    return _ctx().log


def gemm(
    x: jax.Array,
    w: jax.Array,
    *,
    divisors: Tuple[int, int, int] = (1, 1, 1),
    out_dtype=None,
    tag: str = "",
    policy: Optional[Policy] = None,
    cfg: Optional[TileConfig] = None,
) -> jax.Array:
    """``x @ w`` with Stream-K++ kernel selection.

    x: (..., K); w: (K, N) -> (..., N). ``divisors`` are the GSPMD sharding
    factors (dm, dn, dk) so selection keys on the per-shard local shape.
    ``policy``/``cfg`` override selection (used by the tuner itself).
    """
    if x.shape[-1] != w.shape[0]:
        raise ValueError(f"gemm contraction mismatch: {x.shape} @ {w.shape}")
    ctx = _ctx()
    m_global = 1
    for d in x.shape[:-1]:
        m_global *= int(d)
    k_global, n_global = int(w.shape[0]), int(w.shape[1])
    dm, dn, dk = divisors
    local = (max(1, m_global // dm), max(1, n_global // dn), max(1, k_global // dk))

    if policy is None or cfg is None:
        sel = ctx.selector.select(*local)
        policy = policy or sel.policy
        cfg = cfg or sel.cfg
    else:
        sel = Selection(policy, cfg, "forced", 0, 0)
    ctx.log.append(
        SelectionLogEntry((m_global, n_global, k_global), local, sel, tag)
    )

    out_dtype = out_dtype or x.dtype
    if ctx.backend == "xla":
        out = jnp.dot(x, w, preferred_element_type=jnp.float32)
        return out.astype(out_dtype)

    # Pallas path: flatten leading dims, run the kernel, restore shape.
    from repro.kernels.streamk import ops as sk_ops

    lead = x.shape[:-1]
    x2 = x.reshape((m_global, k_global))
    out2 = sk_ops.gemm(
        x2,
        w,
        policy=policy,
        cfg=cfg,
        interpret=(ctx.backend == "pallas_interpret"),
        out_dtype=out_dtype,
    )
    return out2.reshape((*lead, n_global))
