"""Public GEMM dispatch API — the paper's technique as a first-class framework
feature.

Every matmul in ``repro.models`` (attention/MLP projections, grouped MoE
expert GEMMs, batched cross-attention precomputes) routes through one of the
entry points here. At trace time the dispatcher:

  1. builds a :class:`repro.core.op.GemmOp` — the full problem fingerprint:
     global dims, per-shard local dims (callers pass the sharding divisors
     their GSPMD spec implies), group count, dtypes, and the fused
     :class:`~repro.core.op.Epilogue`;
  2. asks the :class:`KernelSelector` (tuned DB -> Bloom filters -> cost
     model, keyed on the op fingerprint) for a (policy, tile config);
  3. executes via the backend registered under the context's backend name.

Backends are *pluggable*: :func:`register_backend` installs a new execution
strategy without touching this module. Built-ins:

  * ``xla``               — jnp einsum (CPU / dry-run lowering; selection
                            still exercised + logged, epilogue fused by XLA),
  * ``pallas``            — the Stream-K++ Pallas kernels (TPU; epilogue
                            fused into the kernel flush / fix-up phase),
  * ``pallas_interpret``  — same kernels, interpret mode (CPU-validated).

Entry points: :func:`gemm` (2-D weight, the original per-call surface),
:func:`gemm_grouped` (stacked ``(G, K, N)`` expert weights — each group is
the same local problem, one selection covers the group; by default all G
groups execute as ONE fused kernel over the concatenated expert tile
space, fingerprinted separately via the 8-part ``grouped_fused`` op key),
and :func:`gemm_batched` (independent per-batch operands of equal shape).

Backend and selector are ambient (context-managed) so model code stays
declarative. Every decision is appended to the active ``SelectionLog`` for
tests/benchmarks to introspect.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core.op import Epilogue, GemmOp, as_epilogue
from repro.core.policies import Policy, TileConfig
from repro.core.quant import (
    QuantizedTensor,
    is_quantized,
    quantize_activations,
    unpack_int4,
)
from repro.core.selector import KernelSelector, Selection, default_selector
from repro.core.tuner import LEGACY_GRID

_state = threading.local()


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

#: BackendFn(x, w, *, op, policy, cfg, g, bias, operand, scale, scale_a,
#:            b_bits) -> out
#:   x: (G, M, K), w: (G, K, N), bias: (G, N) | None, operand: (G, M, N) | None
#:   returns (G, M, N) in op.out_dtype. G == 1 for plain 2-D dispatches.
#:   ``g`` is the selected grid size (persistent-workgroup count) the kernel
#:   partitions the flattened iteration space over; backends without a grid
#:   concept (xla) may ignore it. ``scale``: (G, N) f32 — the
#:   per-output-channel dequant vector of an int8-weight op (``w`` is then
#:   the raw int8 values); backends must apply it to the f32 accumulator
#:   BEFORE the op's epilogue stages (see ``QuantizedTensor``). ``scale_a``:
#:   (G, M) f32 — the per-row activation dequant of an int8xint8 op (``x``
#:   is then int8), applied alongside ``scale`` as the rank-1 rescale.
#:   ``b_bits == 4``: ``w`` is int4-packed (G, ceil(K/2), N) — two nibbles
#:   per byte along K — and the backend must unpack (or let its kernels
#:   unpack per block). The dispatcher passes scale/scale_a/b_bits only for
#:   quantized ops, so backends that predate them keep serving dense
#:   traffic and fail loudly on quantized (unexpected kwarg) instead of
#:   silently skipping a dequant stage.
BackendFn = Callable[..., jax.Array]

_BACKENDS: Dict[str, BackendFn] = {}


def register_backend(name: str, fn: BackendFn, *, overwrite: bool = False) -> None:
    """Register an execution backend under ``name`` (see BackendFn contract).

    New backends plug in without touching the dispatcher: selection,
    logging, and the public API are backend-agnostic."""
    if name in _BACKENDS and not overwrite:
        raise ValueError(f"backend {name!r} already registered")
    _BACKENDS[name] = fn


def list_backends() -> Tuple[str, ...]:
    """Registered backend names, sorted."""
    return tuple(sorted(_BACKENDS))


def get_backend(name: str) -> BackendFn:
    """Resolve a backend by name; raises with the valid names on a miss."""
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown gemm backend {name!r}; registered backends: "
            f"{list(list_backends())}"
        ) from None


def _xla_backend(
    x, w, *, op: GemmOp, policy, cfg, g, bias, operand, scale=None,
    scale_a=None, b_bits=8,
):
    if b_bits == 4:
        # packed int4 weights: unpack to int8 and drop the odd-K pad row
        w = unpack_int4(w)[:, : x.shape[2], :]
    if jnp.issubdtype(x.dtype, jnp.integer) and jnp.issubdtype(
        w.dtype, jnp.integer
    ):
        # int8 x int8 op: integer contraction (exact in int32 for the
        # K <= ~130k these models dispatch), converted to f32 for the
        # rank-1 rescale below — mirroring the kernels' integer mixed_dot
        acc = jnp.einsum(
            "gmk,gkn->gmn", x, w, preferred_element_type=jnp.int32
        ).astype(jnp.float32)
    else:
        if w.dtype != x.dtype and not jnp.issubdtype(w.dtype, jnp.floating):
            # int8-weight op: contract in f32 (conversion from int8 is
            # exact), mirroring the kernels' mixed_dot widening
            x = x.astype(jnp.float32)
            w = w.astype(jnp.float32)
        acc = jnp.einsum(
            "gmk,gkn->gmn", x, w, preferred_element_type=jnp.float32
        )
    if scale_a is not None:
        acc = acc * scale_a[:, :, None].astype(jnp.float32)
    if scale is not None:
        acc = acc * scale[:, None, :].astype(jnp.float32)
    acc = op.epilogue.apply(
        acc,
        bias=None if bias is None else bias[:, None, :],
        operand=operand,
    )
    return acc.astype(op.out_dtype)


def _make_pallas_backend(interpret: bool) -> BackendFn:
    def backend(
        x, w, *, op: GemmOp, policy, cfg, g, bias, operand, scale=None,
        scale_a=None, b_bits=8,
    ):
        from repro.kernels.common import record_launch
        from repro.kernels.streamk import ops as sk_ops
        from repro.kernels.streamk.grouped import gemm_grouped_streamk

        if getattr(op, "fused", False):
            # Fused grouped form: ONE pallas_call spans the concatenated
            # tile space of all G expert groups (a scalar-prefetched
            # row-block -> group table steers the B/bias/scale gathers).
            # Trace and launch cost are G-independent; the per-group loop
            # below remains as the differential oracle (fused=False).
            return gemm_grouped_streamk(
                x,
                w,
                policy=policy,
                cfg=cfg,
                g=g,
                interpret=interpret,
                out_dtype=jnp.dtype(op.out_dtype),
                epilogue=op.epilogue,
                bias=bias,
                operand=operand,
                scale=scale,
                scale_a=scale_a,
                b_bits=b_bits,
            )

        # Loop form: one pallas_call per group, so trace cost grows with G
        # (tracked by benchmarks/perf_trajectory.py). Grouped dispatches
        # default to the fused branch above; this path serves batched ops,
        # explicit fused=False grouped calls, and legacy 7-part journal
        # entries, and doubles as the fused kernel's numerics oracle.
        outs = []
        for i in range(x.shape[0]):  # static group count
            # every group is a distinct runtime kernel launch even when the
            # (identical-shape) trace is jit-cached — count it as one
            record_launch(f"group[{i}]:{policy.name}_{cfg.name}")
            outs.append(
                sk_ops.gemm(
                    x[i],
                    w[i],
                    policy=policy,
                    cfg=cfg,
                    g=g,
                    interpret=interpret,
                    out_dtype=jnp.dtype(op.out_dtype),
                    epilogue=op.epilogue,
                    bias=None if bias is None else bias[i],
                    operand=None if operand is None else operand[i],
                    scale=None if scale is None else scale[i],
                    scale_a=None if scale_a is None else scale_a[i],
                    b_bits=b_bits,
                )
            )
        return jnp.stack(outs)

    return backend


register_backend("xla", _xla_backend)
register_backend("pallas", _make_pallas_backend(interpret=False))
register_backend("pallas_interpret", _make_pallas_backend(interpret=True))


# ---------------------------------------------------------------------------
# Dispatch context + selection log
# ---------------------------------------------------------------------------


@dataclass
class SelectionLogEntry:
    """One dispatch decision: the op fingerprint, what was selected, and
    the caller's tag (e.g. ``"moe.in"``) for test/benchmark introspection."""

    op: GemmOp
    selection: Selection
    tag: str = ""

    @property
    def global_mnk(self) -> Tuple[int, int, int]:
        """Unsharded problem dims of the logged op."""
        return self.op.global_mnk

    @property
    def local_mnk(self) -> Tuple[int, int, int]:
        """Per-shard local dims of the logged op."""
        return self.op.local

    @property
    def g(self) -> int:
        """Group/batch count of the logged op (1 for plain)."""
        return self.op.g


@dataclass
class GemmContext:
    """Ambient dispatch state: the selector, backend name, and log."""

    selector: KernelSelector
    backend: str = "xla"  # any name in list_backends()
    log: List[SelectionLogEntry] = field(default_factory=list)


def _ctx() -> GemmContext:
    ctx = getattr(_state, "ctx", None)
    if ctx is None:
        ctx = GemmContext(selector=default_selector())
        _state.ctx = ctx
    return ctx


@contextmanager
def gemm_context(
    selector: Optional[KernelSelector] = None, backend: Optional[str] = None
):
    """Install a dispatch context for the duration of a trace/eval."""
    old = getattr(_state, "ctx", None)
    base = old or _ctx()
    if backend is not None:
        get_backend(backend)  # fail fast on unknown names
    _state.ctx = GemmContext(
        selector=selector if selector is not None else base.selector,
        backend=backend if backend is not None else base.backend,
    )
    try:
        yield _state.ctx
    finally:
        _state.ctx = old


def current_log() -> List[SelectionLogEntry]:
    """The active context's selection log (created on first use)."""
    return _ctx().log


def current_selector() -> KernelSelector:
    """The active context's selector (created on first use)."""
    return _ctx().selector


# ---------------------------------------------------------------------------
# Core dispatch
# ---------------------------------------------------------------------------


def _dispatch(
    x: jax.Array,  # (G, M, K)
    w: jax.Array,  # (G, K, N)
    op: GemmOp,
    *,
    tag: str,
    policy: Optional[Policy],
    cfg: Optional[TileConfig],
    g: Optional[int],
    bias: Optional[jax.Array],
    operand: Optional[jax.Array],
    scale: Optional[jax.Array] = None,
    scale_a: Optional[jax.Array] = None,
    b_bits: int = 8,
) -> jax.Array:
    ctx = _ctx()
    if policy is None and cfg is None and g is None:
        sel = ctx.selector.select_op(op)
    elif policy is not None and cfg is not None:
        sel = ctx.selector.record_forced(
            op, policy, cfg, g=g if g is not None else LEGACY_GRID
        )
    else:
        # partial override: fill the missing parts from selection, but log
        # what actually runs (source "forced") — never the selector's own
        # pick, which may pair a different policy with this cfg/g
        sel = ctx.selector.select_partial(op, policy, cfg, g=g)
    policy, cfg, grid = sel.policy, sel.cfg, sel.g
    ctx.log.append(SelectionLogEntry(op, sel, tag))
    backend = get_backend(ctx.backend)
    kwargs = dict(op=op, policy=policy, cfg=cfg, g=grid, bias=bias, operand=operand)
    if scale is not None:
        # only quantized ops pass the dequant operands: backends registered
        # against the pre-quantization BackendFn signature keep serving
        # dense traffic unchanged, and a quantized dispatch through one
        # fails loudly (unexpected 'scale') instead of silently skipping
        # the dequant stage
        kwargs["scale"] = scale
    if scale_a is not None:
        kwargs["scale_a"] = scale_a
    if b_bits != 8:
        kwargs["b_bits"] = b_bits
    return backend(x, w, **kwargs)


def _check_epilogue(epilogue: Epilogue, bias, operand) -> None:
    if epilogue.bias != (bias is not None):
        raise ValueError(
            f"epilogue {epilogue.name!r} expects bias={epilogue.bias} but "
            f"bias operand is {'missing' if bias is None else 'present'}"
        )
    if (epilogue.binary != "none") != (operand is not None):
        raise ValueError(
            f"epilogue {epilogue.name!r} expects "
            f"{'an' if epilogue.binary != 'none' else 'no'} binary operand"
        )


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def gemm(
    x: jax.Array,
    w: Union[jax.Array, QuantizedTensor],
    *,
    divisors: Tuple[int, int, int] = (1, 1, 1),
    out_dtype=None,
    tag: str = "",
    policy: Optional[Policy] = None,
    cfg: Optional[TileConfig] = None,
    g: Optional[int] = None,
    epilogue: Union[None, str, Epilogue] = None,
    bias: Optional[jax.Array] = None,
    operand: Optional[jax.Array] = None,
) -> jax.Array:
    """``x @ w`` with Stream-K++ kernel selection.

    x: (..., K); w: (K, N) -> (..., N). ``divisors`` are the GSPMD sharding
    factors (dm, dn, dk) so selection keys on the per-shard local shape.
    ``epilogue`` fuses bias/activation/binary post-ops into the kernel
    (``bias``: (N,), ``operand``: (..., N) matching the output).
    ``policy``/``cfg``/``g`` override selection (used by the tuner itself);
    otherwise the selector chooses all three jointly.

    ``w`` may be a :class:`~repro.core.quant.QuantizedTensor`: the op then
    fingerprints with the mixed ``"<x_dtype>*<w_dtype>"`` in_dtype — e.g.
    ``"float32*int8"``, ``"float32*int4"`` (packed nibbles, unpacked in the
    kernel prologues), or ``"int8*int8"`` when the weight requests dynamic
    activation quantization (``act_bits=8``) — tuning/pruning independently
    of the dense op at the same MNK. The weight scales (and, for int8
    activations, the per-row activation scales computed here at dispatch
    time) ride into the kernel's flush/fix-up as fused dequant epilogue
    stages.
    """
    scale = None
    scale_a = None
    b_bits = 8
    w_name = None
    act_quant = False
    w_shape = w.shape  # QuantizedTensor reports the LOGICAL (K, N)
    if is_quantized(w):
        scale = w.scales
        b_bits = 4 if w.bits == 4 else 8
        w_name = w.dtype_name
        act_quant = w.act_bits == 8
        w = w.values
    if x.shape[-1] != w_shape[0]:
        raise ValueError(f"gemm contraction mismatch: {x.shape} @ {w_shape}")
    epilogue = _infer_epilogue(epilogue, bias, operand)
    lead = x.shape[:-1]
    m_global = 1
    for d in lead:
        m_global *= int(d)
    k_global, n_global = int(w_shape[0]), int(w_shape[1])
    # capture out_dtype from the ORIGINAL activations — dynamic activation
    # quantization must not leak int8 into the output dtype default
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    if act_quant and jnp.issubdtype(x.dtype, jnp.floating):
        x, sa = quantize_activations(x)
        scale_a = sa.reshape(1, m_global)
    op = GemmOp(
        m_global,
        n_global,
        k_global,
        in_dtype=_in_dtype_fingerprint(x, w, w_name=w_name),
        out_dtype=str(out_dtype),
        divisors=tuple(divisors),
        epilogue=epilogue,
    )
    out = _dispatch(
        x.reshape(1, m_global, k_global),
        w[None],
        op,
        tag=tag,
        policy=policy,
        cfg=cfg,
        g=g,
        bias=None if bias is None else bias.reshape(1, n_global),
        operand=None if operand is None else operand.reshape(1, m_global, n_global),
        scale=None if scale is None else scale.reshape(1, n_global),
        scale_a=scale_a,
        b_bits=b_bits,
    )
    return out.reshape(*lead, n_global)


def _gemm_stacked(
    kind: str,
    x: jax.Array,
    w: jax.Array,
    *,
    divisors: Tuple[int, int, int],
    g_divisor: int,
    out_dtype,
    tag: str,
    policy: Optional[Policy],
    cfg: Optional[TileConfig],
    grid: Optional[int],
    epilogue: Union[None, str, Epilogue],
    bias: Optional[jax.Array],
    operand: Optional[jax.Array],
    fused: bool = False,
) -> jax.Array:
    scale = None
    scale_a = None
    b_bits = 8
    w_name = None
    act_quant = False
    w_shape = w.shape  # QuantizedTensor reports the LOGICAL (G, K, N)
    if is_quantized(w):
        scale = w.scales
        b_bits = 4 if w.bits == 4 else 8
        w_name = w.dtype_name
        act_quant = w.act_bits == 8
        w = w.values
    if x.ndim != 3 or len(w_shape) != 3:
        raise ValueError(
            f"gemm_{kind} expects x (G, M, K) and w (G, K, N); got "
            f"{x.shape} @ {tuple(w_shape)}"
        )
    if x.shape[0] != w_shape[0] or x.shape[2] != w_shape[1]:
        raise ValueError(f"gemm_{kind} mismatch: {x.shape} @ {tuple(w_shape)}")
    epilogue = _infer_epilogue(epilogue, bias, operand)
    g, m, k = (int(d) for d in x.shape)
    n = int(w_shape[2])
    # capture out_dtype before any dynamic activation quantization
    out_dtype = jnp.dtype(out_dtype or x.dtype)
    if act_quant and jnp.issubdtype(x.dtype, jnp.floating):
        x, scale_a = quantize_activations(x)  # scales (G, M)
    op = GemmOp(
        m,
        n,
        k,
        g=g,
        kind=kind,
        in_dtype=_in_dtype_fingerprint(x, w, w_name=w_name),
        out_dtype=str(out_dtype),
        divisors=tuple(divisors),
        g_divisor=g_divisor,
        epilogue=epilogue,
        fused=fused,
    )
    if bias is not None and bias.ndim == 1:
        bias = jnp.broadcast_to(bias[None], (g, n))
    return _dispatch(
        x,
        w,
        op,
        tag=tag,
        policy=policy,
        cfg=cfg,
        g=grid,
        bias=bias,
        operand=operand,
        scale=scale,
        scale_a=scale_a,
        b_bits=b_bits,
    )


def gemm_grouped(
    x: jax.Array,
    w: Union[jax.Array, QuantizedTensor],
    *,
    divisors: Tuple[int, int, int] = (1, 1, 1),
    g_divisor: int = 1,
    out_dtype=None,
    tag: str = "",
    policy: Optional[Policy] = None,
    cfg: Optional[TileConfig] = None,
    grid: Optional[int] = None,
    epilogue: Union[None, str, Epilogue] = None,
    bias: Optional[jax.Array] = None,
    operand: Optional[jax.Array] = None,
    fused: bool = True,
) -> jax.Array:
    """Grouped GEMM over stacked weights: x (G, M, K) @ w (G, K, N) ->
    (G, M, N) — the MoE expert shape (G experts, M = expert capacity).

    All groups share one local problem, so a single selection covers the
    group; the op fingerprint still records ``G`` (and ``g_divisor``, the
    expert-parallel sharding factor) so grouped shapes tune and prune
    independently of the plain 2-D path. ``bias``: (G, N) or (N,);
    ``operand``: (G, M, N). ``grid`` overrides the selected grid size
    (named to avoid clashing with the group count ``G``). ``w`` may be a
    stacked :class:`~repro.core.quant.QuantizedTensor` (int8 values
    (G, K, N) + scales (G, N)) — the MoE expert weights of the quantized
    serving path.

    ``fused`` (default True) runs all G groups as ONE kernel over the
    concatenated expert tile space (``kernels/streamk/grouped``) and
    fingerprints the op with the 8-part ``grouped_fused`` key so it tunes,
    journals, prunes and federates independently of the per-group loop.
    ``fused=False`` keeps the legacy one-launch-per-group path — the
    differential oracle and the dispatch form of legacy 7-part journal
    records.
    """
    return _gemm_stacked(
        "grouped",
        x,
        w,
        divisors=divisors,
        g_divisor=g_divisor,
        out_dtype=out_dtype,
        tag=tag,
        policy=policy,
        cfg=cfg,
        grid=grid,
        epilogue=epilogue,
        bias=bias,
        operand=operand,
        fused=fused,
    )


def gemm_batched(
    x: jax.Array,
    w: Union[jax.Array, QuantizedTensor],
    *,
    divisors: Tuple[int, int, int] = (1, 1, 1),
    g_divisor: int = 1,
    out_dtype=None,
    tag: str = "",
    policy: Optional[Policy] = None,
    cfg: Optional[TileConfig] = None,
    grid: Optional[int] = None,
    epilogue: Union[None, str, Epilogue] = None,
    bias: Optional[jax.Array] = None,
    operand: Optional[jax.Array] = None,
) -> jax.Array:
    """Batched GEMM: x (B, M, K) @ w (B, K, N) -> (B, M, N), independent
    per-batch operands of equal shape (one selection covers the batch)."""
    return _gemm_stacked(
        "batched",
        x,
        w,
        divisors=divisors,
        g_divisor=g_divisor,
        out_dtype=out_dtype,
        tag=tag,
        policy=policy,
        cfg=cfg,
        grid=grid,
        epilogue=epilogue,
        bias=bias,
        operand=operand,
    )


def _in_dtype_fingerprint(
    x: jax.Array, w: jax.Array, w_name: Optional[str] = None
) -> str:
    """Input-dtype component of the op key. Mixed activation/weight dtypes
    (e.g. bf16 activations against int8 weights) select different kernels,
    so they must not collide on one fingerprint. Quantized weights pass
    their logical ``w_name`` (``"int8"``/``"int4"`` — the stored dtype of a
    packed int4 tensor is int8 bytes) and ALWAYS fingerprint in the mixed
    ``"a*w"`` form: an ``"int8*int8"`` dynamic-quantization op must not
    collide with a hypothetical plain int8 op's key."""
    xd = str(x.dtype)
    if w_name is not None:
        return f"{xd}*{w_name}"
    wd = str(w.dtype)
    return xd if xd == wd else f"{xd}*{wd}"


def _infer_epilogue(
    epilogue: Union[None, str, Epilogue], bias, operand
) -> Epilogue:
    """Normalise the epilogue argument and cross-check it against the
    supplied operands (a bias without ``bias=True`` in the spec — or vice
    versa — is a caller bug, not something to guess around)."""
    if epilogue is None and (bias is not None or operand is not None):
        raise ValueError(
            "bias/operand supplied without an epilogue spec; pass "
            "epilogue=Epilogue(bias=..., binary=...)"
        )
    spec = as_epilogue(epilogue)
    _check_epilogue(spec, bias, operand)
    return spec
