"""Federated tuning: merge what N workers learned into one selection state.

The offline :class:`~repro.core.tuner.Tuner` shards a sweep across workers
(``Tuner.tune(shard=(i, n))``) and serving processes each append to their own
journal shard; this module is the reassembly layer that turns those partial
artifacts back into ONE :class:`~repro.core.tuner.TuningDatabase` + one
:class:`~repro.core.opensieve.OpenSieve`, so every worker's next
:meth:`~repro.core.selector.KernelSelector.hot_swap` dispatches from the
union instead of re-discovering what a sibling already tuned.

Merge semantics:

  * **Records** — last-writer-wins per fingerprint key on the record's
    hybrid ``(wall, version)`` commit stamp: the wall clock orders commits
    *across* producers (a true time order, up to host clock sync), the
    producer's ``version`` counter breaks sub-resolution ties *within*
    one. A full stamp tie between *differing* payloads is a real conflict
    (two workers tuned the same fingerprint at indistinguishable times):
    it is counted in ``MergeReport.conflicts`` and resolved
    deterministically — higher measured tflops, then policy / cfg / g name
    order — so the merged database is identical whatever order the shards
    arrive in. Records that lose are counted in ``superseded``. Sharded
    sweeps partition fingerprints disjointly, so an offline federated
    sweep merges with zero conflicts and is record-identical (modulo local
    commit stamps) to the single-worker full sweep.

    Clock caveat: the wall half of the stamp is only as good as host clock
    sync; where a *structural* precedence exists it still wins outright —
    journals replay *on top of* the snapshot they post-date
    (``apply_journal_db`` / ``TuningDatabase.load(path, journal=...)``
    overwrite unconditionally, whatever either side's stamps say), and
    ``federate_selector`` merges into the worker's live database, whose
    records stand unless a sibling's strictly outranks them. Artifacts
    written before the hybrid stamp parse with ``wall = 0.0`` and lose to
    any wall-stamped record.
  * **Sieves** — :meth:`OpenSieve.merge` bitwise-ORs the per-policy Bloom
    filters (inserting a key sets the same bits whichever worker's filter it
    landed in, so the union is bit-identical to rebuilding from the merged
    winner map) and bumps ``generation`` past every input, which is what
    makes selector hot-swaps drop picks memoised under any pre-merge sieve.
  * **Journals** — each shard replays into its own staging database first
    (preserving intra-shard time order and producer version stamps), then
    databases merge as above. Torn/malformed lines are skipped and summed
    into ``MergeReport.load_errors`` (see ``replay_journal``).

``federate_selector`` is the worker-side entry point: merge everything that
arrived from the fleet into this worker's selector and hot-swap, after which
a fingerprint tuned in any sibling process dispatches here as a database hit
— no miss, no re-tune.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.op import OpKey
from repro.core.opensieve import OpenSieve
from repro.core.selector import KernelSelector
from repro.core.tuner import TuningDatabase, TuningRecord
from repro.utils.logging import get_logger

log = get_logger("federate")


@dataclass
class MergeReport:
    """What a federated merge did — the observability surface CI and the
    serve CLI print, so shard skew is a number rather than a mystery."""

    sources: int = 0  # databases / journal shards consumed
    examined: int = 0  # records read across all sources
    merged: int = 0  # distinct fingerprint keys in the result
    conflicts: int = 0  # same key, same version, DIFFERENT payload
    superseded: int = 0  # records that lost last-writer-wins
    load_errors: int = 0  # malformed/torn journal lines skipped

    def combine(self, other: "MergeReport") -> "MergeReport":
        """Fold two reports (additive counters; ``merged`` takes the max)."""
        return MergeReport(
            sources=self.sources + other.sources,
            examined=self.examined + other.examined,
            merged=max(self.merged, other.merged),
            conflicts=self.conflicts + other.conflicts,
            superseded=self.superseded + other.superseded,
            load_errors=self.load_errors + other.load_errors,
        )


def record_payload(rec: TuningRecord) -> TuningRecord:
    """The record with its hybrid commit stamp zeroed — what two workers
    must agree on for their records to count as the *same* result. Sharded
    sweeps of one suite produce per-shard clocks and per-run wall stamps,
    so equality checks (and conflict detection) must ignore both
    ``version`` and ``wall``."""
    return dataclasses.replace(rec, version=0, wall=0.0)


def _stamp(rec: TuningRecord) -> Tuple[float, int]:
    """The hybrid commit stamp last-writer-wins orders on: wall clock
    first (comparable across producers), producer version counter second
    (breaks sub-resolution ties within one producer; sole order for
    legacy wall-less artifacts, which all carry wall 0.0)."""
    return (rec.wall, rec.version)


def _wins(challenger: TuningRecord, incumbent: TuningRecord) -> bool:
    """Deterministic total order for last-writer-wins: the hybrid
    (wall, version) stamp first, then measured tflops, then
    (policy, cfg, g) name order as the final arbitrary-but-stable
    tiebreak. Symmetric: merge order never changes the winner."""
    return (
        *_stamp(challenger),
        challenger.tflops,
        challenger.policy,
        challenger.cfg,
        challenger.g,
    ) > (
        *_stamp(incumbent),
        incumbent.tflops,
        incumbent.policy,
        incumbent.cfg,
        incumbent.g,
    )


def merge_records(
    into: TuningDatabase,
    records: Iterable[Tuple[TuningRecord, Optional[Dict[str, float]]]],
    report: Optional[MergeReport] = None,
) -> MergeReport:
    """Fold (record, per_policy) pairs into ``into`` under last-writer-wins.
    Mutates ``into`` (bumping its ``version`` clock past every applied
    record) and returns the report."""
    report = report if report is not None else MergeReport()
    for rec, per_policy in records:
        report.examined += 1
        cur = into.records.get(rec.size)
        if cur is not None and record_payload(cur) != record_payload(rec):
            if _stamp(cur) == _stamp(rec):
                report.conflicts += 1
            report.superseded += 1
        if cur is None or _wins(rec, cur):
            into.records[rec.size] = rec
            # the per-policy table must describe the stored record: install
            # the winner's (when it has one) or drop the loser's stale one
            # — fig2-tolerance-style consumers must never read measurements
            # that belong to a superseded record
            if per_policy is not None:
                into.per_policy[rec.size] = per_policy
            elif cur is not None and record_payload(cur) != record_payload(rec):
                into.per_policy.pop(rec.size, None)
            into.version = max(into.version, rec.version)
    report.merged = len(into.records)
    return report


def merge_databases(
    dbs: Sequence[TuningDatabase],
    into: Optional[TuningDatabase] = None,
) -> Tuple[TuningDatabase, MergeReport]:
    """Merge N workers' databases into one (inputs are not mutated unless
    one of them is passed as ``into``)."""
    out = into if into is not None else TuningDatabase()
    report = MergeReport(sources=len(dbs))
    for db in dbs:
        merge_records(
            out,
            ((rec, db.per_policy.get(key)) for key, rec in db.records.items()),
            report,
        )
        report.load_errors += db.load_errors
        if db.calibration is not None:
            # calibrations LWW-merge under the same hybrid (wall, version)
            # stamp as records (ties broken deterministically — see
            # calibrate.better_calibration), so the fleet converges on one
            # fitted machine whatever order the shards arrive in
            had = out.calibration
            out.set_calibration(db.calibration, stamp=False)
            if had is not None and dataclasses.replace(
                had, wall=0.0, version=0
            ) != dataclasses.replace(db.calibration, wall=0.0, version=0):
                report.superseded += 1  # one of the two differing fits lost
    return out, report


def merge_journal_shards(
    paths: Sequence[str],
    into: Optional[TuningDatabase] = None,
    missing_ok: bool = False,
) -> Tuple[TuningDatabase, MergeReport]:
    """Reassemble journal shards (one append-only JSONL per worker) into one
    database. Each shard replays into its own staging database first — that
    preserves intra-shard commit order (later lines win within a shard) and
    the producers' version stamps — then staging databases merge under
    last-writer-wins. Torn final lines and malformed lines are skipped and
    totalled in the report (``replay_journal`` semantics)."""
    staged: List[TuningDatabase] = []
    for path in paths:
        db = TuningDatabase()
        db.replay_journal(path, missing_ok=missing_ok)
        staged.append(db)
    out, report = merge_databases(staged, into=into)
    report.sources = len(paths)
    return out, report


def apply_journal_db(
    into: TuningDatabase, journal_db: TuningDatabase
) -> TuningDatabase:
    """Apply journal-derived records ON TOP of a snapshot database —
    unconditional overwrite, the ``TuningDatabase.load(path, journal=...)``
    contract: a journal post-dates the snapshot it accompanies, so its
    records win regardless of commit stamps. The structural precedence is
    deliberate even now that stamps carry a wall clock: a snapshot
    regenerated on a skewed (or simply later-running) host must never
    outrank the online commits its own journal recorded after it.
    Producer stamps are preserved; the clock fast-forwards."""
    for key, rec in journal_db.records.items():
        pp = journal_db.per_policy.get(key)
        if pp is None and key in into.per_policy:
            cur = into.records.get(key)
            if cur is None or record_payload(cur) != record_payload(rec):
                into.per_policy.pop(key, None)  # must not describe the loser
        into.add_record(rec, pp, stamp=False)
    if journal_db.calibration is not None:
        # same structural precedence as records: the journal post-dates the
        # snapshot it accompanies, so its calibration wins outright
        into.set_calibration(journal_db.calibration, stamp=False, force=True)
    into.load_errors += journal_db.load_errors
    return into


def merge_sieves(
    sieves: Sequence[OpenSieve], generation: Optional[int] = None
) -> OpenSieve:
    """Union N workers' sieves (see :meth:`OpenSieve.merge`); the result's
    generation lands past every input so hot-swap consumers re-resolve.
    Always returns a detached sieve — inputs are never aliased or mutated,
    so a worker's live sieve keeps serving while the union is assembled."""
    if not sieves:
        raise ValueError("merge_sieves needs at least one sieve")
    out = OpenSieve.from_bytes(sieves[0].to_bytes())  # detached copy
    out.policies = sieves[0].policies
    for s in sieves[1:]:
        out = out.merge(s, generation=0)
    out.generation = (
        generation
        if generation is not None
        else max(s.generation for s in sieves) + 1
    )
    return out


def federate_selector(
    selector: KernelSelector,
    dbs: Sequence[TuningDatabase] = (),
    journals: Sequence[str] = (),
    sieves: Sequence[OpenSieve] = (),
    capacity: int = 10_000,
    fp_rate: float = 0.01,
    missing_ok: bool = False,
) -> MergeReport:
    """Fold fleet state into one worker's selector and hot-swap.

    The worker's own database is the merge base (its in-process commits keep
    last-writer-wins standing against stale fleet copies); sibling databases
    and journal shards fold in on top. The new sieve is built under
    ``max(every input generation, selector's) + 1`` — either by unioning the
    supplied sibling ``sieves`` and folding in any merged winners they have
    not seen, or by rebuilding from the merged database — and the hot-swap
    drops every memoised pick, so the very next dispatch of a fingerprint
    tuned in a sibling process resolves as a database hit here."""
    base = selector.db if selector.db is not None else TuningDatabase()
    merged_report = MergeReport()
    if dbs:
        _, r = merge_databases(list(dbs), into=base)
        merged_report = merged_report.combine(r)
    if journals:
        _, r = merge_journal_shards(list(journals), into=base, missing_ok=missing_ok)
        merged_report = merged_report.combine(r)
    merged_report.merged = len(base.records)

    generation = selector.sieve_generation
    if sieves:
        generation = max(generation, *(s.generation for s in sieves))
    generation += 1
    if sieves:
        sieve = merge_sieves(list(sieves), generation=generation)
        # winners the sibling sieves never encoded (e.g. records that only
        # travelled as journal shards) still need to be queryable
        sieve.build_from_winners(base.winners())
    else:
        sieve = base.build_sieve(
            capacity=capacity, fp_rate=fp_rate, generation=generation
        )
    selector.hot_swap(db=base, sieve=sieve, keys=None, calibration=base.calibration)
    log.info(
        "federated merge: %d sources, %d records examined -> %d merged "
        "(%d conflicts, %d superseded, %d load errors), sieve generation %d",
        merged_report.sources,
        merged_report.examined,
        merged_report.merged,
        merged_report.conflicts,
        merged_report.superseded,
        merged_report.load_errors,
        generation,
    )
    return merged_report


def selection_table(
    selector: KernelSelector, keys: Iterable[OpKey]
) -> Dict[OpKey, Tuple[str, str, int]]:
    """(policy, cfg, g) the selector's database resolves for each key —
    the equivalence surface federated tests/benchmarks compare between a
    merged fleet and a single-worker full sweep."""
    out: Dict[OpKey, Tuple[str, str, int]] = {}
    for key in keys:
        rec = selector.db.records.get(key) if selector.db is not None else None
        if rec is not None:
            out[key] = (rec.policy, rec.cfg, rec.g)
    return out
