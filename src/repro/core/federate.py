"""Federated tuning: merge what N workers learned into one selection state.

The offline :class:`~repro.core.tuner.Tuner` shards a sweep across workers
(``Tuner.tune(shard=(i, n))``) and serving processes each append to their own
journal shard; this module is the reassembly layer that turns those partial
artifacts back into ONE :class:`~repro.core.tuner.TuningDatabase` + one
:class:`~repro.core.opensieve.OpenSieve`, so every worker's next
:meth:`~repro.core.selector.KernelSelector.hot_swap` dispatches from the
union instead of re-discovering what a sibling already tuned.

Merge semantics:

  * **Records** — last-writer-wins per fingerprint key on the record's
    hybrid ``(wall, version)`` commit stamp: the wall clock orders commits
    *across* producers (a true time order, up to host clock sync), the
    producer's ``version`` counter breaks sub-resolution ties *within*
    one. A full stamp tie between *differing* payloads is a real conflict
    (two workers tuned the same fingerprint at indistinguishable times):
    it is counted in ``MergeReport.conflicts`` and resolved
    deterministically — higher measured tflops, then policy / cfg / g name
    order — so the merged database is identical whatever order the shards
    arrive in. Records that lose are counted in ``superseded``. Sharded
    sweeps partition fingerprints disjointly, so an offline federated
    sweep merges with zero conflicts and is record-identical (modulo local
    commit stamps) to the single-worker full sweep.

    Clock caveat: the wall half of the stamp is only as good as host clock
    sync; where a *structural* precedence exists it still wins outright —
    journals replay *on top of* the snapshot they post-date
    (``apply_journal_db`` / ``TuningDatabase.load(path, journal=...)``
    overwrite unconditionally, whatever either side's stamps say), and
    ``federate_selector`` merges into the worker's live database, whose
    records stand unless a sibling's strictly outranks them. Artifacts
    written before the hybrid stamp parse with ``wall = 0.0`` and lose to
    any wall-stamped record.
  * **Sieves** — :meth:`OpenSieve.merge` bitwise-ORs the per-policy Bloom
    filters (inserting a key sets the same bits whichever worker's filter it
    landed in, so the union is bit-identical to rebuilding from the merged
    winner map) and bumps ``generation`` past every input, which is what
    makes selector hot-swaps drop picks memoised under any pre-merge sieve.
  * **Journals** — each shard replays into its own staging database first
    (preserving intra-shard time order and producer version stamps), then
    databases merge as above. Torn/malformed lines are skipped and summed
    into ``MergeReport.load_errors`` (see ``replay_journal``).

Every merge partitions per architecture class (:mod:`repro.core.arch`):
last-writer-wins plays out *within* a class (the ``into`` database's own
class in ``records``, every foreign class in its ``xarch`` bucket), so a
record tuned on a different machine generation can never supersede — or be
superseded by — a local measurement. Single-class fleets (including every
legacy arch-less artifact, which parses into ``"default"``) merge exactly
as before, byte for byte.

``federate_selector`` is the worker-side entry point: merge everything that
arrived from the fleet into this worker's selector and hot-swap, after which
a fingerprint tuned in any same-class sibling dispatches here as a database
hit — no miss, no re-tune — while other-class imports surface as ``"xarch"``
re-ranked warm seeds.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.arch import DEFAULT_ARCH
from repro.core.bloom import optimal_params
from repro.core.op import OpKey
from repro.core.opensieve import OpenSieve
from repro.core.policies import policy_from_name
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import TuningDatabase, TuningRecord
from repro.utils.logging import get_logger

log = get_logger("federate")


@dataclass
class MergeReport:
    """What a federated merge did — the observability surface CI and the
    serve CLI print, so shard skew is a number rather than a mystery."""

    sources: int = 0  # databases / journal shards consumed
    examined: int = 0  # records read across all sources
    merged: int = 0  # distinct fingerprint keys in the result
    conflicts: int = 0  # same key, same version, DIFFERENT payload
    superseded: int = 0  # records that lost last-writer-wins
    load_errors: int = 0  # malformed/torn journal lines skipped

    def combine(self, other: "MergeReport") -> "MergeReport":
        """Fold two reports (additive counters; ``merged`` takes the max)."""
        return MergeReport(
            sources=self.sources + other.sources,
            examined=self.examined + other.examined,
            merged=max(self.merged, other.merged),
            conflicts=self.conflicts + other.conflicts,
            superseded=self.superseded + other.superseded,
            load_errors=self.load_errors + other.load_errors,
        )


def record_payload(rec: TuningRecord) -> TuningRecord:
    """The record with its hybrid commit stamp zeroed — what two workers
    must agree on for their records to count as the *same* result. Sharded
    sweeps of one suite produce per-shard clocks and per-run wall stamps,
    so equality checks (and conflict detection) must ignore both
    ``version`` and ``wall``."""
    return dataclasses.replace(rec, version=0, wall=0.0)


def _stamp(rec: TuningRecord) -> Tuple[float, int]:
    """The hybrid commit stamp last-writer-wins orders on: wall clock
    first (comparable across producers), producer version counter second
    (breaks sub-resolution ties within one producer; sole order for
    legacy wall-less artifacts, which all carry wall 0.0)."""
    return (rec.wall, rec.version)


def _wins(challenger: TuningRecord, incumbent: TuningRecord) -> bool:
    """Deterministic total order for last-writer-wins: the hybrid
    (wall, version) stamp first, then measured tflops, then
    (policy, cfg, g) name order as the final arbitrary-but-stable
    tiebreak. Symmetric: merge order never changes the winner."""
    return (
        *_stamp(challenger),
        challenger.tflops,
        challenger.policy,
        challenger.cfg,
        challenger.g,
    ) > (
        *_stamp(incumbent),
        incumbent.tflops,
        incumbent.policy,
        incumbent.cfg,
        incumbent.g,
    )


def merge_records(
    into: TuningDatabase,
    records: Iterable[Tuple[TuningRecord, Optional[Dict[str, float]]]],
    report: Optional[MergeReport] = None,
) -> MergeReport:
    """Fold (record, per_policy) pairs into ``into`` under last-writer-wins.
    Mutates ``into`` (bumping its ``version`` clock past every applied
    record) and returns the report.

    Last-writer-wins plays out per arch class: a record routes into
    ``into.records`` (its class matches ``into.arch``) or the matching
    ``into.xarch`` bucket, and only contends with incumbents of its OWN
    class — cross-class supersession is impossible by construction. The
    per-policy sweep table only ever describes own-class records."""
    report = report if report is not None else MergeReport()
    for rec, per_policy in records:
        report.examined += 1
        own_class = rec.arch == into.arch
        bucket = into.records if own_class else into.xarch.setdefault(rec.arch, {})
        cur = bucket.get(rec.size)
        if cur is not None and record_payload(cur) != record_payload(rec):
            if _stamp(cur) == _stamp(rec):
                report.conflicts += 1
            report.superseded += 1
        if cur is None or _wins(rec, cur):
            bucket[rec.size] = rec
            # the per-policy table must describe the stored record: install
            # the winner's (when it has one) or drop the loser's stale one
            # — fig2-tolerance-style consumers must never read measurements
            # that belong to a superseded record
            if own_class:
                if per_policy is not None:
                    into.per_policy[rec.size] = per_policy
                elif cur is not None and record_payload(cur) != record_payload(rec):
                    into.per_policy.pop(rec.size, None)
            into.version = max(into.version, rec.version)
    report.merged = into.n_records()
    return report


def merge_databases(
    dbs: Sequence[TuningDatabase],
    into: Optional[TuningDatabase] = None,
) -> Tuple[TuningDatabase, MergeReport]:
    """Merge N workers' databases into one (inputs are not mutated unless
    one of them is passed as ``into``). Sources may carry any mix of arch
    classes — every record (own and ``xarch``) re-routes against the
    result's class, so heterogeneous fleets fold into one database whose
    ``records`` stay pure local-class."""
    out = into if into is not None else TuningDatabase()
    report = MergeReport(sources=len(dbs))
    for db in dbs:
        all_records = [
            (rec, db.per_policy.get(key)) for key, rec in db.records.items()
        ] + [
            (rec, None)
            for recs in db.xarch.values()
            for rec in recs.values()
        ]
        merge_records(out, all_records, report)
        report.load_errors += db.load_errors
        if db.calibration is not None:
            # calibrations LWW-merge under the same hybrid (wall, version)
            # stamp as records (ties broken deterministically — see
            # calibrate.better_calibration), so the fleet converges on one
            # fitted machine PER ARCH CLASS whatever order shards arrive in
            # (set_calibration routes foreign-class fits to
            # ``xarch_calibrations`` — they never steer the local model)
            had = out.calibration
            out.set_calibration(db.calibration, stamp=False)
            if (
                getattr(db.calibration, "arch", DEFAULT_ARCH) == out.arch
                and had is not None
                and dataclasses.replace(had, wall=0.0, version=0)
                != dataclasses.replace(db.calibration, wall=0.0, version=0)
            ):
                report.superseded += 1  # one of the two differing fits lost
        for cm in db.xarch_calibrations.values():
            out.set_calibration(cm, stamp=False)
        out.arch_profiles.update(db.arch_profiles)
    return out, report


def merge_journal_shards(
    paths: Sequence[str],
    into: Optional[TuningDatabase] = None,
    missing_ok: bool = False,
) -> Tuple[TuningDatabase, MergeReport]:
    """Reassemble journal shards (one append-only JSONL per worker) into one
    database. Each shard replays into its own staging database first — that
    preserves intra-shard commit order (later lines win within a shard) and
    the producers' version stamps — then staging databases merge under
    last-writer-wins. Torn final lines and malformed lines are skipped and
    totalled in the report (``replay_journal`` semantics). Staging databases
    adopt the target's arch class so stamped records route identically
    whether they replay here or directly into the target."""
    own_arch = into.arch if into is not None else DEFAULT_ARCH
    staged: List[TuningDatabase] = []
    for path in paths:
        db = TuningDatabase(arch=own_arch)
        db.replay_journal(path, missing_ok=missing_ok)
        staged.append(db)
    out, report = merge_databases(staged, into=into)
    report.sources = len(paths)
    return out, report


def apply_journal_db(
    into: TuningDatabase, journal_db: TuningDatabase
) -> TuningDatabase:
    """Apply journal-derived records ON TOP of a snapshot database —
    unconditional overwrite, the ``TuningDatabase.load(path, journal=...)``
    contract: a journal post-dates the snapshot it accompanies, so its
    records win regardless of commit stamps. The structural precedence is
    deliberate even now that stamps carry a wall clock: a snapshot
    regenerated on a skewed (or simply later-running) host must never
    outrank the online commits its own journal recorded after it.
    Producer stamps are preserved; the clock fast-forwards. The overwrite
    is per arch class: foreign-class journal records land in (and only
    displace within) their ``xarch`` bucket."""
    for key, rec in journal_db.records.items():
        pp = journal_db.per_policy.get(key)
        if pp is None and rec.arch == into.arch and key in into.per_policy:
            cur = into.records.get(key)
            if cur is None or record_payload(cur) != record_payload(rec):
                into.per_policy.pop(key, None)  # must not describe the loser
        into.add_record(rec, pp, stamp=False)
    for recs in journal_db.xarch.values():
        for rec in recs.values():
            into.add_record(rec, None, stamp=False)
    if journal_db.calibration is not None:
        # same structural precedence as records: the journal post-dates the
        # snapshot it accompanies, so its calibration wins outright (routed
        # per class — a foreign-class fit forces only its own bucket)
        into.set_calibration(journal_db.calibration, stamp=False, force=True)
    for cm in journal_db.xarch_calibrations.values():
        into.set_calibration(cm, stamp=False)
    into.arch_profiles.update(journal_db.arch_profiles)
    into.load_errors += journal_db.load_errors
    return into


def merge_sieves(
    sieves: Sequence[OpenSieve], generation: Optional[int] = None
) -> OpenSieve:
    """Union N workers' sieves (see :meth:`OpenSieve.merge`); the result's
    generation lands past every input so hot-swap consumers re-resolve.
    Always returns a detached sieve — inputs are never aliased or mutated,
    so a worker's live sieve keeps serving while the union is assembled."""
    if not sieves:
        raise ValueError("merge_sieves needs at least one sieve")
    out = OpenSieve.from_bytes(sieves[0].to_bytes())  # detached copy
    out.policies = sieves[0].policies
    out.capacity = sieves[0].capacity
    out.fp_rate = sieves[0].fp_rate
    for s in sieves[1:]:
        out = out.merge(s, generation=0)
    out.generation = (
        generation
        if generation is not None
        else max(s.generation for s in sieves) + 1
    )
    return out


def _sieve_geometry(sieve: Optional[OpenSieve]) -> Optional[Tuple[int, int]]:
    """(n_bits, n_hashes) of a sieve's filters (None when unknowable)."""
    if sieve is None:
        return None
    for f in sieve.filters.values():
        return (f.n_bits, f.n_hashes)
    return None


def federate_selector(
    selector: KernelSelector,
    dbs: Sequence[TuningDatabase] = (),
    journals: Sequence[str] = (),
    sieves: Sequence[OpenSieve] = (),
    capacity: Optional[int] = None,
    fp_rate: Optional[float] = None,
    missing_ok: bool = False,
) -> SelectorState:
    """Fold fleet state into one worker's selector and hot-swap.

    The worker's own database is the merge base (its in-process commits keep
    last-writer-wins standing against stale fleet copies); sibling databases
    and journal shards fold in on top, partitioned per arch class. The new
    sieve is built under ``max(every input generation, selector's) + 1`` —
    either by unioning the supplied sibling ``sieves`` and folding in any
    merged winners they have not seen, or by rebuilding from the merged
    database — and the hot-swap drops every memoised pick, so the very next
    dispatch of a fingerprint tuned in a same-class sibling resolves as a
    database hit here (other classes: an ``"xarch"`` warm seed).

    ``capacity``/``fp_rate`` default to the geometry of the selector's
    *installed* sieve — historical fixed defaults could silently rebuild a
    sieve whose Bloom parameters disagreed with what the worker was serving
    (poisoning any later :meth:`OpenSieve.merge`). Passing them explicitly
    against a mismatched installed sieve raises the merge error up front,
    with both configurations named, instead of deep inside a later union.

    Installs — and returns — the :class:`~repro.core.selector.SelectorState`
    snapshot; the :class:`MergeReport` rides along as ``state.report`` (and
    via delegation: ``state.merged``, ``state.conflicts``, ...)."""
    own_sieve = selector.sieve
    explicit = capacity is not None or fp_rate is not None
    if capacity is None:
        own_cap = own_sieve.capacity if own_sieve is not None else None
        capacity = own_cap if own_cap is not None else 10_000
    if fp_rate is None:
        own_fp = own_sieve.fp_rate if own_sieve is not None else None
        fp_rate = own_fp if own_fp is not None else 0.01
    own_geom = _sieve_geometry(own_sieve)
    if explicit and own_geom is not None:
        n_bits, n_hashes = optimal_params(capacity, fp_rate)
        # BloomFilter pads n_bits up to a whole byte; compare what a filter
        # would actually be built with, not the raw formula output
        want = (n_bits + (-n_bits % 8), n_hashes)
        if want != own_geom:
            raise ValueError(
                "cannot merge BloomFilters with mismatched parameters: "
                f"requested capacity={capacity}, fp_rate={fp_rate} derives "
                f"(n_bits={want[0]}, n_hashes={want[1]}) but the selector's "
                f"installed sieve was built with (n_bits={own_geom[0]}, "
                f"n_hashes={own_geom[1]})"
            )
    if sieves:
        first = _sieve_geometry(sieves[0])
        for i, s in enumerate(sieves[1:], start=1):
            geom = _sieve_geometry(s)
            if geom != first:
                raise ValueError(
                    "cannot merge BloomFilters with mismatched parameters: "
                    f"sieve 0 was built with (n_bits, n_hashes) = {first} "
                    f"but sieve {i} with {geom}"
                )

    base = (
        selector.db
        if selector.db is not None
        else TuningDatabase(arch=selector.arch)
    )
    merged_report = MergeReport()
    if dbs:
        _, r = merge_databases(list(dbs), into=base)
        merged_report = merged_report.combine(r)
    if journals:
        _, r = merge_journal_shards(list(journals), into=base, missing_ok=missing_ok)
        merged_report = merged_report.combine(r)
    merged_report.merged = base.n_records()

    generation = selector.sieve_generation
    if sieves:
        generation = max(generation, *(s.generation for s in sieves))
    generation += 1
    if sieves:
        sieve = merge_sieves(list(sieves), generation=generation)
        # winners the sibling sieves never encoded (e.g. records that only
        # travelled as journal shards) still need to be queryable — each
        # class inserts under its own key encoding
        sieve.build_from_winners(base.winners(), arch=base.arch)
        for cls_name, recs in base.xarch.items():
            sieve.build_from_winners(
                {key: policy_from_name(r.policy) for key, r in recs.items()},
                arch=cls_name,
            )
    else:
        sieve = base.build_sieve(
            capacity=capacity, fp_rate=fp_rate, generation=generation
        )
    calibration = (
        base.calibration if base.calibration is not None else selector.calibration
    )
    state = SelectorState(
        db=base,
        sieve=sieve,
        calibration=calibration,
        arch=selector.arch,
        report=merged_report,
    )
    selector.hot_swap(state=state, keys=None)
    log.info(
        "federated merge: %d sources, %d records examined -> %d merged "
        "(%d conflicts, %d superseded, %d load errors), sieve generation %d",
        merged_report.sources,
        merged_report.examined,
        merged_report.merged,
        merged_report.conflicts,
        merged_report.superseded,
        merged_report.load_errors,
        generation,
    )
    return state


def selection_table(
    selector: KernelSelector, keys: Iterable[OpKey]
) -> Dict[OpKey, Tuple[str, str, int]]:
    """(policy, cfg, g) the selector's database resolves for each key —
    the equivalence surface federated tests/benchmarks compare between a
    merged fleet and a single-worker full sweep."""
    out: Dict[OpKey, Tuple[str, str, int]] = {}
    for key in keys:
        rec = selector.db.records.get(key) if selector.db is not None else None
        if rec is not None:
            out[key] = (rec.policy, rec.cfg, rec.g)
    return out
