"""Architecture classes: the tuning key that makes federation fleet-safe.

Federation (PR 4) merged records under the silent assumption that every
producer ran identical hardware — a winner tuned on one device generation
would overwrite (and poison) the winner another generation measured for the
same fingerprint. This module introduces the missing tuning parameter: an
:class:`ArchProfile` — a frozen, hashable description of the machine class a
record was measured on (lane count, VMEM capacity, the compute/bandwidth
roofline ratio, backend tag) — whose canonical string form (:attr:`ArchProfile.cls`)
is stamped onto every :class:`~repro.core.tuner.TuningRecord`.

The contract downstream:

  * records carrying the *same* arch class last-writer-wins merge exactly as
    before (:mod:`repro.core.federate` partitions per class);
  * records from a *different* class never become direct database hits —
    the selector re-ranks their policies under the local (calibrated)
    machine instead (the ``"xarch"`` warm-seed dispatch source), tritonBLAS'
    analytical model as the cross-arch translator;
  * legacy arch-less artifacts parse into the :data:`DEFAULT_ARCH` class
    (``"default"``) and keep dispatching byte-identically.

Profiles are *coarse* on purpose: two hosts of the same device generation
must land in the same class even when their calibrated constants differ by a
few percent, so the ratio term is quantized (:data:`_RATIO_STEP`). Deriving
a profile from a :class:`~repro.core.costmodel.Machine`
(:meth:`ArchProfile.from_machine`) or the live JAX device
(:func:`detect_arch`) yields the same class for the same hardware.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass

from repro.core.costmodel import V5E, Machine

#: arch class every record written before (or without) arch awareness
#: belongs to. Stamping it is encoding-free: journal lines, snapshots, and
#: sieve key bytes of ``"default"``-class artifacts stay byte-identical to
#: the pre-arch formats, which is what keeps single-class fleets (and every
#: existing artifact) on the exact PR-4 merge behavior.
DEFAULT_ARCH = "default"

#: quantization step of the compute/bandwidth ratio term: hosts of one
#: device generation must classify together despite calibration-level
#: drift in their fitted constants, so the ratio rounds to this granularity.
_RATIO_STEP = 25


@dataclass(frozen=True)
class ArchProfile:
    """One machine class: the coordinates tuning records federate within.

    Frozen and hashable — profiles key dictionaries (per-class record
    partitions, per-class calibrations) and participate in journal entries.
    """

    #: execution backend tag ("tpu", "gpu", "cpu", ...)
    backend: str = "tpu"
    #: parallel lanes the scheduler fills (cores / SMs / forced host devices)
    lanes: int = 8
    #: per-lane VMEM / shared-memory capacity in bytes (tile feasibility)
    vmem_bytes: int = V5E.vmem_bytes
    #: quantized peak-FLOP/s : HBM-byte/s roofline ratio — the "clock/byte"
    #: coordinate that separates device generations with the same lane count
    flops_per_byte: int = 250

    @property
    def cls(self) -> str:
        """Canonical class string records are stamped with (stable,
        human-readable: ``"tpu:l8:v16m:r250"``)."""
        return (
            f"{self.backend}:l{self.lanes}"
            f":v{self.vmem_bytes >> 20}m:r{self.flops_per_byte}"
        )

    @classmethod
    def from_machine(cls, mach: Machine, backend: str = "tpu") -> "ArchProfile":
        """Classify a cost-model machine (nominal or calibrated base).

        The roofline ratio quantizes to :data:`_RATIO_STEP` so two hosts of
        one generation with slightly different calibrated constants land in
        the same class."""
        ratio = mach.peak_flops / max(mach.hbm_bw, 1.0)
        return cls(
            backend=backend,
            lanes=mach.lanes,
            vmem_bytes=mach.vmem_bytes,
            flops_per_byte=int(round(ratio / _RATIO_STEP)) * _RATIO_STEP,
        )

    def to_json(self) -> dict:
        """JSON payload (the ``{"arch": ...}`` journal entry body)."""
        d = asdict(self)
        d["cls"] = self.cls
        return d

    @classmethod
    def from_json(cls, d: dict) -> "ArchProfile":
        """Inverse of :meth:`to_json` (the redundant ``cls`` field is
        ignored — the class string is always re-derived, so a hand-edited
        payload cannot desynchronize the two)."""
        return cls(
            backend=str(d.get("backend", "tpu")),
            lanes=int(d.get("lanes", 8)),
            vmem_bytes=int(d.get("vmem_bytes", V5E.vmem_bytes)),
            flops_per_byte=int(d.get("flops_per_byte", 250)),
        )


def detect_arch(mach: Machine = V5E) -> ArchProfile:
    """Profile of the live JAX device (backend tag from the device platform,
    machine coordinates from ``mach`` — the nominal/overridden machine the
    caller scores under). Falls back to ``"cpu"`` when no device backend is
    importable, so classification never blocks startup."""
    backend = "cpu"
    try:  # pragma: no cover - depends on the container's device runtime
        import jax

        backend = jax.devices()[0].platform
    except Exception:  # noqa: BLE001 - any backend failure means "cpu"
        pass
    return ArchProfile.from_machine(mach, backend=backend)


def arch_entry(profile: ArchProfile) -> str:
    """One journal line declaring the producer's arch profile — the third
    tagged entry type the tuning journal understands (see the registry in
    :mod:`repro.core.tuner`). Consumers store it in
    ``TuningDatabase.arch_profiles`` keyed by class string, so a merged
    fleet knows the coordinates behind every class it carries."""
    return json.dumps({"arch": profile.to_json()})


def append_arch(path: str, profile: ArchProfile) -> None:
    """Append an arch-profile entry to the JSONL journal."""
    with open(path, "a") as f:
        f.write(arch_entry(profile) + "\n")
