"""First-class GEMM operator spec — the dispatch key of Stream-K++ selection.

The paper keys its tuned database and Bloom filters on a bare ``(M, N, K)``.
That covers dense 2-D projections but not the shapes a serving stack
actually runs: grouped MoE expert GEMMs (stacked ``(G, K, N)`` weights),
batched GEMMs, mixed dtypes, and activation epilogues fused into the
kernel's flush/fix-up phase. ``GemmOp`` captures the full problem
fingerprint; everything downstream (selector cache, tuning database, Bloom
encoding) keys on it, so grouped and fused variants tune and prune
independently — the "easy adaptation to new problem sizes ... or additional
tuning parameters" extension point the paper calls out.

Key compatibility: a *plain* op (one group, default epilogue) encodes to the
paper's original ``encode_mnk`` bytes and keys as the legacy ``(M, N, K)``
tuple, so tuning artifacts produced for the 2-D path keep working unchanged.

Grouped op forms: a grouped op may dispatch as a *per-group loop* (one
kernel launch per expert group — the original backend) or *fused* (one
persistent-grid kernel spanning the concatenated tile space of all groups,
``fused=True``). The two execute differently enough that they must tune,
journal, Bloom-prune and federate separately, so a fused op keys on the
8-part extended tuple ending in the :data:`GROUPED_FUSED_MARKER`. Legacy
journal/database artifacts carry only 3- and 7-part keys: they parse
unchanged and keep matching exactly the loop-form ops they were tuned for —
an old G-keyed record never leaks onto the fused path (or vice versa).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Optional, Tuple, Union

import jax.numpy as jnp

from repro.core.bloom import encode_mnk

_ACTIVATIONS = ("none", "relu", "gelu", "silu", "square")
_BINARIES = ("none", "mul_silu", "add")


@dataclass(frozen=True)
class Epilogue:
    """Fused post-accumulation epilogue, applied to the f32 accumulator
    before the final cast/store (zero extra HBM passes):

      1. ``bias``       — add a per-output-column bias vector,
      2. ``activation`` — unary activation (relu/gelu/silu/square),
      3. ``binary``     — combine with a second pre-computed operand:
           * ``mul_silu`` : ``acc * silu(operand)`` (the swiglu gate-mul),
           * ``add``      : ``acc + operand``       (residual add).
    """

    activation: str = "none"
    bias: bool = False
    binary: str = "none"

    def __post_init__(self):
        if self.activation not in _ACTIVATIONS:
            raise ValueError(
                f"unknown activation {self.activation!r}; valid: {_ACTIVATIONS}"
            )
        if self.binary not in _BINARIES:
            raise ValueError(
                f"unknown binary epilogue {self.binary!r}; valid: {_BINARIES}"
            )

    @property
    def is_none(self) -> bool:
        """True iff every stage is disabled (the identity epilogue)."""
        return self.activation == "none" and not self.bias and self.binary == "none"

    @property
    def name(self) -> str:
        """Canonical fingerprint string, e.g. ``bias+gelu`` / ``mul_silu``."""
        parts = []
        if self.bias:
            parts.append("bias")
        if self.activation != "none":
            parts.append(self.activation)
        if self.binary != "none":
            parts.append(self.binary)
        return "+".join(parts) if parts else "none"

    def apply(self, acc, *, bias=None, operand=None):
        """Reference semantics on an f32 accumulator (backends and kernels
        must match this)."""
        if self.bias:
            if bias is None:
                raise ValueError(f"epilogue {self.name} requires a bias operand")
            acc = acc + bias.astype(jnp.float32)
        if self.activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        elif self.activation == "gelu":
            import jax

            acc = jax.nn.gelu(acc)
        elif self.activation == "silu":
            import jax

            acc = jax.nn.silu(acc)
        elif self.activation == "square":
            acc = jnp.square(jnp.maximum(acc, 0.0))
        if self.binary != "none":
            if operand is None:
                raise ValueError(f"epilogue {self.name} requires an operand")
            opf = operand.astype(jnp.float32)
            if self.binary == "mul_silu":
                import jax

                acc = acc * jax.nn.silu(opf)
            else:  # "add"
                acc = acc + opf
        return acc


#: the do-nothing epilogue
EPILOGUE_NONE = Epilogue()


def as_epilogue(epilogue: Union[None, str, Epilogue]) -> Epilogue:
    """Normalise None / legacy activation string / Epilogue to Epilogue."""
    if epilogue is None:
        return EPILOGUE_NONE
    if isinstance(epilogue, Epilogue):
        return epilogue
    return Epilogue(activation=epilogue)


#: op-form marker appended to the key of a fused grouped op (single
#: persistent-grid kernel over the concatenated group tile space); its
#: presence is what separates fused records from loop-form grouped records.
GROUPED_FUSED_MARKER = "grouped_fused"

#: selector/db key: legacy (M, N, K) for plain ops, the extended tuple
#: (M, N, K, G, in_dtype, out_dtype, epilogue_name) for grouped/batched/
#: fused-epilogue ops, or the 8-part form with the trailing
#: ``GROUPED_FUSED_MARKER`` for single-kernel fused grouped ops.
OpKey = Union[
    Tuple[int, int, int],
    Tuple[int, int, int, int, str, str, str],
    Tuple[int, int, int, int, str, str, str, str],
]


@dataclass(frozen=True)
class GemmOp:
    """Full fingerprint of one GEMM dispatch.

    ``m, n, k`` are *global* logical dims; ``divisors`` (and ``g_divisor``
    for the group dim) are the GSPMD sharding factors, so ``local`` is the
    per-shard problem the MXU actually sees — which is what selection keys
    on. ``g`` counts groups/batches: stacked expert weights ``(G, K, N)``
    dispatch as one op with ``g = G``.

    ``fused`` marks the single-kernel grouped op form: the pallas backend
    lowers all G groups in ONE persistent-grid ``pallas_call`` over the
    concatenated tile space instead of one launch per group. It is a real
    dispatch-behaviour axis, so it is part of the fingerprint (8-part key,
    see :data:`GROUPED_FUSED_MARKER`); ``fused=False`` (the default for
    directly constructed ops) keys identically to pre-fusion artifacts.
    """

    m: int
    n: int
    k: int
    g: int = 1
    kind: str = "plain"  # "plain" | "grouped" | "batched"
    in_dtype: str = "float32"
    out_dtype: str = "float32"
    divisors: Tuple[int, int, int] = (1, 1, 1)
    g_divisor: int = 1
    epilogue: Epilogue = field(default_factory=Epilogue)
    fused: bool = False

    def __post_init__(self):
        if self.kind not in ("plain", "grouped", "batched"):
            raise ValueError(f"unknown GemmOp kind {self.kind!r}")
        if self.kind == "plain" and self.g != 1:
            raise ValueError("plain ops have g == 1; use gemm_grouped/batched")
        if self.fused and self.kind != "grouped":
            raise ValueError(
                f"fused is the grouped single-kernel op form; kind={self.kind!r}"
            )

    # -- shapes ------------------------------------------------------------
    @property
    def global_mnk(self) -> Tuple[int, int, int]:
        """Unsharded logical problem dims."""
        return (self.m, self.n, self.k)

    @property
    def local(self) -> Tuple[int, int, int]:
        """Per-shard dims after dividing out the GSPMD sharding factors."""
        dm, dn, dk = self.divisors
        return (
            max(1, self.m // dm),
            max(1, self.n // dn),
            max(1, self.k // dk),
        )

    @property
    def g_local(self) -> int:
        """Groups per shard after expert-parallel sharding."""
        return max(1, self.g // self.g_divisor)

    @property
    def mnk_compatible(self) -> bool:
        """Shape-only op (one group, no epilogue): may *consult* tuning
        artifacts keyed on a bare (M, N, K), whatever its dtypes — the
        paper's databases/sieves are dtype-agnostic."""
        return (
            self.g_local == 1
            and self.kind == "plain"
            and self.epilogue.is_none
        )

    @property
    def is_plain(self) -> bool:
        """Keys/encodes identically to the paper's 2-D (M, N, K) path.

        Restricted to the canonical f32->f32 case: a bare (M, N, K) key
        carries no dtype, so only the default-dtype op may claim it as its
        *own* key — otherwise same-shape ops of different dtypes would
        silently overwrite each other's tuning records. Non-f32 shape-only
        ops still read MNK artifacts via :attr:`mnk_compatible` fallback
        in the selector."""
        return (
            self.mnk_compatible
            and self.in_dtype == "float32"
            and self.out_dtype == "float32"
        )

    # -- keys --------------------------------------------------------------
    @property
    def key(self) -> OpKey:
        """Selector/database key: the narrowest form that is still exact."""
        m, n, k = self.local
        if self.is_plain:
            return (m, n, k)
        base = (m, n, k, self.g_local, self.in_dtype, self.out_dtype, self.epilogue.name)
        if self.fused:
            return base + (GROUPED_FUSED_MARKER,)
        return base

    def encode(self) -> bytes:
        """Canonical byte encoding of :attr:`key` (Bloom-filter probe key)."""
        return encode_key(self.key)

    # -- constructors ------------------------------------------------------
    @classmethod
    def plain(
        cls,
        m: int,
        n: int,
        k: int,
        *,
        divisors: Tuple[int, int, int] = (1, 1, 1),
        in_dtype: str = "float32",
        out_dtype: Optional[str] = None,
        epilogue: Union[None, str, Epilogue] = None,
    ) -> "GemmOp":
        """Build a 2-D (single-group) op — the paper's original surface."""
        return cls(
            int(m),
            int(n),
            int(k),
            in_dtype=in_dtype,
            out_dtype=out_dtype or in_dtype,
            divisors=divisors,
            epilogue=as_epilogue(epilogue),
        )


def encode_key(key: OpKey) -> bytes:
    """Canonical Bloom-filter bytes for an op key.

    3-tuples use the paper's original ``encode_mnk`` layout so pre-existing
    filters/databases built from bare problem sizes remain valid; extended
    keys append group count and dtype/epilogue fingerprints, and the fused
    grouped form additionally appends its op-form marker — so loop and
    fused records of the same shape never collide in a Bloom filter.
    """
    if len(key) == 3:
        return encode_mnk(*key)
    m, n, k, g = key[:4]
    tail = "|".join(str(part) for part in key[4:]).encode()
    return struct.pack("<4q", m, n, k, g) + tail


def encode_op(op: GemmOp) -> bytes:
    """Bloom key for a GemmOp (module-level convenience for ``op.encode``)."""
    return op.encode()


def key_to_str(key: OpKey) -> str:
    """JSON-safe key serialization (legacy "m,n,k" format preserved)."""
    return ",".join(str(x) for x in key)


def key_from_str(s: str) -> OpKey:
    """Inverse of :func:`key_to_str` for all three key generations.

    Legacy 3-part ``"m,n,k"`` and 7-part grouped/fused-epilogue keys parse
    exactly as they always did (and so keep dispatching the op forms they
    were tuned for — the per-group loop path for grouped records); 8-part
    keys carry the fused-grouped op-form marker."""
    parts = s.split(",")
    if len(parts) == 3:
        return tuple(int(x) for x in parts)  # type: ignore[return-value]
    if len(parts) not in (7, 8):
        raise ValueError(f"malformed op key {s!r}")
    m, n, k, g = (int(x) for x in parts[:4])
    return (m, n, k, g, *parts[4:])
