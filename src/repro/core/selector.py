"""Runtime kernel selection: Open-sieve query -> candidate policies -> pick.

Dispatch path for a GEMM of local shape (M, N, K):
  1. Exact tuning-database hit -> return the tuned (policy, config).
  2. Otherwise query the Bloom filters. Policies answering "definitely
     absent" are pruned (the paper's headline: up to ~95.8% of evaluations
     skipped, 100% true-negative rate). Surviving candidates are scored with
     the fast analytical model and the best wins.
  3. If every filter says absent (a size the tuner never saw and no filter
     aliases), fall back to the naive single-policy default the original
     Stream-K paper proposes — data-parallel — scored against ALL_SK for
     safety.

Selection happens at *trace time* (shapes are static under jit), so it costs
nothing at runtime on device; the recorded ``SelectionLog`` is how tests and
benchmarks introspect dispatch decisions.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.opensieve import OpenSieve
from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
    policy_from_name,
)
from repro.core.tuner import TuningDatabase
from repro.core.workpart import GemmShape

MNK = Tuple[int, int, int]


@dataclass(frozen=True)
class Selection:
    policy: Policy
    cfg: TileConfig
    source: str  # "tuned" | "sieve" | "fallback"
    evals: int  # how many (policy) evaluations the scorer performed
    pruned: int  # how many the Bloom filters eliminated


@dataclass
class SelectorStats:
    lookups: int = 0
    tuned_hits: int = 0
    sieve_hits: int = 0
    fallbacks: int = 0
    evals: int = 0
    pruned: int = 0

    @property
    def elimination_rate(self) -> float:
        tot = self.evals + self.pruned
        return self.pruned / tot if tot else 0.0


_CFG_BY_NAME = {c.name: c for c in DEFAULT_TILE_CONFIGS}


def _cfg_from_name(name: str) -> TileConfig:
    if name in _CFG_BY_NAME:
        return _CFG_BY_NAME[name]
    bm, bn, bk = (int(x) for x in name.split("x"))
    return TileConfig(bm, bn, bk)


class KernelSelector:
    def __init__(
        self,
        sieve: Optional[OpenSieve] = None,
        db: Optional[TuningDatabase] = None,
        mach: costmodel.Machine = costmodel.V5E,
        policies: Sequence[Policy] = ALL_POLICIES,
        tile_configs: Sequence[TileConfig] = DEFAULT_TILE_CONFIGS,
    ):
        self.sieve = sieve
        self.db = db
        self.mach = mach
        self.policies = tuple(policies)
        self.tile_configs = tuple(tile_configs)
        self.stats = SelectorStats()
        self._cache: Dict[MNK, Selection] = {}

    # -- scoring -----------------------------------------------------------
    def _score(self, size: MNK, pols: Sequence[Policy]) -> Tuple[Policy, TileConfig, int]:
        shape = GemmShape(*size)
        best = None
        evals = 0
        for pol in pols:
            cfg, tf = costmodel.best_config(shape, pol, self.mach, self.tile_configs)
            evals += 1
            if best is None or tf > best[2]:
                best = (pol, cfg, tf)
        return best[0], best[1], evals

    # -- public ------------------------------------------------------------
    def select(self, m: int, n: int, k: int) -> Selection:
        size = (int(m), int(n), int(k))
        if size in self._cache:
            return self._cache[size]
        self.stats.lookups += 1

        sel: Selection
        if self.db is not None and size in self.db.records:
            rec = self.db.records[size]
            sel = Selection(
                policy=policy_from_name(rec.policy),
                cfg=_cfg_from_name(rec.cfg),
                source="tuned",
                evals=0,
                pruned=len(self.policies),
            )
            self.stats.tuned_hits += 1
        elif self.sieve is not None:
            cands = self.sieve.candidates(size)
            pruned = len(self.policies) - len(cands)
            if cands:
                pol, cfg, evals = self._score(size, cands)
                sel = Selection(pol, cfg, "sieve", evals, pruned)
                self.stats.sieve_hits += 1
            else:
                pol, cfg, evals = self._score(size, (DP, ALL_SK))
                sel = Selection(pol, cfg, "fallback", evals, pruned)
                self.stats.fallbacks += 1
        else:
            pol, cfg, evals = self._score(size, self.policies)
            sel = Selection(pol, cfg, "fallback", evals, 0)
            self.stats.fallbacks += 1

        self.stats.evals += sel.evals
        self.stats.pruned += sel.pruned
        self._cache[size] = sel
        return sel


def default_selector() -> KernelSelector:
    """Selector with no tuning artifacts: pure cost-model scoring over all
    policies (used by models when no tuned database is supplied)."""
    return KernelSelector(sieve=None, db=None)
