"""Runtime kernel selection: Open-sieve query -> candidate policies -> pick.

Dispatch path for a :class:`repro.core.op.GemmOp` (selection keys on the op
fingerprint — per-shard local shape, group count, dtypes, epilogue):
  1. Exact tuning-database hit -> return the tuned (policy, config, g).
  2. Otherwise query the Bloom filters. Policies answering "definitely
     absent" are pruned (the paper's headline: up to ~95.8% of evaluations
     skipped, 100% true-negative rate). Surviving candidates are scored with
     the fast analytical model — at the op's *actual* operand byte-widths,
     jointly over the swept grid sizes — and the best wins.
  3. If every filter says absent (a size the tuner never saw and no filter
     aliases): with a :class:`~repro.core.calibrate.CalibratedMachine`
     installed, dispatch from the calibrated model's argmin over ALL
     policies (the ``"model"`` analytical-first warm start — still reported
     to the miss hook, so online adaptation measures hot shapes and
     promotes them to real database records); otherwise fall back to the
     naive single-policy default the original Stream-K paper proposes —
     data-parallel — scored against ALL_SK for safety.

Plain 2-D ops key as the legacy ``(M, N, K)`` tuple, so tuning databases and
sieves built from bare problem sizes keep working; grouped / epilogue-fused
ops key (and therefore tune and prune) independently.

Selection happens at *trace time* (shapes are static under jit), so it costs
nothing at runtime on device; the recorded ``SelectionLog`` is how tests and
benchmarks introspect dispatch decisions. ``SelectorStats`` counts every
dispatch exactly once (cold source, cache hit, or forced), and memoised
repeats re-credit their evals/pruned, so ``elimination_rate`` is weighted by
what the workload actually dispatched — not just by unique shapes.

Elimination accounting is honest about *who* did the eliminating: only
dispatches that actually consulted the Bloom filters credit ``pruned``. A
tuned database hit skips the filters entirely — it contributes zero evals
AND zero pruned, so a warm database drives ``elimination_rate`` toward the
sieve's true contribution instead of inflating the paper-headline metric.
Fully forced overrides perform no selection work and leave the rate
untouched.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.costmodel import DtypeBytes
from repro.core.op import GemmOp, OpKey
from repro.core.opensieve import OpenSieve
from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
    policy_from_name,
)
from repro.core.tuner import LEGACY_GRID, TuningDatabase
from repro.core.workpart import GemmShape

MNK = Tuple[int, int, int]


@dataclass(frozen=True)
class Selection:
    """One (policy, tile config, grid size) pick plus its provenance."""

    policy: Policy
    cfg: TileConfig
    source: str  # "tuned" | "sieve" | "model" | "fallback" | "forced"
    evals: int  # how many (policy) evaluations the scorer performed
    pruned: int  # how many the Bloom filters eliminated
    #: grid size the kernel launches with (tuned winner's g, or the scored
    #: best over the selector's grid sweep; LEGACY_GRID when nothing chose)
    g: int = LEGACY_GRID


@dataclass
class SelectorStats:
    """Per-selector lookup/eval counters (the paper's accounting unit)."""

    lookups: int = 0
    tuned_hits: int = 0
    sieve_hits: int = 0
    #: unseen fingerprints dispatched from the calibrated model's argmin —
    #: the analytical-first warm start (still misses for online adaptation)
    model_warm: int = 0
    fallbacks: int = 0
    cache_hits: int = 0  # memoised repeats of an already-selected op
    forced: int = 0  # caller-supplied (policy, cfg) overrides
    evals: int = 0
    pruned: int = 0  # policies genuinely eliminated by Bloom filters

    @property
    def elimination_rate(self) -> float:
        """Fraction of filter-consulted policy evaluations the sieve skipped.
        Tuned hits bypass the filters and contribute to neither term, so a
        warm database cannot inflate the sieve's paper-headline metric."""
        tot = self.evals + self.pruned
        return self.pruned / tot if tot else 0.0


_CFG_BY_NAME = {c.name: c for c in DEFAULT_TILE_CONFIGS}


def _cfg_from_name(name: str) -> TileConfig:
    if name in _CFG_BY_NAME:
        return _CFG_BY_NAME[name]
    bm, bn, bk = (int(x) for x in name.split("x"))
    return TileConfig(bm, bn, bk)


#: Miss-hook signature: called once per dispatch whose (memoised) selection
#: did NOT come from the tuning database — the signal online adaptation
#: feeds on. Must be cheap; it runs on the trace path.
MissHook = Callable[[GemmOp, Selection], None]


class KernelSelector:
    """The paper's three-stage selection pipeline, memoised per op key:
    tuned-database exact hit -> Bloom-sieve candidate pruning + cost-model
    scoring -> unsieved cost-model fallback."""

    def __init__(
        self,
        sieve: Optional[OpenSieve] = None,
        db: Optional[TuningDatabase] = None,
        mach: costmodel.Machine = costmodel.V5E,
        policies: Sequence[Policy] = ALL_POLICIES,
        tile_configs: Sequence[TileConfig] = DEFAULT_TILE_CONFIGS,
        on_miss: Optional[MissHook] = None,
        grid_sizes: Optional[Sequence[int]] = None,
        calibration=None,
    ):
        self.sieve = sieve
        self.db = db
        self.mach = mach
        self.policies = tuple(policies)
        self.tile_configs = tuple(tile_configs)
        self.on_miss = on_miss
        self.grid_sizes = (
            tuple(grid_sizes)
            if grid_sizes is not None
            else costmodel.default_grid_sizes(mach)
        )
        #: installed CalibratedMachine (or None): when set, all cost-model
        #: scoring runs under the fitted per-dtype-profile machine, and
        #: unseen fingerprints dispatch via the "model" source instead of
        #: the naive fallback
        self.calibration = calibration
        self.stats = SelectorStats()
        self._cache: Dict[OpKey, Selection] = {}

    @property
    def sieve_generation(self) -> int:
        """Build version of the currently installed sieve (0 when none)."""
        return self.sieve.generation if self.sieve is not None else 0

    def _notify_miss(self, op: GemmOp, sel: Selection) -> None:
        if self.on_miss is not None and sel.source != "tuned":
            self.on_miss(op, sel)

    # -- online adaptation --------------------------------------------------
    def hot_swap(
        self,
        db: Optional[TuningDatabase] = None,
        sieve: Optional[OpenSieve] = None,
        keys: Optional[Iterable[OpKey]] = None,
        calibration=None,
    ) -> int:
        """Install updated tuning artifacts mid-stream.

        Reference assignment is atomic, so in-flight lookups finish against
        whichever artifact they already grabbed — the old sieve serves until
        the swap lands. Memoised selections for ``keys`` (all keys when
        ``None``) are dropped so the next dispatch of a freshly tuned
        fingerprint re-resolves against the new database instead of
        replaying a stale sieve/fallback pick. Returns the number of cache
        entries invalidated."""
        if db is not None:
            self.db = db
        if sieve is not None:
            self.sieve = sieve
        if calibration is not None:
            # the (frozen, hashable) machines inside the calibration key
            # every scoring cache, so installing one can never read scores
            # memoised under the previous constants — but a new calibration
            # re-scores EVERY non-tuned pick, so the per-key memo is dropped
            # wholesale regardless of ``keys``
            self.calibration = calibration
            keys = None
        if keys is None:
            n = len(self._cache)
            self._cache.clear()
            return n
        return sum(1 for k in keys if self._cache.pop(k, None) is not None)

    # -- scoring -----------------------------------------------------------
    def scoring_machine(self, dt: DtypeBytes) -> costmodel.Machine:
        """Machine the cost model scores under for a byte-width profile:
        the installed calibration's per-profile fit, else the nominal
        machine. Frozen/hashable either way — it participates in every
        scoring-cache key."""
        if self.calibration is not None:
            return self.calibration.machine_for(dt)
        return self.mach

    def _score(
        self, size: MNK, pols: Sequence[Policy], dt: DtypeBytes
    ) -> Tuple[Policy, TileConfig, int, int]:
        """Best (policy, cfg, g) over the candidate policies — the argmin of
        :func:`costmodel.rank_candidates` at the op's real byte-widths,
        under the (possibly calibrated) scoring machine. ``evals`` counts
        *policies* scored (the unit Bloom pruning removes), whatever the
        width of the inner cfg x g sweep. ``size`` is a bare local (M, N, K)
        or an already-built shape (e.g. the GroupedGemmShape of a fused
        grouped op, whose concatenated tile space the model scores)."""
        shape = size if isinstance(size, GemmShape) else GemmShape(*size)
        pol, cfg, g, _ = costmodel.rank_candidates(
            shape,
            self.scoring_machine(dt),
            tuple(pols),
            self.tile_configs,
            self.grid_sizes,
            dt,
        )[0]
        return pol, cfg, g, len(pols)

    def _db_record(self, op: GemmOp):
        """Exact op-key hit first; shape-only ops of any dtype then fall
        back to the dtype-agnostic legacy (M, N, K) record (the paper's
        databases carry no dtype — a bf16 model must still benefit from
        artifacts tuned on bare sizes)."""
        if self.db is None:
            return None
        rec = self.db.records.get(op.key)
        if rec is None and op.mnk_compatible:
            rec = self.db.records.get(op.local)
        return rec

    def _sieve_candidates(self, op: GemmOp):
        if op.mnk_compatible and op.key != op.local:
            return self.sieve.candidates_any(op.key, op.local)
        return self.sieve.candidates(op.key)

    def _lookup(self, op: GemmOp) -> Tuple[Selection, bool]:
        """Memoised selection for an op; returns (selection, was_cached).
        No stats bookkeeping — callers categorise exactly once."""
        key = op.key
        if key in self._cache:
            return self._cache[key], True

        size = costmodel.op_shape(op)
        dt = costmodel.op_dtypes(op)
        sel: Selection
        rec = self._db_record(op)
        if rec is not None:
            # No filter was consulted: zero evals, zero pruned — a tuned hit
            # must not inflate the sieve's elimination rate.
            sel = Selection(
                policy=policy_from_name(rec.policy),
                cfg=_cfg_from_name(rec.cfg),
                source="tuned",
                evals=0,
                pruned=0,
                g=rec.g,
            )
        elif self.sieve is not None:
            cands = self._sieve_candidates(op)
            pruned = len(self.policies) - len(cands)
            if cands:
                pol, cfg, g, evals = self._score(size, cands, dt)
                sel = Selection(pol, cfg, "sieve", evals, pruned, g=g)
            elif self.calibration is not None:
                # every filter said "definitely absent" — with a calibrated
                # model installed, the unseen fingerprint dispatches from
                # the model's argmin over ALL policies (analytical-first
                # warm start) instead of the naive DP-vs-SK fallback
                pol, cfg, g, evals = self._score(size, self.policies, dt)
                sel = Selection(pol, cfg, "model", evals, pruned, g=g)
            else:
                pol, cfg, g, evals = self._score(size, (DP, ALL_SK), dt)
                sel = Selection(pol, cfg, "fallback", evals, pruned, g=g)
        elif self.calibration is not None:
            pol, cfg, g, evals = self._score(size, self.policies, dt)
            sel = Selection(pol, cfg, "model", evals, 0, g=g)
        else:
            pol, cfg, g, evals = self._score(size, self.policies, dt)
            sel = Selection(pol, cfg, "fallback", evals, 0, g=g)
        self._cache[key] = sel
        return sel, False

    # -- public ------------------------------------------------------------
    def select_op(self, op: GemmOp) -> Selection:
        """Select (policy, tile config, grid size) for a full op fingerprint.

        Every dispatch contributes its (memoised) evals/pruned to ``stats``,
        so ``elimination_rate`` is workload-weighted — a hot op that was
        pruned once keeps crediting that pruning on every repeat, matching
        the paper's per-dispatch accounting. Exactly one category counter
        (tuned/sieve/fallback/cache_hit) is bumped per lookup."""
        self.stats.lookups += 1
        sel, cached = self._lookup(op)
        if cached:
            self.stats.cache_hits += 1
        elif sel.source == "tuned":
            self.stats.tuned_hits += 1
        elif sel.source == "sieve":
            self.stats.sieve_hits += 1
        elif sel.source == "model":
            self.stats.model_warm += 1
        else:
            self.stats.fallbacks += 1
        self.stats.evals += sel.evals
        self.stats.pruned += sel.pruned
        self._notify_miss(op, sel)
        return sel

    def select(self, m: int, n: int, k: int) -> Selection:
        """Legacy 2-D entry point: select for a bare local (M, N, K)."""
        return self.select_op(GemmOp.plain(m, n, k))

    def select_partial(
        self,
        op: GemmOp,
        policy: Optional[Policy] = None,
        cfg: Optional[TileConfig] = None,
        g: Optional[int] = None,
    ) -> Selection:
        """Fill the missing parts of a caller override from normal selection.
        Categorised as one ``forced`` lookup (never double-counted under a
        second category); the underlying selection's evals/pruned still
        count, since the selector really did that work."""
        self.stats.lookups += 1
        self.stats.forced += 1
        base, _ = self._lookup(op)
        sel = Selection(
            policy if policy is not None else base.policy,
            cfg if cfg is not None else base.cfg,
            "forced",
            base.evals,
            base.pruned,
            g=g if g is not None else base.g,
        )
        self.stats.evals += sel.evals
        self.stats.pruned += sel.pruned
        self._notify_miss(op, base)
        return sel

    def record_forced(
        self,
        op: GemmOp,
        policy: Policy,
        cfg: TileConfig,
        g: int = LEGACY_GRID,
    ) -> Selection:
        """Account a fully caller-forced (policy, cfg, g) dispatch (tuner
        sweeps, tests). It performs no evaluations and prunes nothing, so it
        leaves ``elimination_rate`` untouched — but it is a real dispatch,
        visible as one ``forced`` lookup. Forced dispatches of *untuned*
        fingerprints still feed the miss hook: the caller knowing a config
        is exactly the traffic online adaptation wants to learn from."""
        self.stats.lookups += 1
        self.stats.forced += 1
        sel = Selection(policy, cfg, "forced", 0, 0, g=g)
        if self._db_record(op) is None:
            self._notify_miss(op, sel)
        return sel


def default_selector() -> KernelSelector:
    """Selector with no tuning artifacts: pure cost-model scoring over all
    policies (used by models when no tuned database is supplied)."""
    return KernelSelector(sieve=None, db=None)
