"""Runtime kernel selection: Open-sieve query -> candidate policies -> pick.

Dispatch path for a :class:`repro.core.op.GemmOp` (selection keys on the op
fingerprint — per-shard local shape, group count, dtypes, epilogue):
  1. Exact tuning-database hit -> return the tuned (policy, config, g).
     Only records of the selector's OWN arch class qualify: a record tuned
     on a different machine class (:mod:`repro.core.arch`) instead supplies
     its winner/runner-up policies as a *warm seed*, re-ranked by the cost
     model under the local (calibrated) machine — the ``"xarch"`` source,
     which still counts as a miss for online adaptation so local
     measurements eventually supersede the import.
  2. Otherwise query the Bloom filters. Policies answering "definitely
     absent" are pruned (the paper's headline: up to ~95.8% of evaluations
     skipped, 100% true-negative rate). Surviving candidates are scored with
     the fast analytical model — at the op's *actual* operand byte-widths,
     jointly over the swept grid sizes — and the best wins.
  3. If every filter says absent (a size the tuner never saw and no filter
     aliases): with a :class:`~repro.core.calibrate.CalibratedMachine`
     installed, dispatch from the calibrated model's argmin over ALL
     policies (the ``"model"`` analytical-first warm start — still reported
     to the miss hook, so online adaptation measures hot shapes and
     promotes them to real database records); otherwise fall back to the
     naive single-policy default the original Stream-K paper proposes —
     data-parallel — scored against ALL_SK for safety.

Plain 2-D ops key as the legacy ``(M, N, K)`` tuple, so tuning databases and
sieves built from bare problem sizes keep working; grouped / epilogue-fused
ops key (and therefore tune and prune) independently.

Selection happens at *trace time* (shapes are static under jit), so it costs
nothing at runtime on device; the recorded ``SelectionLog`` is how tests and
benchmarks introspect dispatch decisions. ``SelectorStats`` counts every
dispatch exactly once (cold source, cache hit, or forced), and memoised
repeats re-credit their evals/pruned, so ``elimination_rate`` is weighted by
what the workload actually dispatched — not just by unique shapes.

Elimination accounting is honest about *who* did the eliminating: only
dispatches that actually consulted the Bloom filters credit ``pruned``. A
tuned database hit skips the filters entirely — it contributes zero evals
AND zero pruned, so a warm database drives ``elimination_rate`` toward the
sieve's true contribution instead of inflating the paper-headline metric.
Fully forced overrides perform no selection work and leave the rate
untouched.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import costmodel
from repro.core.arch import DEFAULT_ARCH
from repro.core.costmodel import DtypeBytes
from repro.core.op import GemmOp, OpKey
from repro.core.opensieve import OpenSieve
from repro.core.policies import (
    ALL_POLICIES,
    ALL_SK,
    DEFAULT_TILE_CONFIGS,
    DP,
    Policy,
    TileConfig,
    policy_from_name,
)
from repro.core.tuner import LEGACY_GRID, TuningDatabase
from repro.core.workpart import GemmShape

MNK = Tuple[int, int, int]


@dataclass(frozen=True)
class Selection:
    """One (policy, tile config, grid size) pick plus its provenance."""

    policy: Policy
    cfg: TileConfig
    source: str  # "tuned" | "xarch" | "sieve" | "model" | "fallback" | "forced"
    evals: int  # how many (policy) evaluations the scorer performed
    pruned: int  # how many the Bloom filters eliminated
    #: grid size the kernel launches with (tuned winner's g, or the scored
    #: best over the selector's grid sweep; LEGACY_GRID when nothing chose)
    g: int = LEGACY_GRID


@dataclass
class SelectorStats:
    """Per-selector lookup/eval counters (the paper's accounting unit)."""

    lookups: int = 0
    tuned_hits: int = 0
    #: dispatches seeded by a foreign arch class's record — its winner /
    #: runner-up policies re-ranked under the LOCAL machine (never applied
    #: verbatim); still a miss for online adaptation
    xarch_seeds: int = 0
    sieve_hits: int = 0
    #: unseen fingerprints dispatched from the calibrated model's argmin —
    #: the analytical-first warm start (still misses for online adaptation)
    model_warm: int = 0
    fallbacks: int = 0
    cache_hits: int = 0  # memoised repeats of an already-selected op
    forced: int = 0  # caller-supplied (policy, cfg) overrides
    evals: int = 0
    pruned: int = 0  # policies genuinely eliminated by Bloom filters

    @property
    def elimination_rate(self) -> float:
        """Fraction of filter-consulted policy evaluations the sieve skipped.
        Tuned hits bypass the filters and contribute to neither term, so a
        warm database cannot inflate the sieve's paper-headline metric."""
        tot = self.evals + self.pruned
        return self.pruned / tot if tot else 0.0


_CFG_BY_NAME = {c.name: c for c in DEFAULT_TILE_CONFIGS}


def _cfg_from_name(name: str) -> TileConfig:
    if name in _CFG_BY_NAME:
        return _CFG_BY_NAME[name]
    bm, bn, bk = (int(x) for x in name.split("x"))
    return TileConfig(bm, bn, bk)


#: Miss-hook signature: called once per dispatch whose (memoised) selection
#: did NOT come from the tuning database — the signal online adaptation
#: feeds on. Must be cheap; it runs on the trace path.
MissHook = Callable[[GemmOp, Selection], None]

#: sentinel distinguishing "kwarg not passed" from an explicit ``None`` —
#: the legacy ``hot_swap(db=None)`` meaning "keep the current database"
#: must keep working while the deprecated shim detects real usage.
_UNSET = object()


@dataclass(frozen=True)
class SelectorState:
    """One atomic snapshot of a selector's installed tuning artifacts.

    The database, sieve, calibration, and arch class travel as a single
    frozen value: ``KernelSelector(state=...)`` and ``hot_swap(state=...)``
    install all four in one reference assignment, so a federation/gossip
    round can never expose a database from one generation paired with a
    sieve from another. This replaces the grown ``db=/sieve=/calibration=``
    kwarg triple (kept as a deprecated shim)."""

    db: Optional[TuningDatabase] = None
    sieve: Optional[OpenSieve] = None
    #: installed CalibratedMachine (or None): when set, all cost-model
    #: scoring runs under the fitted per-dtype-profile machine, and unseen
    #: fingerprints dispatch via the "model" source instead of the fallback
    calibration: object = None
    #: the selector's own arch class (:mod:`repro.core.arch`) — the class
    #: whose records qualify as direct database hits; every other class is
    #: an ``"xarch"`` warm seed
    arch: str = DEFAULT_ARCH
    #: provenance of the install (e.g. the MergeReport behind a federation
    #: round). Excluded from equality — identical artifacts compare equal
    #: whatever produced them. Unknown attribute reads delegate here, so
    #: ``federate_selector`` can return the state it installed while callers
    #: keep reading ``.merged`` / ``.conflicts`` off the result.
    report: object = field(default=None, compare=False)

    def __getattr__(self, name: str):
        report = object.__getattribute__(self, "report")
        if report is not None:
            return getattr(report, name)
        raise AttributeError(
            f"{type(self).__name__} has no attribute {name!r}"
        )


def _deprecated_kwargs(where: str) -> None:
    warnings.warn(
        f"{where} via db=/sieve=/calibration= kwargs is deprecated; "
        "install a SelectorState (state=SelectorState(db=..., sieve=..., "
        "calibration=..., arch=...)) instead",
        DeprecationWarning,
        stacklevel=3,
    )


class KernelSelector:
    """The paper's selection pipeline, memoised per op key: tuned-database
    exact hit (own arch class) -> cross-arch warm seeds -> Bloom-sieve
    candidate pruning + cost-model scoring -> unsieved cost-model fallback.

    Tuning artifacts live in one frozen :class:`SelectorState`
    (``self.state``); the ``db``/``sieve``/``calibration``/``arch``
    properties read through to it. Install new artifacts atomically with
    :meth:`hot_swap`."""

    def __init__(
        self,
        sieve=_UNSET,
        db=_UNSET,
        mach: costmodel.Machine = costmodel.V5E,
        policies: Sequence[Policy] = ALL_POLICIES,
        tile_configs: Sequence[TileConfig] = DEFAULT_TILE_CONFIGS,
        on_miss: Optional[MissHook] = None,
        grid_sizes: Optional[Sequence[int]] = None,
        calibration=_UNSET,
        state: Optional[SelectorState] = None,
    ):
        legacy = {
            k: v
            for k, v in (("sieve", sieve), ("db", db), ("calibration", calibration))
            if v is not _UNSET
        }
        if state is not None and legacy:
            raise TypeError(
                "pass either state= or the legacy artifact kwargs, not both: "
                f"got state plus {sorted(legacy)}"
            )
        if state is None:
            if any(v is not None for v in legacy.values()):
                _deprecated_kwargs("constructing KernelSelector")
            state = SelectorState(
                db=legacy.get("db"),
                sieve=legacy.get("sieve"),
                calibration=legacy.get("calibration"),
            )
        self._state = state
        self.mach = mach
        self.policies = tuple(policies)
        self.tile_configs = tuple(tile_configs)
        self.on_miss = on_miss
        self.grid_sizes = (
            tuple(grid_sizes)
            if grid_sizes is not None
            else costmodel.default_grid_sizes(mach)
        )
        self.stats = SelectorStats()
        self._cache: Dict[OpKey, Selection] = {}

    # -- state ---------------------------------------------------------------
    @property
    def state(self) -> SelectorState:
        """The installed artifact snapshot (frozen; swap via hot_swap)."""
        return self._state

    @property
    def db(self) -> Optional[TuningDatabase]:
        """Installed tuning database (read-only view into ``state``)."""
        return self._state.db

    @property
    def sieve(self) -> Optional[OpenSieve]:
        """Installed Open-sieve (read-only view into ``state``)."""
        return self._state.sieve

    @property
    def calibration(self):
        """Installed CalibratedMachine or None (view into ``state``)."""
        return self._state.calibration

    @property
    def arch(self) -> str:
        """This selector's arch class (view into ``state``)."""
        return self._state.arch

    @property
    def sieve_generation(self) -> int:
        """Build version of the currently installed sieve (0 when none)."""
        return self.sieve.generation if self.sieve is not None else 0

    def _notify_miss(self, op: GemmOp, sel: Selection) -> None:
        if self.on_miss is not None and sel.source != "tuned":
            self.on_miss(op, sel)

    # -- online adaptation --------------------------------------------------
    def hot_swap(
        self,
        db=_UNSET,
        sieve=_UNSET,
        keys: Optional[Iterable[OpKey]] = None,
        calibration=_UNSET,
        state: Optional[SelectorState] = None,
    ) -> int:
        """Install updated tuning artifacts mid-stream.

        ``state=SelectorState(...)`` is the install path: one reference
        assignment swaps database, sieve, calibration, and arch class
        together, so in-flight lookups finish against whichever snapshot
        they already grabbed — the old sieve serves until the swap lands.
        The per-artifact kwargs survive as a deprecated shim (``None``
        still means "keep current", as it always did).

        Memoised selections for ``keys`` (all keys when ``None``) are
        dropped so the next dispatch of a freshly tuned fingerprint
        re-resolves against the new artifacts instead of replaying a stale
        sieve/fallback pick. Installing a different calibration drops the
        memo wholesale regardless of ``keys``: the (frozen, hashable)
        machines inside it key every scoring cache, and new constants
        re-score EVERY non-tuned pick. Returns the number of cache entries
        invalidated."""
        if state is not None:
            passed = [
                n
                for n, v in (("db", db), ("sieve", sieve), ("calibration", calibration))
                if v is not _UNSET
            ]
            if passed:
                raise TypeError(
                    "pass either state= or the legacy artifact kwargs, not "
                    f"both: got state plus {passed}"
                )
            if state.calibration is not self._state.calibration:
                keys = None
            self._state = state
        else:
            updates = {
                n: v
                for n, v in (("db", db), ("sieve", sieve), ("calibration", calibration))
                if v is not _UNSET and v is not None
            }
            if updates:
                _deprecated_kwargs("hot_swap")
                if "calibration" in updates:
                    keys = None
                self._state = replace(self._state, **updates)
        if keys is None:
            n = len(self._cache)
            self._cache.clear()
            return n
        return sum(1 for k in keys if self._cache.pop(k, None) is not None)

    # -- scoring -----------------------------------------------------------
    def scoring_machine(self, dt: DtypeBytes) -> costmodel.Machine:
        """Machine the cost model scores under for a byte-width profile:
        the installed calibration's per-profile fit, else the nominal
        machine. Frozen/hashable either way — it participates in every
        scoring-cache key."""
        if self.calibration is not None:
            return self.calibration.machine_for(dt)
        return self.mach

    def _score(
        self, size: MNK, pols: Sequence[Policy], dt: DtypeBytes
    ) -> Tuple[Policy, TileConfig, int, int]:
        """Best (policy, cfg, g) over the candidate policies — the argmin of
        :func:`costmodel.rank_candidates` at the op's real byte-widths,
        under the (possibly calibrated) scoring machine. ``evals`` counts
        *policies* scored (the unit Bloom pruning removes), whatever the
        width of the inner cfg x g sweep. ``size`` is a bare local (M, N, K)
        or an already-built shape (e.g. the GroupedGemmShape of a fused
        grouped op, whose concatenated tile space the model scores)."""
        shape = size if isinstance(size, GemmShape) else GemmShape(*size)
        pol, cfg, g, _ = costmodel.rank_candidates(
            shape,
            self.scoring_machine(dt),
            tuple(pols),
            self.tile_configs,
            self.grid_sizes,
            dt,
        )[0]
        return pol, cfg, g, len(pols)

    def _db_record(self, op: GemmOp):
        """Exact op-key hit first; shape-only ops of any dtype then fall
        back to the dtype-agnostic legacy (M, N, K) record (the paper's
        databases carry no dtype — a bf16 model must still benefit from
        artifacts tuned on bare sizes)."""
        if self.db is None:
            return None
        rec = self.db.records.get(op.key)
        if rec is None and op.mnk_compatible:
            rec = self.db.records.get(op.local)
        return rec

    def _xarch_policies(self, op: GemmOp) -> List[Policy]:
        """Warm-seed candidates from foreign-class records of this
        fingerprint: the winner (and distinct runner-up) policies every
        other arch class measured for the key. Never dispatched verbatim —
        the caller re-ranks them under the LOCAL (calibrated) machine, so a
        sibling generation's pick is advice, not an answer. Classes iterate
        in sorted order, keeping the seed set deterministic across fleets."""
        if self.db is None:
            return []
        recs = self.db.xarch_records_for(op.key)
        if not recs and op.mnk_compatible and op.key != op.local:
            recs = self.db.xarch_records_for(op.local)
        pols: List[Policy] = []
        for _cls, rec in recs:
            for name in (rec.policy, rec.runner_up_policy):
                if not name:
                    continue
                try:
                    pol = policy_from_name(name)
                except (KeyError, ValueError):
                    continue  # policy registry drift across producers
                if pol not in pols:
                    pols.append(pol)
        return pols

    def _sieve_candidates(self, op: GemmOp):
        if op.mnk_compatible and op.key != op.local:
            return self.sieve.candidates_any(op.key, op.local, arch=self.arch)
        return self.sieve.candidates(op.key, arch=self.arch)

    def _lookup(self, op: GemmOp) -> Tuple[Selection, bool]:
        """Memoised selection for an op; returns (selection, was_cached).
        No stats bookkeeping — callers categorise exactly once."""
        key = op.key
        if key in self._cache:
            return self._cache[key], True

        size = costmodel.op_shape(op)
        dt = costmodel.op_dtypes(op)
        sel: Selection
        rec = self._db_record(op)
        xpols = self._xarch_policies(op) if rec is None else []
        if rec is not None:
            # No filter was consulted: zero evals, zero pruned — a tuned hit
            # must not inflate the sieve's elimination rate.
            sel = Selection(
                policy=policy_from_name(rec.policy),
                cfg=_cfg_from_name(rec.cfg),
                source="tuned",
                evals=0,
                pruned=0,
                g=rec.g,
            )
        elif xpols:
            # A different arch class tuned this fingerprint: its winner /
            # runner-up policies seed the candidate set, re-ranked under the
            # local machine (no filter consulted — zero pruned). Still a
            # miss for adaptation: the seed serves until a local round
            # measures the shape and supersedes it with a real record.
            pol, cfg, g, evals = self._score(size, xpols, dt)
            sel = Selection(pol, cfg, "xarch", evals, 0, g=g)
        elif self.sieve is not None:
            cands = self._sieve_candidates(op)
            pruned = len(self.policies) - len(cands)
            if cands:
                pol, cfg, g, evals = self._score(size, cands, dt)
                sel = Selection(pol, cfg, "sieve", evals, pruned, g=g)
            elif self.calibration is not None:
                # every filter said "definitely absent" — with a calibrated
                # model installed, the unseen fingerprint dispatches from
                # the model's argmin over ALL policies (analytical-first
                # warm start) instead of the naive DP-vs-SK fallback
                pol, cfg, g, evals = self._score(size, self.policies, dt)
                sel = Selection(pol, cfg, "model", evals, pruned, g=g)
            else:
                pol, cfg, g, evals = self._score(size, (DP, ALL_SK), dt)
                sel = Selection(pol, cfg, "fallback", evals, pruned, g=g)
        elif self.calibration is not None:
            pol, cfg, g, evals = self._score(size, self.policies, dt)
            sel = Selection(pol, cfg, "model", evals, 0, g=g)
        else:
            pol, cfg, g, evals = self._score(size, self.policies, dt)
            sel = Selection(pol, cfg, "fallback", evals, 0, g=g)
        self._cache[key] = sel
        return sel, False

    # -- public ------------------------------------------------------------
    def select_op(self, op: GemmOp) -> Selection:
        """Select (policy, tile config, grid size) for a full op fingerprint.

        Every dispatch contributes its (memoised) evals/pruned to ``stats``,
        so ``elimination_rate`` is workload-weighted — a hot op that was
        pruned once keeps crediting that pruning on every repeat, matching
        the paper's per-dispatch accounting. Exactly one category counter
        (tuned/sieve/fallback/cache_hit) is bumped per lookup."""
        self.stats.lookups += 1
        sel, cached = self._lookup(op)
        if cached:
            self.stats.cache_hits += 1
        elif sel.source == "tuned":
            self.stats.tuned_hits += 1
        elif sel.source == "xarch":
            self.stats.xarch_seeds += 1
        elif sel.source == "sieve":
            self.stats.sieve_hits += 1
        elif sel.source == "model":
            self.stats.model_warm += 1
        else:
            self.stats.fallbacks += 1
        self.stats.evals += sel.evals
        self.stats.pruned += sel.pruned
        self._notify_miss(op, sel)
        return sel

    def select(self, m: int, n: int, k: int) -> Selection:
        """Legacy 2-D entry point: select for a bare local (M, N, K)."""
        return self.select_op(GemmOp.plain(m, n, k))

    def select_partial(
        self,
        op: GemmOp,
        policy: Optional[Policy] = None,
        cfg: Optional[TileConfig] = None,
        g: Optional[int] = None,
    ) -> Selection:
        """Fill the missing parts of a caller override from normal selection.
        Categorised as one ``forced`` lookup (never double-counted under a
        second category); the underlying selection's evals/pruned still
        count, since the selector really did that work."""
        self.stats.lookups += 1
        self.stats.forced += 1
        base, _ = self._lookup(op)
        sel = Selection(
            policy if policy is not None else base.policy,
            cfg if cfg is not None else base.cfg,
            "forced",
            base.evals,
            base.pruned,
            g=g if g is not None else base.g,
        )
        self.stats.evals += sel.evals
        self.stats.pruned += sel.pruned
        self._notify_miss(op, base)
        return sel

    def record_forced(
        self,
        op: GemmOp,
        policy: Policy,
        cfg: TileConfig,
        g: int = LEGACY_GRID,
    ) -> Selection:
        """Account a fully caller-forced (policy, cfg, g) dispatch (tuner
        sweeps, tests). It performs no evaluations and prunes nothing, so it
        leaves ``elimination_rate`` untouched — but it is a real dispatch,
        visible as one ``forced`` lookup. Forced dispatches of *untuned*
        fingerprints still feed the miss hook: the caller knowing a config
        is exactly the traffic online adaptation wants to learn from."""
        self.stats.lookups += 1
        self.stats.forced += 1
        sel = Selection(policy, cfg, "forced", 0, 0, g=g)
        if self._db_record(op) is None:
            self._notify_miss(op, sel)
        return sel


def default_selector() -> KernelSelector:
    """Selector with no tuning artifacts: pure cost-model scoring over all
    policies (used by models when no tuned database is supplied)."""
    return KernelSelector()
