"""Wall-clock helpers: a context-manager timer and an EWMA used by the
straggler monitor."""

from __future__ import annotations

import time
from dataclasses import dataclass, field


class Timer:
    """``with Timer() as t: ...; t.seconds``"""

    def __enter__(self):
        self._t0 = time.perf_counter()
        self.seconds = 0.0
        return self

    def __exit__(self, *exc):
        self.seconds = time.perf_counter() - self._t0
        return False


@dataclass
class EWMA:
    """Exponentially-weighted moving average + variance (for straggler
    detection: flag samples > mean + k*std)."""

    alpha: float = 0.1
    mean: float = 0.0
    var: float = 0.0
    count: int = 0

    def update(self, x: float) -> None:
        if self.count == 0:
            self.mean = x
            self.var = 0.0
        else:
            delta = x - self.mean
            self.mean += self.alpha * delta
            self.var = (1 - self.alpha) * (self.var + self.alpha * delta * delta)
        self.count += 1

    @property
    def std(self) -> float:
        return self.var**0.5

    def is_outlier(self, x: float, k: float = 3.0, min_samples: int = 5) -> bool:
        if self.count < min_samples:
            return False
        return x > self.mean + k * max(self.std, 1e-9)
