"""Pytree helpers used across the framework (no flax/optax dependency)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count(tree) -> int:
    """Total number of scalar elements in a pytree of arrays/ShapeDtypeStructs."""
    return sum(int(x.size) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes of a pytree of arrays/ShapeDtypeStructs."""
    total = 0
    for x in jax.tree.leaves(tree):
        total += int(x.size) * jnp.dtype(x.dtype).itemsize
    return total


def tree_zeros_like(tree):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, x.dtype), tree)


def tree_global_norm(tree):
    """Global L2 norm across every leaf (computed in f32)."""
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.zeros((), jnp.float32)
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    return jnp.sqrt(sq)


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:  # pragma: no cover - defensive
            parts.append(str(p))
    return "/".join(parts)


def tree_map_with_path_names(fn, tree):
    """`jax.tree.map` where ``fn(name, leaf)`` receives a '/'-joined path name."""
    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_path_name(p), x), tree)


def tree_paths(tree):
    """List of '/'-joined path names for every leaf, in tree order."""
    names = []
    jax.tree_util.tree_map_with_path(lambda p, x: names.append(_path_name(p)), tree)
    return names
