from repro.utils.trees import (
    tree_bytes,
    tree_count,
    tree_global_norm,
    tree_map_with_path_names,
    tree_zeros_like,
)
from repro.utils.logging import get_logger
from repro.utils.timing import Timer, EWMA

__all__ = [
    "tree_bytes",
    "tree_count",
    "tree_global_norm",
    "tree_map_with_path_names",
    "tree_zeros_like",
    "get_logger",
    "Timer",
    "EWMA",
]
