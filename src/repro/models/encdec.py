"""Whisper-style encoder-decoder (audio family).

The conv frontend is a STUB per the assignment: ``input_specs()`` feeds
precomputed frame embeddings (B, enc_frames, d_model) — what the two strided
convs would produce — so the transformer backbone is what's exercised.
Positional encoding is sinusoidal-absolute (matching Whisper's encoder; we
use it for the decoder too instead of learned embeddings — noted hardware/
scope adaptation in DESIGN.md).

Decode caches: per-decoder-layer self-attention KV (positional scatter) plus
the cross-attention K/V computed once from the encoder output at prefill.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gemm import gemm
from repro.dist.sharding import ArraySpec, constrain
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.models.lm import _stack_specs

Params = Dict[str, Any]


def sinusoid(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freqs = jnp.exp(
        -math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


class EncDec:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    # -- specs ----------------------------------------------------------------
    def param_specs(self) -> Params:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        enc_layer = {
            "norm1": L.norm_spec(cfg),
            "attn": L.attn_specs(cfg),
            "norm2": L.norm_spec(cfg),
            "mlp": L.mlp_specs(cfg),
        }
        dec_layer = {
            "norm1": L.norm_spec(cfg),
            "self_attn": L.attn_specs(cfg),
            "norm2": L.norm_spec(cfg),
            "cross_attn": L.attn_specs(cfg),
            "norm3": L.norm_spec(cfg),
            "mlp": L.mlp_specs(cfg),
        }
        return {
            "embed": ArraySpec((v, d), cfg.dtype, ("vocab", "embed")),
            "enc_layers": _stack_specs(enc_layer, cfg.n_enc_layers),
            "enc_final_norm": L.norm_spec(cfg),
            "dec_layers": _stack_specs(dec_layer, cfg.n_layers),
            "final_norm": L.norm_spec(cfg),
        }

    # -- encoder ----------------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array, *, div=None) -> jax.Array:
        cfg = self.cfg
        div = div or {}
        b, f, d = frames.shape
        x = frames.astype(cfg.dtype) + sinusoid(jnp.arange(f), d).astype(cfg.dtype)

        def body(x, p):
            h = L.norm_apply(p["norm1"], x, cfg)
            a, _ = L.attn_apply(
                p["attn"], h, cfg, div=div, mask_kind="bidir", use_rope=False
            )
            x = constrain(x + a, "batch", "seq", None)
            h = L.norm_apply(p["norm2"], x, cfg)
            x = constrain(x + L.mlp_apply(p["mlp"], h, cfg, div=div), "batch", "seq", None)
            return x, None

        if cfg.remat:
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return L.norm_apply(params["enc_final_norm"], x, cfg)

    # -- decoder ---------------------------------------------------------------
    def _dec_stack(
        self,
        params,
        x,
        enc_out,
        *,
        div,
        positions,
        caches=None,
        cur_pos=None,
        want_cache=False,
    ):
        cfg = self.cfg

        def body(carry, xs):
            x = carry
            p, c = xs if caches is not None else (xs, None)
            new_c: Dict[str, Any] = {}
            h = L.norm_apply(p["norm1"], x, cfg)
            a, kv = L.attn_apply(
                p["self_attn"],
                h,
                cfg,
                div=div,
                positions=positions,
                use_rope=False,
                cache=c.get("attn") if c else None,
                cur_pos=cur_pos,
            )
            x = constrain(x + a, "batch", "seq", None)
            if kv is not None and want_cache:
                new_c["attn"] = kv
            h = L.norm_apply(p["norm2"], x, cfg)
            if c is not None and "cross" in c:
                ck, cv = c["cross"]["k"], c["cross"]["v"]
            else:
                db, dtp = div.get("batch", 1), div.get("model", 1)
                ck = gemm(
                    enc_out, p["cross_attn"]["wk"], divisors=(db, dtp, 1), tag="xattn.k"
                ).reshape(enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.d_head)
                cv = gemm(
                    enc_out, p["cross_attn"]["wv"], divisors=(db, dtp, 1), tag="xattn.v"
                ).reshape(enc_out.shape[0], enc_out.shape[1], cfg.n_kv_heads, cfg.d_head)
                if want_cache:
                    new_c["cross"] = {"k": ck, "v": cv}
            a, _ = L.attn_apply(
                p["cross_attn"],
                h,
                cfg,
                div=div,
                use_rope=False,
                kv_override=(ck, cv),
            )
            x = constrain(x + a, "batch", "seq", None)
            h = L.norm_apply(p["norm3"], x, cfg)
            x = constrain(x + L.mlp_apply(p["mlp"], h, cfg, div=div), "batch", "seq", None)
            return x, new_c

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = params["dec_layers"] if caches is None else (params["dec_layers"], caches)
        x, new_caches = jax.lax.scan(body, x, xs)
        return x, new_caches

    def _head(self, params, x, div):
        # Whisper ties the output head to the token embedding.
        w = params["embed"].T.astype(self.cfg.dtype)
        return gemm(
            x,
            w,
            divisors=(div.get("batch", 1), div.get("model", 1), 1),
            tag="lm_head",
            out_dtype=self.cfg.dtype,
        )

    # -- public ----------------------------------------------------------------
    def forward(
        self,
        params: Params,
        frames: jax.Array,  # (B, F, D) stubbed frontend output
        dec_tokens: jax.Array,  # (B, S)
        *,
        div: Optional[Dict[str, int]] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        div = div or {}
        enc_out = self.encode(params, frames, div=div)
        b, s = dec_tokens.shape
        x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.dtype)
        x = x + sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.dtype)
        x, _ = self._dec_stack(params, x, enc_out, div=div, positions=jnp.arange(s))
        x = L.norm_apply(params["final_norm"], x, cfg)
        return self._head(params, x, div), jnp.zeros((), jnp.float32)

    def loss_fn(self, params, batch, *, div=None):
        logits, aux = self.forward(
            params, batch["frames"], batch["tokens"], div=div
        )
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom
        return loss, {"nll": loss, "ntokens": jnp.sum(mask)}

    # -- serving -----------------------------------------------------------------
    def cache_specs(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        n, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
        f = cfg.enc_frames
        kv_axes = ("stack", "batch", "kv_seq", "kv_heads", None)
        return {
            "attn": {
                "k": ArraySpec((n, batch, max_seq, kv, dh), cfg.dtype, kv_axes),
                "v": ArraySpec((n, batch, max_seq, kv, dh), cfg.dtype, kv_axes),
            },
            "cross": {
                "k": ArraySpec((n, batch, f, kv, dh), cfg.dtype, kv_axes),
                "v": ArraySpec((n, batch, f, kv, dh), cfg.dtype, kv_axes),
            },
        }

    def init_cache(self, batch: int, max_seq: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_specs(batch, max_seq),
            is_leaf=lambda x: isinstance(x, ArraySpec),
        )

    def prefill(
        self,
        params: Params,
        frames: jax.Array,
        dec_tokens: jax.Array,
        *,
        max_seq: Optional[int] = None,
        div: Optional[Dict[str, int]] = None,
    ):
        cfg = self.cfg
        div = div or {}
        b, s = dec_tokens.shape
        max_seq = max_seq or s
        enc_out = self.encode(params, frames, div=div)
        x = jnp.take(params["embed"], dec_tokens, axis=0).astype(cfg.dtype)
        x = x + sinusoid(jnp.arange(s), cfg.d_model).astype(cfg.dtype)
        x, fresh = self._dec_stack(
            params, x, enc_out, div=div, positions=jnp.arange(s), want_cache=True
        )
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = self._head(params, x[:, -1:], div)
        cache = self.init_cache(b, max_seq)
        for key in ("k", "v"):
            cache["attn"][key] = jax.lax.dynamic_update_slice(
                cache["attn"][key], fresh["attn"][key].astype(cfg.dtype), (0,) * 5
            )
            cache["cross"][key] = fresh["cross"][key].astype(cfg.dtype)
        return logits, cache

    def decode_step(
        self,
        params: Params,
        cache,
        tokens: jax.Array,  # (B, 1)
        cur_pos: jax.Array,  # (B,)
        *,
        div: Optional[Dict[str, int]] = None,
    ):
        cfg = self.cfg
        div = div or {}
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        x = x + sinusoid(cur_pos[:, None], cfg.d_model).astype(cfg.dtype)
        x, new_caches = self._dec_stack(
            params,
            x,
            None,
            div=div,
            positions=cur_pos[:, None],
            caches=cache,
            cur_pos=cur_pos,
            want_cache=True,
        )
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = self._head(params, x, div)
        # cross K/V is static during decode — carry it through unchanged
        new_caches["cross"] = cache["cross"]
        return logits, new_caches
