"""Unified decoder-only LM covering the dense / MoE / SSM / hybrid / VLM
families of the assigned architecture pool.

Layers are *scanned* with stacked parameters (one traced body, small HLO —
essential for 512-device dry-run compiles) and optionally rematerialised.
Heterogeneous stacks (gemma3's 5:1 local:global attention, zamba2's periodic
shared attention block) are expressed as *scanned per-layer flag arrays*
driving masks/selects inside one uniform body, never Python branching —
the whole stack is a single ``lax.scan``.

Caches:
  * attention: stacked (L, B, S_max, KV, dh) k/v tensors, positional scatter
    on decode;
  * SSM: stacked (L, B, nh, dh, ds) state + conv tail — O(1) decode, which is
    what makes ``long_500k`` applicable to the ssm/hybrid archs;
  * hybrid: both (attention slots only live at flagged layers).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gemm import gemm
from repro.dist.sharding import ArraySpec, constrain
from repro.models import layers as L
from repro.models import ssd
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _stack_specs(spec: Dict[str, Any], n: int) -> Dict[str, Any]:
    """Add a leading stacked-layer axis to every ArraySpec in a subtree."""
    return jax.tree.map(
        lambda s: ArraySpec((n, *s.shape), s.dtype, ("stack", *s.axes), init=s.init),
        spec,
        is_leaf=lambda x: isinstance(x, ArraySpec),
    )


class LM:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family in ("dense", "moe", "ssm", "hybrid", "vlm")
        self.cfg = cfg

    # -- weight quantization ------------------------------------------------
    def quantize_weights(
        self,
        params: Params,
        *,
        bits: int = 8,
        act_bits: Optional[int] = None,
    ) -> Tuple[Params, int, int]:
        """One-shot weight quantization for serving: every dense projection
        leaf (attention/MLP/MoE-expert weights, the untied lm_head, the
        shared hybrid block) becomes a
        :class:`~repro.core.quant.QuantizedTensor`; embeddings, routers and
        norms stay full precision. ``bits`` selects the ladder rung (8 or 4
        — int4 packs two nibbles per byte along K); ``act_bits=8``
        additionally requests dynamic int8 activation quantization at
        dispatch (the int8xint8 MXU rung). Scan-stacked leaves quantize per
        layer per output channel, so the stacked decode scan slices values
        and scales coherently. Returns (quantized tree, leaves converted,
        float leaves skipped under quantizable keys)."""
        from repro.core.quant import quantize_lm_params

        return quantize_lm_params(params, bits=bits, act_bits=act_bits)

    # -- layer metadata ------------------------------------------------------
    def layer_flags(self) -> Dict[str, jnp.ndarray]:
        """Per-layer scanned flags: ``is_global`` (gemma3 local:global),
        ``use_attn`` (zamba2 shared block period)."""
        cfg = self.cfg
        n = cfg.n_layers
        if cfg.global_every:
            # every Nth layer is global (pattern ...LLLLLG), rest local
            is_global = jnp.array(
                [(i + 1) % cfg.global_every == 0 for i in range(n)], jnp.bool_
            )
        else:
            is_global = jnp.ones((n,), jnp.bool_)
        if cfg.attn_every:
            use_attn = jnp.array(
                [(i % cfg.attn_every) == (cfg.attn_every - 1) for i in range(n)],
                jnp.bool_,
            )
        else:
            use_attn = jnp.zeros((n,), jnp.bool_)
        return {"is_global": is_global, "use_attn": use_attn}

    # -- parameter specs -------------------------------------------------------
    def param_specs(self) -> Params:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        layer: Dict[str, Any] = {"norm1": L.norm_spec(cfg)}
        if cfg.family in ("dense", "vlm", "moe"):
            layer["attn"] = L.attn_specs(cfg)
            layer["norm2"] = L.norm_spec(cfg)
            layer["moe" if cfg.family == "moe" else "mlp"] = (
                L.moe_specs(cfg) if cfg.family == "moe" else L.mlp_specs(cfg)
            )
        elif cfg.family == "ssm":
            layer["ssm"] = ssd.ssd_specs(cfg)
        elif cfg.family == "hybrid":
            layer["ssm"] = ssd.ssd_specs(cfg)

        specs: Params = {
            "embed": ArraySpec((v, d), cfg.dtype, ("vocab", "embed")),
            "layers": _stack_specs(layer, cfg.n_layers),
            "final_norm": L.norm_spec(cfg),
        }
        if cfg.family == "hybrid" and cfg.attn_every:
            specs["shared_attn"] = {
                "norm1": L.norm_spec(cfg),
                "attn": L.attn_specs(cfg),
                "norm2": L.norm_spec(cfg),
                "mlp": L.mlp_specs(cfg),
            }
        if not cfg.tie_embeddings:
            specs["lm_head"] = ArraySpec((d, v), cfg.dtype, ("embed", "vocab"))
        return specs

    # -- embedding / head -----------------------------------------------------
    def _embed(self, params, tokens, patch_embeds=None):
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
        if cfg.family == "vlm" and patch_embeds is not None:
            # stubbed anyres frontend: precomputed patch embeddings are
            # prepended; text occupies the remaining positions.
            p = patch_embeds.astype(cfg.dtype)
            x = jnp.concatenate([p, x[:, : x.shape[1] - p.shape[1]]], axis=1)
        # pin the residual stream: batch over the DP axes, d_model replicated
        return constrain(x, "batch", "seq", None)

    def _head(self, params, x, div):
        cfg = self.cfg
        w = (
            params["embed"].T.astype(cfg.dtype)
            if cfg.tie_embeddings
            else params["lm_head"]
        )
        return gemm(
            x,
            w,
            divisors=(div.get("batch", 1), div.get("model", 1), 1),
            tag="lm_head",
            out_dtype=cfg.dtype,
        )

    # -- one scanned decoder layer ----------------------------------------------
    def _layer_body(
        self,
        p: Params,
        x,
        *,
        flags,
        div,
        shared_attn: Optional[Params],
        positions,
        cache=None,
        cur_pos=None,
        want_cache: bool = False,
    ):
        """Returns (x, new_cache_entry, aux)."""
        cfg = self.cfg
        new_cache: Dict[str, Any] = {}

        if cfg.family in ("dense", "vlm", "moe"):
            # gemma3-style locality: one mask path; global layers get an
            # effectively infinite window via the scanned flag.
            if cfg.window:
                window = jnp.where(flags["is_global"], jnp.int32(2**30), cfg.window)
                mask_kind = "window"
            else:
                window = 0
                mask_kind = "causal"
            h = L.norm_apply(p["norm1"], x, cfg)
            attn_out, kv = L.attn_apply(
                p["attn"],
                h,
                cfg,
                div=div,
                mask_kind=mask_kind,
                window=window,
                positions=positions,
                cache=cache.get("attn") if cache else None,
                cur_pos=cur_pos,
            )
            x = constrain(x + attn_out, "batch", "seq", None)
            if kv is not None and want_cache:
                new_cache["attn"] = kv
            h = L.norm_apply(p["norm2"], x, cfg)
            if cfg.family == "moe":
                mlp_out, aux = L.moe_apply(p["moe"], h, cfg, div=div)
            else:
                mlp_out, aux = L.mlp_apply(p["mlp"], h, cfg, div=div), 0.0
            x = constrain(x + mlp_out, "batch", "seq", None)
            return x, new_cache, aux

        # ssm / hybrid families
        h = L.norm_apply(p["norm1"], x, cfg)
        ssm_out, ssm_state = ssd.ssd_apply(
            p["ssm"], h, cfg, div=div, state=cache.get("ssm") if cache else None
        )
        x = constrain(x + ssm_out, "batch", "seq", None)
        if want_cache:
            new_cache["ssm"] = ssm_state

        if cfg.family == "hybrid" and shared_attn is not None:
            # shared (weight-tied) transformer block, active at flagged
            # layers; computed unconditionally and gated by select so the
            # scan body stays uniform.
            g = flags["use_attn"].astype(jnp.float32)
            h = L.norm_apply(shared_attn["norm1"], x, cfg)
            attn_out, kv = L.attn_apply(
                shared_attn["attn"],
                h,
                cfg,
                div=div,
                positions=positions,
                cache=cache.get("attn") if cache else None,
                cur_pos=cur_pos,
            )
            x = x + (attn_out.astype(jnp.float32) * g).astype(x.dtype)
            if kv is not None and cache is not None and want_cache:
                # only flagged layers persist their KV
                new_cache["attn"] = jax.tree.map(
                    lambda new, old: jnp.where(flags["use_attn"], new, old),
                    kv,
                    cache["attn"],
                )
            elif kv is not None and want_cache:
                new_cache["attn"] = kv
            h = L.norm_apply(shared_attn["norm2"], x, cfg)
            mlp_out = L.mlp_apply(shared_attn["mlp"], h, cfg, div=div)
            x = x + (mlp_out.astype(jnp.float32) * g).astype(x.dtype)
        return x, new_cache, 0.0

    # -- full stacks ------------------------------------------------------------
    def _scan_layers(
        self,
        params,
        x,
        *,
        div,
        positions,
        caches=None,
        cur_pos=None,
        want_cache=False,
    ):
        """caches: stacked per-layer cache pytree or None. Returns
        (x, new_caches, aux_sum)."""
        cfg = self.cfg
        flags = self.layer_flags()
        shared = params.get("shared_attn")

        def body(carry, xs):
            x, aux = carry
            if caches is None:
                p, fl = xs
                c = None
            else:
                p, fl, c = xs
            x, new_c, aux_i = self._layer_body(
                p,
                x,
                flags=fl,
                div=div,
                shared_attn=shared,
                positions=positions,
                cache=c,
                cur_pos=cur_pos,
                want_cache=want_cache,
            )
            return (x, aux + aux_i), new_c

        if cfg.remat:
            body = jax.checkpoint(body)

        xs = (params["layers"], flags) if caches is None else (
            params["layers"],
            flags,
            caches,
        )
        (x, aux), new_caches = jax.lax.scan(body, (x, 0.0), xs)
        return x, new_caches, aux

    # -- public API ----------------------------------------------------------
    def forward(
        self,
        params: Params,
        tokens: jax.Array,  # (B, S)
        *,
        div: Optional[Dict[str, int]] = None,
        patch_embeds: Optional[jax.Array] = None,
    ) -> Tuple[jax.Array, jax.Array]:
        """Teacher-forced logits (B, S, V) + aux loss."""
        div = div or {}
        x = self._embed(params, tokens, patch_embeds)
        positions = jnp.arange(x.shape[1])
        x, _, aux = self._scan_layers(params, x, div=div, positions=positions)
        x = L.norm_apply(params["final_norm"], x, self.cfg)
        return self._head(params, x, div), aux

    def loss_fn(
        self,
        params: Params,
        batch: Dict[str, jax.Array],
        *,
        div: Optional[Dict[str, int]] = None,
    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        logits, aux = self.forward(
            params,
            batch["tokens"],
            div=div,
            patch_embeds=batch.get("patch_embeds"),
        )
        labels = batch["labels"]
        mask = batch.get("loss_mask")
        if mask is None:
            mask = jnp.ones(labels.shape, jnp.float32)
        if cfg.family == "vlm" and "patch_embeds" in batch:
            # no LM loss on image-patch positions
            npatch = batch["patch_embeds"].shape[1]
            mask = mask.at[:, :npatch].set(0.0)
        logits = logits.astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mask
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll) / denom + aux
        # z-loss for logit drift stability at scale
        zloss = 1e-4 * jnp.sum(jnp.square(logz) * mask) / denom
        metrics = {
            "nll": jnp.sum(nll) / denom,
            "aux": jnp.asarray(aux, jnp.float32),
            "zloss": zloss,
            "ntokens": jnp.sum(mask),
        }
        return loss + zloss, metrics

    # -- serving -----------------------------------------------------------------
    def cache_specs(self, batch: int, max_seq: int) -> Dict[str, Any]:
        """ArraySpec pytree for the decode cache (stacked over layers)."""
        cfg = self.cfg
        if cfg.window_cache and cfg.global_every and cfg.family in ("dense", "vlm"):
            return self.cache_specs_windowed(batch, max_seq)
        n, kv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.d_head
        out: Dict[str, Any] = {}
        if cfg.family in ("dense", "vlm", "moe", "hybrid"):
            kv_dt = "int8" if cfg.kv_cache_dtype == "int8" else cfg.dtype
            kv_axes = ("stack", "batch", "kv_seq", "kv_heads", None)
            out["attn"] = {
                "k": ArraySpec((n, batch, max_seq, kv, dh), kv_dt, kv_axes),
                "v": ArraySpec((n, batch, max_seq, kv, dh), kv_dt, kv_axes),
            }
            if cfg.kv_cache_dtype == "int8":
                sc_axes = ("stack", "batch", "kv_seq", "kv_heads")
                out["attn"]["k_scale"] = ArraySpec(
                    (n, batch, max_seq, kv), "float32", sc_axes
                )
                out["attn"]["v_scale"] = ArraySpec(
                    (n, batch, max_seq, kv), "float32", sc_axes
                )
        if cfg.family in ("ssm", "hybrid"):
            nh, hd, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            conv_dim = cfg.d_inner + 2 * ds
            out["ssm"] = {
                "h": ArraySpec(
                    (n, batch, nh, hd, ds),
                    "float32",
                    ("stack", "batch", "ssm_inner", None, None),
                ),
                "conv": ArraySpec(
                    (n, batch, cfg.ssm_conv_width - 1, conv_dim),
                    cfg.dtype,
                    ("stack", "batch", None, "ssm_inner"),
                ),
            }
        return out

    # -- windowed-cache decode (gemma3-style local:global stacks) -----------
    def _layer_split(self):
        """Static index split for global_every stacks: block-local indices
        (n_blocks, g-1), global indices (n_blocks,), tail-local indices."""
        import numpy as np

        cfg = self.cfg
        g = cfg.global_every
        n_blocks = cfg.n_layers // g
        local_block, global_idx = [], []
        for b_ in range(n_blocks):
            base = b_ * g
            local_block.extend(range(base, base + g - 1))
            global_idx.append(base + g - 1)
        tail = list(range(n_blocks * g, cfg.n_layers))
        return (
            np.asarray(local_block, dtype=np.int64),
            np.asarray(global_idx, dtype=np.int64),
            np.asarray(tail, dtype=np.int64),
            n_blocks,
        )

    def cache_specs_windowed(self, batch: int, max_seq: int) -> Dict[str, Any]:
        """Ring caches for local layers (window slots), full caches only for
        the 1-in-global_every global layers: capacity and decode read
        traffic drop ~global_every-fold for long contexts."""
        cfg = self.cfg
        kv, dh, w = cfg.n_kv_heads, cfg.d_head, cfg.window
        local_block, global_idx, tail, n_blocks = self._layer_split()
        n_local = len(local_block) + len(tail)
        ring_axes = ("stack", "batch", None, "kv_heads", None)
        full_axes = ("stack", "batch", "kv_seq", "kv_heads", None)
        return {
            "local": {
                "k": ArraySpec((n_local, batch, w, kv, dh), cfg.dtype, ring_axes),
                "v": ArraySpec((n_local, batch, w, kv, dh), cfg.dtype, ring_axes),
            },
            "global": {
                "k": ArraySpec(
                    (len(global_idx), batch, max_seq, kv, dh), cfg.dtype, full_axes
                ),
                "v": ArraySpec(
                    (len(global_idx), batch, max_seq, kv, dh), cfg.dtype, full_axes
                ),
            },
        }

    def windowed_cache_from_uniform(self, cache, prompt_len: int):
        """Convert a uniform prefill cache (L, B, S, kv, dh) into the
        windowed layout: local layers keep the last ``window`` positions in
        ring order (position p -> slot p %% W), global layers keep their full
        stripes — makes prefill-then-windowed-decode a drop-in serving path."""
        import numpy as np

        cfg = self.cfg
        w = cfg.window
        local_block, global_idx, tail, n_blocks = self._layer_split()
        local_idx = np.concatenate([local_block, tail])
        s_max = cache["attn"]["k"].shape[2]

        def to_ring(full):  # (n_local, B, S, kv, dh) -> (n_local, B, W, kv, dh)
            # slot j holds the most recent position p <= prompt_len-1 with
            # p % w == j (positions the ring would contain after a decode
            # chain of the same length)
            slots = jnp.arange(w)
            last = prompt_len - 1
            p = last - jnp.mod(last - slots, w)  # may be negative when cold
            p_safe = jnp.clip(p, 0, s_max - 1)
            ring = jnp.take(full, p_safe, axis=2)
            mask = (p >= 0)[None, None, :, None, None]
            return jnp.where(mask, ring, jnp.zeros_like(ring))

        out_local = {
            key: to_ring(cache["attn"][key][local_idx]) for key in ("k", "v")
        }
        out_global = {key: cache["attn"][key][global_idx] for key in ("k", "v")}
        return {"local": out_local, "global": out_global}

    def decode_step_windowed(self, params, cache, tokens, cur_pos, *, div=None):
        """One decode step with ring caches on local layers. Requires
        ``cfg.window_cache`` and ``cfg.global_every > 0``; numerically
        identical to the uniform-cache path (window masking == ring)."""
        cfg = self.cfg
        div = div or {}
        g = cfg.global_every
        local_block, global_idx, tail, n_blocks = self._layer_split()

        take = lambda tree, idx: jax.tree.map(lambda a: a[idx], tree)
        p_block_local = jax.tree.map(
            lambda a: a[local_block].reshape(n_blocks, g - 1, *a.shape[1:]),
            params["layers"],
        )
        p_global = take(params["layers"], global_idx)
        p_tail = take(params["layers"], tail) if len(tail) else None

        n_block_local = len(local_block)
        c_block_local = jax.tree.map(
            lambda a: a[:n_block_local].reshape(n_blocks, g - 1, *a.shape[1:]),
            cache["local"],
        )
        c_tail = jax.tree.map(lambda a: a[n_block_local:], cache["local"])

        x = self._embed(params, tokens)

        def local_layer(x, p, c):
            h = L.norm_apply(p["norm1"], x, cfg)
            a, new_c = L.attn_apply_ring(
                p["attn"], h, cfg, div=div, cache=c, cur_pos=cur_pos
            )
            x = x + a
            h = L.norm_apply(p["norm2"], x, cfg)
            return x + L.mlp_apply(p["mlp"], h, cfg, div=div), new_c

        def local_scan(x, p_stack, c_stack):
            def body(x, pc):
                p, c = pc
                return local_layer(x, p, c)

            return jax.lax.scan(body, x, (p_stack, c_stack))

        def block_body(x, xs):
            p_loc, p_glob, c_loc, c_glob = xs
            x, new_c_loc = local_scan(x, p_loc, c_loc)
            h = L.norm_apply(p_glob["norm1"], x, cfg)
            a, new_c_glob = L.attn_apply(
                p_glob["attn"],
                h,
                cfg,
                div=div,
                positions=cur_pos[:, None],
                cache=c_glob,
                cur_pos=cur_pos,
            )
            x = x + a
            h = L.norm_apply(p_glob["norm2"], x, cfg)
            x = x + L.mlp_apply(p_glob["mlp"], h, cfg, div=div)
            return x, (new_c_loc, new_c_glob)

        x, (nc_loc, nc_glob) = jax.lax.scan(
            block_body, x, (p_block_local, p_global, c_block_local, cache["global"])
        )
        if p_tail is not None and len(tail):
            x, nc_tail = local_scan(x, p_tail, c_tail)
        else:
            nc_tail = c_tail
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = self._head(params, x, div)
        new_cache = {
            "local": jax.tree.map(
                lambda bl, tl: jnp.concatenate(
                    [bl.reshape(n_block_local, *bl.shape[2:]), tl], axis=0
                ),
                nc_loc,
                nc_tail,
            ),
            "global": nc_glob,
        }
        return logits, new_cache

    def init_cache(self, batch: int, max_seq: int):
        from repro.dist.sharding import materialize_tree

        specs = self.cache_specs(batch, max_seq)
        zeros = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            specs,
            is_leaf=lambda x: isinstance(x, ArraySpec),
        )
        return zeros

    def prefill(
        self,
        params: Params,
        tokens: jax.Array,  # (B, S)
        *,
        max_seq: Optional[int] = None,
        div: Optional[Dict[str, int]] = None,
        patch_embeds: Optional[jax.Array] = None,
    ):
        """Run the prompt, build the decode cache. Returns (last_logits, cache)."""
        cfg = self.cfg
        div = div or {}
        b, s = tokens.shape
        max_seq = max_seq or s
        x = self._embed(params, tokens, patch_embeds)
        positions = jnp.arange(s)
        x, prefill_caches, _ = self._scan_layers(
            params, x, div=div, positions=positions, want_cache=True
        )
        x = L.norm_apply(params["final_norm"], x, cfg)
        logits = self._head(params, x[:, -1:], div)

        cache = self.init_cache(b, max_seq)
        if "attn" in cache and prefill_caches and "attn" in prefill_caches:
            for key in ("k", "v"):
                fresh = prefill_caches["attn"][key]  # (L, B, S, kv, dh)
                if cfg.kv_cache_dtype == "int8":
                    from repro.models.layers import kv_quantize

                    q8, sc = kv_quantize(fresh)
                    cache["attn"][key] = jax.lax.dynamic_update_slice(
                        cache["attn"][key], q8, (0, 0, 0, 0, 0)
                    )
                    cache["attn"][f"{key}_scale"] = jax.lax.dynamic_update_slice(
                        cache["attn"][f"{key}_scale"], sc, (0, 0, 0, 0)
                    )
                else:
                    cache["attn"][key] = jax.lax.dynamic_update_slice(
                        cache["attn"][key], fresh.astype(cfg.dtype), (0, 0, 0, 0, 0)
                    )
        if "ssm" in cache and prefill_caches and "ssm" in prefill_caches:
            cache["ssm"] = prefill_caches["ssm"]
        return logits, cache

    def prefill_chunk(
        self,
        params: Params,
        cache,
        tokens: jax.Array,  # (B, C) one prompt chunk
        cur_pos: jax.Array,  # (B,) absolute position of the chunk's first token
        *,
        div: Optional[Dict[str, int]] = None,
    ):
        """Process one prompt chunk against an existing decode cache: the
        chunk's KV rows scatter at absolute positions ``cur_pos..cur_pos+C-1``
        and every query row attends over the cache prefix plus the
        intra-chunk causal span. Chaining ``prefill_chunk`` over a split
        prompt is the incremental equivalent of one :meth:`prefill` — it is
        what lets a serving scheduler interleave long-prompt prefill with
        decode steps instead of head-of-line-blocking the decode batch.

        Returns (last-position logits (B, 1, V), updated cache). Supported
        for the attention-cache families (dense/vlm/moe, uniform cache);
        SSM/hybrid decode state is O(1) per sequence and has no incremental
        multi-token scatter path, and windowed ring caches lose the
        positions a later chunk would need."""
        cfg = self.cfg
        if cfg.family not in ("dense", "vlm", "moe"):
            raise ValueError(
                f"prefill_chunk supports attention-cache families, not "
                f"{cfg.family!r} (SSM state has no incremental chunk scatter)"
            )
        if cfg.window_cache:
            raise ValueError(
                "prefill_chunk requires the uniform decode cache; ring "
                "caches drop positions later chunks must attend over"
            )
        div = div or {}
        x = self._embed(params, tokens)
        c = tokens.shape[1]
        positions = cur_pos[:, None] + jnp.arange(c)[None, :]  # (B, C)
        x, new_caches, _ = self._scan_layers(
            params,
            x,
            div=div,
            positions=positions,
            caches=cache,
            cur_pos=cur_pos,
            want_cache=True,
        )
        x = L.norm_apply(params["final_norm"], x, cfg)
        return self._head(params, x[:, -1:], div), new_caches

    def decode_step(
        self,
        params: Params,
        cache,
        tokens: jax.Array,  # (B, 1)
        cur_pos: jax.Array,  # (B,)
        *,
        div: Optional[Dict[str, int]] = None,
    ):
        """One decode step. Returns (logits (B, 1, V), new_cache)."""
        cfg = self.cfg
        div = div or {}
        if cfg.window_cache and cfg.global_every and cfg.family in ("dense", "vlm"):
            return self.decode_step_windowed(params, cache, tokens, cur_pos, div=div)
        x = self._embed(params, tokens)
        positions = cur_pos[:, None]  # (B, 1) absolute positions for RoPE
        x, new_caches, _ = self._scan_layers(
            params,
            x,
            div=div,
            positions=positions,
            caches=cache,
            cur_pos=cur_pos,
            want_cache=True,
        )
        x = L.norm_apply(params["final_norm"], x, cfg)
        return self._head(params, x, div), new_caches
