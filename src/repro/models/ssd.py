"""Mamba2 / SSD (state-space duality) block — arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm (quadratic intra-chunk
"attention" + linear inter-chunk state recurrence via ``lax.scan``); decode
carries the (heads, d_head, d_state) SSM state per layer and costs O(1) per
token — the property that makes the ``long_500k`` shape tractable for the
SSM/hybrid architectures.

Projections route through ``repro.core.gemm`` like every other matmul in the
framework (the Stream-K++ dispatch layer applies to SSMs too — see DESIGN.md
§Arch-applicability).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gemm import gemm
from repro.dist.sharding import ArraySpec
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]


def ssd_specs(cfg: ModelConfig) -> Dict[str, ArraySpec]:
    d = cfg.d_model
    din = cfg.d_inner
    ds = cfg.ssm_state
    nh = cfg.ssm_heads
    conv_dim = din + 2 * ds
    dt = cfg.dtype
    return {
        # fused input projection: [z (din), x (din), B (ds), C (ds), dt (nh)]
        "w_in": ArraySpec((d, 2 * din + 2 * ds + nh), dt, ("embed", "ssm_inner")),
        "conv_w": ArraySpec((cfg.ssm_conv_width, conv_dim), dt, (None, "ssm_inner")),
        "conv_b": ArraySpec((conv_dim,), dt, ("ssm_inner",), init="zeros"),
        "a_log": ArraySpec((nh,), "float32", (None,), init="zeros"),
        "d_skip": ArraySpec((nh,), "float32", (None,), init="ones"),
        "dt_bias": ArraySpec((nh,), "float32", (None,), init="zeros"),
        "w_out": ArraySpec((din, d), dt, ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    din, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din : 2 * din + 2 * ds]
    dt = zxbcdt[..., 2 * din + 2 * ds :]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over seq via shifted adds (width is tiny)."""
    width = w.shape[0]
    out = xbc * w[width - 1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * w[width - 1 - i]
    return jax.nn.silu((out + b).astype(jnp.float32)).astype(xbc.dtype)


def _ssd_chunked(
    x: jax.Array,  # (B, S, nh, dh)
    dt: jax.Array,  # (B, S, nh) softplus'd
    a: jax.Array,  # (nh,) negative
    b_in: jax.Array,  # (B, S, ds)
    c_in: jax.Array,  # (B, S, ds)
    chunk: int,
    h0: Optional[jax.Array] = None,  # (B, nh, dh, ds) initial state
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD: returns (y (B,S,nh,dh), final_state (B,nh,dh,ds))."""
    bsz, s, nh, dh = x.shape
    ds = b_in.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, "seq must divide chunk"

    xc = x.reshape(bsz, nc, chunk, nh, dh).astype(jnp.float32)
    dtc = dt.reshape(bsz, nc, chunk, nh)
    bc = b_in.reshape(bsz, nc, chunk, ds).astype(jnp.float32)
    cc = c_in.reshape(bsz, nc, chunk, ds).astype(jnp.float32)

    da = dtc * a[None, None, None, :]  # (B,nc,Q,nh) decay increments (<=0)
    da_cs = jnp.cumsum(da, axis=2)  # inclusive cumulative decay in-chunk

    # --- intra-chunk (quadratic within the chunk) ---------------------------
    # L[i,j] = exp(da_cs[i] - da_cs[j]) for i >= j else 0
    li = da_cs[:, :, :, None, :]  # (B,nc,Q,1,nh) at i
    lj = da_cs[:, :, None, :, :]  # (B,nc,1,Q,nh) at j
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bnis,bnjs->bnij", cc, bc)  # (B,nc,Q,Q)
    xdt = xc * dtc[..., None]  # (B,nc,Q,nh,dh)
    y_intra = jnp.einsum("bnij,bnijh,bnjhd->bnihd", scores, lmat, xdt)

    # --- chunk states ---------------------------------------------------------
    # state contribution of chunk n: sum_j exp(da_cs[last] - da_cs[j]) dt_j B_j x_j
    decay_to_end = jnp.exp(da_cs[:, :, -1:, :] - da_cs)  # (B,nc,Q,nh)
    states = jnp.einsum(
        "bnjs,bnjh,bnjhd->bnhds", bc, decay_to_end * dtc, xc
    )  # (B,nc,nh,dh,ds)

    chunk_decay = jnp.exp(da_cs[:, :, -1, :])  # (B,nc,nh) total chunk decay

    # --- inter-chunk recurrence (linear scan over chunks) --------------------
    def step(h, inp):
        st, dec = inp  # (B,nh,dh,ds), (B,nh)
        h_out = h  # state BEFORE this chunk
        h = h * dec[:, :, None, None] + st
        return h, h_out

    h_init = (
        h0.astype(jnp.float32)
        if h0 is not None
        else jnp.zeros((bsz, nh, dh, ds), jnp.float32)
    )
    h_final, h_starts = jax.lax.scan(
        step,
        h_init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    h_starts = jnp.moveaxis(h_starts, 0, 1)  # (B,nc,nh,dh,ds)

    # --- inter-chunk output: y_i += exp(da_cs[i]) * C_i . h_start --------------
    y_inter = jnp.einsum(
        "bnis,bnhds,bnih->bnihd",
        cc,
        h_starts,
        jnp.exp(da_cs),
    )
    y = (y_intra + y_inter).reshape(bsz, s, nh, dh)
    return y, h_final


def ssd_apply(
    p: Params,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    div: Dict[str, int],
    state: Optional[Dict[str, jax.Array]] = None,  # decode carry
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Mamba2 block. ``state=None`` -> chunked training/prefill path (returns
    final state for cache handoff); otherwise single-token decode."""
    bsz, s, d = x.shape
    din, ds, nh, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    db, dtp = div.get("batch", 1), div.get("model", 1)

    zxbcdt = gemm(x, p["w_in"], divisors=(db, dtp, 1), tag="ssm.in")
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    a = -jnp.exp(p["a_log"])
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if state is None or s > 1:
        # training / prefill: causal depthwise conv + chunked SSD
        xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
        xs = xbc[..., :din].reshape(bsz, s, nh, dh)
        b_in = xbc[..., din : din + ds]
        c_in = xbc[..., din + ds :]
        h0 = state["h"] if state is not None else None
        pad = (-s) % cfg.ssm_chunk
        if pad:
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dtp_ = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_p = jnp.pad(b_in, ((0, 0), (0, pad), (0, 0)))
            c_p = jnp.pad(c_in, ((0, 0), (0, pad), (0, 0)))
        else:
            dtp_, b_p, c_p = dt, b_in, c_in
        y, h_final = _ssd_chunked(xs, dtp_, a, b_p, c_p, cfg.ssm_chunk, h0)
        y = y[:, :s]
        y = y + xs[:, :s] * p["d_skip"][None, None, :, None]
        conv_state = xbc_raw_tail(zxbcdt, cfg, s)
        new_state = {"h": h_final, "conv": conv_state}
    else:
        # decode: O(1) recurrent update
        conv_state = state["conv"]  # (B, width-1, conv_dim)
        xbc_raw = zxbcdt[:, 0, din : 2 * din + 2 * ds]
        window = jnp.concatenate([conv_state, xbc_raw[:, None]], axis=1)
        w = p["conv_w"]
        conv_out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        xbc_t = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32)).astype(x.dtype)
        xs = xbc_t[:, :din].reshape(bsz, nh, dh).astype(jnp.float32)
        b_t = xbc_t[:, din : din + ds].astype(jnp.float32)
        c_t = xbc_t[:, din + ds :].astype(jnp.float32)
        dt_t = dt[:, 0]  # (B, nh)
        h = state["h"]
        decay = jnp.exp(dt_t * a[None, :])  # (B, nh)
        h = h * decay[:, :, None, None] + jnp.einsum(
            "bh,bs,bhd->bhds", dt_t, b_t, xs
        )
        y = jnp.einsum("bs,bhds->bhd", c_t, h)
        y = y + xs * p["d_skip"][None, :, None]
        y = y[:, None]  # (B,1,nh,dh)
        new_state = {
            "h": h,
            "conv": jnp.concatenate([conv_state[:, 1:], xbc_raw[:, None]], axis=1),
        }

    y = y.reshape(bsz, s, din).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = gemm(y, p["w_out"], divisors=(db, 1, dtp), tag="ssm.out")
    return out, new_state


def xbc_raw_tail(zxbcdt: jax.Array, cfg: ModelConfig, s: int) -> jax.Array:
    """Last (conv_width-1) pre-conv inputs — the decode conv cache."""
    din, ds = cfg.d_inner, cfg.ssm_state
    width = cfg.ssm_conv_width
    xbc_raw = zxbcdt[..., din : 2 * din + 2 * ds]
    tail = xbc_raw[:, max(0, s - (width - 1)) :]
    if s < width - 1:
        tail = jnp.pad(tail, ((0, 0), (width - 1 - s, 0), (0, 0)))
    return tail


def ssd_init_state(cfg: ModelConfig, batch: int) -> Dict[str, jax.Array]:
    nh, dh, ds = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.d_inner + 2 * ds
    return {
        "h": jnp.zeros((batch, nh, dh, ds), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), cfg.dtype),
    }
