"""Shared model building blocks (pure JAX, no flax).

Every projection routes through ``repro.core.gemm`` so the paper's Stream-K++
selection layer sees every matmul in every architecture. Attention uses a
chunked online-softmax (memory-efficient, O(S*chunk) score memory) so 32k
prefill and 4k training fit without a fused attention kernel; decode attends
directly against the KV cache.

Layer-param *specs* (``ArraySpec`` pytrees) and *apply* functions live side
by side; specs carry the logical sharding axes consumed by
``repro.dist.sharding``.
"""

from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.gemm import gemm, gemm_grouped
from repro.core.op import Epilogue
from repro.core.quant import is_quantized
from repro.dist.sharding import ArraySpec, constrain, constrain_uneven
from repro.models.config import ModelConfig

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def norm_spec(cfg: ModelConfig, d: Optional[int] = None) -> Dict[str, ArraySpec]:
    d = d or cfg.d_model
    spec = {"scale": ArraySpec((d,), "float32", (None,), init="ones")}
    if cfg.norm == "layernorm":
        spec["bias"] = ArraySpec((d,), "float32", (None,), init="zeros")
    return spec


def norm_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6) * p["scale"] + p["bias"]
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, dh); positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attn_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ArraySpec]:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    dt = cfg.dtype
    return {
        "wq": ArraySpec((d, h * dh), dt, ("embed", "heads")),
        "wk": ArraySpec((d, kv * dh), dt, ("embed", "kv_heads")),
        "wv": ArraySpec((d, kv * dh), dt, ("embed", "kv_heads")),
        "wo": ArraySpec((h * dh, d), dt, ("heads", "embed")),
    }


def _is_static_nowindow(window) -> bool:
    return isinstance(window, (int, float)) and window == 0


def _mask(kind: str, qpos, kpos, window):
    """(Sq, Sk) bool validity mask from position vectors. ``window`` may be a
    traced scalar (gemma3: per-layer local/global selected by a scanned
    flag)."""
    q = qpos[:, None]
    k = kpos[None, :]
    if kind == "bidir":
        return jnp.ones((qpos.shape[0], kpos.shape[0]), bool)
    m = q >= k
    if kind == "window" and not _is_static_nowindow(window):
        m = jnp.logical_and(m, q - k < window)
    return m


def kv_quantize(x: jax.Array):
    """Per-(…, head) symmetric int8 quantisation over the head_dim axis.
    x: (..., kv, dh) -> (int8 values, f32 scales (..., kv))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(
        jnp.round(x.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return q, scale


def kv_dequantize(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def chunked_attention(
    q: jax.Array,  # (B, Sq, H, dh)
    k: jax.Array,  # (B, Sk, KV, dh)
    v: jax.Array,  # (B, Sk, KV, dh)
    *,
    mask_kind: str,
    window: int = 0,
    q_positions: jax.Array,  # (Sq,)
    k_positions: jax.Array,  # (Sk,)
    chunk: int = 1024,
    remat_step: bool = False,
) -> jax.Array:
    """Online-softmax attention over KV chunks: score memory is
    O(B*H*Sq*chunk) instead of O(B*H*Sq*Sk)."""
    b, sq, h, dh = q.shape
    _, sk, kvh, _ = k.shape
    groups = h // kvh
    scale = 1.0 / math.sqrt(dh)
    n_chunks = -(-sk // chunk)
    pad = n_chunks * chunk - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad), constant_values=-(10**9))
    kc = k.reshape(b, n_chunks, chunk, kvh, dh)
    vc = v.reshape(b, n_chunks, chunk, kvh, dh)
    pc = k_positions.reshape(n_chunks, chunk)
    qg = q.reshape(b, sq, kvh, groups, dh).astype(jnp.float32)

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kb, vb, pb = xs  # (B, chunk, KV, dh), (chunk,)
        s = jnp.einsum(
            "bqkgd,bckd->bqkgc", qg, kb.astype(jnp.float32)
        ) * scale  # (B, Sq, KV, G, chunk)
        valid = _mask(mask_kind, q_positions, pb, window)  # (Sq, chunk)
        # chunk padding carries sentinel position -1e9: never attendable
        # (the causal test q >= k alone would wrongly admit it)
        valid = jnp.logical_and(valid, (pb >= 0)[None, :])
        s = jnp.where(valid[None, :, None, None, :], s, -1e30)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        corr = jnp.exp(m_prev - m_cur)
        l_cur = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p, vb.astype(jnp.float32)
        )
        return (m_cur, l_cur, acc), None

    if remat_step:
        # flash-attention-style: recompute scores/probs in the backward
        # instead of saving (B,Sq,KV,G,chunk) tensors per chunk step
        step = jax.checkpoint(step)
    init = (
        jnp.full((b, sq, kvh, groups), -jnp.inf, jnp.float32),
        jnp.zeros((b, sq, kvh, groups), jnp.float32),
        jnp.zeros((b, sq, kvh, groups, dh), jnp.float32),
    )
    (m_f, l_f, acc), _ = jax.lax.scan(
        step,
        init,
        (
            jnp.moveaxis(kc, 1, 0),
            jnp.moveaxis(vc, 1, 0),
            pc,
        ),
    )
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention(
    q: jax.Array,  # (B, Sq, H, dh) — Sq == 1 for token decode, > 1 for chunks
    k_cache: jax.Array,  # (B, S, KV, dh)
    v_cache: jax.Array,
    cur_pos: jax.Array,  # (B,) position of the (single) new token, or
    #                      (B, Sq) absolute position of every query row
    *,
    window: int = 0,
) -> jax.Array:
    """Attention of Sq query tokens against the full cache (O(Sq*S) work).

    The single-token decode case (Sq == 1) keeps its historical einsum so
    existing decode traces stay bit-identical; the Sq > 1 case serves
    *chunked prefill*: a prompt chunk whose KV rows were just scattered
    into the cache attends causally over everything at positions
    <= its own (cache prefix + intra-chunk causal, one mask)."""
    b, sq, h, dh = q.shape
    _, s, kvh, _ = k_cache.shape
    groups = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qpos = cur_pos if cur_pos.ndim == 2 else cur_pos[:, None]  # (B, Sq)
    kpos = jnp.arange(s)  # (S,)
    if sq == 1:
        qg = q.reshape(b, kvh, groups, dh).astype(jnp.float32)
        scores = jnp.einsum(
            "bkgd,bskd->bkgs", qg, k_cache.astype(jnp.float32)
        ) * scale
        valid = kpos[None, :] <= qpos[:, 0][:, None]
        if not _is_static_nowindow(window):
            valid = jnp.logical_and(valid, qpos[:, 0][:, None] - kpos < window)
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        p = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bkgs,bskd->bkgd", p, v_cache.astype(jnp.float32))
        return out.reshape(b, 1, h, dh).astype(q.dtype)
    qg = q.reshape(b, sq, kvh, groups, dh).astype(jnp.float32)
    scores = jnp.einsum(
        "bqkgd,bskd->bqkgs", qg, k_cache.astype(jnp.float32)
    ) * scale
    valid = kpos[None, None, :] <= qpos[:, :, None]  # (B, Sq, S)
    if not _is_static_nowindow(window):
        valid = jnp.logical_and(valid, qpos[:, :, None] - kpos[None, None, :] < window)
    scores = jnp.where(valid[:, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention_ring(
    q: jax.Array,  # (B, 1, H, dh)
    k_ring: jax.Array,  # (B, W, KV, dh) rolling window, slot j holds the
    v_ring: jax.Array,  # most recent position p with p % W == j
    cur_pos: jax.Array,  # (B,)
    window: int,
) -> jax.Array:
    """Single-token attention over a ring-buffer window cache: O(W) work and
    O(W) reads instead of O(S) — the windowed-cache serving optimization for
    local-attention layers (gemma3's 5:6 of the stack)."""
    b, _, h, dh = q.shape
    w = k_ring.shape[1]
    kvh = k_ring.shape[2]
    groups = h // kvh
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(b, kvh, groups, dh).astype(jnp.float32)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_ring.astype(jnp.float32)) * scale
    # slot j currently holds position cur - ((cur - j) mod W)
    slots = jnp.arange(w)[None, :]
    kpos = cur_pos[:, None] - jnp.mod(cur_pos[:, None] - slots, w)
    valid = jnp.logical_and(kpos >= 0, cur_pos[:, None] - kpos < window)
    scores = jnp.where(valid[:, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v_ring.astype(jnp.float32))
    return out.reshape(b, 1, h, dh).astype(q.dtype)


def attn_apply_ring(
    p: Params,
    x: jax.Array,  # (B, 1, D)
    cfg: ModelConfig,
    *,
    div: Dict[str, int],
    cache: Dict[str, jax.Array],  # k/v rings (B, W, kv, dh)
    cur_pos: jax.Array,  # (B,)
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Decode step for a local-attention layer against a ring cache."""
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    db, dtp = div.get("batch", 1), div.get("model", 1)
    w = cache["k"].shape[1]

    q = gemm(x, p["wq"], divisors=(db, dtp, 1), tag="attn.q").reshape(b, 1, h, dh)
    knew = gemm(x, p["wk"], divisors=(db, dtp, 1), tag="attn.k").reshape(b, 1, kv, dh)
    vnew = gemm(x, p["wv"], divisors=(db, dtp, 1), tag="attn.v").reshape(b, 1, kv, dh)
    q = rope(q, cur_pos[:, None], cfg.rope_theta)
    knew = rope(knew, cur_pos[:, None], cfg.rope_theta)

    bidx = jnp.arange(b)
    slot = jnp.mod(cur_pos, w)
    k_ring = cache["k"].at[bidx, slot].set(knew[:, 0])
    v_ring = cache["v"].at[bidx, slot].set(vnew[:, 0])
    out = decode_attention_ring(q, k_ring, v_ring, cur_pos, cfg.window)
    y = gemm(out.reshape(b, 1, h * dh), p["wo"], divisors=(db, 1, dtp), tag="attn.o")
    return y, {"k": k_ring, "v": v_ring}


def attn_apply(
    p: Params,
    x: jax.Array,  # (B, S, D)
    cfg: ModelConfig,
    *,
    div: Dict[str, int],
    mask_kind: str = "causal",
    window: int = 0,
    positions: Optional[jax.Array] = None,  # (S,) or (B,S) absolute positions
    cache: Optional[Dict[str, jax.Array]] = None,
    cur_pos: Optional[jax.Array] = None,  # (B,) decode position
    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,  # cross-attn
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """GQA attention. Modes:
      * train/prefill: ``cache=None`` -> chunked attention over x itself
        (returns fresh cache when ``positions`` is provided and prefill=True
        handled by caller via returned k/v).
      * decode: ``cache`` + ``cur_pos`` -> one-token attention, cache updated.
      * cross: ``kv_override`` supplies fixed (k, v).
    """
    b, s, d = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    # Per-shard GEMM divisors: tokens are sharded over the batch axes; the
    # output dim of column-parallel projections over "model"; FSDP-sharded
    # contraction dims are all-gathered by GSPMD so K stays full.
    db, dtp = div.get("batch", 1), div.get("model", 1)

    q = gemm(x, p["wq"], divisors=(db, dtp, 1), tag="attn.q")
    q = q.reshape(b, s, h, dh)

    if kv_override is not None:
        knew = vnew = None
        k_full, v_full = kv_override
    else:
        knew = gemm(x, p["wk"], divisors=(db, dtp, 1), tag="attn.k").reshape(
            b, s, kv, dh
        )
        vnew = gemm(x, p["wv"], divisors=(db, dtp, 1), tag="attn.v").reshape(
            b, s, kv, dh
        )

    if positions is None:
        positions = jnp.arange(s)
    if use_rope and kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        knew = rope(knew, positions, cfg.rope_theta)
    elif use_rope:
        q = rope(q, positions, cfg.rope_theta)

    new_cache = None
    if cache is not None and cur_pos is not None and s > 1:
        # chunked prefill: scatter the chunk's KV rows at absolute positions
        # cur_pos..cur_pos+s-1, then attend each query row over the cache
        # prefix plus the intra-chunk causal span — one decode_attention
        # mask covers both. (kv_cache_dtype == "int8" quantizes the whole
        # chunk at once; kv_quantize is shape-generic over leading axes.)
        bidx = jnp.arange(b)[:, None]
        pos_block = cur_pos[:, None] + jnp.arange(s)[None, :]  # (B, S)
        if cfg.kv_cache_dtype == "int8":
            kq, ks = kv_quantize(knew)
            vq, vs = kv_quantize(vnew)
            k_cache = cache["k"].at[bidx, pos_block].set(kq)
            v_cache = cache["v"].at[bidx, pos_block].set(vq)
            k_scale = cache["k_scale"].at[bidx, pos_block].set(ks)
            v_scale = cache["v_scale"].at[bidx, pos_block].set(vs)
            new_cache = {
                "k": k_cache,
                "v": v_cache,
                "k_scale": k_scale,
                "v_scale": v_scale,
            }
            k_full = kv_dequantize(k_cache, k_scale, cfg.dtype)
            v_full = kv_dequantize(v_cache, v_scale, cfg.dtype)
            out = decode_attention(q, k_full, v_full, pos_block, window=window)
        else:
            k_cache = cache["k"].at[bidx, pos_block].set(knew)
            v_cache = cache["v"].at[bidx, pos_block].set(vnew)
            new_cache = {"k": k_cache, "v": v_cache}
            out = decode_attention(q, k_cache, v_cache, pos_block, window=window)
    elif cache is not None and cur_pos is not None:
        # decode: scatter the new token into the cache, attend over it all
        bidx = jnp.arange(b)
        if cfg.kv_cache_dtype == "int8":
            # quantized KV cache: int8 values + per-(token, head) scales —
            # halves the decode memory term (the dominant roofline term of
            # the decode cells); dequant fuses into the attention dots
            kq, ks = kv_quantize(knew[:, 0])
            vq, vs = kv_quantize(vnew[:, 0])
            k_cache = cache["k"].at[bidx, cur_pos].set(kq)
            v_cache = cache["v"].at[bidx, cur_pos].set(vq)
            k_scale = cache["k_scale"].at[bidx, cur_pos].set(ks)
            v_scale = cache["v_scale"].at[bidx, cur_pos].set(vs)
            new_cache = {
                "k": k_cache,
                "v": v_cache,
                "k_scale": k_scale,
                "v_scale": v_scale,
            }
            k_full = kv_dequantize(k_cache, k_scale, cfg.dtype)
            v_full = kv_dequantize(v_cache, v_scale, cfg.dtype)
            out = decode_attention(q, k_full, v_full, cur_pos, window=window)
        else:
            k_cache = cache["k"].at[bidx, cur_pos].set(knew[:, 0])
            v_cache = cache["v"].at[bidx, cur_pos].set(vnew[:, 0])
            new_cache = {"k": k_cache, "v": v_cache}
            out = decode_attention(q, k_cache, v_cache, cur_pos, window=window)
    elif cfg.attn_impl == "mha_expand" and kv_override is None:
        # perf variant: expand KV to the full head count and shard the head
        # dim (unevenly if needed — GSPMD pads, e.g. 56 heads over 16) so
        # the score tensors stay head-parallel instead of replicated.
        groups = h // kv
        k_full = jnp.repeat(knew, groups, axis=2)
        v_full = jnp.repeat(vnew, groups, axis=2)
        q = constrain_uneven(q, "batch", None, "heads", None)
        k_full = constrain_uneven(k_full, "batch", None, "heads", None)
        v_full = constrain_uneven(v_full, "batch", None, "heads", None)
        out = chunked_attention(
            q,
            k_full,
            v_full,
            mask_kind=mask_kind,
            window=window,
            q_positions=positions if positions.ndim == 1 else positions[0],
            k_positions=positions if positions.ndim == 1 else positions[0],
            chunk=cfg.attn_chunk,
            remat_step=cfg.attn_remat,
        )
        new_cache = {"k": knew, "v": vnew}
    elif kv_override is not None:
        sk = k_full.shape[1]
        out = chunked_attention(
            q,
            k_full,
            v_full,
            mask_kind="bidir",
            q_positions=jnp.arange(s),
            k_positions=jnp.arange(sk),
            chunk=cfg.attn_chunk,
            remat_step=cfg.attn_remat,
        )
    else:
        out = chunked_attention(
            q,
            knew,
            vnew,
            mask_kind=mask_kind,
            window=window,
            q_positions=positions if positions.ndim == 1 else positions[0],
            k_positions=positions if positions.ndim == 1 else positions[0],
            chunk=cfg.attn_chunk,
            remat_step=cfg.attn_remat,
        )
        new_cache = {"k": knew, "v": vnew}  # prefill: caller may keep these

    y = gemm(
        out.reshape(b, s, h * dh), p["wo"], divisors=(db, 1, dtp), tag="attn.o"
    )
    return y, new_cache


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------


def mlp_specs(cfg: ModelConfig) -> Dict[str, ArraySpec]:
    d, f, dt = cfg.d_model, cfg.d_ff, cfg.dtype
    spec = {
        "w_in": ArraySpec((d, f), dt, ("embed", "ffn")),
        "w_out": ArraySpec((f, d), dt, ("ffn", "embed")),
    }
    if cfg.mlp_act == "swiglu":
        spec["w_gate"] = ArraySpec((d, f), dt, ("embed", "ffn"))
    return spec


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig, *, div: Dict[str, int]):
    """Activations ride the GEMM epilogue (applied to the f32 accumulator in
    the kernel flush / fix-up phase) instead of running as separate XLA ops;
    swiglu fuses the gate-multiply into the up-projection's epilogue."""
    db, dtp = div.get("batch", 1), div.get("model", 1)
    if cfg.mlp_act == "swiglu":
        g = gemm(x, p["w_gate"], divisors=(db, dtp, 1), tag="mlp.gate")
        h = gemm(
            x,
            p["w_in"],
            divisors=(db, dtp, 1),
            tag="mlp.in",
            epilogue=Epilogue(binary="mul_silu"),
            operand=g,
        )
    elif cfg.mlp_act == "squared_relu":  # nemotron-4
        h = gemm(x, p["w_in"], divisors=(db, dtp, 1), tag="mlp.in", epilogue="square")
    else:
        h = gemm(x, p["w_in"], divisors=(db, dtp, 1), tag="mlp.in", epilogue="gelu")
    return gemm(h, p["w_out"], divisors=(db, 1, dtp), tag="mlp.out")


# ---------------------------------------------------------------------------
# MoE (capacity-based expert-parallel dispatch; GShard-style, deterministic,
# no sort: position-in-expert via rank-major cumsum)
# ---------------------------------------------------------------------------


def moe_specs(cfg: ModelConfig) -> Dict[str, ArraySpec]:
    d, f, e, dt = cfg.d_model, cfg.d_ff, cfg.n_experts, cfg.dtype
    spec = {
        "router": ArraySpec((d, e), "float32", ("embed", None)),
        "w_in": ArraySpec((e, d, f), dt, ("experts", "embed", None)),
        "w_out": ArraySpec((e, f, d), dt, ("experts", None, "embed")),
    }
    if cfg.mlp_act == "swiglu":
        spec["w_gate"] = ArraySpec((e, d, f), dt, ("experts", "embed", None))
    return spec


def moe_apply(
    p: Params, x: jax.Array, cfg: ModelConfig, *, div: Dict[str, int]
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_load_balance_loss)."""
    if cfg.moe_impl == "sharded":
        return moe_apply_sharded(p, x, cfg, div=div)
    if cfg.moe_impl in ("shard_map", "shard_map_bf16"):
        from repro.dist.sharding import current_plan

        # quantized expert weights fall through to the capacity-dispatch
        # path: shard_map in_specs are rank-pinned P(...) specs for dense
        # (E, K, N) arrays and cannot describe a QuantizedTensor's
        # (values, scales) leaf pair — semantics are identical either way
        if current_plan() is not None and not is_quantized(p["w_in"]):
            return moe_apply_shard_map(p, x, cfg, div=div)
        # no mesh installed (CPU tests): fall through — semantics identical
    hinted = cfg.moe_impl == "hinted"
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)
    if hinted:
        xf = constrain(xf, "batch", None)

    logits = gemm(
        xf.astype(jnp.float32), p["router"], divisors=(div.get("batch", 1), 1, 1),
        tag="moe.router",
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    gates, idx = jax.lax.top_k(probs, k)  # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # capacity per expert; the min(t, 16) floor makes tiny-T dispatch
    # (single-token decode) drop-free — a token can always place its top-k
    cap = max(int(cfg.capacity_factor * t * k / e), min(t, 16), 1)
    if hinted:
        # perf variant: token-major assignment order keeps the flattened
        # (T*k,) axis sharded like T (k is the minor reshape dim so GSPMD
        # propagates the batch sharding); capacity priority becomes
        # position-in-batch — GShard's original — instead of rank-major
        e_flat = constrain(idx.reshape(t * k), "batch")
        tok = jnp.repeat(jnp.arange(t), k)
        gate_flat = gates.reshape(t * k)
    else:
        # rank-major assignment order: rank-0 choices of all tokens first, so
        # a token's primary expert wins capacity over another's secondary.
        e_flat = idx.T.reshape(t * k)  # (k*T,)
        tok = jnp.tile(jnp.arange(t), k)
        gate_flat = gates.T.reshape(t * k)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # (kT, E)
    pos = jnp.cumsum(onehot, axis=0) * onehot - 1
    pos = jnp.max(pos, axis=-1)  # (kT,) position in chosen expert
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)  # cap = trash column

    # dispatch: (E, cap+1, D); trash column absorbs dropped tokens
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[e_flat, slot].set(xf[tok], mode="drop")
    expert_in = buf[:, :cap]
    if hinted:
        # experts-only sharding: the embed dim must stay unsharded because
        # 'data' is already carrying the token dim of the scatter updates
        # (iteration-2 refutation: ('experts',None,'embed') blew memory up)
        expert_in = constrain(expert_in, "experts", None, None)

    # grouped expert GEMMs: one GemmOp with G = E covers the whole stack —
    # exactly the skinny-M (M = capacity) grouped shapes where Stream-K's
    # work-centric decomposition matters most; activations fuse into the
    # kernel epilogue instead of running as separate XLA ops
    dg = div.get("model", 1)
    if cfg.mlp_act == "swiglu":
        g = gemm_grouped(expert_in, p["w_gate"], g_divisor=dg, tag="moe.gate")
        h = gemm_grouped(
            expert_in,
            p["w_in"],
            g_divisor=dg,
            tag="moe.in",
            epilogue=Epilogue(binary="mul_silu"),
            operand=g,
        )
    else:
        h = gemm_grouped(
            expert_in, p["w_in"], g_divisor=dg, tag="moe.in", epilogue="gelu"
        )
    out_e = gemm_grouped(h, p["w_out"], g_divisor=dg, tag="moe.out")  # (E, cap, D)
    if hinted:
        out_e = constrain(out_e, "experts", None, None)

    # combine: gather back per assignment, weight, sum over ranks
    gathered = out_e[e_flat, jnp.minimum(slot, cap - 1)]  # (kT, D)
    w = (gate_flat * keep).astype(jnp.float32)
    if hinted:
        gathered = constrain(gathered, "batch", None)
        combined = (gathered.astype(jnp.float32) * w[:, None]).reshape(t, k, d).sum(1)
    else:
        combined = (gathered.astype(jnp.float32) * w[:, None]).reshape(k, t, d).sum(0)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    frac = jnp.mean(
        onehot.reshape((t, k, e) if hinted else (k, t, e))
        .sum(1 if hinted else 0)
        .astype(jnp.float32),
        axis=0,
    )
    mean_p = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(frac * mean_p)
    return combined.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_sharded(
    p: Params, x: jax.Array, cfg: ModelConfig, *, div: Dict[str, int]
) -> Tuple[jax.Array, jax.Array]:
    """Perf variant (``moe_impl="sharded"``): shard-local capacity dispatch.

    The baseline routes over the *global* token space: the cumsum that
    assigns capacity slots spans all tokens, so under GSPMD it serialises
    across data shards (collective-permute chains) and the dispatch scatter
    gathers activations globally. Here every data shard routes its own
    tokens into its own (E, cap_local) buffer — routing math is embarrassingly
    parallel over shards — and only the expert computation crosses the mesh
    (tokens meet model-sharded experts: the canonical MoE all-to-all).
    Capacity semantics per shard are identical to GShard with per-shard
    groups (the standard formulation at scale)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    groups = div.get("batch", 1)
    if t % groups:
        groups = 1
    tl = t // groups
    xg = constrain(x.reshape(groups, tl, d), "batch", None, None)

    logits = jnp.einsum(
        "gtd,de->gte", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)  # (G, Tl, E)
    gates, idx = jax.lax.top_k(probs, k)  # (G, Tl, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    cap = max(int(cfg.capacity_factor * tl * k / e), min(tl, 16), 1)
    # rank-major within each shard (primary choices win capacity)
    e_flat = idx.transpose(0, 2, 1).reshape(groups, tl * k)  # (G, kTl)
    onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)  # (G, kTl, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1
    pos = jnp.max(pos, axis=-1)  # (G, kTl)
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)

    tok = jnp.tile(jnp.arange(tl), k)[None, :].repeat(groups, 0)  # (G, kTl)
    gidx = jnp.arange(groups)[:, None]
    buf = jnp.zeros((groups, e, cap + 1, d), x.dtype)
    buf = buf.at[gidx, e_flat, slot].set(
        jnp.take_along_axis(xg, tok[..., None], axis=1), mode="drop"
    )
    expert_in = constrain(buf[:, :, :cap], "batch", "experts", None, None)

    # fold the shard-group dim into M: each expert contracts (G*cap, d) in
    # one grouped GemmOp (G = E), keeping the expert GEMMs on the Stream-K++
    # dispatch layer under the shard-local formulation too
    e_in = expert_in.transpose(1, 0, 2, 3).reshape(e, groups * cap, d)
    dg = div.get("model", 1)
    if cfg.mlp_act == "swiglu":
        g_ = gemm_grouped(e_in, p["w_gate"], g_divisor=dg, tag="moe.gate")
        h = gemm_grouped(
            e_in,
            p["w_in"],
            g_divisor=dg,
            tag="moe.in",
            epilogue=Epilogue(binary="mul_silu"),
            operand=g_,
        )
    else:
        h = gemm_grouped(e_in, p["w_in"], g_divisor=dg, tag="moe.in", epilogue="gelu")
    out = gemm_grouped(h, p["w_out"], g_divisor=dg, tag="moe.out")  # (E, G*cap, D)
    out_e = out.reshape(e, groups, cap, d).transpose(1, 0, 2, 3)
    out_e = constrain(out_e, "batch", "experts", None, None)

    gathered = out_e[gidx, e_flat, jnp.minimum(slot, cap - 1)]  # (G, kTl, D)
    w = (gates.transpose(0, 2, 1).reshape(groups, tl * k) * keep).astype(jnp.float32)
    combined = (
        (gathered.astype(jnp.float32) * w[..., None])
        .reshape(groups, k, tl, d)
        .sum(1)
    )

    frac = jnp.mean(
        onehot.reshape(groups, k, tl, e).sum(1).astype(jnp.float32), axis=(0, 1)
    )
    mean_p = jnp.mean(probs, axis=(0, 1))
    aux = cfg.router_aux_coef * e * jnp.sum(frac * mean_p)
    return combined.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_shard_map(
    p: Params, x: jax.Array, cfg: ModelConfig, *, div: Dict[str, int]
) -> Tuple[jax.Array, jax.Array]:
    """Perf variant (``moe_impl="shard_map"``): explicit expert-parallel MoE.

    Three GSPMD formulations failed on this dispatch (§Perf iteration log):
    data-dependent scatters with more than one sharded target axis get
    replicated. The fix is to stop asking the partitioner: under
    ``shard_map`` every (data, model) shard routes the tokens of its data
    row — which the residual stream already replicates across the model
    axis — into buffers for the E/M experts IT owns. Dispatch is therefore
    entirely local; the only communication is the combine ``psum`` over
    'model' (+ GSPMD's usual gradient handling outside).

    Capacity semantics: per data-row capacity, token-major priority — the
    same contract as ``moe_impl="hinted"``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.dist.sharding import current_plan

    plan = current_plan()
    mesh = plan.mesh
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mp = mesh.shape.get("model", 1)

    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    assert e % mp == 0, "expert count must divide the model axis"
    e_loc = e // mp

    def local(xb, router, w_in, w_gate, w_out):
        # xb: (B_loc, S, D) — this data-row's tokens (replicated over model)
        bl = xb.shape[0]
        tl = bl * s
        xf = xb.reshape(tl, d)
        logits = jnp.dot(xf.astype(jnp.float32), router.astype(jnp.float32))
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

        cap = max(int(cfg.capacity_factor * tl * k / e), min(tl, 16), 1)
        e_flat = idx.reshape(tl * k)  # token-major priority
        onehot = jax.nn.one_hot(e_flat, e, dtype=jnp.int32)
        pos = jnp.max(jnp.cumsum(onehot, axis=0) * onehot - 1, axis=-1)
        keep = pos < cap
        slot = jnp.where(keep, pos, cap)

        # dispatch ONLY into this shard's experts: local ids [0, e_loc)
        j = jax.lax.axis_index("model") if "model" in mesh.axis_names else 0
        e_local = e_flat - j * e_loc
        in_range = jnp.logical_and(e_local >= 0, e_local < e_loc)
        e_clamped = jnp.clip(e_local, 0, e_loc - 1)
        slot_masked = jnp.where(in_range, slot, cap)  # out-of-range -> trash
        tok = jnp.repeat(jnp.arange(tl), k)
        buf = jnp.zeros((e_loc, cap + 1, d), x.dtype)
        buf = buf.at[e_clamped, slot_masked].set(xf[tok], mode="drop")
        expert_in = buf[:, :cap]

        # shapes here are already shard-local (shard_map body), so the
        # grouped dispatch runs with unit divisors; G = e_loc experts
        if cfg.mlp_act == "swiglu":
            g_ = gemm_grouped(expert_in, w_gate, tag="moe.gate")
            h = gemm_grouped(
                expert_in,
                w_in,
                tag="moe.in",
                epilogue=Epilogue(binary="mul_silu"),
                operand=g_,
            )
        else:
            h = gemm_grouped(expert_in, w_in, tag="moe.in", epilogue="gelu")
        out_e = gemm_grouped(h, w_out, tag="moe.out")  # (e_loc, cap, D)

        # combine: local assignments only, then sum partial outputs
        gathered = out_e[e_clamped, jnp.minimum(slot_masked, cap - 1)]
        w = (
            gates.reshape(tl * k)
            * keep
            * in_range
        ).astype(jnp.float32)
        combined = (gathered.astype(jnp.float32) * w[:, None]).reshape(
            tl, k, d
        ).sum(1)
        if "model" in mesh.axis_names:
            if cfg.moe_impl == "shard_map_bf16":
                # halve the combine traffic; each shard's partial is a sum
                # of <= k bf16 products — quantisation comparable to the
                # layer's own bf16 output cast
                combined = jax.lax.psum(
                    combined.astype(jnp.bfloat16), "model"
                ).astype(jnp.float32)
            else:
                combined = jax.lax.psum(combined, "model")

        frac = jnp.mean(
            onehot.reshape(tl, k, e).sum(1).astype(jnp.float32), axis=0
        )
        mean_p = jnp.mean(probs, axis=0)
        aux = cfg.router_aux_coef * e * jnp.sum(frac * mean_p)
        return combined.reshape(bl, s, d).astype(x.dtype), aux

    batch_spec = P(dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes else None))
    x_spec = P(batch_spec[0], None, None)
    out = shard_map(
        local,
        mesh=mesh,
        in_specs=(
            x_spec,
            P(None, None),  # router replicated
            P("model", None, None),  # experts over model
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(x_spec, P()),
        check_rep=False,
    )(
        x,
        p["router"],
        p["w_in"],
        p.get("w_gate", p["w_in"]),
        p["w_out"],
    )
    return out
