"""Model substrate: configs, shared layers, and the family model classes."""

from repro.models.config import (
    ALL_SHAPES,
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES_BY_NAME,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
    applicable_shapes,
)


def build_model(cfg: ModelConfig):
    """Factory: ModelConfig -> model object (LM or EncDec)."""
    if cfg.family == "encdec":
        from repro.models.encdec import EncDec

        return EncDec(cfg)
    from repro.models.lm import LM

    return LM(cfg)


__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "TRAIN_4K",
    "PREFILL_32K",
    "DECODE_32K",
    "LONG_500K",
    "applicable_shapes",
    "build_model",
]
