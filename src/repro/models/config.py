"""Model configuration: one dataclass describing every architecture family in
the assigned pool (dense / MoE / SSM / hybrid / enc-dec / VLM backbones)."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # -- MLP --------------------------------------------------------------
    mlp_act: str = "swiglu"  # swiglu | squared_relu | gelu

    # -- MoE ----------------------------------------------------------------
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # -- attention pattern ---------------------------------------------------
    window: int = 0  # sliding-window size for local layers (0 = full)
    global_every: int = 0  # gemma3: every Nth layer is global (rest local)
    rope_theta: float = 10_000.0

    # -- SSM (Mamba2 / SSD) -----------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 64
    ssm_conv_width: int = 4

    # -- hybrid (zamba2): shared attention block every k layers -------------
    attn_every: int = 0

    # -- encoder-decoder (whisper) -------------------------------------------
    n_enc_layers: int = 0
    enc_frames: int = 1500  # stubbed conv frontend output length

    # -- VLM backbone (llava): stubbed vision frontend -------------------------
    n_patches: int = 0

    # -- implementation switches (perf variants; semantics identical) ---------
    moe_impl: str = "global"  # global | sharded | hinted (token-major + hints)
    attn_impl: str = "gqa"  # gqa | mha_expand (expand kv, shard fused heads)
    attn_chunk: int = 1024  # KV chunk of the online-softmax attention
    attn_remat: bool = False  # remat the chunk step (drop prob tensors in bwd)
    kv_cache_dtype: str = "model"  # model (= cfg.dtype) | int8 (quantized cache)
    window_cache: bool = False  # local layers keep a ring of `window` slots
    # (decode only; requires global_every > 0 — see LM.decode_step_windowed)

    # -- numerics / misc -----------------------------------------------------
    dtype: str = "bfloat16"
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False
    remat: bool = True

    def __post_init__(self):
        if self.d_head == 0 and self.n_heads:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    # -- derived -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM state, hybrid, or sliding-window."""
        return self.family in ("ssm", "hybrid") or (
            self.window > 0 and self.global_every > 0
        )

    def param_count(self) -> int:
        """Analytic parameter count — exact: tests assert it equals the
        instantiated param tree for every architecture."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, kv, dh = self.n_heads, self.n_kv_heads, self.d_head
        norm = 2 * d if self.norm == "layernorm" else d  # scale (+ bias)

        def attn_params():
            return d * h * dh + 2 * d * kv * dh + h * dh * d

        def mlp_params():
            n_in = 2 if self.mlp_act == "swiglu" else 1
            return n_in * d * f + f * d

        def moe_params():
            return d * self.n_experts + self.n_experts * mlp_params()

        def mamba_params():
            din, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            conv_dim = din + 2 * ds
            in_proj = d * (2 * din + 2 * ds + nh)
            conv = (self.ssm_conv_width + 1) * conv_dim  # weight + bias
            return in_proj + conv + 3 * nh + din * d

        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v
        if self.family in ("dense", "vlm"):
            total += self.n_layers * (2 * norm + attn_params() + mlp_params())
        elif self.family == "moe":
            total += self.n_layers * (2 * norm + attn_params() + moe_params())
        elif self.family == "ssm":
            total += self.n_layers * (norm + mamba_params())
        elif self.family == "hybrid":
            total += self.n_layers * (norm + mamba_params())
            if self.attn_every:
                total += 2 * norm + attn_params() + mlp_params()  # shared block
        elif self.family == "encdec":
            total += self.n_enc_layers * (2 * norm + attn_params() + mlp_params())
            total += norm  # encoder final norm
            total += self.n_layers * (3 * norm + 2 * attn_params() + mlp_params())
        total += norm  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only top-k experts)."""
        if self.family != "moe" or not self.n_experts:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_in = 2 if self.mlp_act == "swiglu" else 1
        per_expert = n_in * d * f + f * d
        inactive = (self.n_experts - self.top_k) * per_expert * self.n_layers
        return self.param_count() - inactive


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shape cells that apply to an architecture (assignment rules):
    ``long_500k`` only for sub-quadratic archs; every pool arch has a decode
    path (none are encoder-only)."""
    shapes = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        shapes.append(LONG_500K)
    return tuple(shapes)
