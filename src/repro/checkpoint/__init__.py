from repro.checkpoint.manager import CheckpointManager, install_sigterm_handler

__all__ = ["CheckpointManager", "install_sigterm_handler"]
