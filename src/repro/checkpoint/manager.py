"""Fault-tolerant checkpointing.

Design (what a 1000-node deployment needs, scaled to this container):

  * **Atomic commits** — a checkpoint is written to ``step_<N>.tmp`` and
    renamed only when complete; a crash mid-write can never corrupt the
    latest restorable state. A ``LATEST`` pointer file is updated last.
  * **Async** — ``save(..., blocking=False)`` snapshots to host memory
    (device_get) and hands the serialisation to a writer thread, so the
    training loop resumes immediately (the TPU analogue: donate the arrays
    and let the host flush while step N+1 runs).
  * **Elastic / mesh-agnostic** — arrays are stored *unsharded* (gathered)
    with a metadata manifest (paths, shapes, dtypes); ``restore`` takes an
    optional sharding pytree and device_puts each leaf into the *new* mesh
    layout, so a checkpoint taken on a 16x16 mesh restores onto 2x16x16 (or
    1 CPU device) unchanged. On a real multi-host fleet the gather becomes
    a per-host shard dump keyed by the same manifest — the manifest format
    already carries everything needed.
  * **Retention** — keep the most recent ``keep`` checkpoints (the crash-
    loop guard: never delete the checkpoint currently pointed to by LATEST).
  * **Preemption hook** — ``install_sigterm_handler`` flushes a final
    checkpoint on SIGTERM (maintenance events / spot reclaims).
"""

from __future__ import annotations

import json
import os
import queue
import shutil
import signal
import threading
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.utils.logging import get_logger

log = get_logger("checkpoint")


def _flat(tree) -> Dict[str, Any]:
    out = {}

    def name(path):
        parts = []
        for p in path:
            parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
        return "/".join(parts)

    jax.tree_util.tree_map_with_path(lambda p, x: out.__setitem__(name(p), x), tree)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._writer: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        if not os.path.exists(ptr):
            return None
        with open(ptr) as f:
            return int(f.read().strip())

    def all_steps(self) -> List[int]:
        steps = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                steps.append(int(d[5:]))
        return sorted(steps)

    # -- save ------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None, blocking: bool = True):
        """Snapshot ``state`` (pytree of arrays) at ``step``."""
        if self._error:
            raise RuntimeError("async checkpoint writer failed") from self._error
        # snapshot on the caller's thread: device -> host
        host = {k: np.asarray(jax.device_get(v)) for k, v in _flat(state).items()}
        meta = {
            "step": step,
            "arrays": {
                k: {"shape": list(v.shape), "dtype": str(v.dtype)} for k, v in host.items()
            },
            "extra": extra or {},
        }
        if blocking:
            self._write(step, host, meta)
        else:
            self._ensure_writer()
            self._q.put((step, host, meta))

    def _ensure_writer(self):
        if self._writer is None or not self._writer.is_alive():

            def run():
                while True:
                    item = self._q.get()
                    if item is None:
                        return
                    try:
                        self._write(*item)
                    except BaseException as e:  # pragma: no cover
                        self._error = e
                        log.error("async checkpoint write failed: %s", e)

            self._writer = threading.Thread(target=run, daemon=True)
            self._writer.start()

    def wait(self):
        """Barrier for pending async saves."""
        if self._writer and self._writer.is_alive():
            self._q.put(None)
            self._writer.join()
            self._writer = None
        if self._error:
            raise RuntimeError("async checkpoint writer failed") from self._error

    def _write(self, step: int, host: Dict[str, np.ndarray], meta: Dict):
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
        with open(os.path.join(self.dir, "LATEST.tmp"), "w") as f:
            f.write(str(step))
        os.replace(os.path.join(self.dir, "LATEST.tmp"), os.path.join(self.dir, "LATEST"))
        self._gc()
        log.info("checkpoint step %d committed", step)

    def _gc(self):
        steps = self.all_steps()
        latest = self.latest_step()
        for s in steps[: -self.keep] if len(steps) > self.keep else []:
            if s == latest:
                continue
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore ----------------------------------------------------------
    def restore(
        self,
        target,
        step: Optional[int] = None,
        shardings=None,
    ):
        """Restore into the structure of ``target`` (pytree of arrays or
        ShapeDtypeStructs). ``shardings``: optional matching pytree of
        NamedShardings for elastic restore onto a different mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = self._step_dir(step)
        blob = np.load(os.path.join(d, "arrays.npz"))
        flat_names = list(_flat(target).keys())
        missing = [n for n in flat_names if n not in blob]
        if missing:
            raise KeyError(f"checkpoint missing arrays: {missing[:5]} ...")
        leaves, treedef = jax.tree.flatten(target)
        shard_leaves = (
            jax.tree.flatten(shardings)[0] if shardings is not None else [None] * len(leaves)
        )
        out = []
        for name, tgt, shd in zip(flat_names, leaves, shard_leaves):
            arr = blob[name]
            want = np.dtype(tgt.dtype)
            if arr.dtype != want:
                arr = arr.astype(want)
            if shd is not None:
                out.append(jax.device_put(arr, shd))
            else:
                out.append(jax.device_put(arr))
        return jax.tree.unflatten(treedef, out), step

    def read_extra(self, step: Optional[int] = None) -> Dict:
        step = step if step is not None else self.latest_step()
        with open(os.path.join(self._step_dir(step), "meta.json")) as f:
            return json.load(f)["extra"]


def install_sigterm_handler(fn: Callable[[], None]):
    """Preemption path: flush a checkpoint before the scheduler kills us."""

    def handler(signum, frame):  # pragma: no cover - signal path
        log.warning("SIGTERM received — writing preemption checkpoint")
        fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)
