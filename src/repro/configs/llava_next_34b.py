"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling backbone; vision frontend stubbed (576 patch
embeddings prepended). [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    mlp_act="swiglu",
    n_patches=576,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        n_patches=8,
    )
