"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + one shared (weight-tied)
attention+MLP block applied every 6th layer. [arXiv:2411.15242; hf]"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    mlp_act="swiglu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        n_layers=4,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=8,
        attn_every=2,
    )
