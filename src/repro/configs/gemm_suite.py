"""The paper's FP16 GEMM benchmark suite: 923 unique problem sizes with
dimensions in powers of two, M in [1, 8192], N in [64, 8192],
K in [16, 65536] (§5.1).

The full power-of-two grid is 14 x 8 x 13 = 1456 cells; the paper
benchmarks 923 of them (their industry-informed subset is confidential).
We down-select deterministically to exactly 923 by keeping the cells with
the smallest working sets (A + B + C footprint) — i.e. dropping the sizes
that would not have fit the benchmarking budget of an MI250X-era device —
so the suite is reproducible from this file alone.
"""

from __future__ import annotations

from typing import List, Tuple

MNK = Tuple[int, int, int]

N_SIZES = 923


def full_grid() -> List[MNK]:
    ms = [2**i for i in range(0, 14)]  # 1 .. 8192
    ns = [2**i for i in range(6, 14)]  # 64 .. 8192
    ks = [2**i for i in range(4, 17)]  # 16 .. 65536
    return [(m, n, k) for m in ms for n in ns for k in ks]


def working_set_bytes(size: MNK, dtype_bytes: int = 2) -> int:
    m, n, k = size
    return (m * k + k * n + m * n) * dtype_bytes


def suite(n: int = N_SIZES) -> List[MNK]:
    """The 923-size benchmark suite (deterministic)."""
    grid = full_grid()
    # stable sort by working set, then lexicographic for determinism
    grid.sort(key=lambda s: (working_set_bytes(s), s))
    return sorted(grid[:n])
