"""whisper-large-v3 [audio]: 32L enc + 32L dec, d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866 — enc-dec; conv frontend STUBBED (input_specs feeds
1500 frame embeddings). [arXiv:2212.04356; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_act="gelu",
    norm="layernorm",
    n_enc_layers=32,
    enc_frames=1500,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        n_layers=2,
        n_enc_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        enc_frames=16,
    )
