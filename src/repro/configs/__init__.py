"""Architecture registry: ``--arch <id>`` resolution for every assigned
architecture (full configs) and their reduced smoke-test variants."""

from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

_MODULES: Dict[str, str] = {
    "llava-next-34b": "repro.configs.llava_next_34b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "gemma3-27b": "repro.configs.gemma3_27b",
    "granite-8b": "repro.configs.granite_8b",
    "nemotron-4-15b": "repro.configs.nemotron_4_15b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; valid: {list_archs()}")
    return importlib.import_module(_MODULES[name]).FULL


def get_reduced(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; valid: {list_archs()}")
    return importlib.import_module(_MODULES[name]).reduced()
