"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global attention (window 1024), 128k context,
tied embeddings. [hf:google/gemma-3-1b-pt; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    mlp_act="swiglu",
    window=1024,
    global_every=6,  # 5 local : 1 global
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        n_layers=6,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
        window=8,
        global_every=3,
    )
