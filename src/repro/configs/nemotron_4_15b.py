"""nemotron-4-15b [dense]: 32L d_model=6144 48H (GQA kv=8) d_ff=24576
vocab=256000 — GQA, squared-ReLU MLP. [arXiv:2402.16819; unverified]"""

import dataclasses

from repro.models.config import ModelConfig

FULL = ModelConfig(
    name="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="squared_relu",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        FULL,
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_head=16,
        d_ff=128,
        vocab_size=256,
    )
