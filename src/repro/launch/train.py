"""Training launcher CLI.

Examples:
  # 100M-param LM for a few hundred steps on host devices:
  PYTHONPATH=src python -m repro.launch.train --arch granite-8b --preset 100m \
      --steps 300 --batch 8 --seq-len 256 --ckpt-dir /tmp/ckpt

  # full assigned config (reduced smoke on CPU would OOM — use --preset):
  PYTHONPATH=src python -m repro.launch.train --arch olmoe-1b-7b --preset reduced --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, get_reduced, list_archs
from repro.data import SyntheticLMData
from repro.dist.sharding import materialize_tree
from repro.models import build_model
from repro.optim import make_optimizer, warmup_cosine
from repro.train import Trainer, TrainerConfig, init_train_state
from repro.utils.logging import get_logger

log = get_logger("launch.train")


def preset_config(arch: str, preset: str):
    if preset == "full":
        return get_config(arch)
    if preset == "reduced":
        return get_reduced(arch)
    if preset == "100m":
        # ~100M-param member of the arch's family (end-to-end driver scale)
        base = get_reduced(arch)
        kw = dict(
            n_layers=8,
            d_model=512,
            d_ff=2048 if base.d_ff else 0,
            vocab_size=32768,
            d_head=64,
        )
        if base.n_heads:
            kw.update(n_heads=8, n_kv_heads=max(1, min(base.n_kv_heads, 8)))
        if base.n_experts:
            kw.update(n_experts=8, top_k=2, d_ff=1024)
        if base.ssm_state:
            kw.update(ssm_state=64, ssm_head_dim=64, ssm_chunk=64)
        if base.family == "encdec":
            kw.update(n_enc_layers=4, enc_frames=128)
        return dataclasses.replace(base, **kw)
    raise ValueError(f"unknown preset {preset}")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="100m", choices=["full", "reduced", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd", "adafactor"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32", help="override model dtype on CPU")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    model = build_model(cfg)
    log.info(
        "arch=%s preset=%s params=%.1fM", args.arch, args.preset, cfg.param_count() / 1e6
    )

    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(args.seed))
    opt = make_optimizer(
        args.optimizer, warmup_cosine(args.lr, args.warmup, args.steps)
    )
    data = SyntheticLMData(cfg, batch=args.batch, seq_len=args.seq_len, seed=args.seed)
    trainer = Trainer(
        model,
        opt,
        data,
        TrainerConfig(
            total_steps=args.steps,
            ckpt_dir=args.ckpt_dir,
            ckpt_every=args.ckpt_every,
            log_every=10,
            microbatches=args.microbatches,
            grad_compression=args.grad_compression,
            handle_sigterm=args.ckpt_dir is not None,
        ),
    )
    state = init_train_state(model, opt, params, args.grad_compression)
    trainer.fit(state)
    log.info("final loss %.4f (first %.4f)", trainer.history[-1], trainer.history[0])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
