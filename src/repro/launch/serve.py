"""Serving launcher CLI: batched decode with continuous batching.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --preset 100m \
      --requests 16 --max-new-tokens 32
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.configs import list_archs
from repro.core.gemm import gemm_context
from repro.core.selector import default_selector
from repro.dist.sharding import materialize_tree
from repro.launch.train import preset_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="100m", choices=["full", "reduced", "100m"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family == "encdec":
        raise SystemExit("serve CLI drives decoder-only archs; see examples/ for enc-dec")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(args.seed))

    selector = default_selector()
    with gemm_context(selector=selector) as ctx:
        engine = ServeEngine(
            model, params, ServeConfig(n_slots=args.slots, max_seq=args.max_seq, eos=-1)
        )
        rng = np.random.default_rng(args.seed)
        for _ in range(args.requests):
            engine.submit(
                rng.integers(1, cfg.vocab_size, size=int(rng.integers(8, 64))),
                max_new_tokens=args.max_new_tokens,
                temperature=args.temperature,
            )
        t0 = time.time()
        done = engine.run()
        dt = time.time() - t0
    ntok = sum(len(r.out_tokens) for r in done)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s)",
        len(done),
        ntok,
        dt,
        ntok / max(dt, 1e-9),
    )
    # show the Stream-K++ dispatch decisions the decode GEMMs triggered
    seen = {}
    for e in ctx.log:
        seen.setdefault((e.tag, e.local_mnk), e.selection)
    log.info("distinct GEMM dispatches: %d", len(seen))
    for (tag, mnk), sel in sorted(seen.items())[:20]:
        log.info("  %-12s M,N,K=%s -> %s/%s (%s)", tag, mnk, sel.policy.name, sel.cfg.name, sel.source)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
