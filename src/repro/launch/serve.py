"""Serving launcher CLI: batched decode with continuous batching.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --preset 100m \
      --requests 16 --max-new-tokens 32

Quantized serving (int8 weights, fused dequant epilogues):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 16 \
      --quantize int8 --adapt --journal artifacts/tuning_journal.jsonl

``--quantize int8`` converts every projection weight to a QuantizedTensor at
load; decode GEMMs dispatch under mixed ``'<act>*int8'`` fingerprints, so
they tune/journal/warm-start independently of the f32 ops at the same MNK.

Online adaptation (miss-driven autotuning in the decode loop):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 16 \
      --adapt --adapt-every 4 --adapt-budget 0.05 \
      --db artifacts/tuning_db.json --journal artifacts/tuning_journal.jsonl

``--db`` warm-starts the selector from an offline snapshot; ``--journal`` is
replayed on top at startup and appended to as serving traffic teaches the
tuner new fingerprints, so the next run starts where this one left off.

Federated serving (simulated K-process fleet):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 32 \
      --adapt --workers 4 --merge-journals --journal artifacts/tuning_journal.jsonl

``--workers K`` serves the request stream through K engines with fully
separate selector/tuner/database state (what K serving processes would
hold), each appending to its own journal shard ``<journal>.shard<i>``;
``--merge-journals`` federates every existing shard into each worker's
warm-start database (``repro.core.federate``), so a fingerprint one worker
tuned yesterday is a database hit in every worker today. ``--mesh-model N``
installs a host-mesh sharding plan so dispatch fingerprints key on the
per-shard local MNK (mesh-aware federation across identically-sharded
hosts).

Streaming gossip and heterogeneous fleets:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 32 \
      --adapt --workers 2 --gossip-every 8 --arch-class auto \
      --journal artifacts/tuning_journal.jsonl

``--gossip-every N`` keeps federation continuous: every N engine steps each
worker tails its siblings' journal shards (``repro.core.gossip``) and folds
fresh commits into its live selector via an atomic hot-swap — no restart
between learning and benefiting. ``--arch-class auto`` stamps records with
the machine's architecture class; same-class records federate as direct
database hits while other-class records only seed selection as re-ranked
``"xarch"`` candidates (never applied verbatim).

Paged serving with admission control and traffic replay:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 32 \
      --paged --page-size 16 --max-pages 64 --replay poisson

``--paged`` swaps in the block/paged-KV engine (``repro.serve.scheduler``):
KV memory is a page pool, residency is bounded by actual sequence lengths,
and admission is oldest-first under a watermark reserve. ``--max-pages 0``
(the default) sizes the pool to exactly the dense engine's KV rows
(``slots * max_seq / page_size``) so the two modes compare at equal memory.
``--replay poisson|bursty`` schedules submissions on a synthetic arrival
process (one engine step per clock tick) instead of enqueueing everything
up front, and logs the SLO summary (p50/p99 latency, TTFT, page occupancy,
admission counters) the paged engine tracks per request.
"""

from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import time

import jax
import numpy as np

from repro.configs import list_archs
from repro.core import costmodel
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.arch import DEFAULT_ARCH, append_arch, detect_arch
from repro.core.calibrate import (
    CalibrationError,
    append_calibration,
    calibrate_db,
    machine_from_json,
)
from repro.core.federate import apply_journal_db, merge_journal_shards
from repro.core.gemm import gemm_context
from repro.core.gossip import GossipExchange
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import TuningDatabase
from repro.dist.sharding import ShardingPlan, materialize_tree, use_plan
from repro.launch.mesh import make_host_mesh
from repro.launch.train import preset_config
from repro.models import build_model
from repro.serve import (
    AdmissionError,
    PagedServeConfig,
    PagedServeEngine,
    ServeConfig,
    ServeEngine,
)
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def shard_journal_path(journal: str, worker: int, n_workers: int) -> str:
    """Worker ``worker``'s private journal shard (the base path itself for a
    single-worker run, preserving the PR-2 CLI contract)."""
    return journal if n_workers <= 1 else f"{journal}.shard{worker}"


def existing_journal_shards(journal: str) -> list:
    """Every journal shard a previous (possibly differently-sized) fleet
    left behind, base journal included."""
    paths = sorted(glob.glob(f"{journal}.shard*"))
    if os.path.exists(journal):
        paths.insert(0, journal)
    return paths


def replay_arrivals(n: int, pattern: str, rate: float, seed: int) -> list:
    """Arrival step index per request: ``poisson`` draws exponential
    inter-arrival gaps at ``rate`` requests/step; ``bursty`` emits
    back-to-back bursts of 4-12 separated by long idle gaps."""
    rng = np.random.default_rng(seed + 1)
    if pattern == "poisson":
        return [int(t) for t in np.floor(np.cumsum(rng.exponential(1.0 / rate, n)))]
    steps: list = []
    t = 0.0
    while len(steps) < n:
        burst = int(rng.integers(4, 13))
        steps.extend(int(t) for _ in range(min(burst, n - len(steps))))
        t += rng.exponential(burst / rate) + 1.0
    return steps


def replay_stream(
    engine,
    prompts,
    *,
    pattern,
    rate,
    seed,
    max_new,
    temperature,
    gossip=None,
    gossip_every=0,
):
    """Drive ``engine`` on a synthetic arrival process: one engine step per
    clock tick, submissions offered as they come due, queue backpressure
    (:class:`~repro.serve.AdmissionError`) re-offered next tick. With a
    :class:`~repro.core.gossip.GossipExchange`, sibling journal shards are
    polled every ``gossip_every`` clock ticks (plus once at drain), so the
    worker absorbs fleet commits mid-stream. Returns the finished request
    objects."""
    arrivals = replay_arrivals(len(prompts), pattern, rate, seed)
    tracked = []
    i = 0
    step = 0
    while i < len(prompts) or engine.outstanding():
        while i < len(prompts) and arrivals[i] <= step:
            try:
                engine.submit(
                    prompts[i], max_new_tokens=max_new, temperature=temperature
                )
            except AdmissionError:
                break  # queue full: this and younger requests wait a tick
            tracked.append(engine._queue[-1])
            i += 1
        engine.step()
        step += 1
        if gossip is not None and gossip_every > 0 and step % gossip_every == 0:
            gossip.exchange()
    if gossip is not None:
        gossip.exchange()
    return [r for r in tracked if r.done]


def run_with_gossip(engine, gossip, every, max_steps: int = 10_000):
    """``EngineCore.run`` with a gossip exchange every ``every`` steps.

    Mirrors the drain loop exactly (queue + resident tracking, adaptive
    end-of-run flush, exhaustion accounting) and folds sibling journal
    shards in mid-run — the live-fleet path where a worker picks up what a
    sibling tuned moments ago without restarting. A final exchange runs
    after the drain so nothing a sibling committed during our last steps is
    left for the next process lifetime."""
    finished = []
    seen = {}
    steps = 0
    for _ in range(max_steps):
        for r in list(engine._queue):
            seen[r.uid] = r
        for r in engine.outstanding():
            seen[r.uid] = r
        if not engine.step():
            break
        steps += 1
        if every > 0 and steps % every == 0:
            gossip.exchange()
    if engine.adaptive is not None and engine.adapt_every > 0:
        engine.adaptive.drain()
    gossip.exchange()
    for r in seen.values():
        if r.done:
            finished.append(r)
    engine.unfinished = engine.outstanding()
    engine.exhausted = bool(engine.unfinished)
    return finished


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="100m", choices=["full", "reduced", "100m"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument(
        "--paged",
        action="store_true",
        help="serve through the paged-KV engine (page-pool memory, "
        "admission control, optional chunked prefill) instead of the "
        "dense slot engine",
    )
    ap.add_argument(
        "--page-size",
        type=int,
        default=16,
        help="KV rows per page (with --paged)",
    )
    ap.add_argument(
        "--max-pages",
        type=int,
        default=0,
        help="page-pool size; 0 sizes it to the dense engine's KV rows "
        "(slots * max-seq / page-size) for an equal-memory comparison",
    )
    ap.add_argument(
        "--prefill-chunk",
        type=int,
        default=0,
        help="prefill long prompts in chunks of this many tokens, one "
        "chunk per engine step (0: whole-prompt prefill; with --paged)",
    )
    ap.add_argument(
        "--replay",
        default="off",
        choices=["off", "poisson", "bursty"],
        help="schedule submissions on a synthetic arrival process instead "
        "of enqueueing everything up front, and log the per-request SLO "
        "summary",
    )
    ap.add_argument(
        "--replay-rate",
        type=float,
        default=1.0,
        help="mean arrivals per engine step for --replay",
    )
    ap.add_argument(
        "--quantize",
        default="none",
        choices=["none", "int8", "int8-dynamic", "int4"],
        help="one-shot weight quantization at load: projection weights "
        "become QuantizedTensors (per-output-channel symmetric scales, "
        "dequant fused into the GEMM kernels). 'int8' keeps float "
        "activations ('<act>*int8' fingerprints); 'int8-dynamic' also "
        "quantizes activations per row at dispatch, running the int8xint8 "
        "MXU path ('int8*int8'); 'int4' packs weights two nibbles per byte "
        "along K ('<act>*int4', B traffic 0.5 bytes/element)",
    )
    ap.add_argument(
        "--adapt",
        action="store_true",
        help="enable online miss-driven autotuning in the decode loop",
    )
    ap.add_argument(
        "--adapt-every",
        type=int,
        default=4,
        help="decode steps between adaptation rounds (with --adapt)",
    )
    ap.add_argument(
        "--adapt-budget",
        type=float,
        default=None,
        help="wallclock seconds per adaptation round (default: uncapped)",
    )
    ap.add_argument(
        "--adapt-threshold",
        type=int,
        default=1,
        help="trace-time misses before a fingerprint is tuned (selection "
        "runs at trace time, so jit-cached repeats don't re-count: a "
        "fingerprint that traces at all will serve many dispatches)",
    )
    ap.add_argument(
        "--grid-sweep",
        default=None,
        help="comma-separated grid sizes the selector/tuner sweep jointly "
        "with (policy, tile), e.g. '4,8,16' (default: {lanes/2, lanes, "
        "2*lanes} for the machine model)",
    )
    ap.add_argument(
        "--calibrate",
        action="store_true",
        help="fit a CalibratedMachine from the warm-start records before "
        "serving (robust least-squares per dtype profile over journaled "
        "wall clocks); unseen fingerprints then dispatch from the model's "
        "argmin ('model' source) and the fit is journaled for the next run",
    )
    ap.add_argument(
        "--top-k",
        type=int,
        default=None,
        help="budgeted adaptation sweeps: measure only the cost model's "
        "top-k ranked candidates per hot fingerprint instead of the "
        "exhaustive (policy x tile x grid) sweep",
    )
    ap.add_argument(
        "--mach-json",
        default=None,
        help="JSON file of Machine field overrides (e.g. "
        '\'{"peak_flops": 1.5e14, "lanes": 4}\') — the nominal machine '
        "scoring/tuning/calibration run against",
    )
    ap.add_argument(
        "--db",
        default=None,
        help="tuning database snapshot to warm-start the selector from",
    )
    ap.add_argument(
        "--journal",
        default=None,
        help="append-only tuning journal: replayed on start, appended to by "
        "--adapt commits (per-worker shards <journal>.shard<i> when "
        "--workers > 1)",
    )
    ap.add_argument(
        "--workers",
        type=int,
        default=1,
        help="simulate K serving processes with fully separate "
        "selector/tuner state, each journaling to its own shard",
    )
    ap.add_argument(
        "--merge-journals",
        action="store_true",
        help="federate every existing journal shard (<journal> + "
        "<journal>.shard*) into each worker's warm-start database",
    )
    ap.add_argument(
        "--mesh-model",
        type=int,
        default=0,
        help="install a (data, model=N) host-mesh sharding plan so dispatch "
        "fingerprints key on per-shard local MNK (0: no plan)",
    )
    ap.add_argument(
        "--gossip-every",
        type=int,
        default=0,
        help="poll sibling workers' journal shards every N engine steps and "
        "fold fresh commits into the live selector (streaming federation; "
        "0: off; requires --journal)",
    )
    ap.add_argument(
        "--arch-class",
        default="off",
        choices=["off", "auto"],
        help="stamp tuning records with an architecture class: 'auto' "
        "derives an ArchProfile from the (possibly overridden) machine and "
        "live backend, so records only federate as direct hits within the "
        "same device class ('off': the legacy single-class 'default')",
    )
    args = ap.parse_args()
    if args.workers < 1:
        raise SystemExit(f"--workers must be >= 1, got {args.workers}")
    if args.merge_journals and not args.journal:
        raise SystemExit("--merge-journals requires --journal")
    if args.gossip_every < 0:
        raise SystemExit(f"--gossip-every must be >= 0, got {args.gossip_every}")
    if args.gossip_every and not args.journal:
        raise SystemExit("--gossip-every requires --journal")

    cfg = preset_config(args.arch, args.preset)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family == "encdec":
        raise SystemExit("serve CLI drives decoder-only archs; see examples/ for enc-dec")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(args.seed))
    if args.quantize != "none":
        # every decoder-only arch serves through LM, which owns the
        # quantization entry point (enc-dec was rejected above)
        bits = 4 if args.quantize == "int4" else 8
        act_bits = 8 if args.quantize == "int8-dynamic" else None
        params, n_quant, n_skipped = model.quantize_weights(
            params, bits=bits, act_bits=act_bits
        )
        log.info(
            "quantized %d weight leaves to int%d (per-output-channel "
            "scales%s); %d float leaves skipped",
            n_quant,
            bits,
            ", dynamic int8 activations" if act_bits else "",
            n_skipped,
        )

    grid_sizes = None
    if args.grid_sweep:
        try:
            grid_sizes = tuple(
                sorted({int(x) for x in args.grid_sweep.split(",") if x.strip()})
            )
        except ValueError:
            raise SystemExit(f"bad --grid-sweep {args.grid_sweep!r}") from None
        if not grid_sizes or min(grid_sizes) < 1:
            raise SystemExit(f"bad --grid-sweep {args.grid_sweep!r}")

    mach = costmodel.V5E
    if args.mach_json:
        try:
            with open(args.mach_json) as f:
                mach = machine_from_json(json.load(f))
        except (OSError, ValueError, TypeError) as e:
            raise SystemExit(f"bad --mach-json {args.mach_json!r}: {e}") from None
        log.info(
            "machine overrides: peak=%.1f TF/s bw=%.0f GB/s lanes=%d",
            mach.peak_flops / 1e12,
            mach.hbm_bw / 1e9,
            mach.lanes,
        )
    arch_profile = None
    arch_cls = DEFAULT_ARCH
    if args.arch_class == "auto":
        arch_profile = detect_arch(mach)
        arch_cls = arch_profile.cls
        log.info("arch class: %s", arch_cls)
    use_artifacts = bool(args.db or args.journal or args.adapt or args.calibrate)

    def warm_db(w: int) -> TuningDatabase:
        """Worker ``w``'s warm-start database — each simulated process
        loads its own copy, exactly as K real processes would: the snapshot,
        then (without --merge-journals) the base journal plus the worker's
        OWN shard from the previous fleet run, or (with --merge-journals)
        the federation of every shard the whole fleet ever wrote."""
        if args.db and os.path.exists(args.db):
            db = TuningDatabase.load(args.db, arch=arch_cls)
        else:
            db = TuningDatabase(arch=arch_cls)
        if args.journal:
            if args.merge_journals:
                shards = existing_journal_shards(args.journal)
                if shards:
                    # last-writer-wins among the peer shards, then applied
                    # ON TOP of the snapshot (journals post-date it; their
                    # producer clocks are not comparable to the snapshot's)
                    merged, rep = merge_journal_shards(
                        shards,
                        into=TuningDatabase(arch=arch_cls),
                        missing_ok=True,
                    )
                    apply_journal_db(db, merged)
                    log.info(
                        "federated warm start: %d shards -> %d records "
                        "(%d conflicts, %d superseded, %d load errors)",
                        rep.sources,
                        len(db.records),
                        rep.conflicts,
                        rep.superseded,
                        rep.load_errors,
                    )
            else:
                db.replay_journal(args.journal, missing_ok=True)
                own = shard_journal_path(args.journal, w, args.workers)
                if own != args.journal:
                    # a repeat fleet run must not silently cold-start: each
                    # worker at least replays what IT learned last time
                    db.replay_journal(own, missing_ok=True)
                    siblings = [
                        p
                        for p in existing_journal_shards(args.journal)
                        if p not in (args.journal, own)
                    ]
                    if siblings:
                        log.info(
                            "worker %d: %d sibling journal shards exist but "
                            "--merge-journals is off; pass it to warm-start "
                            "from the whole fleet",
                            w,
                            len(siblings),
                        )
        return db

    def build_worker(w: int):
        if use_artifacts:
            db = warm_db(w)
            # a calibration replayed from the journal/snapshot warm-starts
            # model-first dispatch even without --calibrate
            calibration = db.calibration
            if args.calibrate:
                try:
                    db.set_calibration(calibrate_db(db, base=mach))
                except CalibrationError as e:
                    log.warning("worker %d: calibration skipped: %s", w, e)
                else:
                    calibration = db.calibration
                    if args.journal:
                        append_calibration(
                            shard_journal_path(args.journal, w, args.workers),
                            calibration,
                        )
            sieve = db.build_sieve() if db.n_records() else None
            selector = KernelSelector(
                state=SelectorState(
                    db=db, sieve=sieve, calibration=calibration, arch=arch_cls
                ),
                mach=mach,
                grid_sizes=grid_sizes,
            )
            log.info(
                "worker %d warm-start: %d tuned records + %d cross-arch "
                "(%d dropped at load), calibration %s, arch %s",
                w,
                len(db.records),
                db.n_records() - len(db.records),
                db.load_errors,
                "installed" if calibration is not None else "absent",
                arch_cls,
            )
        else:
            selector = KernelSelector(
                mach=mach,
                grid_sizes=grid_sizes,
                state=SelectorState(arch=arch_cls),
            )
        if arch_profile is not None and args.journal:
            # declare this producer's coordinates in its shard, so every
            # consumer of the journal knows the machine behind the class
            append_arch(
                shard_journal_path(args.journal, w, args.workers), arch_profile
            )
        adaptive = None
        if args.adapt:
            adaptive = AdaptiveTuner(
                selector,
                config=AdaptiveConfig(
                    budget_s=args.adapt_budget,
                    hot_threshold=args.adapt_threshold,
                    top_k=args.top_k,
                ),
                journal=shard_journal_path(args.journal, w, args.workers)
                if args.journal
                else None,
            )
        return selector, adaptive

    plan = None
    if args.mesh_model:
        mesh = make_host_mesh(model=args.mesh_model)
        plan = ShardingPlan(mesh)
        log.info(
            "mesh plan installed: %s -> gemm divisors %s",
            dict(mesh.shape),
            plan.gemm_div(),
        )

    # deterministic request stream, dealt round-robin across the workers
    rng = np.random.default_rng(args.seed)
    # prompt lengths must respect the engine's cache bound: submit()
    # rejects len > max_seq
    p_hi = min(64, args.max_seq + 1)
    p_lo = min(8, p_hi - 1)
    prompts = [
        rng.integers(1, cfg.vocab_size, size=int(rng.integers(p_lo, p_hi)))
        for _ in range(args.requests)
    ]

    done = []
    engines = []
    # build every worker's state BEFORE any engine serves: a real fleet's
    # processes all start from the pre-run artifacts, so worker 1 must not
    # warm-start from what worker 0 journaled moments ago in this same run
    worker_state = [build_worker(w) for w in range(args.workers)]
    t0 = time.time()
    with use_plan(plan):
        for w in range(args.workers):
            selector, adaptive = worker_state[w]
            gossip = None
            if args.gossip_every and args.workers > 1:
                # each worker tails every OTHER worker's shard: its own
                # commits are already in its database
                peers = [
                    shard_journal_path(args.journal, x, args.workers)
                    for x in range(args.workers)
                    if x != w
                ]
                gossip = GossipExchange(selector, peers)
            with gemm_context(selector=selector) as ctx:
                if args.paged:
                    max_pages = args.max_pages or (
                        args.slots * args.max_seq // args.page_size
                    )
                    engine = PagedServeEngine(
                        model,
                        params,
                        PagedServeConfig(
                            page_size=args.page_size,
                            max_pages=max_pages,
                            max_active=args.slots,
                            max_seq=args.max_seq,
                            prefill_chunk=args.prefill_chunk,
                            eos=-1,
                            seed=args.seed,
                        ),
                        adaptive=adaptive,
                        adapt_every=args.adapt_every if args.adapt else 0,
                    )
                else:
                    engine = ServeEngine(
                        model,
                        params,
                        ServeConfig(
                            n_slots=args.slots, max_seq=args.max_seq, eos=-1
                        ),
                        adaptive=adaptive,
                        adapt_every=args.adapt_every if args.adapt else 0,
                    )
                wprompts = prompts[w :: args.workers]
                if args.replay != "off":
                    done.extend(
                        replay_stream(
                            engine,
                            wprompts,
                            pattern=args.replay,
                            rate=args.replay_rate,
                            seed=args.seed + w,
                            max_new=args.max_new_tokens,
                            temperature=args.temperature,
                            gossip=gossip,
                            gossip_every=args.gossip_every,
                        )
                    )
                    if adaptive is not None:
                        # replay drives step() directly; flush what run()
                        # would have committed at end of drain
                        adaptive.drain()
                else:
                    for prompt in wprompts:
                        engine.submit(
                            prompt,
                            max_new_tokens=args.max_new_tokens,
                            temperature=args.temperature,
                        )
                    if gossip is not None:
                        done.extend(
                            run_with_gossip(engine, gossip, args.gossip_every)
                        )
                    else:
                        done.extend(engine.run())
                if gossip is not None:
                    log.info(
                        "worker %d gossip: %d rounds, %d sibling entries "
                        "absorbed over %d hot-swaps (%d load errors)",
                        w,
                        gossip.stats.rounds,
                        gossip.stats.entries,
                        gossip.stats.swaps,
                        gossip.stats.load_errors,
                    )
                engines.append((w, engine, adaptive, ctx))
    dt = time.time() - t0
    ntok = sum(len(r.out_tokens) for r in done)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s) across %d worker(s)",
        len(done),
        ntok,
        dt,
        ntok / max(dt, 1e-9),
        args.workers,
    )
    if args.paged:
        for w, engine, _, _ in engines:
            m = engine.metrics()
            log.info(
                "worker %d paged pool: peak %d/%d pages, peak %d resident, "
                "%d admitted / %d rejected / %d truncated, %d stall events",
                w,
                m["peak_used_pages"],
                m["n_pages"],
                m["peak_resident"],
                m["admitted"],
                m["rejected"],
                m["truncated"],
                m["stall_events"],
            )
        if args.replay != "off" and done:
            lat = sorted(r.done_step - r.submit_step for r in done)
            ttft = sorted(r.first_token_step - r.submit_step for r in done)
            pct = lambda a, q: a[min(len(a) - 1, int(q / 100 * len(a)))]  # noqa: E731
            log.info(
                "SLO (steps): latency p50=%d p99=%d, ttft p50=%d p99=%d "
                "over %d completed requests",
                pct(lat, 50),
                pct(lat, 99),
                pct(ttft, 50),
                pct(ttft, 99),
                len(done),
            )
    for w, engine, adaptive, _ in engines:
        if adaptive is not None:
            st = engine.dispatch_stats
            log.info(
                "worker %d adaptation: %d misses (%d model-warm, %d "
                "xarch-seeded) -> %d records committed (sieve generation "
                "%d, %d pending, db=%d records)",
                w,
                st.misses,
                st.model_warm,
                st.xarch_seeds,
                st.adaptations,
                st.sieve_generation,
                st.pending_hot,
                st.db_records,
            )
    if args.workers > 1 and args.journal:
        # federation summary: what the fleet collectively learned this run
        shard_paths = [
            shard_journal_path(args.journal, w, args.workers)
            for w in range(args.workers)
        ]
        merged, rep = merge_journal_shards(
            shard_paths, into=TuningDatabase(arch=arch_cls), missing_ok=True
        )
        log.info(
            "fleet journals federate to %d records (%d shards, %d conflicts); "
            "re-run with --merge-journals to warm-start every worker from them",
            merged.n_records(),
            rep.sources,
            rep.conflicts,
        )
    # show the Stream-K++ dispatch decisions the decode GEMMs triggered
    # (each engine mirrors its traces' selections whether it served under
    # the ambient context or its own selector-scoped one)
    seen = {}
    for _, engine, _, ctx in engines:
        for e in engine.selection_log or ctx.log:
            seen.setdefault((e.tag, e.local_mnk), e.selection)
    log.info("distinct GEMM dispatches: %d", len(seen))
    for (tag, mnk), sel in sorted(seen.items())[:20]:
        log.info(
            "  %-12s M,N,K=%s -> %s/%s g=%d (%s)",
            tag, mnk, sel.policy.name, sel.cfg.name, sel.g, sel.source,
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
