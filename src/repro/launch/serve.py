"""Serving launcher CLI: batched decode with continuous batching.

Example:
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --preset 100m \
      --requests 16 --max-new-tokens 32

Online adaptation (miss-driven autotuning in the decode loop):
  PYTHONPATH=src python -m repro.launch.serve --arch granite-8b --requests 16 \
      --adapt --adapt-every 4 --adapt-budget 0.05 \
      --db artifacts/tuning_db.json --journal artifacts/tuning_journal.jsonl

``--db`` warm-starts the selector from an offline snapshot; ``--journal`` is
replayed on top at startup and appended to as serving traffic teaches the
tuner new fingerprints, so the next run starts where this one left off.
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import numpy as np

from repro.configs import list_archs
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.gemm import gemm_context
from repro.core.selector import KernelSelector
from repro.core.tuner import TuningDatabase
from repro.dist.sharding import materialize_tree
from repro.launch.train import preset_config
from repro.models import build_model
from repro.serve import ServeConfig, ServeEngine
from repro.utils.logging import get_logger

log = get_logger("launch.serve")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_archs())
    ap.add_argument("--preset", default="100m", choices=["full", "reduced", "100m"])
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--dtype", default="float32")
    ap.add_argument(
        "--adapt",
        action="store_true",
        help="enable online miss-driven autotuning in the decode loop",
    )
    ap.add_argument(
        "--adapt-every",
        type=int,
        default=4,
        help="decode steps between adaptation rounds (with --adapt)",
    )
    ap.add_argument(
        "--adapt-budget",
        type=float,
        default=None,
        help="wallclock seconds per adaptation round (default: uncapped)",
    )
    ap.add_argument(
        "--adapt-threshold",
        type=int,
        default=1,
        help="trace-time misses before a fingerprint is tuned (selection "
        "runs at trace time, so jit-cached repeats don't re-count: a "
        "fingerprint that traces at all will serve many dispatches)",
    )
    ap.add_argument(
        "--grid-sweep",
        default=None,
        help="comma-separated grid sizes the selector/tuner sweep jointly "
        "with (policy, tile), e.g. '4,8,16' (default: {lanes/2, lanes, "
        "2*lanes} for the machine model)",
    )
    ap.add_argument(
        "--db",
        default=None,
        help="tuning database snapshot to warm-start the selector from",
    )
    ap.add_argument(
        "--journal",
        default=None,
        help="append-only tuning journal: replayed on start, appended to by "
        "--adapt commits",
    )
    args = ap.parse_args()

    cfg = preset_config(args.arch, args.preset)
    if args.dtype:
        cfg = dataclasses.replace(cfg, dtype=args.dtype)
    if cfg.family == "encdec":
        raise SystemExit("serve CLI drives decoder-only archs; see examples/ for enc-dec")
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(args.seed))

    grid_sizes = None
    if args.grid_sweep:
        try:
            grid_sizes = tuple(
                sorted({int(x) for x in args.grid_sweep.split(",") if x.strip()})
            )
        except ValueError:
            raise SystemExit(f"bad --grid-sweep {args.grid_sweep!r}") from None
        if not grid_sizes or min(grid_sizes) < 1:
            raise SystemExit(f"bad --grid-sweep {args.grid_sweep!r}")
    if args.db or args.journal or args.adapt:
        if args.db and os.path.exists(args.db):
            db = TuningDatabase.load(args.db, journal=args.journal)
        else:
            db = TuningDatabase()
            if args.journal:
                db.replay_journal(args.journal, missing_ok=True)
        sieve = db.build_sieve() if db.records else None
        selector = KernelSelector(sieve=sieve, db=db, grid_sizes=grid_sizes)
        log.info(
            "selector warm-start: %d tuned records (%d dropped at load)",
            len(db.records),
            db.load_errors,
        )
    else:
        selector = KernelSelector(grid_sizes=grid_sizes)
    adaptive = None
    if args.adapt:
        adaptive = AdaptiveTuner(
            selector,
            config=AdaptiveConfig(
                budget_s=args.adapt_budget,
                hot_threshold=args.adapt_threshold,
            ),
            journal=args.journal,
        )
    with gemm_context(selector=selector) as ctx:
        engine = ServeEngine(
            model,
            params,
            ServeConfig(n_slots=args.slots, max_seq=args.max_seq, eos=-1),
            adaptive=adaptive,
            adapt_every=args.adapt_every if args.adapt else 0,
        )
        rng = np.random.default_rng(args.seed)
        # prompt lengths must respect the engine's cache bound: submit()
        # rejects len > max_seq
        p_hi = min(64, args.max_seq + 1)
        p_lo = min(8, p_hi - 1)
        for _ in range(args.requests):
            engine.submit(
                rng.integers(1, cfg.vocab_size, size=int(rng.integers(p_lo, p_hi))),
                max_new_tokens=args.max_new_tokens,
                temperature=args.temperature,
            )
        t0 = time.time()
        done = engine.run()
        dt = time.time() - t0
    ntok = sum(len(r.out_tokens) for r in done)
    log.info(
        "served %d requests, %d tokens in %.2fs (%.1f tok/s)",
        len(done),
        ntok,
        dt,
        ntok / max(dt, 1e-9),
    )
    if adaptive is not None:
        st = engine.dispatch_stats
        log.info(
            "online adaptation: %d misses -> %d records committed "
            "(sieve generation %d, %d pending, db=%d records)",
            st.misses,
            st.adaptations,
            st.sieve_generation,
            st.pending_hot,
            st.db_records,
        )
    # show the Stream-K++ dispatch decisions the decode GEMMs triggered
    # (the engine mirrors its traces' selections whether it served under
    # the ambient context or its own selector-scoped one)
    seen = {}
    for e in engine.selection_log or ctx.log:
        seen.setdefault((e.tag, e.local_mnk), e.selection)
    log.info("distinct GEMM dispatches: %d", len(seen))
    for (tag, mnk), sel in sorted(seen.items())[:20]:
        log.info(
            "  %-12s M,N,K=%s -> %s/%s g=%d (%s)",
            tag, mnk, sel.policy.name, sel.cfg.name, sel.g, sel.source,
        )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
