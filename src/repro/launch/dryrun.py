import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent without
hardware.

For every (architecture x input-shape x mesh) cell this lowers + compiles
the real step function (train_step for train shapes, prefill/serve steps for
inference shapes) against ShapeDtypeStruct stand-ins — no allocation — and
records:

  * ``memory_analysis``  (per-device bytes: does it fit a 16 GiB v5e?),
  * ``cost_analysis``    (HLO FLOPs + bytes for the roofline),
  * collective-traffic accounting parsed from the per-device HLO,
  * the Stream-K++ dispatch log (which policy every GEMM selected).

Artifacts land in ``artifacts/dryrun/<arch>__<shape>__<mesh>[__variant].json``
and are consumed by ``benchmarks/roofline.py`` and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch granite-8b --shape train_4k
  python -m repro.launch.dryrun --arch granite-8b --shape decode_32k --multi-pod
  python -m repro.launch.dryrun --all            # every cell, both meshes
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from typing import Any, Dict, Optional

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


def rules_for_cell(cfg, shape, mesh) -> Dict[str, Any]:
    """Cell-specific sharding-rule overrides (decode caches are the
    interesting case: shard kv-heads over 'model' when divisible, else the
    kv sequence dim; long_500k's batch=1 lets kv_seq absorb the batch axes)."""
    rules: Dict[str, Any] = {}
    model_n = mesh.shape["model"]
    if shape.kind == "train":
        # Megatron-style sequence parallelism for the residual stream: the
        # per-layer remat saves shard over 'model', cutting the dominant
        # activation-memory term by the TP degree.
        rules["seq"] = "model"
    if shape.kind == "decode":
        if cfg.n_kv_heads and cfg.n_kv_heads % model_n == 0:
            rules["kv_heads"] = "model"
            rules["kv_seq"] = ("pod", "data")
        else:
            rules["kv_heads"] = None
            rules["kv_seq"] = ("pod", "data", "model")
    return rules


def _input_axes(cfg, shape) -> Dict[str, tuple]:
    if shape.kind == "train":
        axes = {
            "tokens": ("batch", None),
            "labels": ("batch", None),
            "loss_mask": ("batch", None),
        }
    elif shape.kind == "prefill":
        axes = {"tokens": ("batch", None)}
    else:
        axes = {"tokens": ("batch", None), "cur_pos": ("batch",)}
    if cfg.family == "vlm" and shape.kind != "decode":
        axes["patch_embeds"] = ("batch", None, None)
    if cfg.family == "encdec" and shape.kind != "decode":
        axes["frames"] = ("batch", "frames", None)
    return axes


def _applied_divisor(plan, aspec, dim_index=0) -> int:
    spec = plan.spec_for(aspec)
    part = spec[dim_index] if dim_index < len(spec) else None
    if part is None:
        return 1
    axes = (part,) if isinstance(part, str) else part
    d = 1
    for a in axes:
        d *= plan.mesh.shape[a]
    return d


def _bf16_shadow_bytes(hlo_text: str, min_bytes: int = 1 << 26) -> int:
    """Bytes of f32 buffers that are dtype-promoted copies of bf16 buffers
    (same dims, both present) — the XLA:CPU bf16-emulation artifact."""
    import re as _re
    import math as _math

    f32 = set()
    bf16 = set()
    for m in _re.finditer(r"\b(f32|bf16)\[([0-9,]+)\]", hlo_text):
        dims = tuple(int(x) for x in m.group(2).split(","))
        (f32 if m.group(1) == "f32" else bf16).add(dims)
    total = 0
    for dims in f32 & bf16:
        sz = 4 * _math.prod(dims)
        if sz >= min_bytes:
            total += sz
    return total


def lower_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool,
    variant: str = "baseline",
    extra_rules: Optional[Dict[str, Any]] = None,
    mesh_shape: Optional[tuple] = None,
    microbatches: int = 1,
    config_overrides: Optional[Dict[str, Any]] = None,
    optimizer_name: str = "adamw",
) -> Dict[str, Any]:
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_config
    from repro.core.gemm import gemm_context
    from repro.core.selector import default_selector
    from repro.data.pipeline import input_specs
    from repro.dist.hlo import parse_collectives
    from repro.dist.hlo_cost import analyze as hlo_analyze
    from repro.dist.sharding import ArraySpec, ShardingPlan, abstract_tree, use_plan
    from repro.launch.mesh import make_production_mesh
    from repro.models import SHAPES_BY_NAME, applicable_shapes, build_model
    from repro.optim import AdamW, constant, make_optimizer
    from repro.train import make_train_step, train_gemm_div

    import dataclasses

    cfg = get_config(arch)
    if config_overrides:
        cfg = dataclasses.replace(cfg, **config_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    if shape not in applicable_shapes(cfg):
        return {
            "arch": arch,
            "shape": shape_name,
            "mesh": "multi_pod" if multi_pod else "single_pod",
            "variant": variant,
            "status": "skipped",
            "reason": "shape not applicable (see DESIGN.md §Arch-applicability)",
        }

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod, shape=mesh_shape)
    rules = rules_for_cell(cfg, shape, mesh)
    if extra_rules:
        rules.update(extra_rules)
    plan = ShardingPlan(mesh, rules)
    model = build_model(cfg)

    specs = model.param_specs()
    params_abs = abstract_tree(specs)
    param_sh = plan.tree_shardings(specs)
    repl = NamedSharding(mesh, P())

    # gemm dispatch divisors: what one shard's MXU sees
    ins = input_specs(cfg, shape)
    in_axes = _input_axes(cfg, shape)
    tok_spec = ArraySpec(
        tuple(ins["tokens"].shape), "int32", in_axes["tokens"]
    )
    # mesh-level table probed per array (demoted_dims) like serve_gemm_div,
    # so train fingerprints never claim splits the arrays don't execute;
    # the batch entry uses the tokens spec directly — finer than the
    # count-divisibility heuristic, same ROADMAP item 6 fix
    div = dict(train_gemm_div(model, plan=plan))
    div["batch"] = _applied_divisor(plan, tok_spec, 0)
    div.setdefault("model", mesh.shape["model"])

    input_sh = {
        k: NamedSharding(
            mesh,
            plan.spec_for(ArraySpec(tuple(v.shape), str(v.dtype), in_axes[k])),
        )
        for k, v in ins.items()
    }

    selector = default_selector()
    with gemm_context(selector=selector) as ctx, use_plan(plan):
        if shape.kind == "train":
            optimizer = make_optimizer(optimizer_name, constant(1e-4))
            step_fn = make_train_step(model, optimizer, div=div, microbatches=microbatches)
            opt_abs = jax.eval_shape(optimizer.init, params_abs)
            state_abs = {
                "params": params_abs,
                "opt": opt_abs,
                "step": jax.ShapeDtypeStruct((), jnp.int32),
            }
            # optimizer-state shardings: subtrees mirroring the param tree
            # (mu/nu/master/vel) inherit the param shardings; factored
            # moments (Adafactor) and counters are replicated (they are
            # O(m+n) — negligible)
            state_sh_opt = {}
            for key, sub in opt_abs.items():
                if jax.tree.structure(sub) == jax.tree.structure(params_abs):
                    state_sh_opt[key] = param_sh
                else:
                    state_sh_opt[key] = jax.tree.map(lambda _: repl, sub)
            state_sh = {
                "params": param_sh,
                "opt": state_sh_opt,
                "step": repl,
            }
            out_struct = jax.eval_shape(step_fn, state_abs, ins)
            out_sh = (
                state_sh,
                jax.tree.map(lambda _: repl, out_struct[1]),
            )
            lowered = jax.jit(
                step_fn,
                in_shardings=(state_sh, input_sh),
                out_shardings=out_sh,
                donate_argnums=(0,),
            ).lower(state_abs, ins)
        else:
            cache_specs = model.cache_specs(shape.global_batch, shape.seq_len)
            cache_sh = plan.tree_shardings(cache_specs)
            logits_sh = NamedSharding(
                mesh,
                plan.spec_for(
                    ArraySpec(
                        (shape.global_batch, 1, cfg.vocab_size),
                        "float32",
                        ("batch", None, "vocab"),
                    )
                ),
            )
            if shape.kind == "prefill":
                if cfg.family == "encdec":

                    def prefill_fn(params, inputs):
                        return model.prefill(
                            params,
                            inputs["frames"],
                            inputs["tokens"],
                            max_seq=shape.seq_len,
                            div=div,
                        )

                else:

                    def prefill_fn(params, inputs):
                        kw = {}
                        if "patch_embeds" in inputs:
                            kw["patch_embeds"] = inputs["patch_embeds"]
                        return model.prefill(
                            params,
                            inputs["tokens"],
                            max_seq=shape.seq_len,
                            div=div,
                            **kw,
                        )

                lowered = jax.jit(
                    prefill_fn,
                    in_shardings=(param_sh, input_sh),
                    out_shardings=(logits_sh, cache_sh),
                ).lower(params_abs, ins)
            else:  # decode
                cache_abs = abstract_tree(cache_specs)

                def decode_fn(params, cache, inputs):
                    return model.decode_step(
                        params, cache, inputs["tokens"], inputs["cur_pos"], div=div
                    )

                lowered = jax.jit(
                    decode_fn,
                    in_shardings=(param_sh, cache_sh, input_sh),
                    out_shardings=(logits_sh, cache_sh),
                    donate_argnums=(1,),
                ).lower(params_abs, cache_abs, ins)

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    shadow = _bf16_shadow_bytes(hlo)
    loop_cost = hlo_analyze(hlo)  # loop-aware: multiplies while bodies

    # dispatch log summary: unique local GEMMs and their selections
    dispatch = {}
    for e in ctx.log:
        key = f"{e.tag}:{e.local_mnk}"
        if key not in dispatch:
            dispatch[key] = {
                "local_mnk": list(e.local_mnk),
                "policy": e.selection.policy.name,
                "cfg": e.selection.cfg.name,
                "source": e.selection.source,
            }

    def _mem(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    artifact = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        "variant": variant,
        "status": "ok",
        "n_devices": mesh.devices.size,
        "mesh_shape": {k: int(v) for k, v in mesh.shape.items()},
        "timings_s": {"lower": round(t_lower, 1), "compile": round(t_compile, 1)},
        "memory": {
            "argument_size": _mem("argument_size_in_bytes"),
            "output_size": _mem("output_size_in_bytes"),
            "temp_size": _mem("temp_size_in_bytes"),
            # XLA:CPU emulates bf16 by materialising f32 copies of large
            # bf16 buffers; a TPU backend would not allocate these. We
            # report the raw number AND the shadow-adjusted estimate.
            "cpu_bf16_shadow_size": shadow,
            "temp_size_tpu_estimate": max(0, (_mem("temp_size_in_bytes") or 0) - shadow),
            "generated_code_size": _mem("generated_code_size_in_bytes"),
            "alias_size": _mem("alias_size_in_bytes"),
        },
        "cost": {k: float(v) for k, v in (cost or {}).items() if isinstance(v, (int, float))},
        # loop-aware re-analysis (XLA cost_analysis counts while bodies once)
        "loop_cost": {
            "flops": loop_cost.flops,
            "bytes": loop_cost.bytes,
            "collective_bytes": loop_cost.coll_bytes,
            "collective_counts": loop_cost.coll_counts,
        },
        "collectives": coll.summary(),
        "collective_bytes": coll.total_bytes,
        "hlo_bytes": len(hlo),
        "dispatch": dispatch,
        "params": {
            "total": cfg.param_count(),
            "active": cfg.active_param_count(),
        },
        "config": {
            "rules": {k: list(v) if isinstance(v, tuple) else v for k, v in rules.items()},
            "div": div,
            "mesh_shape_override": list(mesh_shape) if mesh_shape else None,
            "microbatches": microbatches,
            "overrides": config_overrides or {},
        },
    }
    return artifact


def run_one(args) -> int:
    art = lower_cell(
        args.arch, args.shape, args.multi_pod, args.variant,
        extra_rules=json.loads(args.rules) if args.rules else None,
        mesh_shape=tuple(int(x) for x in args.mesh_shape.split(",")) if args.mesh_shape else None,
        microbatches=args.microbatches,
        config_overrides=json.loads(args.overrides) if args.overrides else None,
        optimizer_name=args.optimizer,
    )
    os.makedirs(args.out_dir, exist_ok=True)
    name = f"{args.arch}__{args.shape}__{art['mesh']}"
    if args.variant != "baseline":
        name += f"__{args.variant}"
    path = os.path.join(args.out_dir, name + ".json")
    with open(path, "w") as f:
        json.dump(art, f, indent=1)
    if art["status"] == "ok":
        print(f"[dryrun] OK {name}: compile {art['timings_s']['compile']}s")
        mem = art["memory"]
        print(f"  memory_analysis: args={mem['argument_size']} temp={mem['temp_size']} out={mem['output_size']}")
        print(f"  cost_analysis: flops={art['cost'].get('flops')} collective_bytes={art['collective_bytes']:.3e}")
    else:
        print(f"[dryrun] SKIP {name}: {art.get('reason')}")
    return 0


def run_all(args) -> int:
    """Every (arch x shape x mesh) cell, each in a fresh subprocess (clean
    XLA state, bounded memory); resumable — completed artifacts are skipped."""
    from repro.configs import list_archs
    from repro.models import ALL_SHAPES

    failures = []
    cells = []
    for arch in list_archs():
        for shape in ALL_SHAPES:
            for mp in (False, True):
                cells.append((arch, shape.name, mp))
    print(f"[dryrun] {len(cells)} cells")
    for arch, shape, mp in cells:
        mesh_name = "multi_pod" if mp else "single_pod"
        name = f"{arch}__{shape}__{mesh_name}"
        path = os.path.join(args.out_dir, name + ".json")
        if os.path.exists(path) and not args.force:
            with open(path) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    print(f"[dryrun] cached {name}")
                    continue
        cmd = [
            sys.executable, "-m", "repro.launch.dryrun",
            "--arch", arch, "--shape", shape, "--out-dir", args.out_dir,
        ]
        if mp:
            cmd.append("--multi-pod")
        t0 = time.time()
        r = subprocess.run(cmd, capture_output=True, text=True, timeout=args.cell_timeout)
        dt = time.time() - t0
        if r.returncode != 0:
            failures.append(name)
            with open(path + ".err", "w") as f:
                f.write(r.stdout + "\n" + r.stderr)
            print(f"[dryrun] FAIL {name} ({dt:.0f}s) — see {path}.err")
        else:
            print(r.stdout.strip())
    print(f"[dryrun] done; {len(failures)} failures")
    if failures:
        print("failures:", failures)
    return 1 if failures else 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--rules", help="JSON sharding-rule overrides (perf iterations)")
    ap.add_argument("--mesh-shape", help="e.g. 32,8 (data,model) or 2,32,8")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--overrides", help="JSON ModelConfig field overrides")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--cell-timeout", type=int, default=3600)
    ap.add_argument("--out-dir", default=os.path.normpath(ARTIFACT_DIR))
    args = ap.parse_args()
    if args.all:
        return run_all(args)
    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    try:
        return run_one(args)
    except Exception:
        traceback.print_exc()
        return 1


if __name__ == "__main__":
    sys.exit(main())
