"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16). Multi-pod: 2 pods = 512 chips
as (pod=2, data=16, model=16) — the ``pod`` axis is an outer data-parallel
axis whose collectives cross DCN, so the sharding rules place only the
gradient all-reduce (and nothing latency-sensitive) on it.

Defined as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
``xla_force_host_platform_device_count`` before any jax initialisation.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, shape=None):
    """Default (16,16) / (2,16,16); ``shape`` overrides the (data, model)
    factorisation (e.g. (32, 8)) keeping the chip counts — a perf-iteration
    knob (TP degree trades activation-collective traffic for FSDP traffic)."""
    if shape is None:
        shape = (2, 16, 16) if multi_pod else (16, 16)
    else:
        shape = tuple(shape)
        if multi_pod and len(shape) == 2:
            shape = (2, *shape)
    n = 1
    for d in shape:
        n *= d
    assert n in (256, 512), f"production pod sizes are 256/512 chips, got {n}"
    axes = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Whatever this host offers (tests / examples): (data, model)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
