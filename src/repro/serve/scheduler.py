"""Async request scheduling over paged KV: admission control + chunked
prefill interleaved with decode.

The dense :class:`~repro.serve.engine.ServeEngine` couples three things the
paged engine decouples:

* **capacity** — KV memory is a page pool (``paged_kv``), so how many
  sequences are *resident* is bounded by the sum of their actual lengths,
  not ``n_slots * max_seq``;
* **admission** — ``submit`` is an asynchronous enqueue with queue-depth
  backpressure (:class:`AdmissionError` when the queue is full — callers
  retry later), and the scheduler admits *oldest-first* under a page-budget
  watermark: a request enters only when its whole prompt fits AND a
  configurable reserve stays free for the decode growth of sequences
  already resident. Nothing is ever evicted to make room — admission is the
  only throttle;
* **prefill** — long prompts prefill in chunks of ``prefill_chunk`` tokens,
  at most one chunk per engine step, so a 10k-token prompt contributes one
  bounded unit of work between decode batches instead of head-of-line
  blocking every resident decode for its full prefill latency.

Decode runs at a fixed batch width (``max_active``) over a gathered,
position-contiguous page view (see ``paged_kv``), so the decode GEMM
fingerprints — and therefore tuned dispatch, the adaptive tuner, and the
journal/sieve hot-swap machinery threaded through ``EngineCore`` — are
identical to the dense engine's. Page exhaustion mid-decode *stalls* the
affected sequence (it simply skips steps until a page frees); if every
resident sequence is stalled and no other progress is possible, the oldest
is retired early with ``truncated=True`` rather than deadlocking the loop.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.adaptive import AdaptiveTuner
from repro.core.selector import KernelSelector
from repro.serve.engine import EngineCore, Request
from repro.serve.paged_kv import PagedKVCache, PageTable
from repro.utils.logging import get_logger

log = get_logger("serve.paged")


class AdmissionError(RuntimeError):
    """Queue-depth backpressure: the request queue is full; retry later."""


@dataclass
class PagedServeConfig:
    page_size: int = 16
    max_pages: int = 64
    max_active: int = 8  # decode batch width (fixed; padded with scratch rows)
    max_seq: int = 512  # per-sequence logical cap (prompt + decoded tokens)
    max_queue: int = 0  # queued-request cap; 0 = unbounded (no backpressure)
    watermark: float = 0.1  # fraction of the pool reserved at admission time
    prefill_chunk: int = 0  # tokens per prefill tick; 0 = whole-prompt prefill
    eos: int = 0
    seed: int = 0

    @property
    def reserve_pages(self) -> int:
        return math.ceil(self.watermark * self.max_pages)


@dataclass
class PagedRequest(Request):
    """Request + paged lifecycle state + SLO timestamps."""

    table: PageTable = field(default_factory=PageTable)
    prefilled: int = 0  # prompt tokens already prefilled
    pos: int = 0  # next KV write position (== prompt + decoded so far)
    stalled: bool = False  # waiting on a free page to keep decoding
    submit_step: int = -1
    first_token_step: int = -1
    done_step: int = -1
    submit_wall: float = 0.0
    first_token_wall: float = 0.0
    done_wall: float = 0.0


class PagedServeEngine(EngineCore):
    """Continuous batching over a paged KV pool with admission control."""

    def __init__(
        self,
        model,
        params,
        cfg: PagedServeConfig,
        *,
        div=None,
        selector: Optional[KernelSelector] = None,
        backend: Optional[str] = None,
        adaptive: Optional[AdaptiveTuner] = None,
        adapt_every: int = 0,
    ):
        super().__init__(
            model,
            params,
            max_seq=cfg.max_seq,
            seed=cfg.seed,
            div=div,
            batch_hint=cfg.max_active,
            selector=selector,
            backend=backend,
            adaptive=adaptive,
            adapt_every=adapt_every,
        )
        if cfg.max_active < 1:
            raise ValueError(f"max_active must be >= 1, got {cfg.max_active}")
        self.cfg = cfg
        self.kv = PagedKVCache(
            model, page_size=cfg.page_size, n_pages=cfg.max_pages
        )
        self.active: List[PagedRequest] = []  # admission order
        # admission/SLO counters
        self.admitted = 0
        self.rejected = 0  # queue-depth backpressure refusals
        self.truncated = 0  # anti-deadlock early retirements
        self.stall_events = 0  # decode ticks skipped for want of a page
        self.peak_resident = 0
        self._decode = jax.jit(self._decode_impl, donate_argnums=(1,))
        self._chunk_step = jax.jit(self._chunk_impl, donate_argnums=(1,))

    # -- jitted paged steps ------------------------------------------------
    def _decode_impl(self, params, pool, pages_2d, tokens, pos):
        """gather view -> unchanged model.decode_step -> scatter the one new
        row per sequence back into its page."""
        view = self.kv.gather_view(pool, pages_2d)
        logits, new_view = self.model.decode_step(
            params, view, tokens, pos, div=self.div
        )
        rows = self.kv.rows_at(new_view, pos)
        b = pos.shape[0]
        pg = pages_2d[jnp.arange(b), pos // self.kv.page_size]
        pool = self.kv.scatter_rows(pool, pg, pos % self.kv.page_size, rows)
        return logits, pool

    def _chunk_impl(self, params, pool, pages_2d, chunk, start):
        """One prompt chunk for one sequence (B == 1): gather its pages,
        run model.prefill_chunk, scatter the chunk's rows back."""
        view = self.kv.gather_view(pool, pages_2d)
        logits, new_view = self.model.prefill_chunk(
            params, view, chunk, start, div=self.div
        )
        c = chunk.shape[1]
        pos_block = start[0] + jnp.arange(c)  # (C,)
        rows = jax.tree.map(lambda a: a[:, 0, pos_block], new_view)
        pg = pages_2d[0, pos_block // self.kv.page_size]
        pool = self.kv.scatter_rows(
            pool, pg, pos_block % self.kv.page_size, rows
        )
        return logits, pool

    # -- submission --------------------------------------------------------
    def submit(
        self, prompt, max_new_tokens: int = 32, temperature: float = 0.0
    ) -> int:
        """Asynchronous enqueue. Raises :class:`AdmissionError` when the
        queue is at ``max_queue`` (backpressure — the caller retries), and
        ``ValueError`` for prompts that could never be admitted (empty,
        over ``max_seq``, or needing more pages than the pool can ever
        spare past the watermark reserve)."""
        prompt = self._validate_prompt(prompt)
        need = self.kv.pages_for(len(prompt))
        budget = self.cfg.max_pages - self.cfg.reserve_pages
        if need > budget:
            raise ValueError(
                f"prompt needs {need} pages; admissible budget is {budget} "
                f"({self.cfg.max_pages} pages minus {self.cfg.reserve_pages} "
                "watermark reserve)"
            )
        if self.cfg.max_queue and len(self._queue) >= self.cfg.max_queue:
            self.rejected += 1
            raise AdmissionError(
                f"queue full ({len(self._queue)}/{self.cfg.max_queue}); "
                "retry after the engine drains"
            )
        self._uid += 1
        req = PagedRequest(self._uid, prompt, max_new_tokens, temperature)
        req.submit_step = self._steps
        req.submit_wall = time.monotonic()
        self._queue.append(req)
        return self._uid

    def outstanding(self) -> List[Request]:
        return list(self._queue) + [r for r in self.active if not r.done]

    # -- admission ---------------------------------------------------------
    def _admit(self) -> int:
        """Oldest-first admission under the page watermark: the queue head
        enters only when its whole prompt's pages fit with the reserve left
        over. No skipping ahead (a younger short request must not starve an
        older long one) and no eviction."""
        n = 0
        while self._queue and len(self.active) < self.cfg.max_active:
            head = self._queue[0]
            need = self.kv.pages_for(len(head.prompt))
            if self.kv.free_pages - need < self.cfg.reserve_pages:
                break
            self._queue.pop(0)
            head.table = PageTable(self.kv.alloc(need), 0)
            self.active.append(head)
            self.admitted += 1
            n += 1
        self.peak_resident = max(self.peak_resident, len(self.active))
        return n

    # -- prefill -----------------------------------------------------------
    def _pending_prefill(self) -> Optional[PagedRequest]:
        for r in self.active:
            if r.prefilled < len(r.prompt):
                return r
        return None

    def _prefill_tick(self) -> bool:
        """Advance the oldest prefilling request by one chunk (or its whole
        prompt when ``prefill_chunk`` is 0). Returns True if work ran."""
        req = self._pending_prefill()
        if req is None:
            return False
        remaining = len(req.prompt) - req.prefilled
        chunk = remaining
        if self.cfg.prefill_chunk > 0:
            chunk = min(self.cfg.prefill_chunk, remaining)
        start = req.prefilled
        tokens = jnp.asarray(req.prompt[start : start + chunk])[None, :]
        cap = req.table.capacity * self.kv.page_size
        with self._dispatch_ctx():
            if start == 0 and chunk == len(req.prompt):
                # whole-prompt fast path: the same model.prefill call (and
                # the same numerics) as the dense engine, scattered into
                # this sequence's pages instead of a slot stripe
                logits, fresh = self.model.prefill(
                    self.params, tokens, max_seq=cap, div=self.div
                )
                self.kv.pool = self.kv.scatter_prefill(
                    self.kv.pool, jnp.asarray(req.table.pages, jnp.int32), fresh
                )
            elif start == 0:
                # first chunk: no prefix to attend over; prefill at the
                # chunk length and scatter its pages' worth of rows
                logits, fresh = self.model.prefill(
                    self.params,
                    tokens,
                    max_seq=self.kv.pages_for(chunk) * self.kv.page_size,
                    div=self.div,
                )
                pages = req.table.pages[: self.kv.pages_for(chunk)]
                self.kv.pool = self.kv.scatter_prefill(
                    self.kv.pool, jnp.asarray(pages, jnp.int32), fresh
                )
            else:
                pages_2d = self.kv.padded_tables([req.table])
                logits, self.kv.pool = self._chunk_step(
                    self.params,
                    self.kv.pool,
                    pages_2d,
                    tokens,
                    jnp.asarray([start], jnp.int32),
                )
        req.prefilled += chunk
        req.table.length = req.prefilled
        if req.prefilled < len(req.prompt):
            return True
        # prompt complete: sample the first token (same contract as the
        # dense engine's _prefill_slot)
        req.pos = len(req.prompt)
        tok = self._sample(np.asarray(logits)[0, -1], req.temperature)
        req.out_tokens.append(int(tok))
        req.first_token_step = self._steps
        req.first_token_wall = time.monotonic()
        full = req.pos >= self.cfg.max_seq
        if (
            tok == self.cfg.eos
            or len(req.out_tokens) >= req.max_new_tokens
            or full
        ):
            self._retire(req)
        return True

    # -- decode ------------------------------------------------------------
    def _decode_candidates(self) -> List[PagedRequest]:
        return [
            r
            for r in self.active
            if not r.done and r.prefilled == len(r.prompt)
        ]

    def _ensure_page(self, req: PagedRequest) -> bool:
        """Guarantee ``req.pos`` has a page to write to; stall on exhaustion."""
        if req.pos < req.table.capacity * self.kv.page_size:
            req.stalled = False
            return True
        got = self.kv.try_alloc(1)
        if got is None:
            if not req.stalled:
                self.stall_events += 1
            req.stalled = True
            return False
        req.table.pages.extend(got)
        req.stalled = False
        return True

    def _decode_tick(self) -> bool:
        cand = self._decode_candidates()
        runnable = [r for r in cand if self._ensure_page(r)]
        if not runnable:
            return False
        b = self.cfg.max_active
        runnable = runnable[:b]
        tokens = np.zeros((b, 1), np.int32)
        pos = np.zeros((b,), np.int32)
        tables = []
        for i, r in enumerate(runnable):
            tokens[i, 0] = r.out_tokens[-1]
            pos[i] = r.pos
            tables.append(r.table)
        # pad the batch to the fixed decode width with scratch-page rows
        tables.extend(PageTable() for _ in range(b - len(runnable)))
        pages_2d = self.kv.padded_tables(tables)
        with self._dispatch_ctx():
            logits, self.kv.pool = self._decode(
                self.params,
                self.kv.pool,
                pages_2d,
                jnp.asarray(tokens),
                jnp.asarray(pos),
            )
        logits_np = np.asarray(logits)[:, 0]
        for i, req in enumerate(runnable):
            req.pos += 1
            req.table.length = req.pos
            tok = self._sample(logits_np[i], req.temperature)
            req.out_tokens.append(tok)
            if (
                tok == self.cfg.eos
                or len(req.out_tokens) >= req.max_new_tokens
                or req.pos >= self.cfg.max_seq
            ):
                self._retire(req)
        return True

    def _retire(self, req: PagedRequest, truncated: bool = False):
        req.done = True
        req.truncated = truncated
        req.done_step = self._steps
        req.done_wall = time.monotonic()
        if truncated and req.first_token_wall == 0.0:
            req.first_token_step = self._steps
            req.first_token_wall = req.done_wall
        self.kv.free(req.table.pages)
        req.table = PageTable()
        self.active.remove(req)

    # -- one scheduling quantum --------------------------------------------
    def step(self) -> bool:
        progress = 0
        if self.cfg.prefill_chunk > 0:
            # chunked mode: ONE bounded prefill quantum per step — long
            # prompts interleave with the decode batch below
            progress += self._admit()
            progress += int(self._prefill_tick())
        else:
            # whole-prompt mode: admit/prefill until the pool or the queue
            # is exhausted (retire-at-prefill frees pages mid-loop, exactly
            # like the dense engine's _admit slot reuse)
            while True:
                a = self._admit()
                w = int(self._prefill_tick())
                progress += a + w
                if not (a or w):
                    break
        decoded = int(self._decode_tick())
        progress += decoded
        if not progress:
            if self.active:
                # every resident sequence is stalled on page exhaustion and
                # nothing else can move: retire the oldest (truncated) so
                # its pages unblock the rest — never deadlock the loop
                victim = self.active[0]
                log.warning(
                    "page pool gridlock (%d resident, 0 free of %d pages): "
                    "truncating request %d at %d tokens",
                    len(self.active),
                    self.kv.n_pages,
                    victim.uid,
                    len(victim.out_tokens),
                )
                self.truncated += 1
                self._retire(victim, truncated=True)
                self._maybe_adapt()
                return True
            return False  # drained (submit() rejects never-admissible work)
        self._maybe_adapt()
        return True

    # -- observability -----------------------------------------------------
    def metrics(self) -> Dict[str, float]:
        occ = self.kv.occupancy()
        occ.update(
            admitted=self.admitted,
            rejected=self.rejected,
            truncated=self.truncated,
            stall_events=self.stall_events,
            peak_resident=self.peak_resident,
            resident=len(self.active),
            queued=len(self._queue),
            steps=self._steps,
        )
        return occ
