from repro.serve.engine import (
    DispatchStats,
    EngineCore,
    Request,
    ServeConfig,
    ServeEngine,
    serve_gemm_div,
)
from repro.serve.paged_kv import PagedKVCache, PageExhausted, PageTable
from repro.serve.scheduler import (
    AdmissionError,
    PagedRequest,
    PagedServeConfig,
    PagedServeEngine,
)

__all__ = [
    "AdmissionError",
    "DispatchStats",
    "EngineCore",
    "PagedKVCache",
    "PagedRequest",
    "PagedServeConfig",
    "PagedServeEngine",
    "PageExhausted",
    "PageTable",
    "Request",
    "ServeConfig",
    "ServeEngine",
    "serve_gemm_div",
]
