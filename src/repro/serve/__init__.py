from repro.serve.engine import DispatchStats, Request, ServeConfig, ServeEngine

__all__ = ["DispatchStats", "Request", "ServeConfig", "ServeEngine"]
