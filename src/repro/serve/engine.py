"""Batched serving engine with slot-based continuous batching.

The engine holds a fixed pool of ``n_slots`` sequences sharing one stacked
KV cache (the shape the decode_32k / long_500k dry-run cells lower). New
requests are admitted into free slots between decode steps — continuous
batching — so the decode GEMMs stay at a steady M = n_slots, exactly the
skinny-M regime where the paper's Stream-K++ policies matter most (the
dispatch log in ``repro.core.gemm`` records every selection the engine
triggers).

Decode is greedy or temperature sampling; finished sequences (EOS or length)
free their slot. Per-slot position counters make the shared cache correct
for requests of different lengths.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from contextlib import contextmanager

from repro.core.adaptive import AdaptiveTuner
from repro.core.gemm import current_log, current_selector, gemm_context
from repro.core.selector import KernelSelector, SelectorStats
from repro.dist.sharding import current_plan
from repro.utils.logging import get_logger

log = get_logger("serve")


def serve_gemm_div(model, batch: Optional[int] = None) -> Dict[str, int]:
    """Per-array-aware ambient GEMM divisor table for the serve path.

    ``ShardingPlan.gemm_div`` is mesh-level: it cannot see the per-array
    divisibility demotion ``spec_for`` applies (an odd vocab on a model=4
    mesh executes replicated while the mesh table still claims the split).
    The engine call site is where both halves are known — the installed
    plan AND the concrete model whose weights it will shard — so this probes
    every parameter spec through the plan's own solver
    (:meth:`ShardingPlan.demoted_dims`) and demotes the table's ``model``
    entry to 1 when any tensor-parallel weight dim would be demoted to
    replication. Likewise ``batch`` is demoted when the engine's decode
    width is not divisible by the data-parallel factor. The result: dispatch
    fingerprints never claim a local shape the arrays don't execute, in
    either regime — the resolution of ROADMAP item 6 for serving.
    """
    plan = current_plan()
    if plan is None:
        return {}
    div = dict(plan.gemm_div())
    tp = div.get("model", 1)
    if tp > 1:
        offenders = plan.demoted_dims(model.param_specs(), mesh_axis="model")
        if offenders:
            shown = ", ".join(
                f"dim {d} ({ax or '?'}) of {sh}" for sh, ax, _, d in offenders[:3]
            )
            log.warning(
                "serve fingerprints demote model divisor %d -> 1: %d weight "
                "dim(s) fail the plan's divisibility solver and execute "
                "replicated (e.g. %s); a mesh-level divisor would fingerprint "
                "local shapes the kernels never see",
                tp,
                len(offenders),
                shown,
            )
            div["model"] = 1
    db = div.get("batch", 1)
    if batch is not None and db > 1 and batch % db:
        log.warning(
            "serve fingerprints demote batch divisor %d -> 1: decode width "
            "%d is not divisible, so decode activations execute replicated",
            db,
            batch,
        )
        div["batch"] = 1
    return div


@dataclass(frozen=True)
class DispatchStats:
    """Point-in-time view of the engine's dispatch health: the selector's
    counters plus the online-adaptation loop's. Selector fields
    (``tuned_hits``, ``lookups``, ...) are reachable directly via attribute
    delegation."""

    selector: SelectorStats
    misses: int  # untuned dispatches observed (adaptive) or cold non-DB hits
    adaptations: int  # tuning records committed online
    sieve_generation: int  # build version of the live sieve
    db_records: int  # tuning database size
    pending_hot: int  # promoted fingerprints awaiting an adaptation round
    #: unseen fingerprints served from the calibrated model's argmin (the
    #: "model" selection source) — analytical warm starts, still counted as
    #: misses by the adaptive loop so hot ones get measured and promoted
    model_warm: int = 0
    #: dispatches seeded from a foreign arch class's record (the "xarch"
    #: selection source) — re-ranked warm starts, still adaptive misses so
    #: local measurements supersede the import
    xarch_seeds: int = 0

    def __getattr__(self, name):
        return getattr(self.selector, name)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    truncated: bool = False  # retired early (e.g. paged-pool anti-deadlock)


@dataclass
class ServeConfig:
    n_slots: int = 8
    max_seq: int = 512
    eos: int = 0
    seed: int = 0


class EngineCore:
    """Shared substrate of the serving engines: dispatch-context threading
    (selector/backend scoping + selection-log mirroring), adaptive-tuner
    hooks, sampling, request admission validation, and the run() drain loop
    with exhaustion accounting. Subclasses implement :meth:`step` (one
    scheduling quantum) and :meth:`outstanding` (requests still queued or
    resident)."""

    def __init__(
        self,
        model,
        params,
        *,
        max_seq: int,
        seed: int = 0,
        div=None,
        batch_hint: Optional[int] = None,
        selector: Optional[KernelSelector] = None,
        backend: Optional[str] = None,
        adaptive: Optional[AdaptiveTuner] = None,
        adapt_every: int = 0,
    ):
        self.model = model
        self.params = params
        # Mesh-aware dispatch fingerprints: when the caller installed a
        # ShardingPlan (dist.sharding.use_plan) but passed no explicit div,
        # derive the per-shard GEMM divisors from the plan — every decode
        # GEMM then fingerprints the *local* per-device MNK, so tuning
        # records federate across identically-sharded serving processes.
        # serve_gemm_div additionally demotes the table's tensor-parallel
        # divisor when any serve-path weight dim would be demoted to
        # replication by the plan's own solver (per-array divisibility),
        # so fingerprints never claim a split the arrays don't execute.
        self.div = div if div is not None else serve_gemm_div(model, batch_hint)
        # Online adaptation: an AdaptiveTuner rides the decode loop — every
        # ``adapt_every`` engine steps it gets one budgeted round to tune the
        # hottest untuned fingerprints the serving traffic produced. The
        # tuner is bound to a selector; if the caller did not pass one
        # explicitly, the engine serves through the tuner's.
        if adaptive is not None and selector is None:
            selector = adaptive.selector
        self.adaptive = adaptive
        self.adapt_every = adapt_every
        self._steps = 0
        self._max_seq = max_seq
        # Dispatch threading: when the caller hands the engine a selector
        # and/or backend, every prefill/decode trace runs under that
        # dedicated context; otherwise traces use the ambient context (so
        # wrapping the engine in ``gemm_context`` keeps working). Either
        # way the selections the engine triggers mirror into
        # ``selection_log`` for serving-side introspection.
        self.selector = selector
        self.backend = backend
        self.selection_log: List = []
        self.rng = np.random.default_rng(seed)
        self._queue: List[Request] = []
        self._uid = 0
        # run()-exhaustion accounting: requests still queued or resident
        # when the step budget ran out (None until the first run())
        self.unfinished: List[Request] = []
        self.exhausted: bool = False

    @contextmanager
    def _dispatch_ctx(self):
        if self.selector is not None or self.backend is not None:
            with gemm_context(selector=self.selector, backend=self.backend) as ctx:
                # backend-only construction inherits the ambient selector;
                # remember it so dispatch_stats reads the one that served
                self._ambient_selector = ctx.selector
                start = len(ctx.log)
                try:
                    yield
                finally:
                    # a failing trace still recorded selections before it
                    # raised — keep them observable
                    self.selection_log.extend(ctx.log[start:])
        else:
            # remember which ambient selector served this traffic, so
            # dispatch_stats reads the right counters even after the
            # caller's gemm_context has exited
            self._ambient_selector = current_selector()
            amb_log = current_log()
            start = len(amb_log)
            try:
                yield
            finally:
                self.selection_log.extend(amb_log[start:])

    @property
    def dispatch_stats(self) -> DispatchStats:
        sel = self.selector
        if sel is None:
            sel = getattr(self, "_ambient_selector", None) or current_selector()
        ad = self.adaptive
        if ad is not None:
            misses = ad.stats.misses
            adaptations = ad.stats.adaptations
            pending = ad.pending_hot
            db_records = len(ad.db.records)
        else:
            # without an adaptive loop, "miss" degrades to the cold
            # non-database selections the selector itself counted
            misses = (
                sel.stats.sieve_hits
                + sel.stats.model_warm
                + sel.stats.xarch_seeds
                + sel.stats.fallbacks
            )
            adaptations = 0
            pending = 0
            db_records = len(sel.db.records) if sel.db is not None else 0
        return DispatchStats(
            selector=sel.stats,
            misses=misses,
            adaptations=adaptations,
            sieve_generation=sel.sieve_generation,
            db_records=db_records,
            pending_hot=pending,
            model_warm=sel.stats.model_warm,
            xarch_seeds=sel.stats.xarch_seeds,
        )

    def _sample(self, logits: np.ndarray, temperature: float) -> int:
        if temperature <= 0.0:
            return int(np.argmax(logits))
        p = np.exp((logits - logits.max()) / temperature)
        p /= p.sum()
        return int(self.rng.choice(len(p), p=p))

    def _validate_prompt(self, prompt) -> np.ndarray:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            # an empty prefill would scatter a meaningless KV row and
            # sample from garbage logits — refuse it at the front door
            raise ValueError("empty prompt (0 tokens) cannot be served")
        if len(prompt) > self._max_seq:
            raise ValueError(
                f"prompt length {len(prompt)} exceeds max_seq {self._max_seq}"
            )
        return prompt

    def _maybe_adapt(self):
        self._steps += 1
        if (
            self.adaptive is not None
            and self.adapt_every > 0
            and self._steps % self.adapt_every == 0
        ):
            self.adaptive.adapt()

    # -- drain loop --------------------------------------------------------
    def step(self) -> bool:  # pragma: no cover - abstract
        raise NotImplementedError

    def outstanding(self) -> List[Request]:  # pragma: no cover - abstract
        raise NotImplementedError

    def run(self, max_steps: int = 10_000) -> List[Request]:
        """Drain queue + resident requests; returns finished requests.

        When ``max_steps`` runs out first, the unserved remainder is NOT
        silently dropped: it stays queued/resident on the engine, and is
        additionally flagged on ``self.exhausted`` / listed in
        ``self.unfinished`` so callers can distinguish "drained" from
        "budget ran out" without diffing uid sets."""
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_steps):
            for r in list(self._queue):
                seen[r.uid] = r
            for r in self.outstanding():
                seen[r.uid] = r
            if not self.step():
                break
        if self.adaptive is not None and self.adapt_every > 0:
            # end-of-run flush: short traces must still commit what they
            # learned (and journal it) before the process goes away
            self.adaptive.drain()
        for r in seen.values():
            if r.done:
                finished.append(r)
        self.unfinished = self.outstanding()
        self.exhausted = bool(self.unfinished)
        if self.exhausted:
            log.warning(
                "run(max_steps=%d) exhausted with %d request(s) still "
                "queued/active; they remain resident (see engine.unfinished)",
                max_steps,
                len(self.unfinished),
            )
        return finished


class ServeEngine(EngineCore):
    """Dense slot engine: ``n_slots`` sequences share one stacked KV cache
    out to ``max_seq`` (see module doc)."""

    def __init__(
        self,
        model,
        params,
        cfg: ServeConfig,
        *,
        div=None,
        selector: Optional[KernelSelector] = None,
        backend: Optional[str] = None,
        adaptive: Optional[AdaptiveTuner] = None,
        adapt_every: int = 0,
    ):
        super().__init__(
            model,
            params,
            max_seq=cfg.max_seq,
            seed=cfg.seed,
            div=div,
            batch_hint=cfg.n_slots,
            selector=selector,
            backend=backend,
            adaptive=adaptive,
            adapt_every=adapt_every,
        )
        self.cfg = cfg
        self.cache = model.init_cache(cfg.n_slots, cfg.max_seq)
        self.pos = np.zeros((cfg.n_slots,), np.int32)  # next write position
        self.slot_req: List[Optional[Request]] = [None] * cfg.n_slots
        self._decode = jax.jit(
            lambda p, c, t, pos: model.decode_step(p, c, t, pos, div=self.div),
            donate_argnums=(1,),
        )

    # -- request admission -------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 32, temperature: float = 0.0) -> int:
        prompt = self._validate_prompt(prompt)
        self._uid += 1
        self._queue.append(
            Request(self._uid, prompt, max_new_tokens, temperature)
        )
        return self._uid

    def outstanding(self) -> List[Request]:
        return list(self._queue) + [r for r in self.slot_req if r is not None]

    def _admit(self):
        for slot in range(self.cfg.n_slots):
            # a request can finish AT prefill (EOS / max_new_tokens == 1 /
            # prompt exactly fills the cache) and free its slot immediately;
            # keep admitting into the same slot so a run() whose every
            # request prefill-finishes still drains the queue instead of
            # abandoning it (step() would otherwise see no active slots)
            while self.slot_req[slot] is None and self._queue:
                req = self._queue.pop(0)
                self._prefill_slot(slot, req)
            if not self._queue:
                break

    def _prefill_slot(self, slot: int, req: Request):
        """Prefill one slot. Single-sequence prefill then scatter its cache
        into the shared pool at the slot index. Prompts longer than the
        cache are rejected here too (defense in depth for direct callers —
        ``submit`` already refuses them): prefilling one would silently
        scatter KV entries out of bounds."""
        if len(req.prompt) > self.cfg.max_seq:
            raise ValueError(
                f"prompt length {len(req.prompt)} exceeds max_seq "
                f"{self.cfg.max_seq}; cannot prefill without scattering out "
                "of bounds"
            )
        prompt = jnp.asarray(req.prompt)[None, :]
        with self._dispatch_ctx():
            logits, cache1 = self.model.prefill(
                self.params, prompt, max_seq=self.cfg.max_seq, div=self.div
            )

        def place(pool, fresh):
            return jax.lax.dynamic_update_index_in_dim(pool, fresh[:, 0], slot, 1)

        self.cache = jax.tree.map(place, self.cache, cache1)
        self.pos[slot] = len(req.prompt)
        self.slot_req[slot] = req
        tok = self._sample(np.asarray(logits)[0, -1], req.temperature)
        req.out_tokens.append(int(tok))
        # the prefill-sampled token can already terminate the request; a
        # prompt that exactly fills the cache leaves no decode room, so it
        # finishes with the one prefill-sampled token
        full = self.pos[slot] >= self.cfg.max_seq
        if tok == self.cfg.eos or len(req.out_tokens) >= req.max_new_tokens or full:
            req.done = True
            self.slot_req[slot] = None
            self.pos[slot] = 0

    # -- decode loop ---------------------------------------------------------
    def step(self):
        """One decode step for every active slot."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        tokens = np.zeros((self.cfg.n_slots, 1), np.int32)
        for i in active:
            tokens[i, 0] = self.slot_req[i].out_tokens[-1]
        cur_pos = jnp.asarray(self.pos)
        with self._dispatch_ctx():
            logits, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(tokens), cur_pos
            )
        logits_np = np.asarray(logits)[:, 0]
        for i in active:
            req = self.slot_req[i]
            self.pos[i] += 1
            tok = self._sample(logits_np[i], req.temperature)
            req.out_tokens.append(tok)
            length_done = len(req.out_tokens) >= req.max_new_tokens
            eos_done = tok == self.cfg.eos
            # the cache is full when the *next* write position is out of
            # bounds; pos was already advanced above, so compare pos itself
            # (pos + 1 retired slots one usable token early)
            full = self.pos[i] >= self.cfg.max_seq
            if length_done or eos_done or full:
                req.done = True
                self.slot_req[i] = None
                self.pos[i] = 0
        self._maybe_adapt()
        return True
