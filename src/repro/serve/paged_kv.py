"""Block/paged KV allocation for the serving path.

The dense engine gives every slot a ``max_seq`` stripe of the stacked KV
cache, so resident concurrency is capped at ``n_slots`` and the memory bill
is ``n_slots * max_seq`` token rows whether sequences use them or not. Here
KV lives in a pool of fixed-size *pages* — leaf shape
``(L, n_pages + 1, page_size, ...)`` — and each sequence holds an ordered
*page table* mapping logical position ``p`` to row
``(table[p // page_size], p % page_size)``. Resident concurrency is then
bounded by the total page budget (the sum of actual sequence lengths,
rounded up per sequence), not by slots-times-max-capacity: the vLLM-style
accounting under which a 2x shorter average sequence hosts 2x the users in
the same memory.

Integration contract: ``model.prefill`` / ``model.decode_step`` and the
dispatch fingerprints they produce stay untouched. The adapters below
*gather* a sequence batch's pages into a dense, position-contiguous view —
page ``i`` of a table holds positions ``i*page_size..(i+1)*page_size - 1``,
so concatenated pages ARE the dense layout and the decode attention masks
(``kpos <= cur_pos``) mask the allocated-but-unwritten tail rows exactly as
they mask the dense cache's — run the unchanged model step on the view, and
*scatter* only the newly written rows back into the pool. The decode GEMMs
see a fixed batch width and a (padded) view length, so tuned records keep
hitting.

The pool carries one extra *scratch* page (index ``n_pages``): padding
entries of short page tables and the write-back targets of padded batch
rows point at it, keeping every gather/scatter fully vectorized with no
host-side masking inside the jitted step.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.dist.sharding import ArraySpec


class PageExhausted(RuntimeError):
    """The free list cannot cover an allocation request."""


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` positions (>= 1: even an empty
    table reserves the page its first decode token will write)."""
    return max(1, -(-int(n_tokens) // page_size))


@dataclass
class PageTable:
    """One sequence's ordered page list + how many positions are written."""

    pages: List[int] = field(default_factory=list)
    length: int = 0

    @property
    def capacity(self) -> int:
        return len(self.pages)  # in pages; tokens = capacity * page_size


def paged_cache_specs(model, page_size: int) -> Dict[str, Any]:
    """ArraySpec tree of one *page* of the model's decode cache — the
    model's own ``cache_specs`` with (batch, seq) -> (1, page_size). Raises
    for cache layouts that cannot page (SSM/hybrid state, ring caches)."""
    cfg = model.cfg
    if cfg.family not in ("dense", "vlm", "moe"):
        raise ValueError(
            f"paged KV supports the attention-cache families (dense/vlm/moe); "
            f"{cfg.family!r} decode state is O(1) per sequence and gains "
            "nothing from paging"
        )
    if cfg.window_cache and cfg.global_every:
        raise ValueError(
            "paged KV requires the uniform decode cache; ring caches "
            "already bound local-layer memory at O(window)"
        )
    specs = model.cache_specs(1, page_size)
    if set(specs) != {"attn"}:
        raise ValueError(f"unexpected cache layout {sorted(specs)!r}")
    return specs


class PagedKVCache:
    """Page pool + free-list allocator + gather/scatter adapters.

    The pool is a pytree matching the model's cache tree with the (batch,
    seq) axes replaced by (n_pages + 1, page_size); page ``n_pages`` is the
    scratch page (see module doc). Allocation is FIFO-recycled: freed pages
    go to the back of the free list, so a page's stale contents age out
    instead of being immediately re-read by the next gather (any stale row
    is masked regardless — recycling order only aids debugging).
    """

    def __init__(self, model, *, page_size: int, n_pages: int):
        if page_size < 1 or n_pages < 1:
            raise ValueError(f"bad pool geometry {page_size=} {n_pages=}")
        self.page_size = int(page_size)
        self.n_pages = int(n_pages)
        self.scratch = self.n_pages  # reserved page id for padded rows
        specs = paged_cache_specs(model, page_size)

        def pool_leaf(s: ArraySpec):
            # (L, 1, page_size, *rest) -> (L, n_pages + 1, page_size, *rest)
            shape = (s.shape[0], n_pages + 1, *s.shape[2:])
            return jnp.zeros(shape, s.dtype)

        self.pool = jax.tree.map(
            pool_leaf, specs, is_leaf=lambda x: isinstance(x, ArraySpec)
        )
        self._free: deque = deque(range(self.n_pages))
        self.peak_used = 0

    # -- allocator --------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.n_pages - len(self._free)

    def pages_for(self, n_tokens: int) -> int:
        return pages_for(n_tokens, self.page_size)

    def try_alloc(self, n: int) -> Optional[List[int]]:
        """``n`` pages off the free list, or None (state unchanged) if the
        budget cannot cover them."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        self.peak_used = max(self.peak_used, self.used_pages)
        return pages

    def alloc(self, n: int) -> List[int]:
        pages = self.try_alloc(n)
        if pages is None:
            raise PageExhausted(
                f"need {n} pages, {len(self._free)}/{self.n_pages} free"
            )
        return pages

    def free(self, pages: List[int]):
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(f"freeing invalid page id {p}")
            if p in self._free:
                raise ValueError(f"double free of page {p}")
        self._free.extend(pages)

    def occupancy(self) -> Dict[str, float]:
        return {
            "n_pages": self.n_pages,
            "used_pages": self.used_pages,
            "free_pages": self.free_pages,
            "peak_used_pages": self.peak_used,
            "utilization": self.used_pages / self.n_pages,
        }

    # -- jnp adapters ------------------------------------------------------
    # Pure functions of (pool, indices, values): the scheduler composes and
    # jits them. Leaf layout: pool (L, NP, PS, *rest), dense cache/view
    # (L, B, S, *rest).

    def gather_view(self, pool, pages_2d: jax.Array):
        """Dense position-contiguous view of a batch of page tables.
        ``pages_2d``: (B, P) page ids, short tables padded with scratch.
        Leaf: (L, NP, PS, *r) -> (L, B, P*PS, *r)."""

        def leaf(a):
            g = a[:, pages_2d]  # (L, B, P, PS, *r)
            return g.reshape(g.shape[0], *pages_2d.shape[:1], -1, *g.shape[4:])

        return jax.tree.map(leaf, pool)

    def scatter_rows(self, pool, page_ids: jax.Array, offsets: jax.Array, rows):
        """Write one row per batch element: ``rows`` leaf (L, B, *r) lands at
        ``pool[:, page_ids[b], offsets[b]]``. Padded batch rows must point
        ``page_ids`` at the scratch page."""

        def leaf(a, r):
            return a.at[:, page_ids, offsets].set(r)

        return jax.tree.map(leaf, pool, rows)

    def rows_at(self, view, pos: jax.Array):
        """Extract the per-sequence row at ``pos`` (B,) from a dense view:
        leaf (L, B, S, *r) -> (L, B, *r)."""

        def leaf(a):
            bidx = jnp.arange(a.shape[1])
            return a[:, bidx, pos]

        return jax.tree.map(leaf, view)

    def scatter_prefill(self, pool, pages: jax.Array, fresh):
        """Write one sequence's freshly prefilled cache into its pages.
        ``fresh`` leaf (L, 1, S_pad, *r) with S_pad == len(pages)*PS (the
        caller prefills at the page-padded length); ``pages``: (P,)."""

        def leaf(a, f):
            p = pages.shape[0]
            chunks = f[:, 0].reshape(f.shape[0], p, self.page_size, *f.shape[3:])
            return a.at[:, pages].set(chunks)

        return jax.tree.map(leaf, pool, fresh)

    def padded_tables(self, tables: List[PageTable], min_pages: int = 1):
        """(B, P) int32 page-id array for a batch of tables, P = the max
        table length padded up to a power of two (bounds jit recompiles to
        log2(max_seq/page_size) distinct view shapes); scratch-padded."""
        import numpy as np

        p = max(min_pages, *(len(t.pages) for t in tables)) if tables else min_pages
        p_pad = 1
        while p_pad < p:
            p_pad *= 2
        out = np.full((len(tables), p_pad), self.scratch, np.int32)
        for i, t in enumerate(tables):
            out[i, : len(t.pages)] = t.pages
        return jnp.asarray(out)
