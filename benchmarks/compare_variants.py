"""Print baseline-vs-variant roofline comparisons for the perf log."""

from __future__ import annotations

import glob
import json
import os
import sys

from benchmarks.common import ART
from benchmarks.roofline import analyze_artifact

DRYRUN_DIR = os.path.join(ART, "dryrun")


def row(name: str):
    path = os.path.join(DRYRUN_DIR, name + ".json")
    if not os.path.exists(path):
        return None
    art = json.load(open(path))
    r = analyze_artifact(art)
    if r is None:
        return None
    temp = art["memory"]["temp_size_tpu_estimate"] / 2**30
    return (
        f"{r.variant:16s} comp={r.compute_s:8.2f}s mem={r.memory_s:8.2f}s "
        f"coll={r.collective_s:8.2f}s dom={r.dominant:10s} "
        f"frac={r.roofline_fraction:.2f} mfu={r.mfu:.2f} temp={temp:6.1f}G"
    )


def main(cells):
    for cell in cells:
        print(f"== {cell}")
        base = row(cell)
        if base:
            print("  " + base)
        for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, cell + "__*.json"))):
            name = os.path.basename(path)[: -len(".json")]
            r = row(name)
            if r:
                print("  " + r)


if __name__ == "__main__":
    cells = sys.argv[1:] or [
        "qwen3-moe-235b-a22b__train_4k__single_pod",
        "mistral-large-123b__train_4k__single_pod",
        "llava-next-34b__prefill_32k__single_pod",
    ]
    main(cells)
