"""Wall-clock serving throughput (the one benchmark this CPU-only box can
measure for real): tokens/s of the continuous-batching engine vs slot count
on a ~10M-param model, with Stream-K++ dispatch active.

The paper positions FP16 GEMM tuning for inference engines (§5.1); this is
the engine-level view of the same workload. Absolute numbers are CPU-bound
and meaningless for TPU; the *scaling shape* (throughput vs concurrency) and
the dispatch-path overhead (selection happens at trace time — zero per-token
cost) are the claims under test.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import csv_row


def run() -> List[str]:
    import jax

    from repro.configs import get_reduced
    from repro.core.gemm import gemm_context
    from repro.core.selector import default_selector
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = dataclasses.replace(
        get_reduced("granite-8b"),
        dtype="float32",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=4,
        d_head=32,
        d_ff=512,
        vocab_size=2048,
    )
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    rows = []
    sel = default_selector()
    for slots in (1, 2, 4, 8):
        with gemm_context(selector=sel):
            eng = ServeEngine(
                model, params, ServeConfig(n_slots=slots, max_seq=128, eos=-1)
            )
            n_req = slots * 3
            for _ in range(n_req):
                eng.submit(
                    rng.integers(1, cfg.vocab_size, size=8), max_new_tokens=16
                )
            # warm the jit caches with one step
            eng.step()
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
        ntok = sum(len(r.out_tokens) for r in done) or 1
        rows.append(
            csv_row(
                f"serve.throughput_slots{slots}",
                dt / ntok * 1e6,
                f"{ntok / dt:.1f} tok/s ({n_req} reqs)",
            )
        )
    rows.append(
        csv_row(
            "serve.dispatch_trace_time_only",
            0.0,
            f"{sel.stats.lookups} selections, all at trace time (0 per-token)",
        )
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
