"""Wall-clock serving throughput (the one benchmark this CPU-only box can
measure for real): tokens/s of the continuous-batching engine vs slot count
on a ~10M-param model, with Stream-K++ dispatch active — plus the
quantized-vs-f32 decode delta (int8 weights through the fused-dequant
path, dispatching under mixed ``float32*int8`` fingerprints).

The paper positions FP16 GEMM tuning for inference engines (§5.1); this is
the engine-level view of the same workload. Absolute numbers are CPU-bound
and meaningless for TPU; the *scaling shape* (throughput vs concurrency),
the dispatch-path overhead (selection happens at trace time — zero
per-token cost), and the quantized path actually serving are the claims
under test. The int8 B-operand traffic halving that motivates quantized
decode is a TPU/HBM property the modeled-TFLOP/s trajectory
(perf_trajectory.py) tracks; here the delta row only proves the quantized
engine serves the same stream end to end.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

import numpy as np

from benchmarks.common import csv_row


def run() -> List[str]:
    import jax

    from repro.configs import get_reduced
    from repro.core.gemm import gemm_context
    from repro.core.selector import default_selector
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model
    from repro.serve import ServeConfig, ServeEngine

    cfg = dataclasses.replace(
        get_reduced("granite-8b"),
        dtype="float32",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=4,
        d_head=32,
        d_ff=512,
        vocab_size=2048,
    )
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))

    rows = []
    sel = default_selector()

    def serve_stream(run_params, slots, selector):
        with gemm_context(selector=selector):
            eng = ServeEngine(
                model, run_params, ServeConfig(n_slots=slots, max_seq=128, eos=-1)
            )
            n_req = slots * 3
            stream_rng = np.random.default_rng(0)
            for _ in range(n_req):
                eng.submit(
                    stream_rng.integers(1, cfg.vocab_size, size=8),
                    max_new_tokens=16,
                )
            # warm the jit caches with one step
            eng.step()
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
        ntok = sum(len(r.out_tokens) for r in done) or 1
        return ntok, dt, n_req

    for slots in (1, 2, 4, 8):
        ntok, dt, n_req = serve_stream(params, slots, sel)
        rows.append(
            csv_row(
                f"serve.throughput_slots{slots}",
                dt / ntok * 1e6,
                f"{ntok / dt:.1f} tok/s ({n_req} reqs)",
            )
        )
    rows.append(
        csv_row(
            "serve.dispatch_trace_time_only",
            0.0,
            f"{sel.stats.lookups} selections, all at trace time (0 per-token)",
        )
    )

    # quantized-vs-f32 decode delta: same request stream, int8 weights with
    # fused dequant epilogues, dispatching under float32*int8 fingerprints
    qparams, n_quant = model.quantize_weights(params)
    slots = 4
    ntok_f, dt_f, _ = serve_stream(params, slots, default_selector())
    qsel = default_selector()
    ntok_q, dt_q, _ = serve_stream(qparams, slots, qsel)
    f32_tps = ntok_f / dt_f
    q_tps = ntok_q / dt_q
    rows.append(
        csv_row(
            f"serve.throughput_int8_slots{slots}",
            dt_q / ntok_q * 1e6,
            f"{q_tps:.1f} tok/s int8 vs {f32_tps:.1f} f32 "
            f"({q_tps / f32_tps:.2f}x, {n_quant} quantized leaves)",
        )
    )

    # paged-vs-dense: the same request stream through the paged engine at
    # equal KV memory (n_slots * max_seq rows == max_pages * page_size).
    # Decode runs at the same fixed batch width, so dispatch fingerprints
    # match the dense engine's; traffic_replay.py measures the concurrency
    # headroom the paging actually buys under realistic arrivals.
    from repro.serve import PagedServeConfig, PagedServeEngine

    def serve_stream_paged(run_params, slots, selector):
        with gemm_context(selector=selector):
            eng = PagedServeEngine(
                model,
                run_params,
                PagedServeConfig(
                    page_size=16,
                    max_pages=slots * 128 // 16,
                    max_active=slots,
                    max_seq=128,
                    eos=-1,
                ),
            )
            n_req = slots * 3
            stream_rng = np.random.default_rng(0)
            for _ in range(n_req):
                eng.submit(
                    stream_rng.integers(1, cfg.vocab_size, size=8),
                    max_new_tokens=16,
                )
            eng.step()
            t0 = time.perf_counter()
            done = eng.run()
            dt = time.perf_counter() - t0
        ntok = sum(len(r.out_tokens) for r in done) or 1
        return ntok, dt, n_req

    ntok_p, dt_p, _ = serve_stream_paged(params, slots, default_selector())
    p_tps = ntok_p / dt_p
    rows.append(
        csv_row(
            f"serve.throughput_paged_slots{slots}",
            dt_p / ntok_p * 1e6,
            f"{p_tps:.1f} tok/s paged vs {f32_tps:.1f} dense "
            f"({p_tps / f32_tps:.2f}x at equal KV rows)",
        )
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
