"""Perf trajectory: one ``BENCH_<n>.json`` at the repo root per PR.

Each snapshot records (a) trace-time dispatch overhead (cold / memoised
select_op) and (b) the modeled-TFLOP/s winner — (policy, cfg, g) at the
op's real byte-widths — for a deterministic sample of gemm_suite shapes,
in f32 and bf16. When the previous snapshot (``BENCH_<n-1>.json``) exists,
per-shape and dispatch deltas are computed, embedded under ``"deltas"``,
and printed — the CI bench-smoke job runs this and uploads the file, so
the trajectory of modeled-speed fidelity is diffable across PRs.

Usage:
  PYTHONPATH=src:. python benchmarks/perf_trajectory.py            # next n
  PYTHONPATH=src:. python benchmarks/perf_trajectory.py --index 3  # pin n
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
from typing import Dict, List, Optional

REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

N_SHAPES = 32
#: per-shape dtype profiles: dense f32/bf16 plus the low-precision serving
#: ladder — int8 weights (1-byte B), dynamic int8 x int8 (1-byte A and B,
#: integer MAC) and packed int4 weights (0.5-byte B) — the trajectory
#: tracks whether the shrinking byte-widths keep flipping winners
DTYPES = ("float32", "bfloat16", "float32*int8", "int8*int8", "float32*int4")

#: the ladder rungs whose selection flips the snapshot counts explicitly
LADDER_DTYPES = ("float32*int8", "int8*int8", "float32*int4")


def _out_dtype(dt_name: str) -> str:
    """Stored-output dtype of a fingerprint: mixed "a*w" profiles output at
    the activation dtype — except integer activations (the dynamic-quant
    rung), which keep the pre-quantization float output contract."""
    act = dt_name.split("*", 1)[0]
    return "float32" if act.startswith(("int", "uint")) else act

#: grouped-GEMM trajectory: expert counts swept for the fused one-kernel
#: MoE dispatch vs the per-group launch loop
GROUPED_GS = (4, 8, 16)
GROUPED_MNK = (64, 256, 256)


def _sample_shapes(n: int = N_SHAPES) -> List[tuple]:
    """Deterministic spread over the 923-size suite (every len/n-th shape)."""
    from repro.configs.gemm_suite import suite

    full = suite()
    step = max(1, len(full) // n)
    return full[::step][:n]


def _dispatch_overhead_us() -> Dict[str, float]:
    """Same harness as benchmarks/dispatch_overhead.py (shared size
    generator, cached 923-size DB, shared timer) so the trajectory's
    dispatch numbers cannot drift from that benchmark's artifact."""
    from benchmarks.common import tuned_db
    from benchmarks.dispatch_overhead import _sizes, _time_per
    from repro.core.op import GemmOp
    from repro.core.selector import KernelSelector

    db = tuned_db()
    sel = KernelSelector(sieve=db.build_sieve(), db=db)
    ops = [GemmOp.plain(*s) for s in _sizes(200)]
    return {
        "op_cold_us": _time_per(sel.select_op, ops),
        "op_cached_us": _time_per(sel.select_op, ops),
    }


def _modeled_suite() -> Dict[str, dict]:
    from repro.core.op import GemmOp
    from repro.core.selector import default_selector
    from repro.core import costmodel
    from repro.core.workpart import GemmShape

    sel = default_selector()
    out: Dict[str, dict] = {}
    for m, n, k in _sample_shapes():
        entry = {}
        for dt_name in DTYPES:
            out_dt = _out_dtype(dt_name)
            s = sel.select_op(
                GemmOp.plain(m, n, k, in_dtype=dt_name, out_dtype=out_dt)
            )
            dt = costmodel.profile_for(dt_name, out_dt)
            tflops = costmodel.gemm_tflops(
                GemmShape(m, n, k), s.cfg, s.policy, g=s.g, dt=dt
            )
            entry[dt_name] = {
                "policy": s.policy.name,
                "cfg": s.cfg.name,
                "g": s.g,
                "modeled_tflops": round(tflops, 4),
            }
        out[f"{m}x{n}x{k}"] = entry
    return out


def _ladder_flips(suite: Dict[str, dict]) -> Dict[str, dict]:
    """Selection-flip counts for the quantized ladder: per rung, over the
    sampled shapes, how often the selected (policy, cfg, g) differs from
    the dense-f32 winner and from the int8-weight rung at the same MNK —
    the observable evidence that the cost model scores each rung's real
    byte-widths (packed int4 B at 0.5 bytes/element included)."""
    out: Dict[str, dict] = {}
    total = len(suite)
    for dt_name in LADDER_DTYPES:
        vs_f32 = vs_int8 = 0
        for entry in suite.values():
            pick = entry[dt_name]
            key = (pick["policy"], pick["cfg"], pick["g"])
            f32 = entry["float32"]
            if key != (f32["policy"], f32["cfg"], f32["g"]):
                vs_f32 += 1
            base = entry["float32*int8"]
            if key != (base["policy"], base["cfg"], base["g"]):
                vs_int8 += 1
        out[dt_name] = {
            "samples": total,
            "flips_vs_float32": vs_f32,
            "flips_vs_int8_weight": vs_int8,
        }
    return out


def _grouped_trajectory() -> Dict[str, dict]:
    """Fused one-kernel grouped MoE dispatch vs the per-group launch loop.

    Two measurements per expert count G: (a) *real* kernel-launch counts —
    both op forms dispatched through the interpret backend under
    ``count_launches`` (the fused form must stay at exactly 1 while the
    loop scales with G), and (b) the modeled TFLOP/s of each form's
    selected (policy, cfg, g) — the fused form scored on the concatenated
    ``GroupedGemmShape`` tile space, the loop on the per-group shape it
    launches G times."""
    import jax
    import jax.numpy as jnp

    from repro.core import costmodel, gemm_context, gemm_grouped
    from repro.core.op import GemmOp
    from repro.core.selector import default_selector
    from repro.core.workpart import GemmShape, GroupedGemmShape

    from repro.kernels.common import count_launches

    m, n, k = GROUPED_MNK
    sel = default_selector()
    dt = costmodel.profile_for("float32", "float32")
    out: Dict[str, dict] = {}
    for g in GROUPED_GS:
        ka, kw = jax.random.split(jax.random.PRNGKey(g))
        x = jax.random.normal(ka, (g, m, k), jnp.float32)
        w = jax.random.normal(kw, (g, k, n), jnp.float32)
        launches = {}
        for label, fused in (("fused", True), ("loop", False)):
            jax.clear_caches()  # jit-cached traces would hide re-launches
            with count_launches() as log, gemm_context(backend="pallas_interpret"):
                gemm_grouped(x, w, fused=fused).block_until_ready()
            launches[label] = len(log)
        s_fused = sel.select_op(GemmOp(m, n, k, g=g, kind="grouped", fused=True))
        s_loop = sel.select_op(GemmOp(m, n, k, g=g, kind="grouped", fused=False))
        out[f"G{g}"] = {
            "mnk": f"{m}x{n}x{k}",
            "launches": launches,
            "fused": {
                "policy": s_fused.policy.name,
                "cfg": s_fused.cfg.name,
                "g": s_fused.g,
                "modeled_tflops": round(
                    costmodel.gemm_tflops(
                        GroupedGemmShape(m, n, k, groups=g),
                        s_fused.cfg,
                        s_fused.policy,
                        g=s_fused.g,
                        dt=dt,
                    ),
                    4,
                ),
            },
            "loop": {
                "policy": s_loop.policy.name,
                "cfg": s_loop.cfg.name,
                "g": s_loop.g,
                "modeled_tflops": round(
                    costmodel.gemm_tflops(
                        GemmShape(m, n, k), s_loop.cfg, s_loop.policy, g=s_loop.g, dt=dt
                    ),
                    4,
                ),
            },
        }
    return out


#: top-k window the regret section scores hit rate over (the serving
#: default for budgeted sweeps)
REGRET_TOP_K = 5


def _regret_section() -> Dict[str, dict]:
    """Analytical-first fidelity: calibrate on the 923-record journal, then
    score the calibrated model's argmin against the measurement oracle's
    full-sweep best per suite sample. Regret = oracle wall of the model's
    pick / oracle wall of the measured best (1.0 = the model's argmin IS
    the measured winner); ``topk_hit_rate`` = how often the measured best
    sits inside the model's top-k (what a budgeted sweep would measure).
    The ``budget`` block runs real ``Tuner`` sweeps (full vs top-k) over
    the samples — the measurement-count ratio and selected-config quality
    the acceptance bar reads."""
    from benchmarks.common import tuned_db
    from repro.core import costmodel
    from repro.core.calibrate import CalibrationError, calibrate_db, profile_key
    from repro.core.tuner import Tuner
    from repro.core.workpart import GemmShape

    db = tuned_db()
    try:
        cm = calibrate_db(db)
    except CalibrationError as e:
        return {"error": str(e)}
    mach_hw = costmodel.V5E  # the measurement oracle's machine
    samples = _sample_shapes()
    out: Dict[str, dict] = {
        "calibration": {
            "n_records": cm.n_records,
            "residual": round(cm.residual, 6),
            "fitted_profiles": list(cm.fitted_profiles),
        },
        "top_k": REGRET_TOP_K,
        "profiles": {},
    }
    for dt_name in DTYPES:
        out_dt = _out_dtype(dt_name)
        dt = costmodel.profile_for(dt_name, out_dt)
        mach_cal = cm.machine_for(dt)
        regrets: List[float] = []
        hits = 0
        for m, n, k in samples:
            shape = GemmShape(m, n, k)
            ranked_cal = costmodel.rank_candidates(shape, mach_cal, dt=dt)
            ranked_hw = costmodel.rank_candidates(shape, mach_hw, dt=dt)
            best = ranked_hw[0]
            pick = ranked_cal[0]
            t_pick = costmodel.gemm_time_s(
                shape, pick[1], pick[0], mach_hw, pick[2], dt
            )
            regrets.append(t_pick / best[3])
            head = {
                (p.name, c.name, g)
                for p, c, g, _ in ranked_cal[:REGRET_TOP_K]
            }
            if (best[0].name, best[1].name, best[2]) in head:
                hits += 1
        regrets.sort()
        out["profiles"][dt_name] = {
            "fitted": profile_key(dt) in cm.fitted_profiles,
            "median_regret": round(regrets[len(regrets) // 2], 4),
            "max_regret": round(regrets[-1], 4),
            "topk_hit_rate": round(hits / len(samples), 4),
            "samples": len(samples),
        }
    # the budget block: real sweeps, full-oracle vs top-k, same samples
    t_full = Tuner()
    t_topk = Tuner(top_k=REGRET_TOP_K, calibration=cm)
    within = 0
    ranks: List[int] = []
    for m, n, k in samples:
        rec_full, _ = t_full.tune_size((m, n, k))
        rec_topk, _ = t_topk.tune_size((m, n, k))
        # both tflops come from the same measurement oracle: time within
        # 10% <=> tflops within /1.1
        if rec_topk.tflops * 1.10 >= rec_full.tflops:
            within += 1
        ranks.append(rec_topk.model_rank)
    out["budget"] = {
        "samples": len(samples),
        "full_measurements": t_full.measurements,
        "topk_measurements": t_topk.measurements,
        "measure_ratio": round(
            t_full.measurements / max(t_topk.measurements, 1), 2
        ),
        "within_10pct_of_full": round(within / len(samples), 4),
        "median_winner_model_rank": sorted(ranks)[len(ranks) // 2],
    }
    return out


def _find_indices(out_dir: str) -> List[int]:
    idx = []
    for path in glob.glob(os.path.join(out_dir, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(path))
        if m:
            idx.append(int(m.group(1)))
    return sorted(idx)


def _deltas(cur: dict, prev: dict) -> dict:
    d: dict = {"vs": prev.get("index"), "suite": {}, "dispatch": {}}
    for key, cur_us in cur["dispatch"].items():
        prev_us = prev.get("dispatch", {}).get(key)
        if prev_us:
            d["dispatch"][key] = round(cur_us - prev_us, 3)
    for shape, entry in cur["suite"].items():
        prev_entry = prev.get("suite", {}).get(shape)
        if not prev_entry:
            continue
        for dt_name, cur_dt in entry.items():
            prev_dt = prev_entry.get(dt_name)
            if not prev_dt:
                continue
            delta_tf = round(
                cur_dt["modeled_tflops"] - prev_dt["modeled_tflops"], 4
            )
            changed = (cur_dt["policy"], cur_dt["cfg"], cur_dt["g"]) != (
                prev_dt["policy"],
                prev_dt["cfg"],
                prev_dt.get("g", 8),
            )
            if delta_tf or changed:
                d["suite"].setdefault(shape, {})[dt_name] = {
                    "d_tflops": delta_tf,
                    "winner_changed": changed,
                }
    prev_grouped = prev.get("grouped", {})
    for gk, cur_g in cur.get("grouped", {}).items():
        prev_g = prev_grouped.get(gk)
        if not prev_g:
            continue
        d.setdefault("grouped", {})[gk] = {
            "d_fused_tflops": round(
                cur_g["fused"]["modeled_tflops"]
                - prev_g["fused"]["modeled_tflops"],
                4,
            ),
            "d_launches": {
                lbl: cur_g["launches"][lbl] - prev_g["launches"].get(lbl, 0)
                for lbl in cur_g["launches"]
            },
        }
    return d


def build_snapshot(
    index: Optional[int] = None,
    out_dir: str = REPO_ROOT,
    diff_dir: Optional[str] = None,
) -> str:
    """Write BENCH_<index>.json into ``out_dir``, diffing against the latest
    prior snapshot found in ``diff_dir`` (default: ``out_dir``). CI points
    ``out_dir`` at its artifact folder and ``diff_dir`` at the repo root, so
    only the newly generated snapshot is uploaded."""
    diff_dir = diff_dir or out_dir
    existing = _find_indices(diff_dir)
    if index is None:
        index = (existing[-1] + 1) if existing else 0
    suite = _modeled_suite()
    snapshot = {
        "index": index,
        "dispatch": _dispatch_overhead_us(),
        "suite": suite,
        "ladder": _ladder_flips(suite),
        "grouped": _grouped_trajectory(),
        "regret": _regret_section(),
    }
    prior = [i for i in existing if i < index]
    if prior:
        with open(os.path.join(diff_dir, f"BENCH_{prior[-1]}.json")) as f:
            snapshot["deltas"] = _deltas(snapshot, json.load(f))
    path = os.path.join(out_dir, f"BENCH_{index}.json")
    with open(path, "w") as f:
        json.dump(snapshot, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--index", type=int, default=None, help="pin the snapshot index")
    ap.add_argument("--out-dir", default=REPO_ROOT)
    ap.add_argument(
        "--diff-dir",
        default=None,
        help="where to look for prior snapshots to diff against "
        "(default: --out-dir)",
    )
    args = ap.parse_args()
    path = build_snapshot(
        index=args.index, out_dir=args.out_dir, diff_dir=args.diff_dir
    )
    with open(path) as f:
        snap = json.load(f)
    print(f"wrote {path}")
    print(f"dispatch: {snap['dispatch']}")
    regret = snap.get("regret", {})
    for dt_name, entry in sorted(regret.get("profiles", {}).items()):
        print(
            f"regret {dt_name}: median={entry['median_regret']} "
            f"max={entry['max_regret']} top{regret['top_k']}_hit="
            f"{entry['topk_hit_rate']}"
            + ("" if entry["fitted"] else " (base machine: profile unfitted)")
        )
    budget = regret.get("budget")
    if budget:
        print(
            f"budget: {budget['topk_measurements']} top-k vs "
            f"{budget['full_measurements']} full measurements "
            f"({budget['measure_ratio']}x fewer), "
            f"{budget['within_10pct_of_full']:.0%} of shapes within 10% of "
            f"the full-sweep winner"
        )
    for dt_name, entry in sorted(snap.get("ladder", {}).items()):
        print(
            f"ladder {dt_name}: {entry['flips_vs_float32']}/{entry['samples']} "
            f"winners differ from f32, {entry['flips_vs_int8_weight']} from "
            f"the int8-weight rung"
        )
    for gk, entry in sorted(snap.get("grouped", {}).items()):
        print(
            f"grouped {gk} ({entry['mnk']}): launches "
            f"fused={entry['launches']['fused']} loop={entry['launches']['loop']}, "
            f"modeled fused {entry['fused']['modeled_tflops']} vs loop "
            f"{entry['loop']['modeled_tflops']} TFLOP/s"
        )
    deltas = snap.get("deltas")
    if deltas:
        print(f"deltas vs BENCH_{deltas['vs']}:")
        print(f"  dispatch: {deltas['dispatch']}")
        for shape, entry in sorted(deltas["suite"].items()):
            print(f"  {shape}: {entry}")
    else:
        print("no previous snapshot to diff against")


if __name__ == "__main__":
    main()
