"""Federated tuning: sharded-sweep scaling + merge equivalence.

Simulates an N-worker tuning fleet over a deterministic slice of the paper
suite plus extended op fingerprints (bf16 / grouped / epilogue-fused):

  * each worker runs ``Tuner.tune(shard=(i, n))`` over its disjoint slice,
    journaling to its own shard file;
  * the shards merge through :func:`repro.core.federate.merge_journal_shards`
    and the per-worker sieves union through ``merge_sieves``;
  * the merged state is checked for *bit-identical* selection vs. the
    single-worker full sweep: same records (modulo producer commit clocks),
    same per-fingerprint (policy, cfg, g), byte-identical sieve filters —
    so elimination decisions (100% true-negative rate included) match.

Reported rows: per-worker-count simulated parallel sweep wall-time (the
slowest shard, i.e. what a real fleet would wait for), speedup vs. the
single-worker sweep, and the equivalence verdicts. Near-linear speedup is
the point: tuning knowledge is produced in parallel and merged, not
rediscovered per worker.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from typing import Dict, List, Optional

from benchmarks.common import csv_row
from repro.configs.gemm_suite import suite
from repro.core.federate import (
    merge_journal_shards,
    merge_sieves,
    record_payload,
    selection_table,
)
from repro.core.op import Epilogue, GemmOp
from repro.core.selector import KernelSelector
from repro.core.tuner import Tuner

N_SUITE = 48  # bare (M, N, K) targets sampled from the 923-size suite
WORKER_COUNTS = (2, 4)


def _targets(n_suite: int = N_SUITE) -> List:
    """Deterministic sweep targets: a spread of the paper suite plus the
    extended fingerprints federation must round-trip (dtype / grouped /
    epilogue keys)."""
    full = suite()
    step = max(1, len(full) // n_suite)
    targets: List = list(full[::step][:n_suite])
    targets += [
        GemmOp.plain(64, 2048, 512, in_dtype="bfloat16"),
        GemmOp.plain(16, 1536, 896, in_dtype="bfloat16"),
        GemmOp(32, 1024, 512, g=8, kind="grouped"),
        GemmOp(8, 768, 640, g=4, kind="grouped"),
        GemmOp.plain(128, 512, 512, epilogue=Epilogue(activation="gelu")),
        GemmOp.plain(24, 640, 320, epilogue=Epilogue(bias=True, activation="silu")),
    ]
    return targets


def _sweep_shard(tuner: Tuner, targets, i: int, n: int, journal: str):
    t0 = time.perf_counter()
    db = tuner.tune(targets, shard=(i, n), journal=journal)
    return db, time.perf_counter() - t0


def run(json_path: Optional[str] = None) -> List[str]:
    rows: List[str] = []
    targets = _targets()
    tuner = Tuner()

    tuner.tune(targets)  # warm-up: cost-model caches must not skew scaling
    with tempfile.TemporaryDirectory() as tmp:
        # the single-worker baseline journals too — shards pay journal I/O,
        # so the baseline must as well for the speedup to be honest
        t0 = time.perf_counter()
        full = tuner.tune(targets, journal=os.path.join(tmp, "full.jsonl"))
        t_full = time.perf_counter() - t0
    full_sieve = full.build_sieve()
    full_sel = KernelSelector(sieve=full_sieve, db=full)
    full_table = selection_table(full_sel, full.records)
    rows.append(
        csv_row(
            "federated_full_sweep",
            t_full * 1e6 / len(targets),
            f"1 worker; {len(targets)} targets; wall={t_full:.3f}s",
        )
    )

    report: Dict[str, object] = {
        "targets": len(targets),
        "single_worker_wall_s": round(t_full, 4),
        "workers": {},
    }
    with tempfile.TemporaryDirectory() as tmp:
        for n in WORKER_COUNTS:
            shard_paths = [os.path.join(tmp, f"w{n}_{i}.jsonl") for i in range(n)]
            shard_dbs, shard_walls = [], []
            for i in range(n):
                db, wall = _sweep_shard(tuner, targets, i, n, shard_paths[i])
                shard_dbs.append(db)
                shard_walls.append(wall)
            # a real fleet's sweep takes as long as its slowest shard
            t_parallel = max(shard_walls)
            speedup = t_full / t_parallel if t_parallel > 0 else float("inf")

            merged, rep = merge_journal_shards(shard_paths)
            records_equal = set(merged.records) == set(full.records) and all(
                record_payload(merged.records[k]) == record_payload(full.records[k])
                for k in full.records
            )
            merged_sieve = merge_sieves([db.build_sieve() for db in shard_dbs])
            # byte-identical filters => identical candidate sets for every
            # possible key => elimination decisions (and the Bloom 100%
            # true-negative guarantee) match the full rebuild exactly
            sieves_equal = merged_sieve.to_bytes() == full_sieve.to_bytes()
            merged_sel = KernelSelector(sieve=merged_sieve, db=merged)
            selection_equal = (
                selection_table(merged_sel, full.records) == full_table
            )
            verdict = (
                "identical"
                if records_equal and sieves_equal and selection_equal
                else "DIVERGED"
            )
            rows.append(
                csv_row(
                    f"federated_sweep_{n}w",
                    t_parallel * 1e6 / len(targets),
                    f"speedup={speedup:.2f}x; merge={verdict}; "
                    f"conflicts={rep.conflicts}",
                )
            )
            report["workers"][str(n)] = {
                "parallel_wall_s": round(t_parallel, 4),
                "shard_walls_s": [round(w, 4) for w in shard_walls],
                "speedup": round(speedup, 3),
                "records_equal": records_equal,
                "sieves_equal": sieves_equal,
                "selection_equal": selection_equal,
                "conflicts": rep.conflicts,
                "load_errors": rep.load_errors,
            }
            if verdict == "DIVERGED":  # pragma: no cover - would be a bug
                raise AssertionError(
                    f"{n}-worker federated merge diverged from full sweep: "
                    f"records={records_equal} sieves={sieves_equal} "
                    f"selection={selection_equal}"
                )

    # cold vs. federated warm start: replaying the merged journals into a
    # fresh worker turns the whole sweep into database hits
    with tempfile.TemporaryDirectory() as tmp:
        paths = [os.path.join(tmp, f"s{i}.jsonl") for i in range(2)]
        for i in range(2):
            tuner.tune(targets, shard=(i, 2), journal=paths[i])
        t0 = time.perf_counter()
        warm, _ = merge_journal_shards(paths)
        t_merge = time.perf_counter() - t0
        warm_sel = KernelSelector(sieve=warm.build_sieve(), db=warm)
        hits = sum(
            1
            for key in full.records
            if warm_sel.db.records.get(key) is not None
        )
        rows.append(
            csv_row(
                "federated_merge",
                t_merge * 1e6,
                f"{hits}/{len(full.records)} fingerprints warm after merge",
            )
        )
        report["merge_wall_s"] = round(t_merge, 6)
        report["warm_fingerprints"] = hits

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write the full report as JSON")
    args = ap.parse_args()
    for row in run(json_path=args.json):
        print(row)


if __name__ == "__main__":
    main()
