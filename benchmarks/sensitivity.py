"""Robustness of the paper's claims to the hardware model: sweep the
virtual-lane count C (the TPU analogue of the CU count) and the fix-up
serialisation cost, and report how the winner distribution moves.

This is the calibration due-diligence the CPU-only setting demands: if the
reproduced claim ("DP wins most sizes; SK wins a meaningful minority")
flipped under small machine-model perturbations, the reproduction would be
an artifact. It does not (see derived columns).
"""

from __future__ import annotations

import dataclasses
import time
from typing import List

from benchmarks.common import csv_row
from repro.configs.gemm_suite import suite
from repro.core import costmodel
from repro.core.tuner import Tuner, measure_model


def _winner_fracs(mach) -> dict:
    sizes = suite()[::6]  # 154 sizes: dense enough, fast enough
    db = Tuner(measure_fn=measure_model(mach), mach=mach).tune(sizes)
    total = len(db.records)
    sk = sum(1 for r in db.records.values() if r.policy != "dp")
    return {"dp": (total - sk) / total, "sk": sk / total}


def run() -> List[str]:
    rows = []
    for lanes in (4, 8, 16, 12):  # 12: non-power-of-two "CU count"
        t0 = time.perf_counter()
        mach = dataclasses.replace(costmodel.V5E, lanes=lanes)
        f = _winner_fracs(mach)
        dt_us = (time.perf_counter() - t0) * 1e6
        rows.append(
            csv_row(
                f"sensitivity.lanes{lanes}",
                dt_us,
                f"dp={f['dp']:.3f} sk={f['sk']:.3f}",
            )
        )
    for fixup_us in (0.4, 1.2, 3.6):
        t0 = time.perf_counter()
        mach = dataclasses.replace(costmodel.V5E, fixup_serial_s=fixup_us * 1e-6)
        f = _winner_fracs(mach)
        dt_us = (time.perf_counter() - t0) * 1e6
        rows.append(
            csv_row(
                f"sensitivity.fixup{fixup_us}us",
                dt_us,
                f"dp={f['dp']:.3f} sk={f['sk']:.3f}",
            )
        )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
