"""Benchmark harness: one module per paper table/figure (+ the roofline).
Prints ``name,us_per_call,derived`` CSV (see each module for the claim it
reproduces)."""

from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (
        arch_dispatch,
        bloom_elimination,
        bloom_query,
        dispatch_overhead,
        fig2_tolerance,
        fig3_gains,
        kernel_utilization,
        production_suite,
        roofline,
        sensitivity,
        serving_throughput,
    )

    print("name,us_per_call,derived")
    failures = 0
    for mod in (
        fig2_tolerance,
        fig3_gains,
        bloom_elimination,
        bloom_query,
        dispatch_overhead,
        kernel_utilization,
        arch_dispatch,
        production_suite,
        sensitivity,
        serving_throughput,
        roofline,
    ):
        try:
            for row in mod.run():
                print(row)
        except Exception:  # pragma: no cover
            failures += 1
            print(f"{mod.__name__},nan,ERROR")
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
