"""Benchmark harness: one module per paper table/figure (+ the roofline and
the online-adaptation convergence study). Prints ``name,us_per_call,derived``
CSV (see each module for the claim it reproduces); ``--json`` additionally
writes the rows as structured JSON for CI artifact upload.
"""

from __future__ import annotations

import argparse
import json
import sys
import traceback


def rows_to_json(rows):
    out = []
    for row in rows:
        name, us, derived = row.split(",", 2)
        try:
            us_val = float(us)
        except ValueError:
            us_val = None
        out.append({"name": name, "us_per_call": us_val, "derived": derived})
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="also write rows as JSON")
    args = ap.parse_args()

    from benchmarks import (
        arch_dispatch,
        bloom_elimination,
        bloom_query,
        dispatch_overhead,
        fig2_tolerance,
        fig3_gains,
        kernel_utilization,
        online_adaptation,
        production_suite,
        roofline,
        sensitivity,
        serving_throughput,
        traffic_replay,
    )

    print("name,us_per_call,derived")
    rows = []
    failures = 0
    for mod in (
        fig2_tolerance,
        fig3_gains,
        bloom_elimination,
        bloom_query,
        dispatch_overhead,
        kernel_utilization,
        arch_dispatch,
        production_suite,
        sensitivity,
        serving_throughput,
        traffic_replay,
        online_adaptation,
        roofline,
    ):
        try:
            for row in mod.run():
                rows.append(row)
                print(row)
        except Exception:  # pragma: no cover
            failures += 1
            row = f"{mod.__name__},nan,ERROR"
            rows.append(row)  # failures must show up in the JSON artifact too
            print(row)
            traceback.print_exc(file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows_to_json(rows), f, indent=2)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
