"""Roofline analysis from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Per (arch x shape x mesh) cell, derive the three roofline terms from the
compiled artifact's loop-aware HLO analysis:

  compute term    = HLO_FLOPs_per_device            / peak_FLOP/s
  memory term     = HLO_bytes_per_device            / HBM_bw
  collective term = collective_bytes_per_device     / link_bw

(per-device quantities: the SPMD program IS the per-chip program, so the
"/ chips" in the assignment's global formulation is already applied.)

Also reports MODEL_FLOPS = 6*N*D (train) / 2*N_active*D (inference) per
device, the usefulness ratio MODEL_FLOPS/HLO_FLOPs, the dominant term, and
the roofline fraction = compute_term / max(all terms) — i.e. what fraction
of the step the MXU could be busy if the dominant term were perfectly
overlapped with the rest.
"""

from __future__ import annotations

import glob
import json
import os
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from benchmarks.common import ART, csv_row
from repro.core.costmodel import V5E
from repro.models.config import SHAPES_BY_NAME

DRYRUN_DIR = os.path.join(ART, "dryrun")


@dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    variant: str
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_per_dev: float
    hlo_flops_per_dev: float
    useful_ratio: float
    roofline_fraction: float
    step_time_s: float
    mfu: float
    fits_hbm: bool
    note: str = ""


def model_flops(art: dict) -> float:
    """MODEL_FLOPS per device: 6*N*D (train), 2*N_active*D (inference)."""
    shape = SHAPES_BY_NAME[art["shape"]]
    n_active = art["params"]["active"]
    n_dev = art["n_devices"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / n_dev
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / n_dev
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens / n_dev


def analyze_artifact(art: dict, mach=V5E) -> Optional[RooflineRow]:
    if art.get("status") != "ok":
        return None
    lc = art["loop_cost"]
    compute = lc["flops"] / mach.peak_flops
    # TPU-estimate bytes: the HLO byte count inherits the CPU backend's f32
    # shadows; scale by the bf16-shadow correction measured on temp memory.
    raw_temp = art["memory"].get("temp_size") or 1
    est_temp = art["memory"].get("temp_size_tpu_estimate") or raw_temp
    byte_scale = max(0.4, min(1.0, est_temp / raw_temp))
    memory = lc["bytes"] * byte_scale / mach.hbm_bw
    coll = lc["collective_bytes"] / mach.ici_bw
    terms = {"compute": compute, "memory": memory, "collective": coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(art)
    step = max(terms.values())
    hbm_used = (art["memory"]["argument_size"] or 0) + est_temp
    return RooflineRow(
        arch=art["arch"],
        shape=art["shape"],
        mesh=art["mesh"],
        variant=art.get("variant", "baseline"),
        compute_s=compute,
        memory_s=memory,
        collective_s=coll,
        dominant=dominant,
        model_flops_per_dev=mf,
        hlo_flops_per_dev=lc["flops"],
        useful_ratio=mf / lc["flops"] if lc["flops"] else 0.0,
        roofline_fraction=compute / step if step else 0.0,
        step_time_s=step,
        mfu=(mf / mach.peak_flops) / step if step else 0.0,
        fits_hbm=hbm_used < 16 * 2**30,
    )


def load_rows(variant: Optional[str] = None) -> List[RooflineRow]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            art = json.load(f)
        if variant is not None and art.get("variant", "baseline") != variant:
            continue
        row = analyze_artifact(art)
        if row:
            rows.append(row)
    return rows


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = (
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| 6ND/HLO | roofline frac | MFU | fits 16G |\n"
        "|---|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.compute_s:.4f} | "
            f"{r.memory_s:.4f} | {r.collective_s:.4f} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.roofline_fraction:.2f} | {r.mfu:.2f} | "
            f"{'y' if r.fits_hbm else 'N'} |"
        )
    return hdr + "\n".join(lines)


def run() -> List[str]:
    t0 = time.perf_counter()
    rows = load_rows(variant="baseline")
    dt_us = (time.perf_counter() - t0) * 1e6
    out = []
    if not rows:
        return [csv_row("roofline.missing", dt_us, "run repro.launch.dryrun --all first")]
    single = [r for r in rows if r.mesh == "single_pod"]
    for r in single:
        out.append(
            csv_row(
                f"roofline.{r.arch}.{r.shape}",
                dt_us,
                f"comp={r.compute_s:.4f}s mem={r.memory_s:.4f}s coll={r.collective_s:.4f}s "
                f"dom={r.dominant} frac={r.roofline_fraction:.2f} mfu={r.mfu:.2f}",
            )
        )
    # summary stats
    import numpy as np

    fr = np.asarray([r.roofline_fraction for r in single])
    out.append(
        csv_row(
            "roofline.summary",
            dt_us,
            f"n={len(single)} mean_frac={fr.mean():.2f} worst={fr.min():.2f} "
            f"best={fr.max():.2f}",
        )
    )
    # persist the markdown table for EXPERIMENTS.md
    with open(os.path.join(ART, "roofline_baseline.md"), "w") as f:
        f.write(markdown_table(rows))
    return out


if __name__ == "__main__":
    for row in run():
        print(row)
