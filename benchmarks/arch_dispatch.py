"""Stream-K++ dispatch inside the framework: what the selector chose for the
REAL per-shard GEMMs of every assigned architecture (read from the dry-run
artifacts' dispatch logs), and the modeled gain vs. always-DP.

Hardware-adaptation finding this table documents: on the 8-lane TPU model,
the paper's power-of-two suite rarely quantizes (power-of-two tile counts
divide the lane count), but the production architectures' *non*-power-of-two
dims (gemma3 d=5376 -> 42 tiles; nemotron 48 heads; mistral d_ff=28672/16)
quantize constantly — Stream-K++ matters more inside the framework than on
the synthetic grid. The MI250X sees the inverse (104 CUs vs power-of-two
sizes), which is why the paper's suite shows the effect directly.
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List

from benchmarks.common import ART, csv_row
from repro.core import costmodel
from repro.core.policies import DP, policy_from_name
from repro.core.workpart import GemmShape

DRYRUN_DIR = os.path.join(ART, "dryrun")


def analyze() -> Dict[str, Dict]:
    out: Dict[str, Dict] = {}
    for kind in ("train_4k", "decode_32k"):
        for path in sorted(
            glob.glob(os.path.join(DRYRUN_DIR, f"*__{kind}__single_pod.json"))
        ):
            art = json.load(open(path))
            if art.get("status") != "ok":
                continue
            arch = art["arch"]
            rows = []
            for key, d in art.get("dispatch", {}).items():
                m, n, k = d["local_mnk"]
                if min(m, n, k) < 1:
                    continue
                shape = GemmShape(m, n, k)
                pol = policy_from_name(d["policy"])
                dp_tf = costmodel.best_config(shape, DP)[1]
                sel_tf = costmodel.best_config(shape, pol)[1]
                rows.append(
                    {
                        "tag": key.split(":")[0],
                        "mnk": (m, n, k),
                        "policy": d["policy"],
                        "gain_vs_dp": sel_tf / dp_tf - 1 if dp_tf else 0.0,
                    }
                )
            if rows:
                n_sk = sum(1 for r in rows if r["policy"] != "dp")
                best = max(rows, key=lambda r: r["gain_vs_dp"])
                out[f"{arch}.{kind}"] = {
                    "n_gemms": len(rows),
                    "n_streamk": n_sk,
                    "max_gain": best["gain_vs_dp"],
                    "max_gain_gemm": f"{best['tag']}{best['mnk']}",
                    "mean_gain": sum(r["gain_vs_dp"] for r in rows) / len(rows),
                }
    return out


def run() -> List[str]:
    t0 = time.perf_counter()
    res = analyze()
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = []
    for arch, s in sorted(res.items()):
        rows.append(
            csv_row(
                f"dispatch.{arch}",
                dt_us,
                f"gemms={s['n_gemms']} streamk={s['n_streamk']} "
                f"mean_gain={s['mean_gain']:+.1%} max_gain={s['max_gain']:+.1%} "
                f"at {s['max_gain_gemm']}",
            )
        )
    if not rows:
        rows.append(csv_row("dispatch.missing", dt_us, "run dryrun --all first"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
