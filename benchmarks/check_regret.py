"""CI gate on the perf-trajectory regret section.

Asserts that the newest ``BENCH_<n>.json`` in a directory (or an explicit
file) carries the ``regret`` section the analytical-first stack emits, and
that the calibrated model's median regret stays under a generous threshold
per dtype profile — the tripwire for calibration drift landing in a PR.

Usage:
  PYTHONPATH=src:. python benchmarks/check_regret.py bench-results
  PYTHONPATH=src:. python benchmarks/check_regret.py BENCH_8.json --max-median 2.0
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys


def latest_bench(path: str) -> str:
    """``path`` itself when it is a file, else the highest-index
    ``BENCH_<n>.json`` inside the directory."""
    if os.path.isfile(path):
        return path
    found = []
    for p in glob.glob(os.path.join(path, "BENCH_*.json")):
        m = re.fullmatch(r"BENCH_(\d+)\.json", os.path.basename(p))
        if m:
            found.append((int(m.group(1)), p))
    if not found:
        raise SystemExit(f"no BENCH_<n>.json found under {path!r}")
    return max(found)[1]


def check(path: str, max_median: float) -> int:
    """Validate one snapshot; returns the number of failures (printed)."""
    with open(path) as f:
        snap = json.load(f)
    failures = []
    regret = snap.get("regret")
    if not isinstance(regret, dict):
        failures.append("snapshot has no 'regret' section")
    elif "error" in regret:
        failures.append(f"regret section errored: {regret['error']}")
    elif not regret.get("profiles"):
        failures.append("regret section has no per-profile entries")
    else:
        for dt_name, entry in sorted(regret["profiles"].items()):
            med = entry.get("median_regret")
            if med is None:
                failures.append(f"{dt_name}: missing median_regret")
            elif med > max_median:
                failures.append(
                    f"{dt_name}: median regret {med} exceeds {max_median}x "
                    "— the calibrated model's picks drifted from measured "
                    "reality"
                )
            else:
                print(
                    f"{path}: {dt_name} median regret {med} "
                    f"(<= {max_median}x), top-k hit rate "
                    f"{entry.get('topk_hit_rate')}"
                )
    for msg in failures:
        print(f"FAIL {path}: {msg}", file=sys.stderr)
    return len(failures)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("path", help="BENCH_<n>.json file or a directory of them")
    ap.add_argument(
        "--max-median",
        type=float,
        default=2.0,
        help="fail when any profile's median regret exceeds this factor",
    )
    args = ap.parse_args()
    return 1 if check(latest_bench(args.path), args.max_median) else 0


if __name__ == "__main__":
    raise SystemExit(main())
