"""Gossip convergence: arch-class isolation + streaming cross-worker exchange.

Simulates the heterogeneous always-on fleet the arch-class and gossip
subsystems exist for, and *asserts* the three contract properties (an
``AssertionError`` fails CI — these are acceptance criteria, not metrics):

  1. **Cross-class isolation** — a worker of arch class B federating class
     A's journal never sees A's records as direct database hits: every
     dispatch of an A-tuned fingerprint resolves as an ``"xarch"``
     re-ranked warm seed, B's own-class record partition stays empty, and
     one local adaptation round supersedes every seed with a real
     B-stamped record (``"tuned"`` from then on).
  2. **Same-class byte-identity** — two single-class journal shards merged
     through the arch-aware path reproduce the single-worker full sweep
     *exactly*: payload-equal records, byte-identical sieve filters,
     identical selection table — i.e. the pre-arch (PR 4) single-class
     federation behavior is preserved bit-for-bit.
  3. **Gossip convergence** — two same-class workers that tune disjoint
     workloads and poll each other's journal shards via
     :class:`~repro.core.gossip.GossipExchange` reach **zero cross-worker
     misses with no restart**: after one exchange round each worker
     dispatches the sibling's entire workload as direct ``"tuned"`` hits,
     and a quiet follow-up round installs nothing.

Reported rows: per-dispatch xarch seeding cost, same-class merge wall-time,
and the exchange round wall-time with the convergence verdict.
"""

from __future__ import annotations

import argparse
import json
import os
import tempfile
import time
from dataclasses import replace
from typing import Dict, List, Optional

from benchmarks.common import csv_row
from repro.configs.gemm_suite import suite
from repro.core.adaptive import AdaptiveConfig, AdaptiveTuner
from repro.core.arch import append_arch, detect_arch
from repro.core.federate import (
    federate_selector,
    merge_journal_shards,
    record_payload,
    selection_table,
)
from repro.core.gossip import GossipExchange
from repro.core.selector import KernelSelector, SelectorState
from repro.core.tuner import Tuner
from repro.utils.logging import get_logger

log = get_logger("bench.gossip")

N_SUITE = 16  # targets sampled from the paper suite for the identity check

#: disjoint per-worker workloads for the convergence section (worker 0
#: tunes SIZES_A, worker 1 tunes SIZES_B; convergence means each ends up
#: dispatching the *other's* set as direct database hits)
SIZES_A = [
    (64, 512, 256),
    (96, 768, 384),
    (128, 1024, 512),
    (32, 640, 320),
    (48, 896, 448),
    (80, 1152, 576),
]
SIZES_B = [
    (72, 520, 264),
    (104, 776, 392),
    (136, 1032, 520),
    (40, 648, 328),
    (56, 904, 456),
    (88, 1160, 584),
]


def _two_profiles():
    """Two arch profiles one roofline-ratio step apart: same lane count and
    VMEM, different clock/byte coordinate — the minimal heterogeneous
    fleet (e.g. two device generations)."""
    base = detect_arch()
    return (
        replace(base, flops_per_byte=275),
        replace(base, flops_per_byte=225),
    )


def _suite_slice(n: int = N_SUITE) -> List:
    full = suite()
    step = max(1, len(full) // n)
    return list(full[::step][:n])


def _cross_class_isolation(report: Dict[str, object]) -> List[str]:
    """Property 1: records never cross arch classes as direct DB hits."""
    prof_a, prof_b = _two_profiles()
    assert prof_a.cls != prof_b.cls
    with tempfile.TemporaryDirectory() as tmp:
        shard = os.path.join(tmp, "class_a.jsonl")
        append_arch(shard, prof_a)
        Tuner(arch=prof_a.cls).tune(SIZES_A, journal=shard)

        sel = KernelSelector(state=SelectorState(arch=prof_b.cls))
        state = federate_selector(sel, journals=[shard])
        assert state.merged >= len(SIZES_A)  # report rides on the state

        t0 = time.perf_counter()
        sources = [sel.select(*s).source for s in SIZES_A]
        t_dispatch = time.perf_counter() - t0
        if any(src != "xarch" for src in sources):
            raise AssertionError(
                f"cross-class records leaked as direct hits: sources={sources}"
            )
        if sel.db.records:
            raise AssertionError(
                f"class-A records landed in class-B's own partition: "
                f"{sorted(sel.db.records)}"
            )
        assert sel.stats.xarch_seeds == len(SIZES_A)
        assert set(sel.db.xarch) == {prof_a.cls}

        # xarch seeds stay misses for adaptation: one local round measures
        # every seeded fingerprint and supersedes it with a B-class record
        adaptive = AdaptiveTuner(sel, config=AdaptiveConfig(hot_threshold=1))
        for s in SIZES_A:
            sel.select(*s)  # memoised, but the miss hook still observes
        tuned = adaptive.drain()
        assert tuned == len(SIZES_A)
        after = [sel.select(*s).source for s in SIZES_A]
        if any(src != "tuned" for src in after):
            raise AssertionError(
                f"local adaptation failed to supersede xarch seeds: {after}"
            )
        assert all(r.arch == prof_b.cls for r in sel.db.records.values())

    report["cross_class"] = {
        "classes": [prof_a.cls, prof_b.cls],
        "xarch_seeds": sel.stats.xarch_seeds,
        "direct_cross_hits": 0,
        "superseded_by_local": tuned,
    }
    return [
        csv_row(
            "gossip_xarch_isolation",
            t_dispatch * 1e6 / len(SIZES_A),
            f"{len(SIZES_A)} xarch seeds; 0 direct cross-class hits; "
            f"{tuned} superseded locally",
        )
    ]


def _same_class_identity(report: Dict[str, object]) -> List[str]:
    """Property 2: arch-aware same-class merges match PR 4 byte-for-byte."""
    targets = _suite_slice()
    tuner = Tuner()
    with tempfile.TemporaryDirectory() as tmp:
        full = tuner.tune(targets, journal=os.path.join(tmp, "full.jsonl"))
        paths = [os.path.join(tmp, f"s{i}.jsonl") for i in range(2)]
        for i in range(2):
            tuner.tune(targets, shard=(i, 2), journal=paths[i])
        t0 = time.perf_counter()
        merged, rep = merge_journal_shards(paths)
        t_merge = time.perf_counter() - t0

    records_equal = set(merged.records) == set(full.records) and all(
        record_payload(merged.records[k]) == record_payload(full.records[k])
        for k in full.records
    )
    sieves_equal = (
        merged.build_sieve().to_bytes() == full.build_sieve().to_bytes()
    )
    selection_equal = selection_table(
        KernelSelector(state=SelectorState(db=merged, sieve=merged.build_sieve())),
        full.records,
    ) == selection_table(
        KernelSelector(state=SelectorState(db=full, sieve=full.build_sieve())),
        full.records,
    )
    if not (records_equal and sieves_equal and selection_equal):
        raise AssertionError(
            f"same-class merge diverged from single-class behavior: "
            f"records={records_equal} sieves={sieves_equal} "
            f"selection={selection_equal}"
        )
    report["same_class"] = {
        "targets": len(targets),
        "records_equal": records_equal,
        "sieves_equal": sieves_equal,
        "selection_equal": selection_equal,
        "conflicts": rep.conflicts,
    }
    return [
        csv_row(
            "gossip_same_class_merge",
            t_merge * 1e6,
            f"byte-identical to full sweep; conflicts={rep.conflicts}",
        )
    ]


def _gossip_convergence(report: Dict[str, object]) -> List[str]:
    """Property 3: a gossiping 2-worker fleet reaches 0 cross-worker misses
    with no restart anywhere."""
    work = (SIZES_A, SIZES_B)
    with tempfile.TemporaryDirectory() as tmp:
        shards = [os.path.join(tmp, f"w{i}.jsonl") for i in range(2)]
        sels, adaptives, gossips = [], [], []
        for i in range(2):
            sel = KernelSelector()
            adaptives.append(
                AdaptiveTuner(
                    sel,
                    config=AdaptiveConfig(hot_threshold=1),
                    journal=shards[i],
                )
            )
            gossips.append(GossipExchange(sel, [shards[1 - i]]))
            sels.append(sel)

        # each worker tunes only its own (disjoint) workload, journaling
        for i in range(2):
            for s in work[i]:
                sels[i].select(*s)
            adaptives[i].drain()

        # one exchange round per worker: poll the sibling's shard, fold in
        t0 = time.perf_counter()
        applied = [g.exchange() for g in gossips]
        t_exchange = time.perf_counter() - t0
        assert applied == [len(SIZES_B), len(SIZES_A)], applied

        # convergence: the sibling's entire workload now dispatches as
        # direct database hits — zero cross-worker misses, no restart
        cross_misses = 0
        for i in range(2):
            before = adaptives[i].stats.misses
            sources = [sels[i].select(*s).source for s in work[1 - i]]
            cross_misses += adaptives[i].stats.misses - before
            if any(src != "tuned" for src in sources):
                raise AssertionError(
                    f"worker {i} still misses sibling work after gossip: "
                    f"{sources}"
                )
        if cross_misses != 0:
            raise AssertionError(
                f"{cross_misses} cross-worker misses survived the exchange"
            )

        # a quiet round is free: no new bytes -> nothing staged, no swap
        generations = [s.sieve_generation for s in sels]
        assert [g.exchange() for g in gossips] == [0, 0]
        assert [s.sieve_generation for s in sels] == generations
        swaps = [g.stats.swaps for g in gossips]
        assert swaps == [1, 1], swaps

    report["convergence"] = {
        "workers": 2,
        "per_worker_records": [len(SIZES_A), len(SIZES_B)],
        "entries_exchanged": sum(applied),
        "rounds_to_converge": 1,
        "cross_worker_misses": cross_misses,
        "exchange_wall_s": round(t_exchange, 6),
    }
    return [
        csv_row(
            "gossip_convergence",
            t_exchange * 1e6,
            f"rounds=1; cross_worker_misses=0; "
            f"entries={sum(applied)}; swaps={swaps}",
        )
    ]


def run(json_path: Optional[str] = None) -> List[str]:
    rows: List[str] = []
    report: Dict[str, object] = {}
    rows += _cross_class_isolation(report)
    rows += _same_class_identity(report)
    rows += _gossip_convergence(report)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, help="write the full report as JSON")
    args = ap.parse_args()
    for row in run(json_path=args.json):
        print(row)


if __name__ == "__main__":
    main()
