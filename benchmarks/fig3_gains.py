"""Figure 3 reproduction: distribution of the winning configuration's gain
over the runner-up, split by winner kind (Stream-K-based vs data-parallel).

Paper claims: SK winners show a right-skewed distribution (mean >> median)
with cases exceeding ~40% gain over the runner-up."""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import csv_row, tuned_db


def analyze() -> Dict[str, Dict[str, float]]:
    db = tuned_db()
    gains = {"sk": [], "dp": []}
    for r in db.records.values():
        g = r.gain_over_runner_up
        gains["sk" if r.policy != "dp" else "dp"].append(g)
    out = {}
    for kind, xs in gains.items():
        a = np.asarray(xs) if xs else np.zeros(1)
        out[kind] = {
            "n": len(xs),
            "mean": float(a.mean()),
            "median": float(np.median(a)),
            "p90": float(np.percentile(a, 90)),
            "max": float(a.max()),
        }
    return out


def run() -> List[str]:
    t0 = time.perf_counter()
    res = analyze()
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = []
    for kind in ("sk", "dp"):
        s = res[kind]
        rows.append(
            csv_row(
                f"fig3.{kind}_gain",
                dt_us,
                f"n={s['n']} mean={s['mean']:.3f} median={s['median']:.3f} "
                f"p90={s['p90']:.3f} max={s['max']:.3f}",
            )
        )
    skew = res["sk"]["mean"] - res["sk"]["median"]
    rows.append(csv_row("fig3.sk_right_skew", dt_us, f"{skew:.4f}"))
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
