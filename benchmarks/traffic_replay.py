"""Traffic replay: drive the paged serving engine with synthetic arrival
processes and report an SLO summary (p50/p99 latency, time-to-first-token,
tokens/s, page occupancy, admission counters) as JSON.

Two arrival patterns over a shared step-clock (one engine step == one clock
tick, so every steps-denominated metric is deterministic for a fixed seed):

* ``poisson`` — exponential inter-arrival gaps at ``rate`` requests/step;
* ``bursty``  — back-to-back bursts of 4-12 requests separated by long idle
  gaps, the admission-control stress case (queue backpressure + watermark).

Prompt/output lengths are drawn from the ``configs/`` model zoo: each
request picks an architecture uniformly from :func:`repro.configs.list_archs`
and samples lengths from a profile keyed on that config's family — VLM
prompts are patch-heavy (``n_patches``) with short outputs, encoder-decoder
transcription is long-in/short-out (``enc_frames``), dense chat is
short-in/long-out, MoE and SSM/hybrid sit between. Prompt lengths round up
to page multiples so the prefill jit-compile set stays bounded.

The headline comparison is *equal KV memory*: a dense engine with
``n_slots x max_seq`` KV rows vs a paged engine whose pool has exactly the
same row count (``max_pages x page_size``). Because paged residency is
bounded by actual sequence lengths rather than the worst case, the paged
engine sustains a multiple of the dense resident concurrency — the
``concurrency_ratio`` row (target >= 2x) is the subsystem's claim under
test, alongside the SLO report CI uploads as an artifact.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import math
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from benchmarks.common import csv_row

# -- workload synthesis ------------------------------------------------------

#: per-family (prompt_lo, prompt_hi, out_lo, out_hi) as fractions of the
#: usable sequence budget; see module docstring for the zoo mapping.
_PROFILES: Dict[str, Tuple[float, float, float, float]] = {
    "vlm": (0.45, 0.70, 0.05, 0.15),
    "encdec": (0.50, 0.65, 0.05, 0.15),
    "moe": (0.15, 0.40, 0.20, 0.40),
    "ssm": (0.30, 0.60, 0.10, 0.30),
    "hybrid": (0.30, 0.60, 0.10, 0.30),
    "dense": (0.05, 0.25, 0.15, 0.50),
}


def synth_workload(
    n: int, *, seed: int, max_seq: int, page_size: int, vocab: int
) -> List[Tuple[str, np.ndarray, int]]:
    """``n`` requests of ``(arch, prompt_tokens, max_new_tokens)`` with
    lengths drawn from the zoo profile of a uniformly-sampled arch."""
    from repro.configs import get_config, list_archs

    rng = np.random.default_rng(seed)
    budget = max_seq - page_size  # headroom so prompt + output always fits
    out = []
    for _ in range(n):
        name = list_archs()[int(rng.integers(len(list_archs())))]
        cfg = get_config(name)
        plo, phi, olo, ohi = _PROFILES[cfg.family]
        p = int(rng.uniform(plo, phi) * budget)
        p = max(page_size, math.ceil(p / page_size) * page_size)
        o = max(4, int(rng.uniform(olo, ohi) * budget))
        o = min(o, max_seq - p)
        prompt = rng.integers(1, vocab, size=p).astype(np.int32)
        out.append((name, prompt, o))
    return out


def synth_arrivals(n: int, *, seed: int, pattern: str, rate: float) -> List[int]:
    """Arrival step index per request (non-decreasing)."""
    rng = np.random.default_rng(seed + 1)
    if pattern == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n)
        return [int(t) for t in np.floor(np.cumsum(gaps))]
    if pattern == "bursty":
        steps: List[int] = []
        t = 0.0
        while len(steps) < n:
            burst = int(rng.integers(4, 13))
            steps.extend(int(t) for _ in range(min(burst, n - len(steps))))
            t += rng.exponential(burst / rate) + 1.0
        return steps
    raise ValueError(f"unknown arrival pattern {pattern!r}")


# -- replay loop -------------------------------------------------------------


def replay(engine, workload, arrivals, *, max_steps: int = 200_000):
    """Submit requests as their arrival step comes due, stepping the engine
    once per clock tick. :class:`~repro.serve.AdmissionError` backpressure
    re-offers the same request next tick (arrival order is preserved).
    Returns ``(request_objects, steps, wall_seconds, backpressure_retries)``.
    """
    from repro.serve import AdmissionError

    arrivals = list(arrivals)
    reqs = []
    i = 0
    step = 0
    retries = 0
    t0 = time.perf_counter()
    while i < len(workload) or engine.outstanding():
        while i < len(workload) and arrivals[i] <= step:
            _, prompt, max_new = workload[i]
            try:
                engine.submit(prompt, max_new_tokens=max_new)
            except AdmissionError:
                retries += 1
                break
            reqs.append(engine._queue[-1])
            i += 1
        engine.step()
        step += 1
        if step >= max_steps:
            raise RuntimeError(f"replay exceeded {max_steps} steps")
    return reqs, step, time.perf_counter() - t0, retries


def _pct(vals: List[float], q: float) -> float:
    return float(np.percentile(np.asarray(vals, np.float64), q)) if vals else -1.0


def slo_report(reqs, steps, wall, engine, *, pattern, seed, retries):
    """SLO summary for one replay. Step-denominated percentiles are
    deterministic for a fixed seed; wall-denominated ones are informational
    on a shared CI box."""
    done = [r for r in reqs if r.done]
    ttft_steps = [r.first_token_step - r.submit_step for r in done]
    lat_steps = [r.done_step - r.submit_step for r in done]
    ttft_wall = [r.first_token_wall - r.submit_wall for r in done]
    lat_wall = [r.done_wall - r.submit_wall for r in done]
    ntok = sum(len(r.out_tokens) for r in done)
    report = {
        "pattern": pattern,
        "seed": seed,
        "n_requests": len(reqs),
        "completed": len(done),
        "truncated_requests": sum(r.truncated for r in done),
        "tokens": ntok,
        "tokens_per_s": ntok / wall if wall > 0 else 0.0,
        "steps": steps,
        "wall_s": wall,
        "backpressure_retries": retries,
        "ttft_steps": {"p50": _pct(ttft_steps, 50), "p99": _pct(ttft_steps, 99)},
        "latency_steps": {"p50": _pct(lat_steps, 50), "p99": _pct(lat_steps, 99)},
        "ttft_s": {"p50": _pct(ttft_wall, 50), "p99": _pct(ttft_wall, 99)},
        "latency_s": {"p50": _pct(lat_wall, 50), "p99": _pct(lat_wall, 99)},
    }
    report.update(engine.metrics())
    return report


# -- benchmark entry ---------------------------------------------------------

N_SLOTS = 4  # dense baseline concurrency
MAX_SEQ = 128
PAGE_SIZE = 16
MAX_PAGES = N_SLOTS * MAX_SEQ // PAGE_SIZE  # equal KV rows to the dense cache
MAX_ACTIVE = 16
RATE = 1.0  # mean arrivals per engine step


def _build():
    import jax

    from repro.configs import get_reduced
    from repro.dist.sharding import materialize_tree
    from repro.models import build_model

    cfg = dataclasses.replace(
        get_reduced("granite-8b"),
        dtype="float32",
        n_layers=4,
        d_model=256,
        n_heads=8,
        n_kv_heads=4,
        d_head=32,
        d_ff=512,
        vocab_size=2048,
    )
    model = build_model(cfg)
    params = materialize_tree(model.param_specs(), jax.random.PRNGKey(0))
    return cfg, model, params


def run(
    json_path: Optional[str] = None,
    n_requests: int = 40,  # suite default; the CI SLO artifact runs 100
    seed: int = 0,
    prefill_chunk: int = 0,
) -> List[str]:
    from repro.serve import (
        PagedServeConfig,
        PagedServeEngine,
        ServeConfig,
        ServeEngine,
    )

    cfg, model, params = _build()
    workload = synth_workload(
        n_requests,
        seed=seed,
        max_seq=MAX_SEQ,
        page_size=PAGE_SIZE,
        vocab=cfg.vocab_size,
    )

    def paged_engine():
        return PagedServeEngine(
            model,
            params,
            PagedServeConfig(
                page_size=PAGE_SIZE,
                max_pages=MAX_PAGES,
                max_active=MAX_ACTIVE,
                max_seq=MAX_SEQ,
                max_queue=8,
                prefill_chunk=prefill_chunk,
                eos=-1,
                seed=seed,
            ),
        )

    rows = []
    reports = {}

    # dense baseline at the same arrival process: n_slots * max_seq KV rows
    arr = synth_arrivals(n_requests, seed=seed, pattern="poisson", rate=RATE)
    dense = ServeEngine(
        model, params, ServeConfig(n_slots=N_SLOTS, max_seq=MAX_SEQ, eos=-1)
    )
    dreqs, dsteps, dwall, _ = replay(dense, workload, arr)
    dtok = sum(len(r.out_tokens) for r in dreqs if r.done)
    reports["dense_baseline"] = {
        "n_slots": N_SLOTS,
        "kv_rows": N_SLOTS * MAX_SEQ,
        "completed": sum(r.done for r in dreqs),
        "tokens": dtok,
        "tokens_per_s": dtok / dwall,
        "steps": dsteps,
        "wall_s": dwall,
    }
    rows.append(
        csv_row(
            "replay.dense_poisson",
            dwall / max(1, dtok) * 1e6,
            f"{dtok / dwall:.1f} tok/s, {sum(r.done for r in dreqs)}"
            f"/{n_requests} reqs, {N_SLOTS} resident max",
        )
    )

    for pattern in ("poisson", "bursty"):
        arr = synth_arrivals(n_requests, seed=seed, pattern=pattern, rate=RATE)
        eng = paged_engine()
        reqs, steps, wall, retries = replay(eng, workload, arr)
        rep = slo_report(
            reqs, steps, wall, eng, pattern=pattern, seed=seed, retries=retries
        )
        reports[pattern] = rep
        rows.append(
            csv_row(
                f"replay.paged_{pattern}",
                wall / max(1, rep["tokens"]) * 1e6,
                f"{rep['tokens_per_s']:.1f} tok/s, p50/p99 latency "
                f"{rep['latency_steps']['p50']:.0f}/"
                f"{rep['latency_steps']['p99']:.0f} steps, ttft p50 "
                f"{rep['ttft_steps']['p50']:.0f}, peak {rep['peak_resident']} "
                f"resident, {rep['rejected']} rejected",
            )
        )

    # the subsystem's claim: resident concurrency at equal KV memory
    peak = max(reports[p]["peak_resident"] for p in ("poisson", "bursty"))
    ratio = peak / N_SLOTS
    reports["equal_kv_memory"] = {
        "kv_rows": MAX_PAGES * PAGE_SIZE,
        "dense_resident": N_SLOTS,
        "paged_peak_resident": peak,
        "concurrency_ratio": ratio,
        "target_ratio": 2.0,
    }
    rows.append(
        csv_row(
            "replay.concurrency_ratio",
            0.0,
            f"{ratio:.1f}x dense residency ({peak} vs {N_SLOTS} seqs) at "
            f"{MAX_PAGES * PAGE_SIZE} KV rows each (target >= 2.0x)",
        )
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(reports, f, indent=2)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="write the SLO report here")
    ap.add_argument(
        "--chunk",
        type=int,
        default=0,
        help="prefill chunk size for the paged engine (0 = whole-prompt)",
    )
    args = ap.parse_args()
    for row in run(
        args.json,
        n_requests=args.requests,
        seed=args.seed,
        prefill_chunk=args.chunk,
    ):
        print(row)


if __name__ == "__main__":
    main()
