"""Figure 2 reproduction: fraction of problem sizes where a Stream-K-based
schedule is the winner, and where one is within a {5,10,15,20}% slow-down
tolerance of the data-parallel baseline.

Paper claims: DP optimal for ~87% of sizes; SK-based schedules within
tolerance for ~60% (5%) -> ~97.6% (20%)."""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

from benchmarks.common import csv_row, tuned_db


def analyze() -> Dict[str, float]:
    db = tuned_db()
    total = len(db.records)
    sk_wins = sum(1 for r in db.records.values() if r.policy != "dp")
    out = {
        "n_sizes": total,
        "dp_win_frac": (total - sk_wins) / total,
        "sk_win_frac": sk_wins / total,
    }
    for tol in (0.0, 0.05, 0.10, 0.15, 0.20):
        n = 0
        for size, per in db.per_policy.items():
            dp = per["dp"]
            best_sk = max(v for k, v in per.items() if k != "dp")
            if best_sk >= dp * (1 - tol):
                n += 1
        out[f"sk_within_{int(tol * 100)}pct"] = n / total
    # per-policy win histogram
    hist: Dict[str, int] = {}
    for r in db.records.values():
        hist[r.policy] = hist.get(r.policy, 0) + 1
    out["win_histogram"] = hist
    return out


def run() -> List[str]:
    t0 = time.perf_counter()
    res = analyze()
    dt_us = (time.perf_counter() - t0) * 1e6
    rows = [
        csv_row("fig2.dp_win_frac", dt_us, f"{res['dp_win_frac']:.3f}"),
        csv_row("fig2.sk_win_frac", dt_us, f"{res['sk_win_frac']:.3f}"),
    ]
    for tol in (0, 5, 10, 15, 20):
        key = f"sk_within_{tol}pct"
        rows.append(csv_row(f"fig2.{key}", dt_us, f"{res[key]:.3f}"))
    rows.append(
        csv_row(
            "fig2.win_histogram",
            dt_us,
            "; ".join(f"{k}:{v}" for k, v in sorted(res["win_histogram"].items())),
        )
    )
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
