"""§4.2 reproduction: Bloom-filter policy-evaluation elimination rate and
true-negative validation.

Paper claims: up to ~95.8% of the additional policy evaluations eliminated;
100% true-negative rate. We measure (a) on the tuned suite itself, and
(b) on unseen sizes (where ALL policies should usually be eliminated)."""

from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import csv_row, tuned_db
from repro.configs.gemm_suite import full_grid, suite
from repro.core.policies import ALL_POLICIES


def analyze() -> Dict[str, float]:
    db = tuned_db()
    sieve = db.build_sieve()
    tn = sieve.validate_true_negative_rate(db.winners())

    # elimination over the tuned sizes (the paper's tuning-time saving:
    # ckProfiler would otherwise evaluate every policy for every size)
    for size in db.records:
        sieve.candidates(size)
    on_suite = sieve.stats.elimination_rate

    # unseen sizes: the filters should prune ~everything (false positives
    # only); the unseen set is the complement of the suite in the 2^k grid
    sieve2 = db.build_sieve()
    seen = set(db.records)
    unseen = [s for s in full_grid() if s not in seen]
    for size in unseen:
        sieve2.candidates(size)
    on_unseen = sieve2.stats.elimination_rate

    # blended: a tuning pass over the full power-of-two grid (suite sizes
    # carry exactly one live filter — 7/8 pruned; unseen sizes prune all 8
    # modulo false positives) — the paper's "up to ~95.8%" regime
    blended = (
        sieve.stats.pruned_evals + sieve2.stats.pruned_evals
    ) / (
        sieve.stats.pruned_evals
        + sieve.stats.candidate_evals
        + sieve2.stats.pruned_evals
        + sieve2.stats.candidate_evals
    )
    return {
        "true_negative_rate": tn,
        "elimination_on_suite": on_suite,
        "elimination_on_unseen": on_unseen,
        "elimination_blended_grid": blended,
        "n_suite": len(seen),
        "n_unseen": len(unseen),
    }


def run() -> List[str]:
    t0 = time.perf_counter()
    res = analyze()
    dt_us = (time.perf_counter() - t0) * 1e6
    return [
        csv_row("bloom.true_negative_rate", dt_us, f"{res['true_negative_rate']:.4f}"),
        csv_row("bloom.elimination_on_suite", dt_us, f"{res['elimination_on_suite']:.4f}"),
        csv_row("bloom.elimination_on_unseen", dt_us, f"{res['elimination_on_unseen']:.4f}"),
        csv_row("bloom.elimination_blended_grid", dt_us, f"{res['elimination_blended_grid']:.4f}"),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
