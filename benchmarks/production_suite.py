"""The production GEMM suite: every distinct per-shard (M, N, K) the 10
architectures actually dispatch (harvested from the dry-run artifacts'
dispatch logs across all shapes/meshes/variants), tuned like the paper's
synthetic suite.

This closes the loop the paper leaves open: its 923 sizes are a synthetic
power-of-two grid ("generalized to maintain confidentiality"); a deployment
cares about the sizes its own models emit. On the TPU machine model the
synthetic grid rarely quantizes (power-of-two tile counts divide the lane
count) while the production shapes — skinny decode GEMMs, non-power-of-two
model dims like gemma3's 5376 or nemotron's 6144 — quantize constantly, so
the winner histogram here is where the HYBRID policies and ALL_SK earn
their place.
"""

from __future__ import annotations

import glob
import json
import os
import time
from typing import Dict, List, Set, Tuple

from benchmarks.common import ART, csv_row
from repro.core.tuner import Tuner

DRYRUN_DIR = os.path.join(ART, "dryrun")


def harvest_sizes() -> List[Tuple[int, int, int]]:
    sizes: Set[Tuple[int, int, int]] = set()
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        art = json.load(open(path))
        for d in art.get("dispatch", {}).values():
            m, n, k = (int(x) for x in d["local_mnk"])
            if min(m, n, k) >= 1:
                sizes.add((m, n, k))
    return sorted(sizes)


def run() -> List[str]:
    t0 = time.perf_counter()
    sizes = harvest_sizes()
    if not sizes:
        return [csv_row("prod_suite.missing", 0.0, "run dryrun --all first")]
    db = Tuner().tune(sizes)
    hist: Dict[str, int] = {}
    for r in db.records.values():
        hist[r.policy] = hist.get(r.policy, 0) + 1
    total = len(sizes)
    sk = sum(v for kk, v in hist.items() if kk != "dp")
    # gains where SK wins
    gains = [
        r.gain_over_runner_up for r in db.records.values() if r.policy != "dp"
    ]
    import numpy as np

    g = np.asarray(gains) if gains else np.zeros(1)
    dt_us = (time.perf_counter() - t0) * 1e6
    return [
        csv_row("prod_suite.n_sizes", dt_us, str(total)),
        csv_row("prod_suite.sk_win_frac", dt_us, f"{sk / total:.3f}"),
        csv_row(
            "prod_suite.win_histogram",
            dt_us,
            "; ".join(f"{kk}:{v}" for kk, v in sorted(hist.items())),
        ),
        csv_row(
            "prod_suite.sk_gains",
            dt_us,
            f"mean={g.mean():.3f} median={np.median(g):.3f} max={g.max():.3f}",
        ),
    ]


if __name__ == "__main__":
    for row in run():
        print(row)
